"""AOT build path: lower L2 (model + predictor, calling L1 Pallas kernels)
to HLO *text* and export weights/datasets for the rust runtime.

HLO text — not `.serialize()` — is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
published `xla` 0.1.6 crate) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs under --out (default ../artifacts):
  manifest.json                 — executable/weight/dataset index (rust reads this)
  model.prefill.b{1,2,4}.hlo.txt
  model.decode.b{1,2,4}.hlo.txt
  predictor.b8.hlo.txt
  weights/model/*.bin           — raw little-endian tensors
  weights/predictor_trained/*.bin
  weights/predictor_init/*.bin  — "pre-trained BGE" row of Table 2
  corpus.json                   — serving corpus (test-split prompts)
  predictor_test.json           — held-out step dataset for Table 2 / Fig 2b
  embed_groups.json             — Fig 1 sentence groups
  predictor_metrics.json        — build-time eval + training history
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as D
from . import model as M
from . import predictor as P
from .configs import (BATCH_SIZES, CORPUS, GAMMA_ALPHA, GAMMA_BETA, MODEL,
                      PREDICTOR, PREDICTOR_BATCH, SERVED_MODELS,
                      TRAINING_MODELS, WINDOW_SIZE)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _dtype_tag(arr: np.ndarray) -> str:
    return {np.dtype(np.float32): "f32", np.dtype(np.int32): "i32"}[arr.dtype]


def export_weights(out_dir: str, rel: str, named_arrays) -> list:
    """Write raw little-endian blobs; return manifest entries (ordered)."""
    d = os.path.join(out_dir, rel)
    os.makedirs(d, exist_ok=True)
    entries = []
    for i, (name, arr) in enumerate(named_arrays):
        arr = np.asarray(arr)
        fname = f"{i:03d}_{name.replace('.', '_')}.bin"
        arr.astype(arr.dtype.newbyteorder("<")).tofile(os.path.join(d, fname))
        entries.append({
            "name": name,
            "file": f"{rel}/{fname}",
            "shape": list(arr.shape),
            "dtype": _dtype_tag(arr),
        })
    return entries


def _spec(shape, dtype) -> dict:
    return {"shape": list(shape), "dtype": dtype}


def lower_model(out_dir: str, params, manifest: dict, quiet=False):
    cfg = MODEL
    weight_specs = [jax.ShapeDtypeStruct(M.param_shapes(cfg)[n], jnp.float32)
                    for n in M.param_order(cfg)]
    for b in BATCH_SIZES:
        t0 = time.time()
        # ---- prefill ----
        pre = jax.jit(M.make_prefill_fn(cfg))
        args = weight_specs + [
            jax.ShapeDtypeStruct((b, cfg.prompt_max), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ]
        text = to_hlo_text(pre.lower(*args))
        name = f"model.prefill.b{b}"
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        manifest["executables"][name] = {
            "hlo": f"{name}.hlo.txt",
            "weights": "model",
            "inputs": [
                {"name": "tokens", **_spec((b, cfg.prompt_max), "i32")},
                {"name": "lengths", **_spec((b,), "i32")},
            ],
            "outputs": [
                {"name": "kv", **_spec(M.kv_shape(b, cfg), "f32")},
                {"name": "first_token", **_spec((b,), "i32")},
            ],
        }
        # ---- decode window ----
        dec = jax.jit(M.make_decode_fn(cfg, WINDOW_SIZE))
        args = weight_specs + [
            jax.ShapeDtypeStruct(M.kv_shape(b, cfg), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ]
        text = to_hlo_text(dec.lower(*args))
        name = f"model.decode.b{b}"
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        manifest["executables"][name] = {
            "hlo": f"{name}.hlo.txt",
            "weights": "model",
            "inputs": [
                {"name": "kv", **_spec(M.kv_shape(b, cfg), "f32")},
                {"name": "lengths", **_spec((b,), "i32")},
                {"name": "last_token", **_spec((b,), "i32")},
                {"name": "active", **_spec((b,), "i32")},
            ],
            "outputs": [
                {"name": "kv", **_spec(M.kv_shape(b, cfg), "f32")},
                {"name": "tokens", **_spec((b, WINDOW_SIZE), "i32")},
                {"name": "lengths", **_spec((b,), "i32")},
            ],
        }
        if not quiet:
            print(f"[aot] lowered model b{b} in {time.time()-t0:.1f}s", flush=True)


def lower_predictor(out_dir: str, manifest: dict, quiet=False):
    cfg = PREDICTOR
    b = PREDICTOR_BATCH
    weight_specs = [jax.ShapeDtypeStruct(P.param_shapes(cfg)[n], jnp.float32)
                    for n in P.param_order(cfg)]
    fn = jax.jit(P.make_predict_fn(cfg))
    args = weight_specs + [
        jax.ShapeDtypeStruct((b, cfg.prompt_max), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.float32),
    ]
    text = to_hlo_text(fn.lower(*args))
    name = f"predictor.b{b}"
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(text)
    manifest["executables"][name] = {
        "hlo": f"{name}.hlo.txt",
        "weights": "predictor_trained",
        "alt_weights": ["predictor_init"],
        "inputs": [
            {"name": "tokens", **_spec((b, cfg.prompt_max), "i32")},
            {"name": "prompt_len", **_spec((b,), "i32")},
            {"name": "gen_count", **_spec((b,), "f32")},
        ],
        "outputs": [
            {"name": "pred_remaining", **_spec((b,), "f32")},
            {"name": "pooled", **_spec((b, cfg.d_model), "f32")},
        ],
    }
    if not quiet:
        print(f"[aot] lowered predictor b{b}", flush=True)


def export_corpus(out_dir: str, corpus_entries) -> None:
    obj = {
        "window_size": WINDOW_SIZE,
        "gamma_alpha": GAMMA_ALPHA,
        "gamma_beta": GAMMA_BETA,
        "prompt_max": MODEL.prompt_max,
        "entries": [
            {"tokens": e.tokens.tolist(), "topic": int(e.topic),
             "total_len": int(e.total_len)}
            for e in corpus_entries
        ],
    }
    with open(os.path.join(out_dir, "corpus.json"), "w") as f:
        json.dump(obj, f)


def export_predictor_test(out_dir: str, ds: D.StepDataset, n_max=2000) -> None:
    n = min(n_max, len(ds))
    idx = np.arange(n)
    obj = {
        # combined inputs (prompt + SEP + suffix) — lets rust cross-check
        # its own input construction against python's
        "tokens": ds.tokens[idx].tolist(),
        "prompt_len": ds.prompt_len[idx].tolist(),
        # raw parts, the form the serving path sees
        "raw_prompt": [ds.raw_prompt[i].tolist() for i in idx],
        "suffix": [ds.suffix[i].tolist() for i in idx],
        "gen_count": ds.gen_count[idx].tolist(),
        "step": ds.step[idx].tolist(),
        "target": ds.target[idx].tolist(),
    }
    with open(os.path.join(out_dir, "predictor_test.json"), "w") as f:
        json.dump(obj, f)


def export_embed_groups(out_dir: str) -> None:
    groups = D.embedding_groups()
    obj = {k: v.tolist() for k, v in groups.items()}
    with open(os.path.join(out_dir, "embed_groups.json"), "w") as f:
        json.dump(obj, f)


def build(out_dir: str, *, train_budget_s: float = 240.0,
          fast: bool = False, quiet: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    t_start = time.time()

    # ---------------- corpus + datasets ----------------
    corpus = D.generate_corpus(CORPUS)
    train_e, val_e, test_e = corpus.split()
    if fast:
        train_e, val_e, test_e = train_e[:300], val_e[:100], test_e[:100]
    train_ds = D.step_dataset(train_e)
    val_ds = D.step_dataset(val_e)
    test_ds = D.step_dataset(test_e)
    if not quiet:
        print(f"[aot] corpus: {len(corpus.entries)} prompts; step examples "
              f"train={len(train_ds)} val={len(val_ds)} test={len(test_ds)}",
              flush=True)

    # ---------------- predictor training ----------------
    init_p = P.init_params()
    metrics_init = P.evaluate(init_p, test_ds)
    budget = 20.0 if fast else train_budget_s
    trained_p, history = P.train(init_p, train_ds, val_ds,
                                 time_budget_s=budget, verbose=not quiet)
    metrics_trained = P.evaluate(trained_p, test_ds)
    if not quiet:
        print(f"[aot] predictor init:    {metrics_init}", flush=True)
        print(f"[aot] predictor trained: {metrics_trained}", flush=True)

    # ---------------- manifest skeleton ----------------
    manifest: dict = {
        "window_size": WINDOW_SIZE,
        "batch_sizes": list(BATCH_SIZES),
        "predictor_batch": PREDICTOR_BATCH,
        "model_config": {
            "vocab": MODEL.vocab, "d_model": MODEL.d_model,
            "n_layers": MODEL.n_layers, "n_heads": MODEL.n_heads,
            "d_ff": MODEL.d_ff, "max_seq": MODEL.max_seq,
            "prompt_max": MODEL.prompt_max, "n_params": MODEL.n_params,
        },
        "predictor_config": {
            "d_model": PREDICTOR.d_model, "prompt_max": PREDICTOR.prompt_max,
            "gen_scale": P.GEN_SCALE, "plen_scale": P.PLEN_SCALE,
            "target_scale": P.TARGET_SCALE,
        },
        "gamma_alpha": GAMMA_ALPHA,
        "gamma_beta": GAMMA_BETA,
        "served_models": [
            {"name": m.name, "abbrev": m.abbrev, "params_b": m.params_b,
             "avg_latency_ms": m.avg_latency_ms,
             "kv_bytes_per_token": m.kv_bytes_per_token,
             "preempt_batch": m.preempt_batch,
             "mem_limit_frac": m.mem_limit_frac}
            for m in SERVED_MODELS
        ],
        "training_models": [
            {"name": n, "size_b": s, "producer": p}
            for (n, s, p) in TRAINING_MODELS
        ],
        "executables": {},
        "weights": {},
    }

    # ---------------- weights ----------------
    model_p = M.init_params()
    manifest["weights"]["model"] = export_weights(
        out_dir, "weights/model",
        [(n, model_p[n]) for n in M.param_order()])
    manifest["weights"]["predictor_trained"] = export_weights(
        out_dir, "weights/predictor_trained",
        [(n, trained_p[n]) for n in P.param_order()])
    manifest["weights"]["predictor_init"] = export_weights(
        out_dir, "weights/predictor_init",
        [(n, init_p[n]) for n in P.param_order()])

    # ---------------- HLO lowering ----------------
    lower_model(out_dir, model_p, manifest, quiet=quiet)
    lower_predictor(out_dir, manifest, quiet=quiet)

    # ---------------- datasets ----------------
    export_corpus(out_dir, test_e)
    export_predictor_test(out_dir, test_ds)
    export_embed_groups(out_dir)
    from .golden import build_golden
    build_golden(out_dir)

    metrics = {
        "predictor_init": metrics_init,
        "predictor_trained": metrics_trained,
        "history": history,
        "build_seconds": time.time() - t_start,
    }
    with open(os.path.join(out_dir, "predictor_metrics.json"), "w") as f:
        json.dump(metrics, f, indent=2)
    manifest["predictor_metrics"] = metrics
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if not quiet:
        print(f"[aot] done in {time.time()-t_start:.0f}s -> {out_dir}",
              flush=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--train-budget", type=float, default=240.0,
                    help="wall-clock budget for predictor training (s)")
    ap.add_argument("--fast", action="store_true",
                    help="tiny datasets + short training (CI smoke)")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    build(args.out, train_budget_s=args.train_budget, fast=args.fast,
          quiet=args.quiet)


if __name__ == "__main__":
    main()
