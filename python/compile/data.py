"""Synthetic LMSYS-Chat-1M substitute + predictor datasets.

The real paper samples prompts from LMSYS-Chat-1M and collects response
lengths from 13 LLMs served by vLLM (Table 7).  Offline we cannot ship that
corpus, so we build a generator that preserves the two properties ELIS's
evaluation depends on:

1. response lengths are heavy-tailed and span ~5..480 tokens, so FCFS
   suffers head-of-line blocking that SRTF-style scheduling can fix;
2. the length is *predictable from the prompt* (topic/verbosity signal plus
   noise), so a learned predictor attains a meaningful R^2 — and the
   *remaining* length becomes easier to predict as generation progresses
   (the paper's Fig 2b).

Each topic owns a band of the token space and a latent verbosity drawn
geometrically from [base_min, base_max].  A prompt is a sequence of tokens
from its topic band (plus a few common "function" tokens); its true output
length is `clip(round(base * mod(prompt_len) * lognormal(sigma)), out_min,
out_max)`.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

from .configs import CORPUS, PREDICTOR, WINDOW_SIZE, CorpusConfig


# Token ids 0..RESERVED-1 are reserved: 0 = PAD, 1 = BOS/common, 3 = SEP.
RESERVED = 16
SEP_ID = 3

# ---------------------------------------------------------------------------
# Response-content signal (paper §3.3's mechanism).  Real LLM responses
# "look" verbose or terse — the paper's iterative predictor reads the
# partial output and refines its estimate.  Synthetic response streams
# reproduce that: tokens are drawn from a band keyed to the response's
# length bucket, switching to a "closing" band in the final ~25 tokens.
# The rust SimEngine implements the IDENTICAL formula
# (engine::sim_response_token) so inference-time streams match training.
# ---------------------------------------------------------------------------
N_BUCKETS = 16
BAND_WIDTH = 16
# bands occupy the top (N_BUCKETS + 1) * BAND_WIDTH ids of the vocab
RESPONSE_BAND_IDS = (N_BUCKETS + 1) * BAND_WIDTH
CLOSING_TOKENS = 25

# predictor input layout: prompt head + SEP + generated-suffix tail
PROMPT_KEEP = 47
SUFFIX_MAX = 16


def length_bucket(total: int) -> int:
    return int(np.clip(np.log2(max(total, 5) / 5.0), 0, N_BUCKETS - 1))


def response_token(i: int, total: int, topic: int, vocab: int) -> int:
    """Deterministic synthetic response token (mirrored in rust)."""
    if total - i <= CLOSING_TOKENS:
        band_start = vocab - BAND_WIDTH  # closing band
    else:
        band_start = vocab - BAND_WIDTH * (2 + length_bucket(total))
    return band_start + (i * 7 + topic * 3) % BAND_WIDTH


def response_stream(total: int, topic: int, vocab: int) -> np.ndarray:
    return np.array([response_token(i, total, topic, vocab)
                     for i in range(total)], dtype=np.int32)


def predictor_input(prompt: np.ndarray, suffix: np.ndarray,
                    prompt_max: int) -> Tuple[np.ndarray, int]:
    """Combined predictor input: prompt[:47] + SEP + last-16 generated
    tokens, zero-padded to prompt_max.  Mirrored exactly by
    rust predictor::build_input."""
    head = prompt[:PROMPT_KEEP]
    tail = suffix[-SUFFIX_MAX:] if len(suffix) else suffix
    seq = np.concatenate([head, np.array([SEP_ID], np.int32), tail])
    seq = seq[:prompt_max].astype(np.int32)
    out = np.zeros(prompt_max, dtype=np.int32)
    out[: len(seq)] = seq
    return out, int(len(seq))


@dataclass
class CorpusEntry:
    tokens: np.ndarray      # (prompt_len,) int32, unpadded
    topic: int
    total_len: int          # true response length in tokens


@dataclass
class Corpus:
    entries: List[CorpusEntry]
    cfg: CorpusConfig

    def split(self) -> Tuple[List[CorpusEntry], List[CorpusEntry], List[CorpusEntry]]:
        """Deterministic 6:2:2 split (paper §4.2)."""
        n = len(self.entries)
        a = int(n * self.cfg.split[0])
        b = a + int(n * self.cfg.split[1])
        return self.entries[:a], self.entries[a:b], self.entries[b:]


def topic_bases(cfg: CorpusConfig = CORPUS) -> np.ndarray:
    """Latent verbosity per topic, geometric ladder over [base_min, base_max]."""
    t = np.arange(cfg.n_topics) / max(cfg.n_topics - 1, 1)
    return cfg.base_min * (cfg.base_max / cfg.base_min) ** t


def _topic_band(topic: int, vocab: int, n_topics: int) -> Tuple[int, int]:
    # prompt-topic bands live below the response bands
    usable = vocab - RESERVED - RESPONSE_BAND_IDS
    width = usable // n_topics
    lo = RESERVED + topic * width
    return lo, lo + width


def length_modulation(prompt_len: int) -> float:
    """Deterministic prompt-length effect on response length."""
    return 1.0 + 0.3 * np.sin(prompt_len / 20.0)


def true_length(topic: int, prompt_len: int, noise: float,
                cfg: CorpusConfig = CORPUS) -> int:
    base = topic_bases(cfg)[topic]
    raw = base * length_modulation(prompt_len) * np.exp(noise)
    return int(np.clip(np.round(raw), cfg.out_min, cfg.out_max))


def generate_corpus(cfg: CorpusConfig = CORPUS) -> Corpus:
    rng = np.random.default_rng(cfg.seed)
    entries: List[CorpusEntry] = []
    bases = topic_bases(cfg)
    for _ in range(cfg.n_prompts):
        topic = int(rng.integers(0, cfg.n_topics))
        plen = int(rng.integers(cfg.prompt_min, cfg.prompt_max + 1))
        lo, hi = _topic_band(topic, PREDICTOR.vocab, cfg.n_topics)
        toks = rng.integers(lo, hi, size=plen).astype(np.int32)
        # sprinkle common tokens so topics share some vocabulary (makes the
        # predictor's job non-trivial but solvable)
        n_common = max(1, plen // 8)
        pos = rng.choice(plen, size=n_common, replace=False)
        toks[pos] = rng.integers(1, RESERVED, size=n_common)
        noise = float(rng.normal(0.0, cfg.noise_sigma))
        raw = bases[topic] * length_modulation(plen) * np.exp(noise)
        total = int(np.clip(np.round(raw), cfg.out_min, cfg.out_max))
        entries.append(CorpusEntry(tokens=toks, topic=topic, total_len=total))
    return Corpus(entries=entries, cfg=cfg)


def pad_tokens(tokens: np.ndarray, plen_max: int) -> np.ndarray:
    out = np.zeros(plen_max, dtype=np.int32)
    out[: min(len(tokens), plen_max)] = tokens[:plen_max]
    return out


# ---------------------------------------------------------------------------
# Predictor step-dataset: (prompt, generated_so_far) -> remaining tokens.
# One example per 50-token scheduling iteration of each prompt (§3.3).
# ---------------------------------------------------------------------------

@dataclass
class StepDataset:
    tokens: np.ndarray      # (N, prompt_max) int32 — predictor_input()
    prompt_len: np.ndarray  # (N,) int32 — combined valid length
    gen_count: np.ndarray   # (N,) int32 — tokens already generated (k * 50)
    step: np.ndarray        # (N,) int32 — iteration index k
    target: np.ndarray      # (N,) float32 — remaining tokens
    total: np.ndarray       # (N,) float32 — full response length
    raw_prompt: List[np.ndarray]  # unpadded prompts (for export)
    suffix: List[np.ndarray]      # generated suffix fed to the predictor

    def __len__(self) -> int:
        return len(self.target)

    def subset(self, idx: np.ndarray) -> "StepDataset":
        return StepDataset(
            self.tokens[idx], self.prompt_len[idx], self.gen_count[idx],
            self.step[idx], self.target[idx], self.total[idx],
            [self.raw_prompt[i] for i in idx],
            [self.suffix[i] for i in idx])


def step_dataset(entries: List[CorpusEntry],
                 prompt_max: int = PREDICTOR.prompt_max,
                 window: int = WINDOW_SIZE,
                 max_steps_per_prompt: int = 10) -> StepDataset:
    toks, plens, gens, steps, targets, totals = [], [], [], [], [], []
    raw_prompts, suffixes = [], []
    for e in entries:
        stream = response_stream(e.total_len, e.topic, PREDICTOR.vocab)
        n_steps = min(int(np.ceil(e.total_len / window)), max_steps_per_prompt)
        for k in range(n_steps):
            gen = k * window
            suffix = stream[:gen][-SUFFIX_MAX:]
            combined, clen = predictor_input(e.tokens, suffix, prompt_max)
            toks.append(combined)
            plens.append(clen)
            gens.append(gen)
            steps.append(k)
            targets.append(float(e.total_len - gen))
            totals.append(float(e.total_len))
            raw_prompts.append(e.tokens)
            suffixes.append(suffix)
    return StepDataset(
        tokens=np.stack(toks).astype(np.int32),
        prompt_len=np.array(plens, dtype=np.int32),
        gen_count=np.array(gens, dtype=np.int32),
        step=np.array(steps, dtype=np.int32),
        target=np.array(targets, dtype=np.float32),
        total=np.array(totals, dtype=np.float32),
        raw_prompt=raw_prompts,
        suffix=suffixes,
    )


# ---------------------------------------------------------------------------
# Fig 1 substitute: two sentence groups, one tight topic vs scattered topics.
# ---------------------------------------------------------------------------

def embedding_groups(n_per_group: int = 100,
                     seed: int = 31337) -> Dict[str, np.ndarray]:
    """Group A: 100 prompts from a single topic ("weather"); group B: 100
    prompts spread over all other topics.  The encoder should embed A in a
    tight cluster and B scattered (paper Fig 1)."""
    rng = np.random.default_rng(seed)
    cfg = CORPUS
    pm = PREDICTOR.prompt_max

    def mk(topic: int) -> np.ndarray:
        plen = int(rng.integers(cfg.prompt_min, cfg.prompt_max + 1))
        lo, hi = _topic_band(topic, PREDICTOR.vocab, cfg.n_topics)
        t = rng.integers(lo, hi, size=plen).astype(np.int32)
        return pad_tokens(t, pm)

    group_a = np.stack([mk(0) for _ in range(n_per_group)])
    group_b = np.stack([mk(int(rng.integers(1, cfg.n_topics)))
                        for _ in range(n_per_group)])
    return {"similar": group_a.astype(np.int32),
            "dissimilar": group_b.astype(np.int32)}
