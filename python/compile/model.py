"""L2 — TinyGPT, the served decoder model (the paper's vLLM workload).

Two jitted entry points are AOT-lowered per batch size and executed from the
rust runtime:

* `prefill(params, tokens, lengths)` — processes the prompt, fills the KV
  cache (the paper's prefill phase / TTFT) and emits the first generated
  token.
* `decode_window(params, kv, lengths, last_token, active)` — runs exactly
  WINDOW_SIZE (=50) decode steps (the paper's *scheduling iteration*,
  §4.1), updating the KV cache in place and returning the window's tokens.

Both call the L1 Pallas attention kernels so the kernels lower into the same
HLO the rust coordinator loads.  Weights are *arguments* (not constants) so
one HLO text serves any checkpoint; `aot.py` exports the weight blobs in the
flattening order given by `param_order()`.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .configs import MODEL, WINDOW_SIZE, ModelConfig
from .kernels.attention import decode_attention, prefill_attention

Params = Dict[str, jnp.ndarray]


def param_order(cfg: ModelConfig = MODEL) -> List[str]:
    """Canonical flattening order shared with the rust weight loader."""
    names = ["tok_emb", "pos_emb"]
    for i in range(cfg.n_layers):
        names += [
            f"l{i}.ln1_g", f"l{i}.ln1_b",
            f"l{i}.wq", f"l{i}.wk", f"l{i}.wv", f"l{i}.wo",
            f"l{i}.ln2_g", f"l{i}.ln2_b",
            f"l{i}.w1", f"l{i}.b1", f"l{i}.w2", f"l{i}.b2",
        ]
    names += ["lnf_g", "lnf_b"]
    return names


def param_shapes(cfg: ModelConfig = MODEL) -> Dict[str, Tuple[int, ...]]:
    d, f = cfg.d_model, cfg.d_ff
    shapes: Dict[str, Tuple[int, ...]] = {
        "tok_emb": (cfg.vocab, d),
        "pos_emb": (cfg.max_seq, d),
        "lnf_g": (d,), "lnf_b": (d,),
    }
    for i in range(cfg.n_layers):
        shapes.update({
            f"l{i}.ln1_g": (d,), f"l{i}.ln1_b": (d,),
            f"l{i}.wq": (d, d), f"l{i}.wk": (d, d),
            f"l{i}.wv": (d, d), f"l{i}.wo": (d, d),
            f"l{i}.ln2_g": (d,), f"l{i}.ln2_b": (d,),
            f"l{i}.w1": (d, f), f"l{i}.b1": (f,),
            f"l{i}.w2": (f, d), f"l{i}.b2": (d,),
        })
    return shapes


def init_params(cfg: ModelConfig = MODEL) -> Params:
    """Deterministic random init (the served model is a synthetic workload;
    its text is not meaningful, its compute/memory profile is)."""
    rng = np.random.default_rng(cfg.seed)
    params: Params = {}
    for name, shape in param_shapes(cfg).items():
        if name.endswith(("_g",)):
            arr = np.ones(shape, np.float32)
        elif name.endswith(("_b", ".b1", ".b2")):
            arr = np.zeros(shape, np.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else 1
            arr = rng.normal(0.0, fan_in ** -0.5, size=shape).astype(np.float32)
        params[name] = jnp.asarray(arr)
    return params


def _layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _split_heads(x, n_heads):
    b, t, d = x.shape
    return x.reshape(b, t, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, t, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * dh)


def kv_shape(batch: int, cfg: ModelConfig = MODEL) -> Tuple[int, ...]:
    """KV cache layout: (L, 2, B, H, S, Dh)."""
    return (cfg.n_layers, 2, batch, cfg.n_heads, cfg.max_seq, cfg.d_head)


def prefill(params: Params, tokens: jnp.ndarray, lengths: jnp.ndarray,
            cfg: ModelConfig = MODEL):
    """Process the prompt; returns (kv, first_token, last_logits).

    tokens:  (B, prompt_max) int32 padded with 0
    lengths: (B,) int32 true prompt lengths (>= 1)
    """
    b, t = tokens.shape
    x = params["tok_emb"][tokens] + params["pos_emb"][:t][None]
    kv_layers = []
    for i in range(cfg.n_layers):
        h = _layer_norm(x, params[f"l{i}.ln1_g"], params[f"l{i}.ln1_b"])
        q = _split_heads(h @ params[f"l{i}.wq"], cfg.n_heads)
        k = _split_heads(h @ params[f"l{i}.wk"], cfg.n_heads)
        v = _split_heads(h @ params[f"l{i}.wv"], cfg.n_heads)
        attn = prefill_attention(q, k, v, lengths)          # L1 Pallas kernel
        x = x + _merge_heads(attn) @ params[f"l{i}.wo"]
        h2 = _layer_norm(x, params[f"l{i}.ln2_g"], params[f"l{i}.ln2_b"])
        x = x + jax.nn.relu(h2 @ params[f"l{i}.w1"] + params[f"l{i}.b1"]) \
            @ params[f"l{i}.w2"] + params[f"l{i}.b2"]
        # stash prompt K/V padded out to max_seq
        pad = cfg.max_seq - t
        k_pad = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kv_layers.append(jnp.stack([k_pad, v_pad], axis=0))
    kv = jnp.stack(kv_layers, axis=0)                       # (L,2,B,H,S,Dh)
    x = _layer_norm(x, params["lnf_g"], params["lnf_b"])
    logits = x @ params["tok_emb"].T                        # tied head
    # logits at the last *valid* prompt position
    idx = jnp.clip(lengths - 1, 0, t - 1)
    last = jnp.take_along_axis(
        logits, idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    first_token = jnp.argmax(last, axis=-1).astype(jnp.int32)
    return kv, first_token, last


def _decode_step(params: Params, kv, lengths, token, cfg: ModelConfig):
    """One decode step for the whole batch; returns (kv, next_token)."""
    b = token.shape[0]
    x = params["tok_emb"][token] + params["pos_emb"][lengths]   # (B, D)
    x = x[:, None, :]                                           # (B, 1, D)
    new_kv = kv
    for i in range(cfg.n_layers):
        h = _layer_norm(x, params[f"l{i}.ln1_g"], params[f"l{i}.ln1_b"])
        q = (h @ params[f"l{i}.wq"])[:, 0]                       # (B, D)
        k = (h @ params[f"l{i}.wk"])[:, 0]
        v = (h @ params[f"l{i}.wv"])[:, 0]
        qh = q.reshape(b, cfg.n_heads, cfg.d_head)
        kh = k.reshape(b, cfg.n_heads, cfg.d_head)
        vh = v.reshape(b, cfg.n_heads, cfg.d_head)

        # write k/v into the cache at position `lengths[b]` per sequence
        def write(cache_b, vec_b, pos_b):
            # cache_b: (H, S, Dh); vec_b: (H, Dh)
            return jax.lax.dynamic_update_slice(
                cache_b, vec_b[:, None, :], (0, pos_b, 0))

        k_cache = jax.vmap(write)(new_kv[i, 0], kh, lengths)
        v_cache = jax.vmap(write)(new_kv[i, 1], vh, lengths)
        new_kv = new_kv.at[i, 0].set(k_cache).at[i, 1].set(v_cache)

        attn = decode_attention(qh, k_cache, v_cache, lengths + 1)  # Pallas
        attn_m = attn.reshape(b, 1, cfg.d_model)
        x = x + attn_m @ params[f"l{i}.wo"]
        h2 = _layer_norm(x, params[f"l{i}.ln2_g"], params[f"l{i}.ln2_b"])
        x = x + jax.nn.relu(h2 @ params[f"l{i}.w1"] + params[f"l{i}.b1"]) \
            @ params[f"l{i}.w2"] + params[f"l{i}.b2"]
    x = _layer_norm(x, params["lnf_g"], params["lnf_b"])
    logits = (x @ params["tok_emb"].T)[:, 0]
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return new_kv, nxt


def decode_window(params: Params, kv, lengths, last_token, active,
                  cfg: ModelConfig = MODEL, window: int = WINDOW_SIZE):
    """Run one 50-token scheduling iteration.

    kv:         (L, 2, B, H, S, Dh) float32
    lengths:    (B,) int32 — total tokens (prompt + generated) per sequence
    last_token: (B,) int32 — the most recent token of each sequence
    active:     (B,) int32 — 1 for live slots, 0 for padding slots

    Returns (kv, tokens (B, window) int32, new_lengths (B,) int32).
    Inactive slots still flow through the compute (batch shape is static)
    but their cache position is pinned so they are side-effect free.
    """
    def body(carry, _):
        kv, lens, tok = carry
        # pin inactive slots at position 0 writes? — write to a scratch slot
        # (max_seq - 1) so real data is never clobbered.
        safe_lens = jnp.where(active > 0, lens,
                              jnp.int32(cfg.max_seq - 1))
        new_kv, nxt = _decode_step(params, kv, safe_lens, tok, cfg)
        new_lens = jnp.where(active > 0, lens + 1, lens)
        nxt = jnp.where(active > 0, nxt, tok)
        return (new_kv, new_lens, nxt), nxt

    (kv, new_lengths, _), toks = jax.lax.scan(
        body, (kv, lengths, last_token), None, length=window)
    return kv, toks.T.astype(jnp.int32), new_lengths


# ---------------------------------------------------------------------------
# Flattened-signature wrappers for AOT lowering (rust passes weights first,
# then the dynamic inputs, in param_order()).
# ---------------------------------------------------------------------------

def flatten_params(params: Params, cfg: ModelConfig = MODEL) -> List[jnp.ndarray]:
    return [params[n] for n in param_order(cfg)]


def unflatten_params(flat: List[jnp.ndarray], cfg: ModelConfig = MODEL) -> Params:
    return dict(zip(param_order(cfg), flat))


def make_prefill_fn(cfg: ModelConfig = MODEL):
    n = len(param_order(cfg))

    def fn(*args):
        params = unflatten_params(list(args[:n]), cfg)
        tokens, lengths = args[n], args[n + 1]
        kv, first, _ = prefill(params, tokens, lengths, cfg)
        return kv, first

    return fn


def make_decode_fn(cfg: ModelConfig = MODEL, window: int = WINDOW_SIZE):
    n = len(param_order(cfg))

    def fn(*args):
        params = unflatten_params(list(args[:n]), cfg)
        kv, lengths, last_token, active = args[n:n + 4]
        return decode_window(params, kv, lengths, last_token, active,
                             cfg, window)

    return fn
