"""Golden cross-layer check data.

Runs the L2 jax model (with the L1 Pallas kernels) on a fixed prompt and
records the greedy token stream.  The rust runtime must reproduce these
exact tokens through the AOT HLO + exported weights — proving the whole
python->HLO->PJRT->rust path is semantics-preserving.

Standalone: `python -m compile.golden --out ../artifacts` (also invoked by
aot.build).
"""

import argparse
import json
import os

import jax.numpy as jnp
import numpy as np

from . import model as M
from .configs import MODEL, WINDOW_SIZE


GOLDEN_PROMPT = [1, 100, 200, 300, 777, 901, 1500, 33]


def build_golden(out_dir: str) -> dict:
    params = M.init_params()
    b = 1
    toks = np.zeros((b, MODEL.prompt_max), np.int32)
    toks[0, : len(GOLDEN_PROMPT)] = GOLDEN_PROMPT
    lens = np.array([len(GOLDEN_PROMPT)], np.int32)
    kv, first, _ = M.prefill(params, jnp.asarray(toks), jnp.asarray(lens))
    active = jnp.ones(b, jnp.int32)
    kv2, w1, nl = M.decode_window(params, kv, jnp.asarray(lens), first, active)
    _, w2, _ = M.decode_window(params, kv2, nl, w1[:, -1], active)
    stream = [int(first[0])] + [int(t) for t in np.asarray(w1[0])] + \
             [int(t) for t in np.asarray(w2[0])]
    obj = {
        "prompt": GOLDEN_PROMPT,
        "prompt_len": len(GOLDEN_PROMPT),
        "window_size": WINDOW_SIZE,
        # first token + two full windows
        "tokens": stream,
    }
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(obj, f)
    return obj


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    g = build_golden(args.out)
    print(f"golden: {len(g['tokens'])} tokens, first 5 = {g['tokens'][:5]}")


if __name__ == "__main__":
    main()
