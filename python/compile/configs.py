"""Shared hyper-parameters for the ELIS build path.

Everything the three layers must agree on lives here: the served TinyGPT
model (the vLLM substitute), the response-length predictor (the BGE
substitute), the synthetic LMSYS-like corpus, and the 50-token scheduling
window the paper's ISRTF scheduler operates on.
"""

from dataclasses import dataclass, field
from typing import List, Tuple


# The paper's scheduling iteration: one window = 50 decode tokens (§4.1).
WINDOW_SIZE = 50

# Batch sizes the paper evaluates (Fig 6 uses {1, 2, 4}; Table 5 uses 4).
# One AOT executable is compiled per batch size.
BATCH_SIZES = (1, 2, 4)

# Predictor executes on fixed batches of 8 (padded); the frontend batches
# priority refreshes across jobs.
PREDICTOR_BATCH = 8


@dataclass(frozen=True)
class ModelConfig:
    """TinyGPT — the served decoder LLM (substitute for OPT/LLaMA on vLLM)."""

    vocab: int = 2048
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 1024
    # prompt slots + generated-token slots; must hold prompt_max + out_max.
    max_seq: int = 576
    prompt_max: int = 64
    seed: int = 1234

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def n_params(self) -> int:
        per_layer = 4 * self.d_model * self.d_model + 2 * self.d_model * self.d_ff
        return (
            self.vocab * self.d_model
            + self.max_seq * self.d_model
            + self.n_layers * per_layer
        )


@dataclass(frozen=True)
class PredictorConfig:
    """BGE-substitute encoder + 8 FC layers (paper §4.2).

    The paper freezes a 110M BGE and trains eight 1024-wide FC layers.  We
    shrink the encoder (2 layers, d=96) and the head (256-wide) so build-time
    training fits a single CPU core, keeping the same structure: token
    embedding -> bidirectional encoder -> mean pooling -> 8 FC layers ->
    scalar remaining-length regression.
    """

    vocab: int = 2048
    d_model: int = 96
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 192
    prompt_max: int = 64
    n_fc: int = 8
    fc_hidden: int = 256
    seed: int = 4321
    # extra scalar features appended to the pooled embedding:
    # [generated_so_far / 100, prompt_len / 64]
    n_extra_feats: int = 2

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


@dataclass(frozen=True)
class CorpusConfig:
    """Synthetic LMSYS-Chat-1M substitute.

    Prompts are drawn from topic bands of the token space; each topic has a
    latent verbosity that drives the true response length, with log-normal
    noise, giving the heavy-tailed length mix that causes head-of-line
    blocking (and a learnable signal for the predictor).
    """

    n_prompts: int = 10000
    n_topics: int = 16
    prompt_min: int = 8
    prompt_max: int = 64
    out_min: int = 5
    out_max: int = 480
    # log-normal multiplicative noise on the topic base length
    noise_sigma: float = 0.35
    seed: int = 777
    # train/val/test split, paper's 6:2:2
    split: Tuple[float, float, float] = (0.6, 0.2, 0.2)
    # topic base lengths span [base_min, base_max] geometrically
    base_min: float = 20.0
    base_max: float = 300.0


# Five serving-model profiles mirroring paper Table 4 (avg latency on A100).
# `latency_scale` is each model's measured avg latency relative to real time;
# the rust sim engine turns these into per-window service times.
@dataclass(frozen=True)
class ServedModelProfile:
    name: str
    abbrev: str
    params_b: float           # parameter count, billions
    avg_latency_ms: float     # paper Table 4
    kv_bytes_per_token: int   # per-token KV footprint (fp16, all layers)
    preempt_batch: int        # paper Table 6: min batch size that preempts
    mem_limit_frac: float     # paper Table 6: vLLM memory limit used


SERVED_MODELS: List[ServedModelProfile] = [
    ServedModelProfile("OPT-6.7B", "opt6.7", 6.7, 1315.5, 2 * 2 * 32 * 32 * 128, 30, 0.40),
    ServedModelProfile("OPT-13B", "opt13", 13.0, 2643.2, 2 * 2 * 40 * 40 * 128, 60, 0.40),
    ServedModelProfile("LlaMA2-7B", "lam7", 7.0, 6522.2, 2 * 2 * 32 * 32 * 128, 40, 0.30),
    ServedModelProfile("LlaMA2-13B", "lam13", 13.0, 8610.2, 2 * 2 * 40 * 40 * 128, 120, 0.90),
    ServedModelProfile("Vicuna-13B", "vic", 13.0, 2964.9, 2 * 2 * 40 * 40 * 128, 90, 0.40),
]


# Paper Table 7: the 13 models whose vLLM outputs trained the predictor.
TRAINING_MODELS: List[Tuple[str, float, str]] = [
    ("LlaMA-7B", 7, "Meta"),
    ("LlaMA-13B", 13, "Meta"),
    ("LlaMA2-7B", 7, "Huggyllama"),
    ("LlaMA2-13B", 13, "Huggyllama"),
    ("Vicuna-7B", 7, "LMSYS"),
    ("Vicuna-13B", 13, "LMSYS"),
    ("OPT-1B", 1.3, "Facebook"),
    ("OPT-3B", 2.7, "Facebook"),
    ("OPT-7B", 6.7, "Facebook"),
    ("OPT-13B", 13, "Facebook"),
    ("GPT-NeoX", 20, "EleutherAI"),
    ("Gemma", 7, "Google"),
    ("SOLAR", 11, "Upstage"),
]

# FabriX trace fit (paper Fig 4): request intervals ~ Gamma(alpha, beta).
GAMMA_ALPHA = 0.73
GAMMA_BETA = 10.41

MODEL = ModelConfig()
PREDICTOR = PredictorConfig()
CORPUS = CorpusConfig()
