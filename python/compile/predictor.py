"""L2 — the response-length predictor (the paper's BGE + 8 FC layers, §4.2).

Structure mirrors the paper: a bidirectional transformer encoder embeds the
prompt, token embeddings are mean-pooled, and eight fully-connected ReLU
layers regress the *remaining* response length.  Iterative prediction
(§3.3) is realised by feeding the generated-token count as an input
feature: at scheduling iteration k the predictor sees (prompt, k*50) and
predicts the tokens still to come.

Unlike the served model, predictor weights are *trained* at build time on
the synthetic step dataset (hand-rolled Adam; no optimizer deps available
offline).  Both the freshly-initialised weights ("pre-trained BGE" row of
Table 2) and the trained weights are exported, sharing a single HLO.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .configs import PREDICTOR, PredictorConfig
from .data import StepDataset
from .kernels.attention import encoder_attention
from .kernels.ref import encoder_attention_ref

Params = Dict[str, jnp.ndarray]

# Normalisation constants baked into the graph (shared with rust via the
# artifact manifest metadata).
GEN_SCALE = 100.0
PLEN_SCALE = 64.0
TARGET_SCALE = 100.0


def param_order(cfg: PredictorConfig = PREDICTOR) -> List[str]:
    names = ["tok_emb", "pos_emb"]
    for i in range(cfg.n_layers):
        names += [
            f"e{i}.ln1_g", f"e{i}.ln1_b",
            f"e{i}.wq", f"e{i}.wk", f"e{i}.wv", f"e{i}.wo",
            f"e{i}.ln2_g", f"e{i}.ln2_b",
            f"e{i}.w1", f"e{i}.b1", f"e{i}.w2", f"e{i}.b2",
        ]
    names += ["ln_g", "ln_b"]
    for i in range(cfg.n_fc):
        names += [f"fc{i}.w", f"fc{i}.b"]
    return names


def param_shapes(cfg: PredictorConfig = PREDICTOR) -> Dict[str, Tuple[int, ...]]:
    d, f = cfg.d_model, cfg.d_ff
    shapes: Dict[str, Tuple[int, ...]] = {
        "tok_emb": (cfg.vocab, d),
        "pos_emb": (cfg.prompt_max, d),
        "ln_g": (d,), "ln_b": (d,),
    }
    for i in range(cfg.n_layers):
        shapes.update({
            f"e{i}.ln1_g": (d,), f"e{i}.ln1_b": (d,),
            f"e{i}.wq": (d, d), f"e{i}.wk": (d, d),
            f"e{i}.wv": (d, d), f"e{i}.wo": (d, d),
            f"e{i}.ln2_g": (d,), f"e{i}.ln2_b": (d,),
            f"e{i}.w1": (d, f), f"e{i}.b1": (f,),
            f"e{i}.w2": (f, d), f"e{i}.b2": (d,),
        })
    in_dim = d + cfg.n_extra_feats
    for i in range(cfg.n_fc):
        out_dim = 1 if i == cfg.n_fc - 1 else cfg.fc_hidden
        shapes[f"fc{i}.w"] = (in_dim, out_dim)
        shapes[f"fc{i}.b"] = (out_dim,)
        in_dim = out_dim
    return shapes


def init_params(cfg: PredictorConfig = PREDICTOR, seed=None) -> Params:
    rng = np.random.default_rng(cfg.seed if seed is None else seed)
    params: Params = {}
    for name, shape in param_shapes(cfg).items():
        if name.endswith("_g"):
            arr = np.ones(shape, np.float32)
        elif name.endswith(("_b", ".b")):
            arr = np.zeros(shape, np.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else 1
            arr = rng.normal(0.0, fan_in ** -0.5, size=shape).astype(np.float32)
        params[name] = jnp.asarray(arr)
    return params


def _layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def forward(params: Params, tokens, prompt_len, gen_count,
            cfg: PredictorConfig = PREDICTOR, *, use_pallas: bool = True):
    """Predict remaining response length.

    tokens:     (B, prompt_max) int32 padded prompt
    prompt_len: (B,) int32
    gen_count:  (B,) float32 — tokens generated so far (k * 50)

    Returns (pred_remaining (B,), pooled (B, d_model)).
    The pooled embedding is exported so Fig 1's cluster analysis can run on
    the same artifact.
    """
    b, t = tokens.shape
    h, dh = cfg.n_heads, cfg.d_head
    x = params["tok_emb"][tokens] + params["pos_emb"][:t][None]
    for i in range(cfg.n_layers):
        y = _layer_norm(x, params[f"e{i}.ln1_g"], params[f"e{i}.ln1_b"])
        q = (y @ params[f"e{i}.wq"]).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
        k = (y @ params[f"e{i}.wk"]).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
        v = (y @ params[f"e{i}.wv"]).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
        # Pallas (interpret mode) cannot be reverse-differentiated, so the
        # training path uses the jnp oracle — identical numerics, proven by
        # test_kernels.py — while export/eval use the L1 Pallas kernel.
        if use_pallas:
            attn = encoder_attention(q, k, v, prompt_len)   # L1 Pallas kernel
        else:
            attn = encoder_attention_ref(q, k, v, prompt_len)
        attn = attn.transpose(0, 2, 1, 3).reshape(b, t, cfg.d_model)
        x = x + attn @ params[f"e{i}.wo"]
        y2 = _layer_norm(x, params[f"e{i}.ln2_g"], params[f"e{i}.ln2_b"])
        x = x + jax.nn.relu(y2 @ params[f"e{i}.w1"] + params[f"e{i}.b1"]) \
            @ params[f"e{i}.w2"] + params[f"e{i}.b2"]
    x = _layer_norm(x, params["ln_g"], params["ln_b"])
    # mean pooling over valid tokens (paper: CLS/mean-pool of BGE)
    mask = (jnp.arange(t)[None, :] < prompt_len[:, None]).astype(x.dtype)
    pooled = (x * mask[:, :, None]).sum(1) / jnp.maximum(
        mask.sum(1, keepdims=True), 1.0)
    feats = jnp.concatenate(
        [pooled,
         (gen_count / GEN_SCALE)[:, None],
         (prompt_len.astype(x.dtype) / PLEN_SCALE)[:, None]], axis=-1)
    z = feats
    for i in range(cfg.n_fc):
        z = z @ params[f"fc{i}.w"] + params[f"fc{i}.b"]
        if i < cfg.n_fc - 1:
            z = jax.nn.relu(z)
    pred = z[:, 0] * TARGET_SCALE
    return pred, pooled


# ---------------------------------------------------------------------------
# Build-time training (hand-rolled Adam, time-budgeted).
# ---------------------------------------------------------------------------

def _loss_fn(params, batch, cfg):
    pred, _ = forward(params, batch["tokens"], batch["prompt_len"],
                      batch["gen_count"], cfg, use_pallas=False)
    err = (pred - batch["target"]) / TARGET_SCALE
    # Huber: robust to the heavy length tail
    delta = 1.0
    a = jnp.abs(err)
    return jnp.where(a <= delta, 0.5 * a * a, delta * (a - 0.5 * delta)).mean()


def train(params: Params, train_ds: StepDataset, val_ds: StepDataset,
          cfg: PredictorConfig = PREDICTOR, *,
          batch_size: int = 64, lr: float = 1e-3, max_epochs: int = 12,
          time_budget_s: float = 240.0, seed: int = 99,
          verbose: bool = True) -> Tuple[Params, Dict]:
    """Adam with a wall-clock budget; returns (params, history)."""
    opt_m = {k: jnp.zeros_like(v) for k, v in params.items()}
    opt_v = {k: jnp.zeros_like(v) for k, v in params.items()}
    b1, b2, eps = 0.9, 0.999, 1e-8

    @jax.jit
    def step(params, opt_m, opt_v, t, batch):
        loss, grads = jax.value_and_grad(_loss_fn)(params, batch, cfg)
        new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, opt_m, grads)
        new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, opt_v, grads)
        def upd(p, m, v):
            mh = m / (1 - b1 ** t)
            vh = v / (1 - b2 ** t)
            return p - lr * mh / (jnp.sqrt(vh) + eps)
        return jax.tree.map(upd, params, new_m, new_v), new_m, new_v, loss

    @jax.jit
    def val_loss(params, batch):
        return _loss_fn(params, batch, cfg)

    def to_batch(ds: StepDataset, idx):
        return {
            "tokens": jnp.asarray(ds.tokens[idx]),
            "prompt_len": jnp.asarray(ds.prompt_len[idx]),
            "gen_count": jnp.asarray(ds.gen_count[idx].astype(np.float32)),
            "target": jnp.asarray(ds.target[idx]),
        }

    rng = np.random.default_rng(seed)
    n = len(train_ds)
    t0 = time.time()
    history = {"train_loss": [], "val_loss": []}
    t = 0
    # fixed-size validation slice to keep jit shapes stable
    val_idx = rng.choice(len(val_ds), size=min(512, len(val_ds)), replace=False)
    val_batch = to_batch(val_ds, val_idx)
    for epoch in range(max_epochs):
        perm = rng.permutation(n)
        losses = []
        for s in range(0, n - batch_size + 1, batch_size):
            t += 1
            batch = to_batch(train_ds, perm[s:s + batch_size])
            params, opt_m, opt_v, loss = step(params, opt_m, opt_v, t, batch)
            losses.append(float(loss))
            if time.time() - t0 > time_budget_s:
                break
        vl = float(val_loss(params, val_batch))
        history["train_loss"].append(float(np.mean(losses)))
        history["val_loss"].append(vl)
        if verbose:
            print(f"[predictor] epoch {epoch}: train={np.mean(losses):.4f} "
                  f"val={vl:.4f} elapsed={time.time()-t0:.0f}s", flush=True)
        if time.time() - t0 > time_budget_s:
            break
        # early stop on plateau
        if len(history["val_loss"]) >= 3 and \
           history["val_loss"][-1] > history["val_loss"][-3] * 0.995:
            break
    return params, history


def evaluate(params: Params, ds: StepDataset,
             cfg: PredictorConfig = PREDICTOR, batch_size: int = 256) -> Dict:
    """MAE / RMSE / R^2 on a step dataset (paper Table 2 metrics)."""
    preds = []
    fwd = jax.jit(lambda tk, pl_, gc: forward(params, tk, pl_, gc, cfg)[0])
    n = len(ds)
    for s in range(0, n, batch_size):
        idx = np.arange(s, min(s + batch_size, n))
        # pad to full batch for stable jit shapes
        pad = batch_size - len(idx)
        sel = np.concatenate([idx, np.repeat(idx[-1:], pad)])
        p = fwd(jnp.asarray(ds.tokens[sel]),
                jnp.asarray(ds.prompt_len[sel]),
                jnp.asarray(ds.gen_count[sel].astype(np.float32)))
        preds.append(np.asarray(p)[: len(idx)])
    pred = np.concatenate(preds)
    y = ds.target
    mae = float(np.abs(pred - y).mean())
    rmse = float(np.sqrt(((pred - y) ** 2).mean()))
    ss_res = float(((pred - y) ** 2).sum())
    ss_tot = float(((y - y.mean()) ** 2).sum())
    r2 = 1.0 - ss_res / max(ss_tot, 1e-12)
    return {"mae": mae, "rmse": rmse, "r2": r2, "n": int(n)}


def flatten_params(params: Params, cfg: PredictorConfig = PREDICTOR):
    return [params[n] for n in param_order(cfg)]


def unflatten_params(flat, cfg: PredictorConfig = PREDICTOR) -> Params:
    return dict(zip(param_order(cfg), flat))


def make_predict_fn(cfg: PredictorConfig = PREDICTOR):
    """Flattened-signature wrapper for AOT lowering."""
    n = len(param_order(cfg))

    def fn(*args):
        params = unflatten_params(list(args[:n]), cfg)
        tokens, prompt_len, gen_count = args[n:n + 3]
        pred, pooled = forward(params, tokens, prompt_len, gen_count, cfg)
        return pred, pooled

    return fn
