"""Re-lower ONLY the served-model HLOs (perf-pass tool).

Kernel/structure changes to TinyGPT (e.g. the §Perf decode-grid variant)
don't touch weights or the predictor, so re-running the full `aot.build`
(which retrains) would waste ~10 minutes per iteration.  This script
re-lowers model.{prefill,decode}.b{1,2,4} + golden.json in-place against an
existing artifacts directory.

    cd python && python -m compile.lower_only --out ../artifacts
"""

import argparse
import json
import os

from . import aot
from . import model as M
from .golden import build_golden


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    manifest_path = os.path.join(args.out, "manifest.json")
    with open(manifest_path) as f:
        manifest = json.load(f)
    params = M.init_params()
    aot.lower_model(args.out, params, manifest)
    build_golden(args.out)
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print("re-lowered model HLOs + golden")


if __name__ == "__main__":
    main()
