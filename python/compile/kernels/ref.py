"""Pure-jnp oracles for the Pallas attention kernels.

These are the correctness references: `test_kernels.py` sweeps shapes and
dtypes with hypothesis and asserts the Pallas kernels (interpret=True) match
these implementations to tight tolerances.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """Single-token multi-head attention against a KV cache.

    Args:
      q:        (B, H, Dh)  query for the token being decoded.
      k_cache:  (B, H, S, Dh)
      v_cache:  (B, H, S, Dh)
      lengths:  (B,) int32 — number of valid cache slots per sequence
                (the current token's k/v must already be written).

    Returns:
      (B, H, Dh) attention output.
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    # (B, H, S)
    scores = jnp.einsum("bhd,bhsd->bhs", q, k_cache) * scale
    s = k_cache.shape[2]
    mask = jnp.arange(s)[None, None, :] < lengths[:, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs * mask.astype(probs.dtype)
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhs,bhsd->bhd", probs, v_cache)


def prefill_attention_ref(q, k, v, lengths):
    """Causal + padding-masked self attention over the prompt.

    Args:
      q, k, v:  (B, H, T, Dh)
      lengths:  (B,) int32 — valid prompt length per sequence.

    Returns:
      (B, H, T, Dh)
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) * scale
    t = q.shape[2]
    i = jnp.arange(t)[:, None]
    j = jnp.arange(t)[None, :]
    causal = j <= i                                    # (T, T)
    valid = jnp.arange(t)[None, None, None, :] < lengths[:, None, None, None]
    mask = causal[None, None, :, :] & valid
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs * mask.astype(probs.dtype)
    denom = jnp.maximum(probs.sum(axis=-1, keepdims=True), 1e-30)
    probs = probs / denom
    return jnp.einsum("bhts,bhsd->bhtd", probs, v)


def encoder_attention_ref(q, k, v, lengths):
    """Bidirectional padding-masked attention (predictor encoder)."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) * scale
    t = q.shape[2]
    valid = jnp.arange(t)[None, None, None, :] < lengths[:, None, None, None]
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs * valid.astype(probs.dtype)
    denom = jnp.maximum(probs.sum(axis=-1, keepdims=True), 1e-30)
    probs = probs / denom
    return jnp.einsum("bhts,bhsd->bhtd", probs, v)
