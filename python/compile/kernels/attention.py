"""Pallas attention kernels — the L1 hot-spot of the served model.

vLLM's contribution at this level is PagedAttention: a CUDA kernel where each
threadblock gathers one sequence's KV pages from HBM into shared memory and
runs the dot-products on tensor cores.  The TPU re-think (see DESIGN.md
§Hardware-Adaptation): instead of a gather over pages, each grid step stages
one (batch, head) KV tile HBM->VMEM via `BlockSpec`, and the q.K^T / p.V
contractions are dense `dot`s the MXU can consume.  Length masking replaces
the page table: slots >= `length` are masked to -inf before the softmax.

All kernels run with interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers the kernel body to plain HLO,
which is exactly what the rust runtime loads.  Real-TPU efficiency is
estimated analytically in DESIGN.md §Perf.
"""

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

# interpret=True is mandatory on CPU (see module docstring); kept as a flag
# so a TPU build can flip it off without touching call sites.
INTERPRET = True


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, scale):
    """One (batch, head) tile: q (1, Dh) against KV (S, Dh).

    VMEM footprint per grid step: (2*S*Dh + 2*Dh + S) * 4 bytes — for the
    production shape (S=576, Dh=64) that is ~300 KB, comfortably inside a
    TPU core's ~16 MB VMEM, leaving room for double-buffering the next
    (batch, head) tile while this one computes.
    """
    q = q_ref[...]                      # (Dh,)   — leading dims squeezed
    k = k_ref[...]                      # (S, Dh)
    v = v_ref[...]                      # (S, Dh)
    length = len_ref[0]
    # MXU-friendly contraction: (S, Dh) x (Dh,) -> (S,)
    scores = jnp.dot(k, q) * scale      # (S,)
    s = scores.shape[0]
    mask = jax.lax.iota(jnp.int32, s) < length
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores)
    p = jnp.exp(scores - m)
    p = jnp.where(mask, p, 0.0)
    denom = jnp.sum(p)
    # (S,) x (S, Dh) -> (Dh,)
    o_ref[...] = jnp.dot(p / denom, v)


def _decode_kernel_allheads(q_ref, k_ref, v_ref, len_ref, o_ref, *, scale):
    """One batch row, ALL heads per grid step: q (H, Dh) vs KV (H, S, Dh).

    Perf variant (§Perf L1): on the CPU interpret path the per-grid-step
    bookkeeping dominates, so collapsing the head axis into the block cuts
    grid steps by H× (decode window = 50 sequential steps, each with its
    own grid).  On TPU this trades per-(batch,head) VMEM tiles (~300 KB)
    for per-batch tiles (H× larger, ~1.2 MB at production shape) — still
    comfortably inside VMEM, with the same MXU contractions batched over H.
    """
    q = q_ref[...]                      # (H, Dh)
    k = k_ref[...]                      # (H, S, Dh)
    v = v_ref[...]                      # (H, S, Dh)
    length = len_ref[0]
    # batched contraction over heads: (H, S, Dh) x (H, Dh) -> (H, S)
    scores = jax.lax.dot_general(
        k, q, (((2,), (1,)), ((0,), (0,)))) * scale
    s = scores.shape[1]
    mask = (jax.lax.broadcasted_iota(jnp.int32, (1, s), 1) < length)
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=1, keepdims=True)
    p = jnp.exp(scores - m)
    p = jnp.where(mask, p, 0.0)
    denom = jnp.sum(p, axis=1, keepdims=True)
    p = p / denom
    # (H, S) x (H, S, Dh) -> (H, Dh)
    o_ref[...] = jax.lax.dot_general(p, v, (((1,), (1,)), ((0,), (0,))))


def decode_attention(q, k_cache, v_cache, lengths, *, interpret=None,
                     grid_mode=None):
    """Pallas decode attention.

    Args / returns exactly as `ref.decode_attention_ref`:
      q (B, H, Dh), k_cache/v_cache (B, H, S, Dh), lengths (B,) int32
      -> (B, H, Dh)

    grid_mode: "bh" (one (batch, head) tile per grid step) or "batch"
    (all heads per step — the §Perf default; see _decode_kernel_allheads).
    Env override: ELIS_DECODE_GRID.
    """
    if interpret is None:
        interpret = INTERPRET
    if grid_mode is None:
        grid_mode = os.environ.get("ELIS_DECODE_GRID", "batch")
    b, h, dh = q.shape
    s = k_cache.shape[2]
    scale = 1.0 / (dh ** 0.5)
    if grid_mode == "batch":
        kernel = functools.partial(_decode_kernel_allheads, scale=scale)
        return pl.pallas_call(
            kernel,
            grid=(b,),
            in_specs=[
                pl.BlockSpec((None, h, dh), lambda i: (i, 0, 0)),
                pl.BlockSpec((None, h, s, dh), lambda i: (i, 0, 0, 0)),
                pl.BlockSpec((None, h, s, dh), lambda i: (i, 0, 0, 0)),
                pl.BlockSpec((1,), lambda i: (i,)),
            ],
            out_specs=pl.BlockSpec((None, h, dh), lambda i: (i, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
            interpret=interpret,
        )(q, k_cache, v_cache, lengths)
    kernel = functools.partial(_decode_kernel, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            # q: one (1, Dh) row per (batch, head)
            pl.BlockSpec((None, None, dh), lambda i, j: (i, j, 0)),
            # KV: one (S, Dh) tile per (batch, head) — the HBM->VMEM stage
            pl.BlockSpec((None, None, s, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, s, dh), lambda i, j: (i, j, 0, 0)),
            # per-sequence valid length (scalar per batch row)
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((None, None, dh), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        interpret=interpret,
    )(q, k_cache, v_cache, lengths)
    return out


def _prefill_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, scale):
    """One (batch, head) tile: causal attention over the whole prompt.

    The (T, T) score tile for T=64 is 16 KB — a single MXU-sized block, so
    no inner flash loop is needed at prompt scale; longer prompts would tile
    the key dimension with a running (m, l) rescale exactly like flash
    attention.
    """
    q = q_ref[...]                      # (T, Dh)
    k = k_ref[...]                      # (T, Dh)
    v = v_ref[...]                      # (T, Dh)
    length = len_ref[0]
    t = q.shape[0]
    scores = jnp.dot(q, k.T) * scale    # (T, T)
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    mask = (cols <= rows) & (cols < length)
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=1, keepdims=True)
    p = jnp.exp(scores - m)
    p = jnp.where(mask, p, 0.0)
    denom = jnp.maximum(jnp.sum(p, axis=1, keepdims=True), 1e-30)
    o_ref[...] = jnp.dot(p / denom, v)


def prefill_attention(q, k, v, lengths, *, interpret=None):
    """Pallas causal prefill attention.

    q, k, v: (B, H, T, Dh); lengths: (B,) int32 -> (B, H, T, Dh)
    """
    if interpret is None:
        interpret = INTERPRET
    b, h, t, dh = q.shape
    scale = 1.0 / (dh ** 0.5)
    kernel = functools.partial(_prefill_kernel, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((None, None, t, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, t, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, t, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((None, None, t, dh), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, t, dh), q.dtype),
        interpret=interpret,
    )(q, k, v, lengths)
    return out


def _encoder_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, scale):
    """Bidirectional (padding-masked) attention tile for the predictor."""
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    length = len_ref[0]
    t = q.shape[0]
    scores = jnp.dot(q, k.T) * scale
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    mask = cols < length
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=1, keepdims=True)
    p = jnp.exp(scores - m)
    p = jnp.where(mask, p, 0.0)
    denom = jnp.maximum(jnp.sum(p, axis=1, keepdims=True), 1e-30)
    o_ref[...] = jnp.dot(p / denom, v)


def encoder_attention(q, k, v, lengths, *, interpret=None):
    """Pallas bidirectional attention for the predictor encoder.

    q, k, v: (B, H, T, Dh); lengths: (B,) int32 -> (B, H, T, Dh)
    """
    if interpret is None:
        interpret = INTERPRET
    b, h, t, dh = q.shape
    scale = 1.0 / (dh ** 0.5)
    kernel = functools.partial(_encoder_kernel, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((None, None, t, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, t, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, t, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((None, None, t, dh), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, t, dh), q.dtype),
        interpret=interpret,
    )(q, k, v, lengths)
    return out
