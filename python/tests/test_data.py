"""Synthetic-corpus tests: the properties ELIS's evaluation depends on."""

import numpy as np
from dataclasses import replace
from hypothesis import given, settings, strategies as st

from compile import data as D
from compile.configs import CORPUS, PREDICTOR, WINDOW_SIZE

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def _corpus(n=500, seed=3):
    return D.generate_corpus(replace(CORPUS, n_prompts=n, seed=seed))


def test_corpus_reproducible():
    a = _corpus(100, 5)
    b = _corpus(100, 5)
    for ea, eb in zip(a.entries, b.entries):
        np.testing.assert_array_equal(ea.tokens, eb.tokens)
        assert ea.total_len == eb.total_len


def test_lengths_in_bounds():
    c = _corpus()
    for e in c.entries:
        assert CORPUS.out_min <= e.total_len <= CORPUS.out_max
        assert CORPUS.prompt_min <= len(e.tokens) <= CORPUS.prompt_max
        assert (e.tokens >= 1).all() and (e.tokens < PREDICTOR.vocab).all()


def test_lengths_heavy_tailed():
    """Mix of short and long responses — the precondition for head-of-line
    blocking (the phenomenon ISRTF fixes)."""
    c = _corpus(2000)
    lens = np.array([e.total_len for e in c.entries])
    assert np.percentile(lens, 10) < 40
    assert np.percentile(lens, 90) > 150
    assert lens.std() / lens.mean() > 0.5


def test_topic_predicts_length():
    """Within-topic length variance must be well below total variance —
    otherwise no predictor could work."""
    c = _corpus(3000)
    lens = np.array([e.total_len for e in c.entries], dtype=np.float64)
    topics = np.array([e.topic for e in c.entries])
    total_var = lens.var()
    within = np.mean([lens[topics == t].var()
                      for t in range(CORPUS.n_topics)
                      if (topics == t).sum() > 10])
    assert within < 0.5 * total_var


def test_split_proportions():
    c = _corpus(1000)
    tr, va, te = c.split()
    assert abs(len(tr) - 600) <= 1
    assert abs(len(va) - 200) <= 1
    assert len(tr) + len(va) + len(te) == 1000


@given(st.integers(0, 10_000))
def test_true_length_deterministic(seed):
    rng = np.random.default_rng(seed)
    topic = int(rng.integers(0, CORPUS.n_topics))
    plen = int(rng.integers(CORPUS.prompt_min, CORPUS.prompt_max))
    noise = float(rng.normal(0, CORPUS.noise_sigma))
    a = D.true_length(topic, plen, noise)
    b = D.true_length(topic, plen, noise)
    assert a == b
    assert CORPUS.out_min <= a <= CORPUS.out_max


def test_step_dataset_structure():
    c = _corpus(50)
    ds = D.step_dataset(c.entries)
    assert len(ds) >= len(c.entries)          # at least one step per prompt
    assert (ds.gen_count == ds.step * WINDOW_SIZE).all()
    assert (ds.target == ds.total - ds.gen_count).all()
    assert (ds.target > 0).all()              # never train on finished jobs
    assert ds.tokens.shape[1] == PREDICTOR.prompt_max


def test_pad_tokens():
    t = np.array([5, 6, 7], np.int32)
    out = D.pad_tokens(t, 8)
    np.testing.assert_array_equal(out[:3], t)
    assert (out[3:] == 0).all()
    # truncation
    long = np.arange(1, 20, dtype=np.int32)
    out2 = D.pad_tokens(long, 8)
    np.testing.assert_array_equal(out2, long[:8])


def test_embedding_groups_disjoint_topics():
    g = D.embedding_groups(n_per_group=20)
    assert g["similar"].shape == (20, PREDICTOR.prompt_max)
    assert g["dissimilar"].shape == (20, PREDICTOR.prompt_max)
    # group A tokens live in topic-0's band, group B outside it
    lo, hi = D._topic_band(0, PREDICTOR.vocab, CORPUS.n_topics)
    a = g["similar"][g["similar"] > 0]
    b = g["dissimilar"][g["dissimilar"] > 0]
    assert ((a >= lo) & (a < hi)).all()
    assert (~((b >= lo) & (b < hi))).all()
