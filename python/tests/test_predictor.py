"""Predictor (BGE-substitute) tests: architecture, training signal,
iterative-prediction property (paper §3.3), and pallas/ref agreement."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile import predictor as P
from compile.configs import CORPUS, PREDICTOR, WINDOW_SIZE
from dataclasses import replace


@pytest.fixture(scope="module")
def small_corpus():
    cfg = replace(CORPUS, n_prompts=400, seed=11)
    return D.generate_corpus(cfg)


@pytest.fixture(scope="module")
def params():
    return P.init_params()


def test_forward_shapes(params):
    b = 8
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, PREDICTOR.vocab,
                                    size=(b, PREDICTOR.prompt_max)).astype(np.int32))
    plen = jnp.asarray(np.full(b, 10, np.int32))
    gen = jnp.asarray(np.zeros(b, np.float32))
    pred, pooled = P.forward(params, toks, plen, gen)
    assert pred.shape == (b,)
    assert pooled.shape == (b, PREDICTOR.d_model)


def test_pallas_and_ref_paths_agree(params):
    """The training path (jnp ref) and export path (Pallas) must be the same
    function."""
    b = 4
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, PREDICTOR.vocab,
                                    size=(b, PREDICTOR.prompt_max)).astype(np.int32))
    plen = jnp.asarray(rng.integers(1, PREDICTOR.prompt_max, size=b).astype(np.int32))
    gen = jnp.asarray(rng.uniform(0, 300, size=b).astype(np.float32))
    p1, e1 = P.forward(params, toks, plen, gen, use_pallas=True)
    p2, e2 = P.forward(params, toks, plen, gen, use_pallas=False)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-4, atol=1e-4)


def test_padding_does_not_change_prediction(params):
    b = 2
    rng = np.random.default_rng(2)
    toks = np.zeros((b, PREDICTOR.prompt_max), np.int32)
    toks[:, :12] = rng.integers(16, PREDICTOR.vocab, size=(b, 12))
    plen = jnp.asarray(np.full(b, 12, np.int32))
    gen = jnp.asarray(np.zeros(b, np.float32))
    p1, _ = P.forward(params, jnp.asarray(toks), plen, gen)
    toks2 = toks.copy()
    toks2[:, 12:] = 1777       # poison padding
    p2, _ = P.forward(params, jnp.asarray(toks2), plen, gen)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-4, atol=1e-3)


def test_training_improves_metrics(small_corpus, params):
    train_e, val_e, test_e = small_corpus.split()
    train_ds = D.step_dataset(train_e)
    val_ds = D.step_dataset(val_e)
    test_ds = D.step_dataset(test_e)
    before = P.evaluate(params, test_ds)
    trained, hist = P.train(params, train_ds, val_ds,
                            time_budget_s=45.0, max_epochs=3, verbose=False)
    after = P.evaluate(trained, test_ds)
    assert after["mae"] < before["mae"]
    assert after["r2"] > before["r2"]
    assert hist["train_loss"][-1] < hist["train_loss"][0] * 1.05


def test_step_dataset_targets_shrink_with_iteration(small_corpus):
    """For any single prompt, the remaining-length target decreases by one
    window per step — the structural reason iterative prediction gets
    easier (Fig 2b).  (Cross-cohort means can rise: only long jobs survive
    to high steps.)"""
    ds = D.step_dataset(small_corpus.entries[:100])
    # steps of one prompt are contiguous (insertion order), so walk runs of
    # consecutive step indices sharing the same total
    i = 0
    n = len(ds)
    while i < n:
        j = i
        while (j + 1 < n and ds.step[j + 1] == ds.step[j] + 1
               and ds.total[j + 1] == ds.total[i]):
            j += 1
        seq = ds.target[i:j + 1]
        assert all(seq[k + 1] == seq[k] - 50 for k in range(len(seq) - 1)), \
            f"targets not stepping down by window: {seq}"
        i = j + 1


def test_evaluate_metrics_sane(params, small_corpus):
    ds = D.step_dataset(small_corpus.entries[:50])
    m = P.evaluate(params, ds)
    assert m["mae"] >= 0 and m["rmse"] >= m["mae"] * 0.5
    assert m["n"] == len(ds)


def test_param_order_matches_shapes(params):
    order = P.param_order()
    shapes = P.param_shapes()
    assert set(order) == set(shapes.keys())
    for n in order:
        assert tuple(params[n].shape) == tuple(shapes[n])


def test_fc_stack_depth():
    """Paper: eight FC layers after the encoder."""
    assert PREDICTOR.n_fc == 8
    order = P.param_order()
    assert sum(1 for n in order if n.startswith("fc")) == 16  # w+b each
