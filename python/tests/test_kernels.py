"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes as the core correctness signal for the
kernels that end up inside every AOT artifact.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as A
from compile.kernels import ref as R

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rand(rng, shape, dtype):
    x = rng.normal(0.0, 1.0, size=shape)
    return jnp.asarray(x.astype(dtype))


shape_strategy = st.tuples(
    st.integers(1, 4),              # B
    st.sampled_from([1, 2, 4]),     # H
    st.sampled_from([4, 16, 33]),   # S
    st.sampled_from([4, 8, 24]),    # Dh
)


@given(shape=shape_strategy, seed=st.integers(0, 2**31 - 1),
       grid_mode=st.sampled_from(["bh", "batch"]))
def test_decode_attention_matches_ref(shape, seed, grid_mode):
    b, h, s, dh = shape
    rng = np.random.default_rng(seed)
    q = _rand(rng, (b, h, dh), np.float32)
    k = _rand(rng, (b, h, s, dh), np.float32)
    v = _rand(rng, (b, h, s, dh), np.float32)
    lens = jnp.asarray(rng.integers(1, s + 1, size=b).astype(np.int32))
    got = A.decode_attention(q, k, v, lens, grid_mode=grid_mode)
    want = R.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_decode_grid_modes_agree():
    """The §Perf batch-grid variant must be numerically identical to the
    (batch, head) grid."""
    rng = np.random.default_rng(7)
    b, h, s, dh = 3, 4, 33, 8
    q = _rand(rng, (b, h, dh), np.float32)
    k = _rand(rng, (b, h, s, dh), np.float32)
    v = _rand(rng, (b, h, s, dh), np.float32)
    lens = jnp.asarray(np.array([5, 20, 33], np.int32))
    a = A.decode_attention(q, k, v, lens, grid_mode="bh")
    b_ = A.decode_attention(q, k, v, lens, grid_mode="batch")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                               rtol=1e-6, atol=1e-6)


@given(shape=shape_strategy, seed=st.integers(0, 2**31 - 1))
def test_prefill_attention_matches_ref(shape, seed):
    b, h, t, dh = shape
    rng = np.random.default_rng(seed)
    q = _rand(rng, (b, h, t, dh), np.float32)
    k = _rand(rng, (b, h, t, dh), np.float32)
    v = _rand(rng, (b, h, t, dh), np.float32)
    lens = jnp.asarray(rng.integers(1, t + 1, size=b).astype(np.int32))
    got = A.prefill_attention(q, k, v, lens)
    want = R.prefill_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@given(shape=shape_strategy, seed=st.integers(0, 2**31 - 1))
def test_encoder_attention_matches_ref(shape, seed):
    b, h, t, dh = shape
    rng = np.random.default_rng(seed)
    q = _rand(rng, (b, h, t, dh), np.float32)
    k = _rand(rng, (b, h, t, dh), np.float32)
    v = _rand(rng, (b, h, t, dh), np.float32)
    lens = jnp.asarray(rng.integers(1, t + 1, size=b).astype(np.int32))
    got = A.encoder_attention(q, k, v, lens)
    want = R.encoder_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_decode_attention_ignores_masked_slots():
    """Garbage beyond `length` must not affect the output."""
    rng = np.random.default_rng(0)
    b, h, s, dh = 2, 2, 16, 8
    q = _rand(rng, (b, h, dh), np.float32)
    k = _rand(rng, (b, h, s, dh), np.float32)
    v = _rand(rng, (b, h, s, dh), np.float32)
    lens = jnp.asarray(np.array([5, 9], np.int32))
    base = A.decode_attention(q, k, v, lens)
    k2 = k.at[:, :, 10:].set(1e6)
    v2 = v.at[:, :, 10:].set(-1e6)
    poisoned = A.decode_attention(q, k2, v2, lens)
    np.testing.assert_allclose(np.asarray(base), np.asarray(poisoned),
                               rtol=1e-6, atol=1e-6)


def test_prefill_attention_is_causal():
    """Changing future tokens must not change earlier positions."""
    rng = np.random.default_rng(1)
    b, h, t, dh = 1, 2, 12, 8
    q = _rand(rng, (b, h, t, dh), np.float32)
    k = _rand(rng, (b, h, t, dh), np.float32)
    v = _rand(rng, (b, h, t, dh), np.float32)
    lens = jnp.asarray(np.array([t], np.int32))
    base = A.prefill_attention(q, k, v, lens)
    k2 = k.at[:, :, 8:].add(3.0)
    v2 = v.at[:, :, 8:].add(-2.0)
    out = A.prefill_attention(q, k2, v2, lens)
    np.testing.assert_allclose(np.asarray(base[:, :, :8]),
                               np.asarray(out[:, :, :8]),
                               rtol=1e-6, atol=1e-6)


def test_attention_probabilities_sum_to_one_effect():
    """With v = const the output must be exactly that const (softmax sums 1)."""
    b, h, s, dh = 1, 1, 8, 4
    rng = np.random.default_rng(2)
    q = _rand(rng, (b, h, dh), np.float32)
    k = _rand(rng, (b, h, s, dh), np.float32)
    v = jnp.full((b, h, s, dh), 3.25, jnp.float32)
    lens = jnp.asarray(np.array([s], np.int32))
    out = A.decode_attention(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), 3.25, rtol=1e-5)


@pytest.mark.parametrize("length", [1, 3, 16])
def test_decode_attention_single_batch_lengths(length):
    rng = np.random.default_rng(3)
    b, h, s, dh = 1, 4, 16, 8
    q = _rand(rng, (b, h, dh), np.float32)
    k = _rand(rng, (b, h, s, dh), np.float32)
    v = _rand(rng, (b, h, s, dh), np.float32)
    lens = jnp.asarray(np.array([length], np.int32))
    got = A.decode_attention(q, k, v, lens)
    want = R.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
