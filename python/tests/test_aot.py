"""AOT artifact tests.

If artifacts/ already exists (built by `make artifacts`), validate it in
place; otherwise build a tiny --fast bundle into tmp.  Checks cover the
contract the rust runtime depends on: manifest completeness, HLO text
non-emptiness, weight-blob sizes, and dataset schema.
"""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile.configs import BATCH_SIZES, MODEL, PREDICTOR_BATCH

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    if os.path.exists(os.path.join(ART, "manifest.json")):
        return os.path.abspath(ART)
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.build(out, fast=True, quiet=True)
    return out


@pytest.fixture(scope="module")
def manifest(artifacts):
    with open(os.path.join(artifacts, "manifest.json")) as f:
        return json.load(f)


def test_manifest_lists_all_executables(manifest):
    names = set(manifest["executables"].keys())
    for b in BATCH_SIZES:
        assert f"model.prefill.b{b}" in names
        assert f"model.decode.b{b}" in names
    assert f"predictor.b{PREDICTOR_BATCH}" in names


def test_hlo_files_exist_and_parse_shape(artifacts, manifest):
    for name, exe in manifest["executables"].items():
        path = os.path.join(artifacts, exe["hlo"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert "HloModule" in text
        assert len(text) > 1000


def test_weight_blobs_match_manifest(artifacts, manifest):
    for group, entries in manifest["weights"].items():
        for e in entries:
            path = os.path.join(artifacts, e["file"])
            assert os.path.exists(path), e["file"]
            n_elems = int(np.prod(e["shape"])) if e["shape"] else 1
            assert os.path.getsize(path) == n_elems * 4  # f32/i32
        names = [e["name"] for e in entries]
        assert len(names) == len(set(names))


def test_predictor_weight_groups_align(manifest):
    a = manifest["weights"]["predictor_trained"]
    b = manifest["weights"]["predictor_init"]
    assert [e["name"] for e in a] == [e["name"] for e in b]
    assert [e["shape"] for e in a] == [e["shape"] for e in b]


def test_corpus_schema(artifacts):
    with open(os.path.join(artifacts, "corpus.json")) as f:
        c = json.load(f)
    assert c["window_size"] == 50
    assert len(c["entries"]) > 10
    for e in c["entries"][:20]:
        assert 1 <= len(e["tokens"]) <= c["prompt_max"]
        assert e["total_len"] >= 1


def test_predictor_test_schema(artifacts):
    with open(os.path.join(artifacts, "predictor_test.json")) as f:
        t = json.load(f)
    n = len(t["target"])
    assert n > 10
    for k in ("tokens", "prompt_len", "gen_count", "step"):
        assert len(t[k]) == n


def test_embed_groups_schema(artifacts):
    with open(os.path.join(artifacts, "embed_groups.json")) as f:
        g = json.load(f)
    assert set(g.keys()) == {"similar", "dissimilar"}
    assert len(g["similar"]) == len(g["dissimilar"])


def test_predictor_metrics_improved(artifacts):
    with open(os.path.join(artifacts, "predictor_metrics.json")) as f:
        m = json.load(f)
    assert m["predictor_trained"]["mae"] < m["predictor_init"]["mae"]
    assert m["predictor_trained"]["r2"] > m["predictor_init"]["r2"]


def test_manifest_served_models_match_paper_table4(manifest):
    names = {m["abbrev"]: m for m in manifest["served_models"]}
    assert set(names) == {"opt6.7", "opt13", "lam7", "lam13", "vic"}
    assert names["lam13"]["avg_latency_ms"] == pytest.approx(8610.2)
    assert names["lam13"]["preempt_batch"] == 120


def test_manifest_training_models_match_paper_table7(manifest):
    assert len(manifest["training_models"]) == 13
