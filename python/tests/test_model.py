"""L2 correctness: TinyGPT prefill/decode-window semantics.

The decode window is the unit the rust coordinator schedules; these tests
pin the invariants the coordinator relies on: KV-cache consistency between
prefill and decode, window-size token production, inactive-slot isolation,
and batch-composition independence.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import MODEL, WINDOW_SIZE


@pytest.fixture(scope="module")
def params():
    return M.init_params()


def _prompt(rng, b):
    toks = np.zeros((b, MODEL.prompt_max), np.int32)
    lens = rng.integers(4, 20, size=b).astype(np.int32)
    for i in range(b):
        toks[i, : lens[i]] = rng.integers(16, MODEL.vocab, size=lens[i])
    return jnp.asarray(toks), jnp.asarray(lens)


def test_prefill_shapes(params):
    rng = np.random.default_rng(0)
    toks, lens = _prompt(rng, 2)
    kv, first, last = M.prefill(params, toks, lens)
    assert kv.shape == M.kv_shape(2)
    assert first.shape == (2,)
    assert last.shape == (2, MODEL.vocab)
    assert first.dtype == jnp.int32


def test_prefill_last_token_uses_true_length(params):
    """Padding after the prompt must not change the first generated token."""
    rng = np.random.default_rng(1)
    toks, lens = _prompt(rng, 2)
    _, first_a, _ = M.prefill(params, toks, lens)
    # poison the pad region
    toks_b = np.asarray(toks).copy()
    for i in range(2):
        toks_b[i, int(lens[i]):] = 999
    _, first_b, _ = M.prefill(params, jnp.asarray(toks_b), lens)
    np.testing.assert_array_equal(np.asarray(first_a), np.asarray(first_b))


def test_decode_window_produces_window_tokens(params):
    rng = np.random.default_rng(2)
    toks, lens = _prompt(rng, 2)
    kv, first, _ = M.prefill(params, toks, lens)
    active = jnp.ones(2, jnp.int32)
    kv2, w, nl = M.decode_window(params, kv, lens, first, active)
    assert w.shape == (2, WINDOW_SIZE)
    np.testing.assert_array_equal(np.asarray(nl), np.asarray(lens) + WINDOW_SIZE)
    assert (np.asarray(w) >= 0).all() and (np.asarray(w) < MODEL.vocab).all()


def test_decode_deterministic(params):
    rng = np.random.default_rng(3)
    toks, lens = _prompt(rng, 1)
    kv, first, _ = M.prefill(params, toks, lens)
    active = jnp.ones(1, jnp.int32)
    _, w1, _ = M.decode_window(params, kv, lens, first, active)
    _, w2, _ = M.decode_window(params, kv, lens, first, active)
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))


def test_batch_composition_independence(params):
    """A sequence decoded alone must produce the same tokens as when batched
    with another sequence — the property continuous batching depends on."""
    rng = np.random.default_rng(4)
    toks2, lens2 = _prompt(rng, 2)
    kv2, first2, _ = M.prefill(params, toks2, lens2)
    active2 = jnp.ones(2, jnp.int32)
    _, w2, _ = M.decode_window(params, kv2, lens2, first2, active2)

    toks1 = toks2[:1]
    lens1 = lens2[:1]
    kv1, first1, _ = M.prefill(params, toks1, lens1)
    active1 = jnp.ones(1, jnp.int32)
    _, w1, _ = M.decode_window(params, kv1, lens1, first1, active1)

    np.testing.assert_array_equal(np.asarray(w1[0]), np.asarray(w2[0]))


def test_inactive_slot_is_isolated(params):
    """An inactive slot must not change active slots' outputs, and must not
    advance its own length."""
    rng = np.random.default_rng(5)
    toks, lens = _prompt(rng, 2)
    kv, first, _ = M.prefill(params, toks, lens)
    all_active = jnp.ones(2, jnp.int32)
    _, w_all, _ = M.decode_window(params, kv, lens, first, all_active)

    half = jnp.asarray(np.array([1, 0], np.int32))
    _, w_half, nl_half = M.decode_window(params, kv, lens, first, half)
    np.testing.assert_array_equal(np.asarray(w_all[0]), np.asarray(w_half[0]))
    assert int(nl_half[1]) == int(lens[1])          # inactive: not advanced
    assert int(nl_half[0]) == int(lens[0]) + WINDOW_SIZE


def test_two_windows_continue_consistently(params):
    """Decoding 2 windows must equal decoding the same 100 steps — i.e. the
    KV state returned by one window is a valid input for the next."""
    rng = np.random.default_rng(6)
    toks, lens = _prompt(rng, 1)
    kv, first, _ = M.prefill(params, toks, lens)
    active = jnp.ones(1, jnp.int32)
    kv_a, w_a, nl_a = M.decode_window(params, kv, lens, first, active)
    kv_b, w_b, nl_b = M.decode_window(params, kv_a, nl_a, w_a[:, -1], active)
    # windows continue: token streams are deterministic continuations
    assert int(nl_b[0]) == int(lens[0]) + 2 * WINDOW_SIZE
    # re-run the first window; results must be identical (pure function)
    _, w_a2, _ = M.decode_window(params, kv, lens, first, active)
    np.testing.assert_array_equal(np.asarray(w_a), np.asarray(w_a2))


def test_param_order_matches_shapes(params):
    order = M.param_order()
    shapes = M.param_shapes()
    assert set(order) == set(shapes.keys())
    assert len(order) == len(set(order))
    for n in order:
        assert tuple(params[n].shape) == tuple(shapes[n])


def test_flatten_roundtrip(params):
    flat = M.flatten_params(params)
    back = M.unflatten_params(flat)
    for n in M.param_order():
        np.testing.assert_array_equal(np.asarray(params[n]), np.asarray(back[n]))
