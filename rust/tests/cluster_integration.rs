//! Integration tests for the cluster runtime: threaded worker pool,
//! std-only HTTP frontend, the virtual-clock determinism guarantee the
//! pool refactor must preserve, and (PR 5) the distributed worker pods —
//! wire protocol, fault-injection failover, and a true multi-process
//! end-to-end run over `elis worker` children.  No artifacts required.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use elis::cluster::pool::run_cmd_window;
use elis::cluster::{wire, Admission, AdmissionConfig, ApiBridge, Gateway,
                    HttpServer, RemoteWorkerPool, SseDecoder, WorkerCmd,
                    WorkerPool};
use elis::coordinator::{
    run_serving, ClockMode, CoordinatorBuilder, EventSink, Policy, Scheduler,
    ServeConfig,
};
use elis::engine::profiles::ModelProfile;
use elis::engine::sim_engine::SimEngine;
use elis::engine::{Engine, SeqSpec, SeqWindowOut, WindowOutcome};
use elis::predictor::oracle::OraclePredictor;
use elis::runtime::manifest::ServedModelMeta;
use elis::telemetry::{AttributionSink, FlightRecorder, ShadowMode,
                      ShadowScheduler, TelemetrySink};
use elis::util::json::Json;
use elis::workload::{Corpus, RequestGenerator, TraceRequest};

fn profile() -> ModelProfile {
    ModelProfile::from_meta(&ServedModelMeta {
        name: "test".into(),
        abbrev: "test".into(),
        params_b: 7.0,
        avg_latency_ms: 2000.0,
        kv_bytes_per_token: 1 << 20,
        preempt_batch: 0,
        mem_limit_frac: 0.9,
    })
}

fn sim_engines(n: usize) -> Vec<Box<dyn Engine>> {
    (0..n)
        .map(|_| {
            Box::new(SimEngine::new(profile(), 50, 4, 8 << 30))
                as Box<dyn Engine>
        })
        .collect()
}

// ---------------------------------------------------------------------------
// virtual-clock determinism: the pool refactor must not perturb simulation
// ---------------------------------------------------------------------------

/// The threaded-runtime refactor (engine backend enum, Result-returning
/// poll_completions, idle-tick config) must leave virtual-clock reports
/// bit-identical: same trace + seed twice, and with wildly different
/// `idle_tick_ms` (which only wall mode reads).
#[test]
fn virtual_reports_are_bit_identical_across_pool_refactor_knobs() {
    let corpus = Corpus::synthetic(300, 87);
    let mut gen = RequestGenerator::fabrix(3.0, 87);
    let trace = gen.trace(&corpus, 50);

    let run = |idle_tick_ms: f64| {
        let mut sched =
            Scheduler::new(Policy::Isrtf, Box::new(OraclePredictor));
        let mut engines = sim_engines(2);
        let cfg = ServeConfig {
            workers: 2,
            max_iterations: 5_000_000,
            seed: 87,
            idle_tick_ms,
            ..Default::default()
        };
        run_serving(&cfg, &trace, &mut engines, &mut sched).unwrap()
    };

    let a = run(10.0);
    let b = run(10.0);
    let c = run(1000.0);
    assert_eq!(a.records, b.records, "same-knob reruns must be identical");
    assert_eq!(a.records, c.records,
               "idle_tick_ms must not affect the virtual timeline");
    assert_eq!(a.makespan_ms, c.makespan_ms);
    assert_eq!(a.sched_iterations, c.sched_iterations);
    assert_eq!(a.total_preemptions, c.total_preemptions);
}

// ---------------------------------------------------------------------------
// worker-pool overlap: threaded wall-clock must beat sequential wall-clock
// ---------------------------------------------------------------------------

/// Deterministic-duration engine: every window burns real wall time, so
/// makespans measure whether windows overlap across workers.
struct SleepEngine {
    window_ms: u64,
    window: usize,
    max_batch: usize,
    seqs: BTreeMap<u64, (usize, usize)>, // id -> (target, generated)
}

impl SleepEngine {
    fn new(window_ms: u64) -> SleepEngine {
        SleepEngine { window_ms, window: 50, max_batch: 1,
                      seqs: BTreeMap::new() }
    }
}

impl Engine for SleepEngine {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn admit(&mut self, seq: SeqSpec) -> Result<()> {
        // failover re-admissions resume from the coordinator's copy of
        // the response so far, like the real engines
        self.seqs
            .insert(seq.id, (seq.target_total.max(1), seq.resume.len()));
        Ok(())
    }

    fn run_window(&mut self, seq_ids: &[u64]) -> Result<WindowOutcome> {
        std::thread::sleep(Duration::from_millis(self.window_ms));
        let mut outputs = Vec::new();
        for &id in seq_ids {
            let (target, generated) =
                *self.seqs.get(&id).expect("unknown seq");
            let take = (target - generated).min(self.window);
            let generated = generated + take;
            self.seqs.insert(id, (target, generated));
            outputs.push(SeqWindowOut {
                id,
                new_tokens: vec![1; take],
                done: generated >= target,
            });
        }
        Ok(WindowOutcome {
            outputs,
            service_ms: self.window_ms as f64,
            preempted: Vec::new(),
        })
    }

    fn set_priority_order(&mut self, _order: &[u64]) {}

    fn remove(&mut self, seq_id: u64) {
        self.seqs.remove(&seq_id);
    }

    fn evict(&mut self, _seq_id: u64) {}

    fn generated(&self, seq_id: u64) -> usize {
        self.seqs.get(&seq_id).map(|s| s.1).unwrap_or(0)
    }

    fn is_resident(&self, seq_id: u64) -> bool {
        self.seqs.contains_key(&seq_id)
    }

    fn kv_utilization(&self) -> f64 {
        0.0
    }

    fn describe(&self) -> String {
        format!("SleepEngine[{} ms/window]", self.window_ms)
    }
}

fn burst_trace_total(n: u64, total_len: usize) -> Vec<TraceRequest> {
    (0..n)
        .map(|i| TraceRequest {
            id: i,
            arrival_ms: 0.0,
            prompt: vec![5; 8],
            total_len,
            topic: 0,
            tenant: None,
        })
        .collect()
}

fn burst_trace(n: u64) -> Vec<TraceRequest> {
    burst_trace_total(n, 50) // exactly one 50-token window per job
}

/// Acceptance: a 4-worker wall-clock run over a bursty trace overlaps
/// windows across threads — its makespan lands strictly (and decisively)
/// below the sequential single-thread makespan on the same trace.
#[test]
fn pooled_wall_clock_overlaps_windows_across_workers() {
    const WINDOW_MS: u64 = 40;
    const JOBS: u64 = 16;
    let trace = burst_trace(JOBS);
    let cfg = ServeConfig {
        workers: 4,
        max_batch: 1, // one job per window: 16 windows of 40 ms each
        clock: ClockMode::Wall,
        max_iterations: 100_000,
        ..Default::default()
    };

    // baseline: the pre-pool path — every window executes inline, so the
    // 4 "workers" still run sequentially on this one thread
    let sequential = {
        let mut engines: Vec<Box<dyn Engine>> = (0..4)
            .map(|_| Box::new(SleepEngine::new(WINDOW_MS)) as Box<dyn Engine>)
            .collect();
        let mut sched =
            Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
        run_serving(&cfg, &trace, &mut engines, &mut sched).unwrap()
    };

    // threaded: same trace, same engines, one OS thread per engine
    let pooled = {
        let engines: Vec<Box<dyn Engine>> = (0..4)
            .map(|_| Box::new(SleepEngine::new(WINDOW_MS)) as Box<dyn Engine>)
            .collect();
        let mut sched =
            Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
        CoordinatorBuilder::from_config(cfg.clone())
            .build_pooled(&trace, WorkerPool::new(engines), &mut sched)
            .unwrap()
            .run_to_completion()
            .unwrap()
    };

    assert_eq!(sequential.n(), JOBS as usize);
    assert_eq!(pooled.n(), JOBS as usize);
    let floor = (JOBS * WINDOW_MS) as f64;
    assert!(sequential.makespan_ms >= floor * 0.95,
            "sequential baseline must pay every window inline: {} < {}",
            sequential.makespan_ms, floor);
    assert!(pooled.makespan_ms < sequential.makespan_ms,
            "pooled {} must beat sequential {}",
            pooled.makespan_ms, sequential.makespan_ms);
    // 4 workers overlap ~4x; even with channel + idle-tick overhead the
    // makespan must land well under the sequential floor
    assert!(pooled.makespan_ms < sequential.makespan_ms * 0.6,
            "windows did not overlap: pooled {} vs sequential {}",
            pooled.makespan_ms, sequential.makespan_ms);
}

/// The pooled backend is wall-clock only; virtual mode must refuse it
/// loudly instead of silently degrading determinism.
#[test]
fn pooled_backend_rejects_virtual_clock() {
    let trace = burst_trace(2);
    let mut sched = Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
    let engines: Vec<Box<dyn Engine>> =
        vec![Box::new(SleepEngine::new(1)) as Box<dyn Engine>];
    let err = CoordinatorBuilder::new()
        .clock(ClockMode::Virtual)
        .build_pooled(&trace, WorkerPool::new(engines), &mut sched)
        .err()
        .expect("virtual + pool must be rejected");
    assert!(err.to_string().contains("Wall"), "{err:#}");
}

/// `--dispatch-shards` on a pooled wall-clock backend: per-node planning
/// fans out to the shard pool while apply stays serial — the run completes
/// every job and the coordinator reports the resolved shard count.
#[test]
fn pooled_backend_runs_with_dispatch_shards() {
    const WINDOW_MS: u64 = 5;
    const JOBS: u64 = 12;
    let trace = burst_trace(JOBS);
    let cfg = ServeConfig {
        workers: 4,
        max_batch: 1,
        clock: ClockMode::Wall,
        max_iterations: 100_000,
        dispatch_shards: 2,
        ..Default::default()
    };
    let engines: Vec<Box<dyn Engine>> = (0..4)
        .map(|_| Box::new(SleepEngine::new(WINDOW_MS)) as Box<dyn Engine>)
        .collect();
    let mut sched = Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
    let mut coord = CoordinatorBuilder::from_config(cfg)
        .build_pooled(&trace, WorkerPool::new(engines), &mut sched)
        .unwrap();
    assert_eq!(coord.dispatch_shards(), 2,
               "two planner shards must be live on this 4-worker pool");
    let r = coord.run_to_completion().unwrap();
    assert_eq!(r.n(), JOBS as usize);
    for rec in &r.records {
        assert!(rec.tokens >= 1);
    }
}

// ---------------------------------------------------------------------------
// HTTP frontend end-to-end: POST work in, scrape /metrics, all jobs finish
// ---------------------------------------------------------------------------

/// One raw HTTP/1.1 round trip over a fresh TcpStream.
fn http(addr: SocketAddr, request_line: &str, body: &str) -> String {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    write!(stream,
           "{request_line} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\
            Connection: close\r\n\r\n{body}", body.len())
        .expect("write request");
    let mut out = String::new();
    stream.read_to_string(&mut out).expect("read response");
    out
}

/// Open one keep-alive connection, decode a `stream: true` generate via
/// [`SseDecoder`], then run a `wait: true` generate for the *same*
/// `(total_len, topic)` over the same socket (proving HTTP keep-alive
/// along the way).  Returns the streamed per-window token chunks and the
/// wait reply's `token_ids` — the sim engine is deterministic in
/// `(total_len, topic)`, so callers assert they match byte for byte.
fn stream_then_wait(addr: SocketAddr, total_len: usize, topic: usize)
                    -> (Vec<Vec<i32>>, Vec<i32>) {
    let mut conn = TcpStream::connect(addr).expect("connect");
    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let body = format!(
        r#"{{"stream": true, "total_len": {total_len}, "topic": {topic}}}"#);
    write!(conn,
           "POST /v1/generate HTTP/1.1\r\nHost: test\r\n\
            Content-Length: {}\r\n\r\n{body}", body.len())
        .expect("write stream request");

    // response head: a chunked SSE stream on a keep-alive connection
    let mut head = Vec::new();
    let mut byte = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") {
        conn.read_exact(&mut byte).expect("reading response head");
        head.push(byte[0]);
    }
    let head = String::from_utf8_lossy(&head).to_string();
    assert!(head.starts_with("HTTP/1.1 200"), "{head}");
    assert!(head.contains("text/event-stream"), "{head}");
    assert!(head.contains("Transfer-Encoding: chunked"), "{head}");
    assert!(head.contains("Connection: keep-alive"), "{head}");

    let mut dec = SseDecoder::default();
    let mut chunks: Vec<Vec<i32>> = Vec::new();
    let mut saw_done = false;
    let mut buf = [0u8; 4096];
    while !(saw_done && dec.is_done()) {
        let n = conn.read(&mut buf).expect("reading the event stream");
        assert!(n > 0, "server closed mid-stream");
        for ev in dec.push(&buf[..n]) {
            match ev.name.as_deref() {
                Some("accepted") => {}
                None => {
                    assert!(!saw_done, "token chunk after the done event");
                    let j = Json::parse(&ev.data).expect("chunk json");
                    chunks.push(j.get("tokens")
                        .and_then(Json::as_i32_vec)
                        .expect("chunk tokens"));
                }
                Some("done") => saw_done = true,
                Some(other) => {
                    panic!("unexpected SSE event {other}: {}", ev.data)
                }
            }
        }
    }

    // keep-alive: the very same socket serves a plain wait generate
    let body = format!(
        r#"{{"wait": true, "total_len": {total_len}, "topic": {topic}}}"#);
    write!(conn,
           "POST /v1/generate HTTP/1.1\r\nHost: test\r\n\
            Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
           body.len())
        .expect("write wait request");
    let mut out = String::new();
    conn.read_to_string(&mut out).expect("read wait response");
    assert!(out.starts_with("HTTP/1.1 200"), "{out}");
    let json_body = out.split("\r\n\r\n").nth(1).expect("wait body");
    let ids = Json::parse(json_body)
        .expect("wait json")
        .get("token_ids")
        .and_then(Json::as_i32_vec)
        .expect("token_ids");
    (chunks, ids)
}

#[test]
fn http_frontend_serves_generate_metrics_and_health_end_to_end() {
    // 2 pooled sim workers; 2 seed jobs, the rest arrives over HTTP
    let trace = {
        let corpus = Corpus::synthetic(50, 7);
        let mut gen = RequestGenerator::fabrix(1000.0, 7);
        gen.trace(&corpus, 2)
    };
    let telemetry = TelemetrySink::new(2);
    let recorder = FlightRecorder::default();
    // JCT attribution + FCFS shadow counterfactual, exactly as `elis
    // serve --listen --shadow fcfs` wires them: attribution registers
    // ahead of the completion bridge so breakdowns exist when waiting
    // handlers wake, the shadow scheduler attaches to /metrics
    let explain = AttributionSink::default();
    let shadow = ShadowScheduler::new(ShadowMode::Fcfs, 512);
    telemetry.attach_shadow(shadow.clone());
    let (api_tx, mut bridge) = ApiBridge::channel();
    let mut sched = Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
    let cfg = ServeConfig {
        workers: 2,
        clock: ClockMode::Wall,
        max_iterations: 1_000_000,
        ..Default::default()
    };
    let mut coord = CoordinatorBuilder::from_config(cfg)
        .sink(Box::new(telemetry.clone()))
        .sink(Box::new(recorder.clone()))
        .sink(Box::new(explain.clone()))
        .sink(Box::new(shadow.clone()))
        .sink(Box::new(bridge.completion_sink()))
        .build_pooled(&trace, WorkerPool::new(sim_engines(2)), &mut sched)
        .unwrap();

    let gateway = Gateway {
        telemetry: Some(telemetry.clone()),
        api_tx,
        wait_timeout: Duration::from_secs(25),
        admission: Admission::unlimited(),
        stats: bridge.frontend_stats(),
        trace: Some(recorder.clone()),
        explain: Some(explain.clone()),
        started: Instant::now(),
    };
    let mut server = HttpServer::serve("127.0.0.1:0", gateway, 8).unwrap();
    let addr = server.local_addr();

    // the client lives on its own thread — handlers + serving loop must
    // cooperate for every call to return
    let client = std::thread::spawn(move || {
        let mut responses = Vec::new();
        responses.push(("healthz", http(addr, "GET /healthz", "")));
        for _ in 0..3 {
            responses.push((
                "generate",
                http(addr, "POST /v1/generate",
                     r#"{"total_len": 30, "tenant": "api"}"#),
            ));
        }
        let wait_resp = http(addr, "POST /v1/generate",
                             r#"{"total_len": 20, "tenant": "api", "wait": true}"#);
        // the wait reply names its job; explain it over the same API
        let wait_job = wait_resp
            .split("\r\n\r\n")
            .nth(1)
            .and_then(|b| Json::parse(b).ok())
            .and_then(|j| j.get("job_id").and_then(Json::as_usize))
            .expect("wait reply carries job_id");
        responses.push(("generate-wait", wait_resp));
        responses.push((
            "explain",
            http(addr, &format!("GET /debug/explain?job={wait_job}"), ""),
        ));
        responses.push((
            "explain-missing",
            http(addr, "GET /debug/explain?job=999999", ""),
        ));
        responses.push(("metrics", http(addr, "GET /metrics", "")));
        // the wait generate above finished, so execute spans exist by now
        responses.push(("trace", http(addr, "GET /debug/trace", "")));
        responses.push(("missing", http(addr, "GET /nope", "")));
        responses.push(("bad-json", http(addr, "POST /v1/generate", "{oops")));
        responses
    });

    // drive the serving loop until the client finished and every admitted
    // job (seed + HTTP) completed
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        bridge.pump(&mut coord);
        if coord.is_done() {
            if client.is_finished() {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        } else {
            coord.step().unwrap();
        }
        assert!(Instant::now() < deadline, "serving loop did not converge");
    }
    let responses = client.join().expect("client thread");
    server.shutdown();

    // 2 seed + 3 async + 1 wait jobs, all finished
    assert_eq!(coord.total_jobs(), 6);
    assert_eq!(coord.finished_jobs(), 6);

    for (label, resp) in &responses {
        match *label {
            "healthz" => {
                assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
                assert!(resp.contains("\"status\":\"ok\""), "{resp}");
                assert!(resp.contains("\"workers_dead\":0"), "{resp}");
                assert!(resp.contains("\"uptime_s\""), "{resp}");
            }
            "generate" => {
                assert!(resp.starts_with("HTTP/1.1 202"), "{resp}");
                assert!(resp.contains("\"job_id\""), "{resp}");
                assert!(resp.contains("\"trace_id\""), "{resp}");
                assert!(resp.contains("\"accepted\""), "{resp}");
            }
            "generate-wait" => {
                assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
                assert!(resp.contains("\"finished\""), "{resp}");
                assert!(resp.contains("\"tokens\":20"), "{resp}");
                // the reply carries the attribution object inline
                let body = resp.split("\r\n\r\n").nth(1).expect("wait body");
                let j = Json::parse(body).expect("wait json");
                let b = j.get("breakdown").expect("breakdown in wait reply");
                let total = b.get("total_ms").and_then(Json::as_f64)
                    .expect("total_ms");
                let jct = j.get("jct_ms").and_then(Json::as_f64).unwrap();
                assert!((total - jct).abs() < 1.0,
                        "breakdown {total} != jct {jct}:\n{body}");
            }
            "explain" => {
                assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
                let body = resp.split("\r\n\r\n").nth(1).expect("body");
                let j = Json::parse(body).expect("explain json");
                let b = j.get("breakdown").expect("breakdown");
                let total = b.get("total_ms").and_then(Json::as_f64).unwrap();
                let jct = j.get("jct_ms").and_then(Json::as_f64).unwrap();
                assert!((total - jct).abs() < 1.0,
                        "explain breakdown {total} != jct {jct}:\n{body}");
                assert_eq!(j.get("tenant").and_then(Json::as_str),
                           Some("api"), "{body}");
            }
            "explain-missing" => {
                assert!(resp.starts_with("HTTP/1.1 404"), "{resp}");
            }
            "metrics" => {
                assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
                assert!(resp.contains("text/plain; version=0.0.4"), "{resp}");
                assert!(resp.contains("# TYPE elis_node_windows_total counter"),
                        "{resp}");
                assert!(resp.contains("elis_tenant_jobs_admitted_total\
                                       {tenant=\"api\"}"),
                        "{resp}");
                // shadow counterfactual families render once attached
                assert!(resp.contains("elis_shadow_jct_delta_ms"), "{resp}");
                assert!(resp.contains("elis_shadow_jct_saved_ratio"),
                        "{resp}");
                assert!(resp.contains("elis_shadow_mode{mode=\"fcfs\"}"),
                        "{resp}");
                // fixed-bound histogram exposition rides alongside the
                // P² summaries
                assert!(resp.contains("elis_tenant_jct_ms_hist_bucket{"),
                        "{resp}");
                assert!(resp.contains("le=\"+Inf\""), "{resp}");
            }
            "trace" => {
                assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
                let body = resp.split("\r\n\r\n").nth(1).expect("trace body");
                let j = Json::parse(body).expect("chrome trace JSON");
                let n_exec = j.get("traceEvents").unwrap().as_arr().unwrap()
                    .iter()
                    .filter(|e| e.get("name").and_then(Json::as_str)
                                == Some("execute"))
                    .count();
                assert!(n_exec >= 1, "no execute spans recorded:\n{body}");
            }
            "missing" => assert!(resp.starts_with("HTTP/1.1 404"), "{resp}"),
            "bad-json" => assert!(resp.starts_with("HTTP/1.1 400"), "{resp}"),
            other => panic!("unknown label {other}"),
        }
    }

    // the sink agrees: 4 HTTP jobs under tenant "api"
    telemetry.with_state(|st| {
        assert_eq!(st.tenants["api"].finished, 4);
        let finished: u64 = st.tenants.values().map(|t| t.finished).sum();
        assert_eq!(finished, 6);
    });
}

// ---------------------------------------------------------------------------
// distributed workers: fault injection over real TCP (PR 5 tentpole)
// ---------------------------------------------------------------------------

/// Records `on_worker_lost` events so tests can assert the failover path
/// actually fired (and how many jobs it re-homed).
#[derive(Clone, Default)]
struct LostEvents(Arc<Mutex<Vec<(usize, usize)>>>);

impl EventSink for LostEvents {
    fn on_worker_lost(&mut self, node: usize, rehomed: usize,
                      _now_ms: f64) {
        self.0.lock().unwrap().push((node, rehomed));
    }
}

/// A hand-rolled worker pod speaking the public wire API, with a kill
/// switch: after `kill_after` completed windows it drops the connection
/// *on receipt of the next window* — mid-window from the coordinator's
/// point of view, since the `RunWindow` is in flight and will never be
/// answered.  `kill_after: usize::MAX` behaves like a healthy pod.
fn killable_pod(addr: SocketAddr, kill_after: usize, window_ms: u64) {
    let mut stream = TcpStream::connect(addr).expect("pod connect");
    let hello = wire::Hello {
        version: wire::WIRE_VERSION,
        max_batch: 1,
        trace: false, // a pre-trace pod: the coordinator must not send ids
        describe: format!("KillableSleepEngine[{window_ms} ms]"),
    };
    wire::client_handshake(&mut stream, &hello).expect("pod handshake");
    let mut engine = SleepEngine::new(window_ms);
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut completed = 0usize;
    loop {
        let payload = match wire::read_frame(&mut reader, wire::MAX_FRAME) {
            Ok(Some(p)) => p,
            _ => return, // coordinator hung up
        };
        match wire::decode_cmd(&payload).expect("pod decode") {
            WorkerCmd::SetPreemptionCap(cap) => engine.set_preemption_cap(cap),
            WorkerCmd::Remove(id) => engine.remove(id),
            WorkerCmd::RunWindow {
                admits, priority_order, batch, echo, trace,
            } => {
                assert!(trace.is_none(),
                        "hello declared no trace support; the coordinator \
                         must not ask this pod for trace echoes");
                if completed == kill_after {
                    // the fault: vanish with this window unanswered
                    let _ = stream.shutdown(Shutdown::Both);
                    return;
                }
                let (fresh, outcome) = run_cmd_window(
                    &mut engine, admits, &priority_order, &batch);
                let reply =
                    wire::encode_done(&echo, &fresh, &outcome, &None)
                        .to_string();
                wire::write_frame(&mut stream, reply.as_bytes())
                    .expect("pod reply");
                stream.flush().expect("pod flush");
                completed += 1;
            }
        }
    }
}

/// Fault injection (ISSUE 5 acceptance): one of two TCP workers is
/// killed mid-window.  The coordinator must roll back the partial
/// admits, re-dispatch the dead pod's jobs to the survivor, and finish
/// the whole trace with a report equal (same jobs, same token totals) to
/// a single-worker run that never failed — including jobs that had
/// already generated tokens on the dead pod and resume on the survivor.
#[test]
fn killed_remote_worker_fails_over_and_report_matches_reference() {
    const JOBS: u64 = 10;
    const TOTAL_LEN: usize = 100; // 2 windows per job -> mid-job progress

    // reference: one in-process worker, same engine timing, no faults
    let reference = {
        let trace = burst_trace_total(JOBS, TOTAL_LEN);
        let cfg = ServeConfig {
            workers: 1,
            max_batch: 1,
            clock: ClockMode::Wall,
            max_iterations: 100_000,
            ..Default::default()
        };
        let mut engines: Vec<Box<dyn Engine>> =
            vec![Box::new(SleepEngine::new(5))];
        let mut sched =
            Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
        run_serving(&cfg, &trace, &mut engines, &mut sched).unwrap()
    };

    // distributed: two pods over loopback TCP; pod B dies on its 2nd
    // window — its first job has 50 of 100 tokens at that point, so the
    // survivor must *resume* it mid-response, not restart it
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let healthy = std::thread::spawn(move || {
        killable_pod(addr, usize::MAX, 5)
    });
    let doomed = std::thread::spawn(move || killable_pod(addr, 1, 5));
    let pool =
        RemoteWorkerPool::accept(&listener, 2, Duration::from_secs(10))
            .unwrap();

    let trace = burst_trace_total(JOBS, TOTAL_LEN);
    let lost = LostEvents::default();
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 1,
        clock: ClockMode::Wall,
        max_iterations: 100_000,
        ..Default::default()
    };
    let mut sched = Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
    let explain = AttributionSink::default();
    let mut coord = CoordinatorBuilder::from_config(cfg)
        .sink(Box::new(lost.clone()))
        .sink(Box::new(explain.clone()))
        .build_remote(&trace, pool, &mut sched)
        .unwrap();
    let report = coord.run_to_completion().unwrap();
    drop(coord); // hang up on the survivor so its thread exits

    // the trace completed despite the mid-run kill...
    assert_eq!(report.n(), JOBS as usize);
    let events = lost.0.lock().unwrap().clone();
    assert!(!events.is_empty(), "failover must have fired");
    assert!(events.iter().map(|&(_, n)| n).sum::<usize>() >= 1,
            "the dead pod's jobs must have been re-homed: {events:?}");

    // ...and job-for-job the output equals the fault-free reference
    let tokens = |r: &elis::metrics::ServeReport| -> Vec<(u64, usize)> {
        let mut v: Vec<(u64, usize)> =
            r.records.iter().map(|j| (j.id, j.tokens)).collect();
        v.sort_unstable();
        v
    };
    assert_eq!(tokens(&report), tokens(&reference),
               "failover must not lose or duplicate tokens");
    for rec in &report.records {
        assert_eq!(rec.tokens, TOTAL_LEN, "job {} under-generated", rec.id);
    }

    // attribution holds through the kill: every breakdown still sums to
    // its JCT, and the re-homed jobs carry the stall as failover time
    let mut failover_ms = 0.0;
    for rec in &report.records {
        let ex = explain.explain(rec.id).expect("explain record");
        assert!((ex.breakdown.total_ms() - rec.jct_ms).abs() < 1.0,
                "job {}: breakdown {} != jct {}", rec.id,
                ex.breakdown.total_ms(), rec.jct_ms);
        failover_ms += ex.breakdown.failover_stall_ms;
    }
    assert!(failover_ms >= 0.0);

    healthy.join().unwrap();
    doomed.join().unwrap();
}

/// Losing *every* worker cannot hang the run: once the last pod is gone
/// the coordinator errs out instead of idling forever.
#[test]
fn losing_all_remote_workers_fails_the_run_loudly() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let pod = std::thread::spawn(move || killable_pod(addr, 1, 2));
    let pool =
        RemoteWorkerPool::accept(&listener, 1, Duration::from_secs(10))
            .unwrap();
    let trace = burst_trace_total(4, 50);
    let cfg = ServeConfig {
        workers: 1,
        max_batch: 1,
        clock: ClockMode::Wall,
        max_iterations: 100_000,
        ..Default::default()
    };
    let mut sched = Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
    let err = CoordinatorBuilder::from_config(cfg)
        .build_remote(&trace, pool, &mut sched)
        .unwrap()
        .run_to_completion()
        .expect_err("no surviving worker must fail the run");
    assert!(err.to_string().contains("workers are lost"), "{err:#}");
    pod.join().unwrap();
}

// ---------------------------------------------------------------------------
// distributed workers: multi-process end-to-end over elis binaries
// ---------------------------------------------------------------------------

/// Kills the child on drop so a failed assertion cannot leak processes.
struct ChildGuard(std::process::Child);

impl Drop for ChildGuard {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

/// Read the serve child's stdout until a line containing `prefix`
/// appears; returns the whitespace-delimited token right after it.
fn read_addr_line(lines: &mut impl BufRead, prefix: &str) -> String {
    loop {
        let mut line = String::new();
        let n = lines.read_line(&mut line).expect("reading serve stdout");
        assert!(n > 0, "serve exited before printing '{prefix}'");
        if let Some(rest) = line.split(prefix).nth(1) {
            return rest.split_whitespace().next()
                .unwrap_or_default().to_string();
        }
    }
}

fn node_finished_sum(metrics: &str) -> u64 {
    metrics
        .lines()
        .filter(|l| l.starts_with("elis_node_jobs_finished_total{"))
        .filter_map(|l| l.rsplit(' ').next()?.trim().parse::<u64>().ok())
        .sum()
}

/// The full §5 topology as real processes: `elis serve --worker-listen`
/// in one child, two `elis worker --connect` pods in two more, a bursty
/// trace replayed from disk, one extra job over HTTP, and `/metrics`
/// per-node counters summing to the total.  Everything exits cleanly on
/// `--idle-exit-ms`.
#[test]
fn distributed_multi_process_end_to_end() {
    const TRACE_JOBS: u64 = 8;
    let bin = env!("CARGO_BIN_EXE_elis");
    let dir = std::env::temp_dir()
        .join(format!("elis-dist-e2e-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let trace_path = dir.join("trace.json");
    elis::workload::trace_io::save(&burst_trace(TRACE_JOBS), &trace_path)
        .unwrap();

    let mut serve = std::process::Command::new(bin)
        .args(["serve",
               "--worker-listen", "127.0.0.1:0",
               "--listen", "127.0.0.1:0",
               "--workers", "2",
               "--trace", trace_path.to_str().unwrap(),
               // isrtf consults the predictor, so the run also feeds the
               // elis_predictor_* accuracy metrics asserted below
               "--scheduler", "isrtf",
               "--predictor", "oracle",
               "--batch", "2",
               "--idle-exit-ms", "3000"])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::inherit())
        .spawn()
        .expect("spawning elis serve");
    let stdout = serve.stdout.take().unwrap();
    let serve = ChildGuard(serve);
    let mut lines = BufReader::new(stdout);

    // serve prints the bound registration address, then blocks until
    // both pods register
    let worker_addr = read_addr_line(&mut lines, "workers: listening on ");
    let pods: Vec<ChildGuard> = (0..2)
        .map(|_| {
            ChildGuard(
                std::process::Command::new(bin)
                    .args(["worker", "--connect", &worker_addr,
                           "--engine", "sim", "--batch", "2"])
                    .stdout(std::process::Stdio::null())
                    .stderr(std::process::Stdio::inherit())
                    .spawn()
                    .expect("spawning elis worker"),
            )
        })
        .collect();

    // registration done -> the HTTP frontend comes up
    let http_addr: SocketAddr =
        read_addr_line(&mut lines, "listening on http://")
            .parse()
            .expect("parsing the HTTP address");

    // one extra job through the HTTP frontend, held to completion — the
    // generate path crosses process AND machine boundaries here
    let resp = http(http_addr, "POST /v1/generate",
                    r#"{"total_len": 30, "tenant": "api", "wait": true}"#);
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    assert!(resp.contains("\"finished\""), "{resp}");

    // streaming crosses the same process boundaries: SSE chunks computed
    // on a worker pod, then a wait generate over the same keep-alive
    // socket must assemble to the identical token sequence
    let (chunks, ids) = stream_then_wait(http_addr, 150, 3);
    assert!(chunks.len() >= 2,
            "want >=2 streamed chunks before done, got {}", chunks.len());
    assert_eq!(chunks.concat(), ids,
               "distributed streamed tokens must match the wait reply");

    // scrape /metrics until the per-node finished counters account for
    // every job (trace + HTTP), i.e. the pods really did the work
    let expect = TRACE_JOBS + 3;
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let metrics = http(http_addr, "GET /metrics", "");
        if node_finished_sum(&metrics) == expect {
            break;
        }
        assert!(Instant::now() < deadline,
                "per-node counters never reached {expect}:\n{metrics}");
        std::thread::sleep(Duration::from_millis(25));
    }

    // every job finished under isrtf+oracle, so predictor accuracy and
    // scheduler-overhead telemetry must be live on /metrics
    let metrics = http(http_addr, "GET /metrics", "");
    let abs_count = metrics
        .lines()
        .find(|l| l.starts_with("elis_predictor_abs_err_tokens_count"))
        .and_then(|l| l.rsplit(' ').next()?.trim().parse::<f64>().ok())
        .unwrap_or(-1.0);
    assert_eq!(abs_count, expect as f64,
               "every finish must fold into the predictor sketch:\n{metrics}");
    assert!(metrics.contains("elis_predictor_kendall_tau"), "{metrics}");
    assert!(metrics.contains("elis_sched_overhead_ms_total"), "{metrics}");
    assert!(metrics.contains("elis_node_queue_depth{node=\"0\"}"),
            "{metrics}");

    // structured health while both pods are alive
    let hz = http(http_addr, "GET /healthz", "");
    assert!(hz.starts_with("HTTP/1.1 200"), "{hz}");
    assert!(hz.contains("\"status\":\"ok\""), "{hz}");
    assert!(hz.contains("\"workers_dead\":0"), "{hz}");

    // the acceptance bar: /debug/trace is valid Chrome trace JSON and its
    // pod-side execute spans carry the *worker children's* pids — the
    // timeline demonstrably crosses the process boundary
    let resp = http(http_addr, "GET /debug/trace", "");
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    let body = resp.split("\r\n\r\n").nth(1).expect("trace body");
    let j = Json::parse(body).expect("chrome trace JSON");
    let pod_pids: Vec<f64> =
        pods.iter().map(|p| p.0.id() as f64).collect();
    let seen: Vec<f64> = j.get("traceEvents").unwrap().as_arr().unwrap()
        .iter()
        .filter(|e| e.get("name").and_then(Json::as_str) == Some("pod exec"))
        .filter_map(|e| e.get("args")?.get("pod_pid")?.as_f64())
        .collect();
    assert!(!seen.is_empty(), "no pod-side spans in the trace:\n{body}");
    let own = std::process::id() as f64;
    assert!(seen.iter().all(|p| pod_pids.contains(p) && *p != own),
            "pod spans {seen:?} must carry worker pids {pod_pids:?}, \
             never the test's own {own}");

    // idle-exit drains everything: serve exits 0, pods see the hangup
    // and exit 0
    let mut serve = serve;
    let status = serve.0.wait().expect("waiting for serve");
    assert!(status.success(), "serve exited with {status:?}");
    let mut rest = String::new();
    lines.read_to_string(&mut rest).unwrap();
    for mut pod in pods {
        let status = pod.0.wait().expect("waiting for a worker pod");
        assert!(status.success(), "worker exited with {status:?}\n{rest}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// wait-generate racing shutdown (ISSUE 5 test-gap satellite)
// ---------------------------------------------------------------------------

/// A `wait: true` generate that lands exactly as the serving loop exits
/// (`--idle-exit-ms` fired) must get a *terminal* response promptly —
/// the shutdown drain answers 503 — never a connection held until the
/// wait timeout.
#[test]
fn wait_generate_racing_shutdown_gets_terminal_response() {
    let (api_tx, mut bridge) = ApiBridge::channel();
    let gateway = Gateway {
        telemetry: None,
        api_tx,
        // deliberately huge: if the drain failed, the test would hang
        // far past its own deadline instead of passing by accident
        wait_timeout: Duration::from_secs(60),
        admission: Admission::unlimited(),
        stats: bridge.frontend_stats(),
        trace: None,
        explain: None,
        started: Instant::now(),
    };
    let mut server = HttpServer::serve("127.0.0.1:0", gateway, 2).unwrap();
    let addr = server.local_addr();
    let t0 = Instant::now();

    // the serving loop has already decided to exit; this request races it
    let client = std::thread::spawn(move || {
        http(addr, "POST /v1/generate",
             r#"{"total_len": 10, "wait": true}"#)
    });

    // serve_http's exit sequence: drain (answers everything queued or
    // waiting with 503), close the channel, shut the server down.  Loop
    // the drain until the racing request has surfaced.
    let deadline = Instant::now() + Duration::from_secs(10);
    while bridge.drain_shutdown() == 0 {
        assert!(Instant::now() < deadline,
                "the racing request never reached the bridge");
        std::thread::sleep(Duration::from_millis(2));
    }
    drop(bridge);
    server.shutdown();

    let resp = client.join().expect("client thread");
    assert!(resp.starts_with("HTTP/1.1 503"), "{resp}");
    assert!(resp.contains("shutting down"), "{resp}");
    assert!(t0.elapsed() < Duration::from_secs(30),
            "the held connection must resolve well before wait_timeout");
}

/// Graceful shutdown joins every server thread even with no traffic.
#[test]
fn http_server_shutdown_is_idempotent_and_quiet() {
    let (api_tx, _bridge) = ApiBridge::channel();
    let gateway = Gateway {
        telemetry: None,
        api_tx,
        wait_timeout: Duration::from_secs(1),
        admission: Admission::unlimited(),
        stats: _bridge.frontend_stats(),
        trace: None,
        explain: None,
        started: Instant::now(),
    };
    let mut server = HttpServer::serve("127.0.0.1:0", gateway, 2).unwrap();
    let addr = server.local_addr();
    // no telemetry -> /metrics is 503, health still fine
    assert!(http(addr, "GET /metrics", "").starts_with("HTTP/1.1 503"));
    assert!(http(addr, "GET /healthz", "").starts_with("HTTP/1.1 200"));
    server.shutdown();
    server.shutdown(); // second call is a no-op
}

// ---------------------------------------------------------------------------
// token streaming (ISSUE 6): SSE chunks == wait reply, byte for byte
// ---------------------------------------------------------------------------

/// In-process streaming end-to-end: a `stream: true` generate over the
/// pooled sim workers must deliver at least two per-window token chunks
/// before the done event, and the assembled stream must equal the
/// `token_ids` of an identical `wait: true` generate issued over the
/// *same* keep-alive connection.
#[test]
fn streaming_generate_matches_wait_reply_over_one_keep_alive_conn() {
    let (api_tx, mut bridge) = ApiBridge::channel();
    let stats = bridge.frontend_stats();
    let mut sched = Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
    let cfg = ServeConfig {
        workers: 2,
        clock: ClockMode::Wall,
        max_iterations: 1_000_000,
        ..Default::default()
    };
    let trace: Vec<TraceRequest> = Vec::new();
    let mut coord = CoordinatorBuilder::from_config(cfg)
        .sink(Box::new(bridge.completion_sink()))
        .build_pooled(&trace, WorkerPool::new(sim_engines(2)), &mut sched)
        .unwrap();

    let gateway = Gateway {
        telemetry: None,
        api_tx,
        wait_timeout: Duration::from_secs(25),
        admission: Admission::unlimited(),
        stats: stats.clone(),
        trace: None,
        explain: None,
        started: Instant::now(),
    };
    let mut server = HttpServer::serve("127.0.0.1:0", gateway, 4).unwrap();
    let addr = server.local_addr();

    // total_len 150 with window size 50 -> three streamed chunks
    let client = std::thread::spawn(move || stream_then_wait(addr, 150, 3));

    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        bridge.pump(&mut coord);
        if coord.is_done() {
            if client.is_finished() {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        } else {
            coord.step().unwrap();
        }
        assert!(Instant::now() < deadline, "serving loop did not converge");
    }
    let (chunks, ids) = client.join().expect("client thread");
    server.shutdown();

    assert!(chunks.len() >= 2,
            "want >=2 streamed chunks before done, got {}", chunks.len());
    assert!(chunks.iter().all(|c| !c.is_empty()));
    assert_eq!(chunks.concat(), ids,
               "streamed tokens must equal the wait reply byte-for-byte");
    assert_eq!(coord.finished_jobs(), 2);
    assert_eq!(stats.streams(), 0, "the streams gauge must return to 0");
}

// ---------------------------------------------------------------------------
// front-door overload: bounded queue sheds 429, drain answers held streams
// ---------------------------------------------------------------------------

/// With `queue_cap: 2` and no serving loop pumping yet, two held
/// wait-generates fill the pending-admission queue and the third is shed
/// with `429` + `Retry-After` immediately.  Once the loop starts, both
/// admitted requests still finish (the coordinator keeps draining), and
/// a stream held open across shutdown is answered with a terminal SSE
/// error event and a clean chunked terminator, never a silent hang.
#[test]
fn overload_sheds_429_and_drain_answers_held_streams() {
    let (api_tx, mut bridge) = ApiBridge::channel();
    let stats = bridge.frontend_stats();
    let gateway = Gateway {
        telemetry: None,
        api_tx,
        wait_timeout: Duration::from_secs(60),
        admission: Admission::new(AdmissionConfig {
            queue_cap: 2,
            ..Default::default()
        }),
        stats: stats.clone(),
        trace: None,
        explain: None,
        started: Instant::now(),
    };
    let mut server = HttpServer::serve("127.0.0.1:0", gateway, 8).unwrap();
    let addr = server.local_addr();

    let held: Vec<_> = (0..2)
        .map(|_| {
            std::thread::spawn(move || {
                http(addr, "POST /v1/generate",
                     r#"{"total_len": 30, "wait": true}"#)
            })
        })
        .collect();
    let deadline = Instant::now() + Duration::from_secs(10);
    while stats.depth() < 2 {
        assert!(Instant::now() < deadline, "held requests never queued");
        std::thread::sleep(Duration::from_millis(2));
    }

    let resp = http(addr, "POST /v1/generate", r#"{"total_len": 30}"#);
    assert!(resp.starts_with("HTTP/1.1 429"), "{resp}");
    assert!(resp.contains("Retry-After:"), "{resp}");
    assert_eq!(stats.rejected(), 1);

    // the serving loop comes up late; the held pair must still finish
    let mut sched = Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
    let cfg = ServeConfig {
        workers: 1,
        clock: ClockMode::Wall,
        max_iterations: 1_000_000,
        ..Default::default()
    };
    let trace: Vec<TraceRequest> = Vec::new();
    let mut coord = CoordinatorBuilder::from_config(cfg)
        .sink(Box::new(bridge.completion_sink()))
        .build_pooled(&trace, WorkerPool::new(sim_engines(1)), &mut sched)
        .unwrap();
    let deadline = Instant::now() + Duration::from_secs(30);
    while !held.iter().all(|h| h.is_finished()) {
        bridge.pump(&mut coord);
        if !coord.is_done() {
            coord.step().unwrap();
        } else {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(Instant::now() < deadline, "held requests never finished");
    }
    for h in held {
        let resp = h.join().expect("held client");
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        assert!(resp.contains("\"finished\""), "{resp}");
    }
    assert_eq!(stats.depth(), 0);

    // a stream admitted but never finished (the loop stops stepping)
    // must be answered by the shutdown drain
    let streamer = std::thread::spawn(move || {
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let body = r#"{"stream": true, "total_len": 100000}"#;
        write!(conn,
               "POST /v1/generate HTTP/1.1\r\nHost: test\r\n\
                Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
               body.len())
            .unwrap();
        let mut raw = Vec::new();
        conn.read_to_end(&mut raw).unwrap();
        String::from_utf8_lossy(&raw).to_string()
    });
    let deadline = Instant::now() + Duration::from_secs(10);
    while stats.streams() == 0 {
        bridge.pump(&mut coord);
        assert!(Instant::now() < deadline, "the stream never registered");
        std::thread::sleep(Duration::from_millis(2));
    }
    let drained = bridge.drain_shutdown();
    assert!(drained >= 1, "the held stream must be answered by the drain");
    let raw = streamer.join().expect("stream client");
    assert!(raw.contains("event: accepted"), "{raw}");
    assert!(raw.contains("event: error"), "{raw}");
    assert!(raw.contains("shutting down"), "{raw}");
    assert!(raw.ends_with("0\r\n\r\n"), "{raw}");
    drop(bridge);
    server.shutdown();
}
