//! Integration tests for the cluster runtime (PR 3): threaded worker
//! pool, std-only HTTP frontend, and the virtual-clock determinism
//! guarantee the pool refactor must preserve.  No artifacts required.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use anyhow::Result;

use elis::cluster::{ApiBridge, Gateway, HttpServer, WorkerPool};
use elis::coordinator::{
    run_serving, ClockMode, CoordinatorBuilder, Policy, Scheduler,
    ServeConfig,
};
use elis::engine::profiles::ModelProfile;
use elis::engine::sim_engine::SimEngine;
use elis::engine::{Engine, SeqSpec, SeqWindowOut, WindowOutcome};
use elis::predictor::oracle::OraclePredictor;
use elis::runtime::manifest::ServedModelMeta;
use elis::telemetry::TelemetrySink;
use elis::workload::{Corpus, RequestGenerator, TraceRequest};

fn profile() -> ModelProfile {
    ModelProfile::from_meta(&ServedModelMeta {
        name: "test".into(),
        abbrev: "test".into(),
        params_b: 7.0,
        avg_latency_ms: 2000.0,
        kv_bytes_per_token: 1 << 20,
        preempt_batch: 0,
        mem_limit_frac: 0.9,
    })
}

fn sim_engines(n: usize) -> Vec<Box<dyn Engine>> {
    (0..n)
        .map(|_| {
            Box::new(SimEngine::new(profile(), 50, 4, 8 << 30))
                as Box<dyn Engine>
        })
        .collect()
}

// ---------------------------------------------------------------------------
// virtual-clock determinism: the pool refactor must not perturb simulation
// ---------------------------------------------------------------------------

/// The threaded-runtime refactor (engine backend enum, Result-returning
/// poll_completions, idle-tick config) must leave virtual-clock reports
/// bit-identical: same trace + seed twice, and with wildly different
/// `idle_tick_ms` (which only wall mode reads).
#[test]
fn virtual_reports_are_bit_identical_across_pool_refactor_knobs() {
    let corpus = Corpus::synthetic(300, 87);
    let mut gen = RequestGenerator::fabrix(3.0, 87);
    let trace = gen.trace(&corpus, 50);

    let run = |idle_tick_ms: f64| {
        let mut sched =
            Scheduler::new(Policy::Isrtf, Box::new(OraclePredictor));
        let mut engines = sim_engines(2);
        let cfg = ServeConfig {
            workers: 2,
            max_iterations: 5_000_000,
            seed: 87,
            idle_tick_ms,
            ..Default::default()
        };
        run_serving(&cfg, &trace, &mut engines, &mut sched).unwrap()
    };

    let a = run(10.0);
    let b = run(10.0);
    let c = run(1000.0);
    assert_eq!(a.records, b.records, "same-knob reruns must be identical");
    assert_eq!(a.records, c.records,
               "idle_tick_ms must not affect the virtual timeline");
    assert_eq!(a.makespan_ms, c.makespan_ms);
    assert_eq!(a.sched_iterations, c.sched_iterations);
    assert_eq!(a.total_preemptions, c.total_preemptions);
}

// ---------------------------------------------------------------------------
// worker-pool overlap: threaded wall-clock must beat sequential wall-clock
// ---------------------------------------------------------------------------

/// Deterministic-duration engine: every window burns real wall time, so
/// makespans measure whether windows overlap across workers.
struct SleepEngine {
    window_ms: u64,
    window: usize,
    max_batch: usize,
    seqs: BTreeMap<u64, (usize, usize)>, // id -> (target, generated)
}

impl SleepEngine {
    fn new(window_ms: u64) -> SleepEngine {
        SleepEngine { window_ms, window: 50, max_batch: 1,
                      seqs: BTreeMap::new() }
    }
}

impl Engine for SleepEngine {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn admit(&mut self, seq: SeqSpec) -> Result<()> {
        self.seqs.insert(seq.id, (seq.target_total.max(1), 0));
        Ok(())
    }

    fn run_window(&mut self, seq_ids: &[u64]) -> Result<WindowOutcome> {
        std::thread::sleep(Duration::from_millis(self.window_ms));
        let mut outputs = Vec::new();
        for &id in seq_ids {
            let (target, generated) =
                *self.seqs.get(&id).expect("unknown seq");
            let take = (target - generated).min(self.window);
            let generated = generated + take;
            self.seqs.insert(id, (target, generated));
            outputs.push(SeqWindowOut {
                id,
                new_tokens: vec![1; take],
                done: generated >= target,
            });
        }
        Ok(WindowOutcome {
            outputs,
            service_ms: self.window_ms as f64,
            preempted: Vec::new(),
        })
    }

    fn set_priority_order(&mut self, _order: &[u64]) {}

    fn remove(&mut self, seq_id: u64) {
        self.seqs.remove(&seq_id);
    }

    fn evict(&mut self, _seq_id: u64) {}

    fn generated(&self, seq_id: u64) -> usize {
        self.seqs.get(&seq_id).map(|s| s.1).unwrap_or(0)
    }

    fn is_resident(&self, seq_id: u64) -> bool {
        self.seqs.contains_key(&seq_id)
    }

    fn kv_utilization(&self) -> f64 {
        0.0
    }

    fn describe(&self) -> String {
        format!("SleepEngine[{} ms/window]", self.window_ms)
    }
}

fn burst_trace(n: u64) -> Vec<TraceRequest> {
    (0..n)
        .map(|i| TraceRequest {
            id: i,
            arrival_ms: 0.0,
            prompt: vec![5; 8],
            total_len: 50, // exactly one 50-token window per job
            topic: 0,
            tenant: None,
        })
        .collect()
}

/// Acceptance: a 4-worker wall-clock run over a bursty trace overlaps
/// windows across threads — its makespan lands strictly (and decisively)
/// below the sequential single-thread makespan on the same trace.
#[test]
fn pooled_wall_clock_overlaps_windows_across_workers() {
    const WINDOW_MS: u64 = 40;
    const JOBS: u64 = 16;
    let trace = burst_trace(JOBS);
    let cfg = ServeConfig {
        workers: 4,
        max_batch: 1, // one job per window: 16 windows of 40 ms each
        clock: ClockMode::Wall,
        max_iterations: 100_000,
        ..Default::default()
    };

    // baseline: the pre-pool path — every window executes inline, so the
    // 4 "workers" still run sequentially on this one thread
    let sequential = {
        let mut engines: Vec<Box<dyn Engine>> = (0..4)
            .map(|_| Box::new(SleepEngine::new(WINDOW_MS)) as Box<dyn Engine>)
            .collect();
        let mut sched =
            Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
        run_serving(&cfg, &trace, &mut engines, &mut sched).unwrap()
    };

    // threaded: same trace, same engines, one OS thread per engine
    let pooled = {
        let engines: Vec<Box<dyn Engine>> = (0..4)
            .map(|_| Box::new(SleepEngine::new(WINDOW_MS)) as Box<dyn Engine>)
            .collect();
        let mut sched =
            Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
        CoordinatorBuilder::from_config(cfg.clone())
            .build_pooled(&trace, WorkerPool::new(engines), &mut sched)
            .unwrap()
            .run_to_completion()
            .unwrap()
    };

    assert_eq!(sequential.n(), JOBS as usize);
    assert_eq!(pooled.n(), JOBS as usize);
    let floor = (JOBS * WINDOW_MS) as f64;
    assert!(sequential.makespan_ms >= floor * 0.95,
            "sequential baseline must pay every window inline: {} < {}",
            sequential.makespan_ms, floor);
    assert!(pooled.makespan_ms < sequential.makespan_ms,
            "pooled {} must beat sequential {}",
            pooled.makespan_ms, sequential.makespan_ms);
    // 4 workers overlap ~4x; even with channel + idle-tick overhead the
    // makespan must land well under the sequential floor
    assert!(pooled.makespan_ms < sequential.makespan_ms * 0.6,
            "windows did not overlap: pooled {} vs sequential {}",
            pooled.makespan_ms, sequential.makespan_ms);
}

/// The pooled backend is wall-clock only; virtual mode must refuse it
/// loudly instead of silently degrading determinism.
#[test]
fn pooled_backend_rejects_virtual_clock() {
    let trace = burst_trace(2);
    let mut sched = Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
    let engines: Vec<Box<dyn Engine>> =
        vec![Box::new(SleepEngine::new(1)) as Box<dyn Engine>];
    let err = CoordinatorBuilder::new()
        .clock(ClockMode::Virtual)
        .build_pooled(&trace, WorkerPool::new(engines), &mut sched)
        .err()
        .expect("virtual + pool must be rejected");
    assert!(err.to_string().contains("Wall"), "{err:#}");
}

// ---------------------------------------------------------------------------
// HTTP frontend end-to-end: POST work in, scrape /metrics, all jobs finish
// ---------------------------------------------------------------------------

/// One raw HTTP/1.1 round trip over a fresh TcpStream.
fn http(addr: SocketAddr, request_line: &str, body: &str) -> String {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    write!(stream,
           "{request_line} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\
            Connection: close\r\n\r\n{body}", body.len())
        .expect("write request");
    let mut out = String::new();
    stream.read_to_string(&mut out).expect("read response");
    out
}

#[test]
fn http_frontend_serves_generate_metrics_and_health_end_to_end() {
    // 2 pooled sim workers; 2 seed jobs, the rest arrives over HTTP
    let trace = {
        let corpus = Corpus::synthetic(50, 7);
        let mut gen = RequestGenerator::fabrix(1000.0, 7);
        gen.trace(&corpus, 2)
    };
    let telemetry = TelemetrySink::new(2);
    let (api_tx, mut bridge) = ApiBridge::channel();
    let mut sched = Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
    let cfg = ServeConfig {
        workers: 2,
        clock: ClockMode::Wall,
        max_iterations: 1_000_000,
        ..Default::default()
    };
    let mut coord = CoordinatorBuilder::from_config(cfg)
        .sink(Box::new(telemetry.clone()))
        .sink(Box::new(bridge.completion_sink()))
        .build_pooled(&trace, WorkerPool::new(sim_engines(2)), &mut sched)
        .unwrap();

    let gateway = Gateway {
        telemetry: Some(telemetry.clone()),
        api_tx,
        wait_timeout: Duration::from_secs(25),
    };
    let mut server = HttpServer::serve("127.0.0.1:0", gateway, 3).unwrap();
    let addr = server.local_addr();

    // the client lives on its own thread — handlers + serving loop must
    // cooperate for every call to return
    let client = std::thread::spawn(move || {
        let mut responses = Vec::new();
        responses.push(("healthz", http(addr, "GET /healthz", "")));
        for _ in 0..3 {
            responses.push((
                "generate",
                http(addr, "POST /v1/generate",
                     r#"{"total_len": 30, "tenant": "api"}"#),
            ));
        }
        responses.push((
            "generate-wait",
            http(addr, "POST /v1/generate",
                 r#"{"total_len": 20, "tenant": "api", "wait": true}"#),
        ));
        responses.push(("metrics", http(addr, "GET /metrics", "")));
        responses.push(("missing", http(addr, "GET /nope", "")));
        responses.push(("bad-json", http(addr, "POST /v1/generate", "{oops")));
        responses
    });

    // drive the serving loop until the client finished and every admitted
    // job (seed + HTTP) completed
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        bridge.pump(&mut coord);
        if coord.is_done() {
            if client.is_finished() {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        } else {
            coord.step().unwrap();
        }
        assert!(Instant::now() < deadline, "serving loop did not converge");
    }
    let responses = client.join().expect("client thread");
    server.shutdown();

    // 2 seed + 3 async + 1 wait jobs, all finished
    assert_eq!(coord.total_jobs(), 6);
    assert_eq!(coord.finished_jobs(), 6);

    for (label, resp) in &responses {
        match *label {
            "healthz" => {
                assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
                assert!(resp.contains("ok"), "{resp}");
            }
            "generate" => {
                assert!(resp.starts_with("HTTP/1.1 202"), "{resp}");
                assert!(resp.contains("\"job_id\""), "{resp}");
                assert!(resp.contains("\"accepted\""), "{resp}");
            }
            "generate-wait" => {
                assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
                assert!(resp.contains("\"finished\""), "{resp}");
                assert!(resp.contains("\"tokens\":20"), "{resp}");
            }
            "metrics" => {
                assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
                assert!(resp.contains("text/plain; version=0.0.4"), "{resp}");
                assert!(resp.contains("# TYPE elis_node_windows_total counter"),
                        "{resp}");
                assert!(resp.contains("elis_tenant_jobs_admitted_total\
                                       {tenant=\"api\"}"),
                        "{resp}");
            }
            "missing" => assert!(resp.starts_with("HTTP/1.1 404"), "{resp}"),
            "bad-json" => assert!(resp.starts_with("HTTP/1.1 400"), "{resp}"),
            other => panic!("unknown label {other}"),
        }
    }

    // the sink agrees: 4 HTTP jobs under tenant "api"
    telemetry.with_state(|st| {
        assert_eq!(st.tenants["api"].finished, 4);
        let finished: u64 = st.tenants.values().map(|t| t.finished).sum();
        assert_eq!(finished, 6);
    });
}

/// Graceful shutdown joins every server thread even with no traffic.
#[test]
fn http_server_shutdown_is_idempotent_and_quiet() {
    let (api_tx, _bridge) = ApiBridge::channel();
    let gateway = Gateway {
        telemetry: None,
        api_tx,
        wait_timeout: Duration::from_secs(1),
    };
    let mut server = HttpServer::serve("127.0.0.1:0", gateway, 2).unwrap();
    let addr = server.local_addr();
    // no telemetry -> /metrics is 503, health still fine
    assert!(http(addr, "GET /metrics", "").starts_with("HTTP/1.1 503"));
    assert!(http(addr, "GET /healthz", "").starts_with("HTTP/1.1 200"));
    server.shutdown();
    server.shutdown(); // second call is a no-op
}
