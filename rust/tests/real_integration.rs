//! Integration tests over the REAL artifact path: HLO text -> PJRT ->
//! TinyGPT + predictor.  Skipped (cleanly) when `make artifacts` has not
//! run yet.

use std::path::PathBuf;
use std::sync::Arc;

use elis::coordinator::{run_serving, ClockMode, Policy, Scheduler, ServeConfig};
use elis::engine::pjrt_engine::PjrtEngine;
use elis::engine::{Engine, SeqSpec};
use elis::predictor::eval::StepDataset;
use elis::predictor::hlo::HloPredictor;
use elis::predictor::LengthPredictor;
use elis::runtime::{default_artifacts_dir, Manifest, Runtime, WeightStore};
use elis::util::json::Json;
use elis::workload::{Corpus, RequestGenerator};

fn artifacts() -> Option<PathBuf> {
    let dir = default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir:?} (run `make artifacts`)");
        None
    }
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(d) => d,
            None => return,
        }
    };
}

fn load_engine(dir: &PathBuf) -> (Manifest, PjrtEngine) {
    let manifest = Manifest::load(dir).unwrap();
    let store = WeightStore::load(&manifest).unwrap();
    let rt = Runtime::cpu().unwrap();
    let engine = PjrtEngine::load(rt, &manifest, &store, 1 << 20).unwrap();
    (manifest, engine)
}

#[test]
fn golden_tokens_match_python_exactly() {
    let dir = require_artifacts!();
    let golden_path = dir.join("golden.json");
    if !golden_path.exists() {
        eprintln!("SKIP: no golden.json");
        return;
    }
    let g = Json::parse(&std::fs::read_to_string(golden_path).unwrap()).unwrap();
    let prompt = g.get("prompt").and_then(Json::as_i32_vec).unwrap();
    let expect = g.get("tokens").and_then(Json::as_i32_vec).unwrap();

    let (_, mut engine) = load_engine(&dir);
    engine
        .admit(SeqSpec { id: 1, prompt, target_total: expect.len() , topic: 0,
                         resume: Vec::new() })
        .unwrap();
    let mut got: Vec<i32> = Vec::new();
    while got.len() < expect.len() {
        let w = engine.run_window(&[1]).unwrap();
        let out = &w.outputs[0];
        got.extend_from_slice(&out.new_tokens);
        if out.done {
            break;
        }
    }
    assert_eq!(got.len(), expect.len());
    assert_eq!(got, expect,
               "rust HLO path must reproduce the jax token stream exactly");
}

#[test]
fn decode_is_deterministic_across_batch_sizes() {
    let dir = require_artifacts!();
    let (_, mut e1) = load_engine(&dir);
    let (_, mut e2) = load_engine(&dir);
    let prompt = vec![1, 50, 900, 333, 1200];

    e1.admit(SeqSpec { id: 1, prompt: prompt.clone(), target_total: 60 , topic: 0,
                       resume: Vec::new() }).unwrap();
    let mut a = Vec::new();
    loop {
        let w = e1.run_window(&[1]).unwrap();
        a.extend_from_slice(&w.outputs[0].new_tokens);
        if w.outputs[0].done {
            break;
        }
    }

    // same job batched with a second sequence: identical token stream
    e2.admit(SeqSpec { id: 1, prompt: prompt.clone(), target_total: 60 , topic: 0,
                       resume: Vec::new() }).unwrap();
    e2.admit(SeqSpec { id: 2, prompt: vec![1, 7, 8, 9], target_total: 60 , topic: 0,
                       resume: Vec::new() }).unwrap();
    let mut b = Vec::new();
    loop {
        let w = e2.run_window(&[1, 2]).unwrap();
        let out = w.outputs.iter().find(|o| o.id == 1).unwrap();
        b.extend_from_slice(&out.new_tokens);
        if out.done {
            break;
        }
    }
    assert_eq!(a, b, "batch composition must not change a sequence's tokens");
}

#[test]
fn hlo_predictor_beats_mean_baseline_on_test_set() {
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let store = WeightStore::load(&manifest).unwrap();
    let rt = Runtime::cpu().unwrap();
    let mut p = HloPredictor::load(rt, &manifest, &store, None).unwrap();
    let ds = StepDataset::load(&dir).unwrap();
    let m = ds.evaluate(&mut p, 400);
    // mean-baseline has R^2 = 0 by definition; the trained artifact must be
    // meaningfully better, and in the ballpark of the build-time metrics
    assert!(m.r2 > 0.2, "R^2 {}", m.r2);
    assert!(m.mae < 100.0, "MAE {}", m.mae);
}

#[test]
fn predictor_init_weights_are_worse_than_trained() {
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let store = WeightStore::load(&manifest).unwrap();
    let rt = Runtime::cpu().unwrap();
    let mut trained = HloPredictor::load(rt.clone(), &manifest, &store, None).unwrap();
    let mut init =
        HloPredictor::load(rt, &manifest, &store, Some("predictor_init")).unwrap();
    let ds = StepDataset::load(&dir).unwrap();
    let mt = ds.evaluate(&mut trained, 300);
    let mi = ds.evaluate(&mut init, 300);
    assert!(mt.mae < mi.mae, "trained {} vs init {}", mt.mae, mi.mae);
    assert!(mt.r2 > mi.r2);
}

#[test]
fn iterative_prediction_remaining_shrinks_for_real_predictor() {
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let store = WeightStore::load(&manifest).unwrap();
    let rt = Runtime::cpu().unwrap();
    let mut p = HloPredictor::load(rt, &manifest, &store, None).unwrap();
    let corpus = Corpus::load(&dir).unwrap();
    // average predicted remaining must fall as generated grows
    let sample: Vec<_> = corpus.entries.iter().take(32).collect();
    let mut means = Vec::new();
    for gen in [0usize, 100, 200] {
        let queries: Vec<elis::predictor::PredictQuery<'_>> = sample
            .iter()
            .enumerate()
            .map(|(i, e)| elis::predictor::PredictQuery {
                job_id: i as u64,
                prompt: &e.tokens,
                gen_suffix: &[],
                generated: gen,
                true_total: e.total_len,
            })
            .collect();
        let preds = p.predict(&queries);
        means.push(preds.iter().sum::<f64>() / preds.len() as f64);
    }
    assert!(means[1] < means[0], "{means:?}");
    assert!(means[2] < means[1], "{means:?}");
}

#[test]
fn real_serving_small_trace_completes() {
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let store = WeightStore::load(&manifest).unwrap();
    let corpus = Corpus::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();

    // pick short jobs to bound test runtime
    let mut short = corpus.clone();
    short.entries.retain(|e| e.total_len <= 80);
    short.entries.truncate(30);
    let mut gen = RequestGenerator::fabrix(5.0, 3);
    let trace = gen.trace(&short, 4);

    let mut engines: Vec<Box<dyn Engine>> = vec![Box::new(
        PjrtEngine::load(rt.clone(), &manifest, &store, 1 << 20).unwrap(),
    )];
    let mut sched = Scheduler::new(
        Policy::Isrtf,
        Box::new(HloPredictor::load(rt, &manifest, &store, None).unwrap()),
    );
    let cfg = ServeConfig {
        clock: ClockMode::Wall,
        max_iterations: 10_000,
        ..Default::default()
    };
    let r = run_serving(&cfg, &trace, &mut engines, &mut sched).unwrap();
    assert_eq!(r.n(), 4);
    for rec in &r.records {
        assert!(rec.tokens >= 1);
        assert!(rec.jct_ms > 0.0);
    }
}

#[test]
fn embeddings_cluster_by_topic() {
    // Fig 1 property as a test: same-topic prompts embed closer together
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let store = WeightStore::load(&manifest).unwrap();
    let rt = Runtime::cpu().unwrap();
    let mut p = HloPredictor::load(rt, &manifest, &store, None).unwrap();

    let text = std::fs::read_to_string(dir.join("embed_groups.json")).unwrap();
    let j = Json::parse(&text).unwrap();
    let take = |k: &str| -> Vec<Vec<i32>> {
        j.get(k)
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .take(24)
            .map(|r| {
                r.as_i32_vec().unwrap().into_iter().filter(|&t| t != 0).collect()
            })
            .collect()
    };
    let sim = p.embed(&take("similar")).unwrap();
    let dis = p.embed(&take("dissimilar")).unwrap();

    let dist = |a: &[f32], b: &[f32]| -> f64 {
        a.iter()
            .zip(b)
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    };
    let mean_pairwise = |v: &[Vec<f32>]| -> f64 {
        let mut s = 0.0;
        let mut n = 0.0;
        for i in 0..v.len() {
            for k in i + 1..v.len() {
                s += dist(&v[i], &v[k]);
                n += 1.0;
            }
        }
        s / n
    };
    let d_sim = mean_pairwise(&sim);
    let d_dis = mean_pairwise(&dis);
    assert!(d_sim < d_dis * 0.8,
            "same-topic spread {d_sim} must be well below mixed {d_dis}");
}
