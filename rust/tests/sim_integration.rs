//! Integration tests over the full coordinator + sim engine stack.
//! No artifacts required — everything runs on synthetic corpora and the
//! calibrated discrete-event engine.

use elis::coordinator::{
    run_serving, ClockMode, CoordinatorBuilder, LbStrategy, Policy,
    PreemptionPolicy, Scheduler, ServeConfig, SharedCounter,
};
use elis::engine::profiles::ModelProfile;
use elis::engine::sim_engine::SimEngine;
use elis::engine::Engine;
use elis::metrics::ServeReport;
use elis::predictor::oracle::{FrozenOracle, OraclePredictor};
use elis::predictor::surrogate::SurrogatePredictor;
use elis::predictor::LengthPredictor;
use elis::runtime::manifest::ServedModelMeta;
use elis::workload::{Corpus, RequestGenerator};

fn profile(avg_latency_ms: f64) -> ModelProfile {
    ModelProfile::from_meta(&ServedModelMeta {
        name: "test".into(),
        abbrev: "test".into(),
        params_b: 7.0,
        avg_latency_ms,
        kv_bytes_per_token: 1 << 20,
        preempt_batch: 0,
        mem_limit_frac: 0.9,
    })
}

fn engines(n: usize, kv_bytes: usize) -> Vec<Box<dyn Engine>> {
    (0..n)
        .map(|_| {
            Box::new(SimEngine::new(profile(2000.0), 50, 4, kv_bytes))
                as Box<dyn Engine>
        })
        .collect()
}

fn run_with(policy: Policy, predictor: Box<dyn LengthPredictor>,
            workers: usize, rps: f64, n: usize, seed: u64,
            preemption: PreemptionPolicy, aging: f64) -> ServeReport {
    let corpus = Corpus::synthetic(400, seed);
    let mut gen = RequestGenerator::fabrix(rps, seed);
    let trace = gen.trace(&corpus, n);
    let mut sched = Scheduler::new(policy, predictor).with_aging(aging);
    let cfg = ServeConfig {
        workers,
        preemption,
        max_iterations: 5_000_000,
        seed,
        ..Default::default()
    };
    let mut e = engines(workers, 8 << 30);
    run_serving(&cfg, &trace, &mut e, &mut sched).unwrap()
}

fn run(policy: Policy, workers: usize, rps: f64, n: usize, seed: u64) -> ServeReport {
    run_with(policy, Box::new(OraclePredictor), workers, rps, n, seed,
             PreemptionPolicy::default(), 0.0)
}

#[test]
fn every_job_completes_with_consistent_metrics() {
    let r = run(Policy::Fcfs, 2, 2.0, 60, 1);
    assert_eq!(r.n(), 60);
    for rec in &r.records {
        assert!(rec.finish_ms >= rec.arrival_ms);
        assert!(rec.jct_ms >= rec.service_ms - 1e-6 || rec.queue_delay_ms == 0.0);
        assert!(rec.ttft_ms >= 0.0);
        assert!(rec.windows >= 1);
        assert!(rec.tokens >= 1);
    }
    assert!(r.makespan_ms > 0.0);
    assert!(r.sched_iterations > 0);
}

#[test]
fn srpt_and_sjf_beat_fcfs_under_load() {
    // average over 3 seeds to be robust
    let mut fcfs = 0.0;
    let mut srpt = 0.0;
    let mut sjf = 0.0;
    for seed in 0..3 {
        fcfs += run(Policy::Fcfs, 1, 3.0, 80, seed).avg_jct_s();
        srpt += run(Policy::Srpt, 1, 3.0, 80, seed).avg_jct_s();
        sjf += run_with(Policy::Sjf, Box::new(FrozenOracle), 1, 3.0, 80, seed,
                        PreemptionPolicy::default(), 0.0)
            .avg_jct_s();
    }
    assert!(srpt < fcfs, "SRPT {srpt} vs FCFS {fcfs}");
    assert!(sjf < fcfs, "SJF {sjf} vs FCFS {fcfs}");
}

#[test]
fn isrtf_with_noisy_predictor_beats_fcfs() {
    let mut fcfs = 0.0;
    let mut isrtf = 0.0;
    for seed in 0..3 {
        fcfs += run(Policy::Fcfs, 1, 3.0, 80, seed).avg_jct_s();
        isrtf += run_with(Policy::Isrtf,
                          Box::new(SurrogatePredictor::calibrated(seed)),
                          1, 3.0, 80, seed,
                          PreemptionPolicy::default(), 0.0)
            .avg_jct_s();
    }
    assert!(isrtf < fcfs, "ISRTF(noisy) {isrtf} vs FCFS {fcfs}");
}

#[test]
fn isrtf_sits_between_fcfs_and_oracle_srpt() {
    let mut fcfs = 0.0;
    let mut isrtf = 0.0;
    let mut srpt = 0.0;
    for seed in 10..14 {
        fcfs += run(Policy::Fcfs, 1, 3.0, 80, seed).avg_jct_s();
        isrtf += run_with(Policy::Isrtf,
                          Box::new(SurrogatePredictor::calibrated(seed)),
                          1, 3.0, 80, seed,
                          PreemptionPolicy::default(), 0.0)
            .avg_jct_s();
        srpt += run(Policy::Srpt, 1, 3.0, 80, seed).avg_jct_s();
    }
    assert!(srpt <= isrtf + 1e-9, "oracle {srpt} must not lose to noisy {isrtf}");
    assert!(isrtf < fcfs, "ISRTF {isrtf} vs FCFS {fcfs}");
}

#[test]
fn queueing_delay_is_the_mechanism() {
    // paper §6.2: the JCT win comes almost entirely from queueing delay
    let fcfs = run(Policy::Fcfs, 1, 4.0, 80, 3);
    let srpt = run(Policy::Srpt, 1, 4.0, 80, 3);
    let jct_gain = fcfs.avg_jct_s() - srpt.avg_jct_s();
    let qd_gain = fcfs.avg_queue_delay_s() - srpt.avg_queue_delay_s();
    assert!(jct_gain > 0.0);
    assert!((jct_gain - qd_gain).abs() / jct_gain < 0.25,
            "JCT gain {jct_gain} should be ~= queue-delay gain {qd_gain}");
}

#[test]
fn scaling_workers_increases_throughput() {
    let r1 = run(Policy::Isrtf, 1, 6.0, 80, 5);
    let r4 = run(Policy::Isrtf, 4, 6.0, 80, 5);
    assert!(r4.avg_jct_s() < r1.avg_jct_s());
    assert!(r4.avg_queue_delay_s() < r1.avg_queue_delay_s());
}

#[test]
fn load_balancer_spreads_jobs() {
    let r = run(Policy::Fcfs, 4, 8.0, 100, 7);
    let mut per_node = [0usize; 4];
    for rec in &r.records {
        per_node[rec.node] += 1;
    }
    for &c in &per_node {
        assert!(c >= 10, "node starved: {per_node:?}");
    }
}

#[test]
fn preemption_occurs_under_tiny_kv_pool_and_respects_budget() {
    let corpus = Corpus::synthetic(200, 11);
    let mut gen = RequestGenerator::fabrix(5.0, 11);
    let trace = gen.trace(&corpus, 40);
    let policy = PreemptionPolicy {
        enabled: true,
        max_preemptions_per_job: 2,
        max_per_iteration: usize::MAX,
    };
    let mut sched = Scheduler::new(Policy::Srpt, Box::new(OraclePredictor));
    let cfg = ServeConfig {
        preemption: policy,
        max_iterations: 5_000_000,
        ..Default::default()
    };
    // pool of ~40 blocks -> heavy preemption pressure
    let mut e: Vec<Box<dyn Engine>> = vec![Box::new(SimEngine::new(
        profile(2000.0), 50, 4, 40 * 16 * (1 << 20)))];
    let r = run_serving(&cfg, &trace, &mut e, &mut sched).unwrap();
    assert_eq!(r.n(), 40, "all jobs still finish despite preemption");
    assert!(r.total_preemptions > 0, "tiny pool must preempt");
}

#[test]
fn disabled_preemption_still_completes() {
    let corpus = Corpus::synthetic(100, 13);
    let mut gen = RequestGenerator::fabrix(3.0, 13);
    let trace = gen.trace(&corpus, 30);
    let mut sched = Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
    let cfg = ServeConfig {
        preemption: PreemptionPolicy::disabled(),
        max_iterations: 5_000_000,
        ..Default::default()
    };
    let mut e = engines(1, 8 << 30);
    let r = run_serving(&cfg, &trace, &mut e, &mut sched).unwrap();
    assert_eq!(r.n(), 30);
}

#[test]
fn aging_bounds_long_job_starvation() {
    // without aging, a very long job under SRPT + constant short-job stream
    // waits much longer than with aging
    let no_aging = run_with(Policy::Srpt, Box::new(OraclePredictor), 1, 4.0,
                            120, 17, PreemptionPolicy::default(), 0.0);
    let aged = run_with(Policy::Srpt, Box::new(OraclePredictor), 1, 4.0,
                        120, 17, PreemptionPolicy::default(), 10.0);
    let max_no = no_aging.max_jct_s();
    let max_aged = aged.max_jct_s();
    assert!(max_aged <= max_no * 1.2,
            "aging should not blow up worst-case JCT: {max_aged} vs {max_no}");
    // aging trades average JCT for tail fairness; the trade must stay sane
    assert!(aged.avg_jct_s() <= no_aging.avg_jct_s() * 2.5,
            "aged {} vs {}", aged.avg_jct_s(), no_aging.avg_jct_s());
}

#[test]
fn wall_clock_mode_works_with_sim_engine() {
    // tiny run in wall mode (arrivals in the past -> no sleeping)
    let corpus = Corpus::synthetic(50, 19);
    let mut gen = RequestGenerator::fabrix(1000.0, 19); // all arrive ~instantly
    let trace = gen.trace(&corpus, 10);
    let mut sched = Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
    let cfg = ServeConfig {
        clock: ClockMode::Wall,
        max_iterations: 100_000,
        ..Default::default()
    };
    let mut e = engines(1, 8 << 30);
    let r = run_serving(&cfg, &trace, &mut e, &mut sched).unwrap();
    assert_eq!(r.n(), 10);
}

#[test]
fn round_robin_lb_also_completes() {
    let corpus = Corpus::synthetic(100, 23);
    let mut gen = RequestGenerator::fabrix(4.0, 23);
    let trace = gen.trace(&corpus, 40);
    let mut sched = Scheduler::new(Policy::Isrtf,
                                   Box::new(SurrogatePredictor::calibrated(23)));
    let cfg = ServeConfig {
        workers: 3,
        lb: LbStrategy::RoundRobin,
        max_iterations: 5_000_000,
        ..Default::default()
    };
    let mut e = engines(3, 8 << 30);
    let r = run_serving(&cfg, &trace, &mut e, &mut sched).unwrap();
    assert_eq!(r.n(), 40);
}

#[test]
fn mlfq_baseline_runs_and_degrades_gracefully() {
    let mlfq = run(Policy::Mlfq, 1, 3.0, 80, 29);
    let fcfs = run(Policy::Fcfs, 1, 3.0, 80, 29);
    assert_eq!(mlfq.n(), 80);
    // MLFQ should at least not be catastrophically worse than FCFS
    assert!(mlfq.avg_jct_s() < fcfs.avg_jct_s() * 2.0);
}

#[test]
fn run_serving_matches_coordinator_builder() {
    // acceptance: the compatibility wrapper and a hand-built Coordinator
    // must produce identical reports (records, makespan, preemptions) for
    // a fixed seed, on the same trace
    let corpus = Corpus::synthetic(300, 41);
    let mut gen = RequestGenerator::fabrix(3.0, 41);
    let trace = gen.trace(&corpus, 60);
    let cfg = ServeConfig {
        workers: 2,
        max_iterations: 5_000_000,
        seed: 41,
        ..Default::default()
    };

    let mut sched_a = Scheduler::new(Policy::Isrtf,
                                     Box::new(SurrogatePredictor::calibrated(41)));
    let mut e_a = engines(2, 8 << 30);
    let a = run_serving(&cfg, &trace, &mut e_a, &mut sched_a).unwrap();

    let mut sched_b = Scheduler::new(Policy::Isrtf,
                                     Box::new(SurrogatePredictor::calibrated(41)));
    let mut e_b = engines(2, 8 << 30);
    let b = CoordinatorBuilder::from_config(cfg)
        .build(&trace, &mut e_b, &mut sched_b)
        .unwrap()
        .run_to_completion()
        .unwrap();

    assert_eq!(a.records, b.records, "per-job records must be identical");
    assert_eq!(a.makespan_ms, b.makespan_ms);
    assert_eq!(a.total_preemptions, b.total_preemptions);
    assert_eq!(a.sched_iterations, b.sched_iterations);
    assert_eq!(a.scheduler, b.scheduler);
    assert_eq!(a.predictor_name, b.predictor_name);
}

#[test]
fn stepped_api_exposes_progress() {
    let corpus = Corpus::synthetic(200, 43);
    let mut gen = RequestGenerator::fabrix(4.0, 43);
    let trace = gen.trace(&corpus, 30);
    let mut sched = Scheduler::new(Policy::Srpt, Box::new(OraclePredictor));
    let mut e = engines(1, 8 << 30);
    let mut coord = CoordinatorBuilder::new()
        .max_iterations(5_000_000)
        .seed(43)
        .build(&trace, &mut e, &mut sched)
        .unwrap();

    assert_eq!(coord.total_jobs(), 30);
    assert_eq!(coord.finished_jobs(), 0);
    assert!(!coord.is_done());

    let (mut admitted, mut completed, mut dispatched) = (0usize, 0usize, 0usize);
    let mut last_now = 0.0f64;
    while !coord.is_done() {
        let s = coord.step().unwrap();
        assert!(s.now_ms >= last_now, "virtual time must be monotone");
        last_now = s.now_ms;
        admitted += s.admitted;
        completed += s.completed;
        dispatched += s.dispatched;
    }
    assert_eq!(admitted, 30, "every arrival is ingested exactly once");
    assert_eq!(completed as u64, coord.iterations(),
               "virtual mode applies every dispatched window once");
    assert_eq!(dispatched as u64, coord.iterations());
    assert_eq!(coord.finished_jobs(), 30);
    let r = coord.report();
    assert_eq!(r.n(), 30);
    assert_eq!(r.sched_iterations, coord.iterations());

    // stepping a finished coordinator is a no-op
    let s = coord.step().unwrap();
    assert!(s.done && s.admitted == 0 && s.dispatched == 0 && !s.idled);
}

#[test]
fn event_sink_sees_the_whole_run() {
    let corpus = Corpus::synthetic(200, 47);
    let mut gen = RequestGenerator::fabrix(3.0, 47);
    let trace = gen.trace(&corpus, 40);
    let mut sched = Scheduler::new(Policy::Isrtf,
                                   Box::new(SurrogatePredictor::calibrated(47)));
    let mut e = engines(2, 8 << 30);
    let counter = SharedCounter::new();
    let r = CoordinatorBuilder::new()
        .workers(2)
        .max_iterations(5_000_000)
        .sink(Box::new(counter.clone()))
        .build(&trace, &mut e, &mut sched)
        .unwrap()
        .run_to_completion()
        .unwrap();

    let c = counter.snapshot();
    assert_eq!(c.admitted, 40);
    assert_eq!(c.finished, 40);
    assert_eq!(c.preempted, r.total_preemptions);
    assert_eq!(c.batches, r.sched_iterations);
    assert_eq!(c.windows, r.sched_iterations,
               "every formed batch completes exactly one window");
}

#[test]
fn deterministic_given_seed() {
    let a = run(Policy::Isrtf, 2, 3.0, 50, 31);
    let b = run(Policy::Isrtf, 2, 3.0, 50, 31);
    assert_eq!(a.n(), b.n());
    assert!((a.avg_jct_s() - b.avg_jct_s()).abs() < 1e-9);
    assert_eq!(a.sched_iterations, b.sched_iterations);
}

#[test]
fn higher_rps_multiple_worsens_jct() {
    let low = run(Policy::Fcfs, 1, 1.0, 60, 37);
    let high = run(Policy::Fcfs, 1, 5.0, 60, 37);
    assert!(high.avg_jct_s() > low.avg_jct_s());
}
