//! Integration tests over the full coordinator + sim engine stack.
//! No artifacts required — everything runs on synthetic corpora and the
//! calibrated discrete-event engine.

use std::cell::RefCell;
use std::rc::Rc;

use elis::coordinator::{
    run_serving, ClockMode, CoordinatorBuilder, EventSink, JobId,
    LbStrategy, Policy, PreemptionPolicy, PriorityShaper, Scheduler,
    ServeConfig, SharedCounter,
};
use elis::engine::profiles::ModelProfile;
use elis::engine::sim_engine::SimEngine;
use elis::engine::Engine;
use elis::metrics::ServeReport;
use elis::predictor::eval::kendall_tau;
use elis::predictor::heuristic::HeuristicPredictor;
use elis::predictor::oracle::{FrozenOracle, OraclePredictor};
use elis::predictor::rank::RankPredictor;
use elis::predictor::surrogate::SurrogatePredictor;
use elis::predictor::{LengthPredictor, ObservedCompletion, PredictQuery};
use elis::stats::rng::Pcg64;
use elis::runtime::manifest::ServedModelMeta;
use elis::telemetry::{AttributionSink, ShadowMode, ShadowScheduler,
                      SloPolicy, SloSpec, TelemetrySink, WfqPolicy};
use elis::workload::{Corpus, RequestGenerator, TraceRequest};

fn profile(avg_latency_ms: f64) -> ModelProfile {
    ModelProfile::from_meta(&ServedModelMeta {
        name: "test".into(),
        abbrev: "test".into(),
        params_b: 7.0,
        avg_latency_ms,
        kv_bytes_per_token: 1 << 20,
        preempt_batch: 0,
        mem_limit_frac: 0.9,
    })
}

fn engines(n: usize, kv_bytes: usize) -> Vec<Box<dyn Engine>> {
    (0..n)
        .map(|_| {
            Box::new(SimEngine::new(profile(2000.0), 50, 4, kv_bytes))
                as Box<dyn Engine>
        })
        .collect()
}

fn run_with(policy: Policy, predictor: Box<dyn LengthPredictor>,
            workers: usize, rps: f64, n: usize, seed: u64,
            preemption: PreemptionPolicy, aging: f64) -> ServeReport {
    let corpus = Corpus::synthetic(400, seed);
    let mut gen = RequestGenerator::fabrix(rps, seed);
    let trace = gen.trace(&corpus, n);
    let mut sched = Scheduler::new(policy, predictor).with_aging(aging);
    let cfg = ServeConfig {
        workers,
        preemption,
        max_iterations: 5_000_000,
        seed,
        ..Default::default()
    };
    let mut e = engines(workers, 8 << 30);
    run_serving(&cfg, &trace, &mut e, &mut sched).unwrap()
}

fn run(policy: Policy, workers: usize, rps: f64, n: usize, seed: u64) -> ServeReport {
    run_with(policy, Box::new(OraclePredictor), workers, rps, n, seed,
             PreemptionPolicy::default(), 0.0)
}

#[test]
fn every_job_completes_with_consistent_metrics() {
    let r = run(Policy::Fcfs, 2, 2.0, 60, 1);
    assert_eq!(r.n(), 60);
    for rec in &r.records {
        assert!(rec.finish_ms >= rec.arrival_ms);
        assert!(rec.jct_ms >= rec.service_ms - 1e-6 || rec.queue_delay_ms == 0.0);
        assert!(rec.ttft_ms.expect("finished jobs have a first token") >= 0.0);
        assert!(rec.windows >= 1);
        assert!(rec.tokens >= 1);
    }
    assert!(r.makespan_ms > 0.0);
    assert!(r.sched_iterations > 0);
}

#[test]
fn srpt_and_sjf_beat_fcfs_under_load() {
    // average over 3 seeds to be robust
    let mut fcfs = 0.0;
    let mut srpt = 0.0;
    let mut sjf = 0.0;
    for seed in 0..3 {
        fcfs += run(Policy::Fcfs, 1, 3.0, 80, seed).avg_jct_s();
        srpt += run(Policy::Srpt, 1, 3.0, 80, seed).avg_jct_s();
        sjf += run_with(Policy::Sjf, Box::new(FrozenOracle), 1, 3.0, 80, seed,
                        PreemptionPolicy::default(), 0.0)
            .avg_jct_s();
    }
    assert!(srpt < fcfs, "SRPT {srpt} vs FCFS {fcfs}");
    assert!(sjf < fcfs, "SJF {sjf} vs FCFS {fcfs}");
}

#[test]
fn isrtf_with_noisy_predictor_beats_fcfs() {
    let mut fcfs = 0.0;
    let mut isrtf = 0.0;
    for seed in 0..3 {
        fcfs += run(Policy::Fcfs, 1, 3.0, 80, seed).avg_jct_s();
        isrtf += run_with(Policy::Isrtf,
                          Box::new(SurrogatePredictor::calibrated(seed)),
                          1, 3.0, 80, seed,
                          PreemptionPolicy::default(), 0.0)
            .avg_jct_s();
    }
    assert!(isrtf < fcfs, "ISRTF(noisy) {isrtf} vs FCFS {fcfs}");
}

#[test]
fn isrtf_sits_between_fcfs_and_oracle_srpt() {
    let mut fcfs = 0.0;
    let mut isrtf = 0.0;
    let mut srpt = 0.0;
    for seed in 10..14 {
        fcfs += run(Policy::Fcfs, 1, 3.0, 80, seed).avg_jct_s();
        isrtf += run_with(Policy::Isrtf,
                          Box::new(SurrogatePredictor::calibrated(seed)),
                          1, 3.0, 80, seed,
                          PreemptionPolicy::default(), 0.0)
            .avg_jct_s();
        srpt += run(Policy::Srpt, 1, 3.0, 80, seed).avg_jct_s();
    }
    assert!(srpt <= isrtf + 1e-9, "oracle {srpt} must not lose to noisy {isrtf}");
    assert!(isrtf < fcfs, "ISRTF {isrtf} vs FCFS {fcfs}");
}

#[test]
fn queueing_delay_is_the_mechanism() {
    // paper §6.2: the JCT win comes almost entirely from queueing delay
    let fcfs = run(Policy::Fcfs, 1, 4.0, 80, 3);
    let srpt = run(Policy::Srpt, 1, 4.0, 80, 3);
    let jct_gain = fcfs.avg_jct_s() - srpt.avg_jct_s();
    let qd_gain = fcfs.avg_queue_delay_s() - srpt.avg_queue_delay_s();
    assert!(jct_gain > 0.0);
    assert!((jct_gain - qd_gain).abs() / jct_gain < 0.25,
            "JCT gain {jct_gain} should be ~= queue-delay gain {qd_gain}");
}

#[test]
fn scaling_workers_increases_throughput() {
    let r1 = run(Policy::Isrtf, 1, 6.0, 80, 5);
    let r4 = run(Policy::Isrtf, 4, 6.0, 80, 5);
    assert!(r4.avg_jct_s() < r1.avg_jct_s());
    assert!(r4.avg_queue_delay_s() < r1.avg_queue_delay_s());
}

#[test]
fn load_balancer_spreads_jobs() {
    let r = run(Policy::Fcfs, 4, 8.0, 100, 7);
    let mut per_node = [0usize; 4];
    for rec in &r.records {
        per_node[rec.node] += 1;
    }
    for &c in &per_node {
        assert!(c >= 10, "node starved: {per_node:?}");
    }
}

#[test]
fn preemption_occurs_under_tiny_kv_pool_and_respects_budget() {
    let corpus = Corpus::synthetic(200, 11);
    let mut gen = RequestGenerator::fabrix(5.0, 11);
    let trace = gen.trace(&corpus, 40);
    let policy = PreemptionPolicy {
        enabled: true,
        max_preemptions_per_job: 2,
        max_per_iteration: usize::MAX,
    };
    let mut sched = Scheduler::new(Policy::Srpt, Box::new(OraclePredictor));
    let cfg = ServeConfig {
        preemption: policy,
        max_iterations: 5_000_000,
        ..Default::default()
    };
    // pool of ~40 blocks -> heavy preemption pressure
    let mut e: Vec<Box<dyn Engine>> = vec![Box::new(SimEngine::new(
        profile(2000.0), 50, 4, 40 * 16 * (1 << 20)))];
    let r = run_serving(&cfg, &trace, &mut e, &mut sched).unwrap();
    assert_eq!(r.n(), 40, "all jobs still finish despite preemption");
    assert!(r.total_preemptions > 0, "tiny pool must preempt");
}

#[test]
fn disabled_preemption_still_completes() {
    let corpus = Corpus::synthetic(100, 13);
    let mut gen = RequestGenerator::fabrix(3.0, 13);
    let trace = gen.trace(&corpus, 30);
    let mut sched = Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
    let cfg = ServeConfig {
        preemption: PreemptionPolicy::disabled(),
        max_iterations: 5_000_000,
        ..Default::default()
    };
    let mut e = engines(1, 8 << 30);
    let r = run_serving(&cfg, &trace, &mut e, &mut sched).unwrap();
    assert_eq!(r.n(), 30);
}

#[test]
fn aging_bounds_long_job_starvation() {
    // without aging, a very long job under SRPT + constant short-job stream
    // waits much longer than with aging
    let no_aging = run_with(Policy::Srpt, Box::new(OraclePredictor), 1, 4.0,
                            120, 17, PreemptionPolicy::default(), 0.0);
    let aged = run_with(Policy::Srpt, Box::new(OraclePredictor), 1, 4.0,
                        120, 17, PreemptionPolicy::default(), 10.0);
    let max_no = no_aging.max_jct_s();
    let max_aged = aged.max_jct_s();
    assert!(max_aged <= max_no * 1.2,
            "aging should not blow up worst-case JCT: {max_aged} vs {max_no}");
    // aging trades average JCT for tail fairness; the trade must stay sane
    assert!(aged.avg_jct_s() <= no_aging.avg_jct_s() * 2.5,
            "aged {} vs {}", aged.avg_jct_s(), no_aging.avg_jct_s());
}

#[test]
fn wall_clock_mode_works_with_sim_engine() {
    // tiny run in wall mode (arrivals in the past -> no sleeping)
    let corpus = Corpus::synthetic(50, 19);
    let mut gen = RequestGenerator::fabrix(1000.0, 19); // all arrive ~instantly
    let trace = gen.trace(&corpus, 10);
    let mut sched = Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
    let cfg = ServeConfig {
        clock: ClockMode::Wall,
        max_iterations: 100_000,
        ..Default::default()
    };
    let mut e = engines(1, 8 << 30);
    let r = run_serving(&cfg, &trace, &mut e, &mut sched).unwrap();
    assert_eq!(r.n(), 10);
}

#[test]
fn round_robin_lb_also_completes() {
    let corpus = Corpus::synthetic(100, 23);
    let mut gen = RequestGenerator::fabrix(4.0, 23);
    let trace = gen.trace(&corpus, 40);
    let mut sched = Scheduler::new(Policy::Isrtf,
                                   Box::new(SurrogatePredictor::calibrated(23)));
    let cfg = ServeConfig {
        workers: 3,
        lb: LbStrategy::RoundRobin,
        max_iterations: 5_000_000,
        ..Default::default()
    };
    let mut e = engines(3, 8 << 30);
    let r = run_serving(&cfg, &trace, &mut e, &mut sched).unwrap();
    assert_eq!(r.n(), 40);
}

#[test]
fn mlfq_baseline_runs_and_degrades_gracefully() {
    let mlfq = run(Policy::Mlfq, 1, 3.0, 80, 29);
    let fcfs = run(Policy::Fcfs, 1, 3.0, 80, 29);
    assert_eq!(mlfq.n(), 80);
    // MLFQ should at least not be catastrophically worse than FCFS
    assert!(mlfq.avg_jct_s() < fcfs.avg_jct_s() * 2.0);
}

#[test]
fn run_serving_matches_coordinator_builder() {
    // acceptance: the compatibility wrapper and a hand-built Coordinator
    // must produce identical reports (records, makespan, preemptions) for
    // a fixed seed, on the same trace
    let corpus = Corpus::synthetic(300, 41);
    let mut gen = RequestGenerator::fabrix(3.0, 41);
    let trace = gen.trace(&corpus, 60);
    let cfg = ServeConfig {
        workers: 2,
        max_iterations: 5_000_000,
        seed: 41,
        ..Default::default()
    };

    let mut sched_a = Scheduler::new(Policy::Isrtf,
                                     Box::new(SurrogatePredictor::calibrated(41)));
    let mut e_a = engines(2, 8 << 30);
    let a = run_serving(&cfg, &trace, &mut e_a, &mut sched_a).unwrap();

    let mut sched_b = Scheduler::new(Policy::Isrtf,
                                     Box::new(SurrogatePredictor::calibrated(41)));
    let mut e_b = engines(2, 8 << 30);
    let b = CoordinatorBuilder::from_config(cfg)
        .build(&trace, &mut e_b, &mut sched_b)
        .unwrap()
        .run_to_completion()
        .unwrap();

    assert_eq!(a.records, b.records, "per-job records must be identical");
    assert_eq!(a.makespan_ms, b.makespan_ms);
    assert_eq!(a.total_preemptions, b.total_preemptions);
    assert_eq!(a.sched_iterations, b.sched_iterations);
    assert_eq!(a.scheduler, b.scheduler);
    assert_eq!(a.predictor_name, b.predictor_name);
}

#[test]
fn stepped_api_exposes_progress() {
    let corpus = Corpus::synthetic(200, 43);
    let mut gen = RequestGenerator::fabrix(4.0, 43);
    let trace = gen.trace(&corpus, 30);
    let mut sched = Scheduler::new(Policy::Srpt, Box::new(OraclePredictor));
    let mut e = engines(1, 8 << 30);
    let mut coord = CoordinatorBuilder::new()
        .max_iterations(5_000_000)
        .seed(43)
        .build(&trace, &mut e, &mut sched)
        .unwrap();

    assert_eq!(coord.total_jobs(), 30);
    assert_eq!(coord.finished_jobs(), 0);
    assert!(!coord.is_done());

    let (mut admitted, mut completed, mut dispatched) = (0usize, 0usize, 0usize);
    let mut last_now = 0.0f64;
    while !coord.is_done() {
        let s = coord.step().unwrap();
        assert!(s.now_ms >= last_now, "virtual time must be monotone");
        last_now = s.now_ms;
        admitted += s.admitted;
        completed += s.completed;
        dispatched += s.dispatched;
    }
    assert_eq!(admitted, 30, "every arrival is ingested exactly once");
    assert_eq!(completed as u64, coord.iterations(),
               "virtual mode applies every dispatched window once");
    assert_eq!(dispatched as u64, coord.iterations());
    assert_eq!(coord.finished_jobs(), 30);
    let r = coord.report();
    assert_eq!(r.n(), 30);
    assert_eq!(r.sched_iterations, coord.iterations());

    // stepping a finished coordinator is a no-op
    let s = coord.step().unwrap();
    assert!(s.done && s.admitted == 0 && s.dispatched == 0 && !s.idled);
}

#[test]
fn event_sink_sees_the_whole_run() {
    let corpus = Corpus::synthetic(200, 47);
    let mut gen = RequestGenerator::fabrix(3.0, 47);
    let trace = gen.trace(&corpus, 40);
    let mut sched = Scheduler::new(Policy::Isrtf,
                                   Box::new(SurrogatePredictor::calibrated(47)));
    let mut e = engines(2, 8 << 30);
    let counter = SharedCounter::new();
    let r = CoordinatorBuilder::new()
        .workers(2)
        .max_iterations(5_000_000)
        .sink(Box::new(counter.clone()))
        .build(&trace, &mut e, &mut sched)
        .unwrap()
        .run_to_completion()
        .unwrap();

    let c = counter.snapshot();
    assert_eq!(c.admitted, 40);
    assert_eq!(c.finished, 40);
    assert_eq!(c.preempted, r.total_preemptions);
    assert_eq!(c.batches, r.sched_iterations);
    assert_eq!(c.windows, r.sched_iterations,
               "every formed batch completes exactly one window");
}

// ---------------------------------------------------------------------------
// telemetry subsystem + SLO policy + streaming admission (PR 2)
// ---------------------------------------------------------------------------

/// Two-tenant trace engineered so FCFS badly misses the tight budget:
/// six long "free" jobs sit ahead of six short "paid" jobs, all arriving
/// at t=0, so arrival-order service makes every paid job wait ~all of the
/// free work while deadline-order service clears paid almost immediately.
fn skewed_two_tenant_trace() -> Vec<TraceRequest> {
    (0..12u64)
        .map(|i| {
            let long = i < 6;
            TraceRequest {
                id: i,
                arrival_ms: 0.0,
                prompt: vec![7; 16],
                total_len: if long { 400 } else { 20 },
                topic: 0,
                tenant: Some(if long { "free" } else { "paid" }.to_string()),
            }
        })
        .collect()
}

fn paid_free_slo() -> SloSpec {
    SloSpec::new(120_000.0).tenant("paid", 6_000.0)
}

#[test]
fn slo_policy_cuts_deadline_misses_vs_fcfs() {
    let trace = skewed_two_tenant_trace();
    let run = |with_policy: bool| {
        let mut sched = Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
        let mut e = engines(1, 8 << 30);
        let cfg = ServeConfig { max_iterations: 1_000_000, ..Default::default() };
        let telemetry = TelemetrySink::with_slo(1, paid_free_slo());
        let mut b = CoordinatorBuilder::from_config(cfg)
            .sink(Box::new(telemetry.clone()));
        if with_policy {
            b = b.priority_shaper(Box::new(SloPolicy::new(&telemetry,
                                                          paid_free_slo())));
        }
        let r = b.build(&trace, &mut e, &mut sched)
            .unwrap()
            .run_to_completion()
            .unwrap();
        (r, telemetry)
    };

    let (fcfs_report, fcfs_tel) = run(false);
    let (slo_report, slo_tel) = run(true);
    assert_eq!(fcfs_report.n(), 12);
    assert_eq!(slo_report.n(), 12, "SLO policy must not lose jobs");

    // the sink's ledger must agree with an independent count off the records
    let misses = |r: &ServeReport| {
        let spec = paid_free_slo();
        r.records
            .iter()
            .filter(|rec| rec.jct_ms > spec.slo_for(rec.tenant.as_deref().unwrap()))
            .count() as u64
    };
    assert_eq!(misses(&fcfs_report), fcfs_tel.total_deadline_misses());
    assert_eq!(misses(&slo_report), slo_tel.total_deadline_misses());

    // FCFS serves the long free jobs first -> paid blows its 6 s budget
    assert!(fcfs_tel.deadline_misses("paid") >= 4,
            "skew must hurt FCFS: {} paid misses",
            fcfs_tel.deadline_misses("paid"));
    assert!(slo_tel.total_deadline_misses() < fcfs_tel.total_deadline_misses(),
            "SLO policy must cut misses: {} vs {}",
            slo_tel.total_deadline_misses(), fcfs_tel.total_deadline_misses());
}

#[test]
fn telemetry_observer_leaves_reports_identical() {
    // acceptance: a registered sink (no policy) must not perturb the
    // schedule — reports stay byte-identical to a sink-less run
    let corpus = Corpus::synthetic(300, 61);
    let mut gen = RequestGenerator::fabrix(3.0, 61);
    let mut trace = gen.trace(&corpus, 50);
    elis::workload::assign_tenants(
        &mut trace, &[("paid".into(), 1), ("free".into(), 2)]);
    let cfg = ServeConfig {
        workers: 2,
        max_iterations: 5_000_000,
        ..Default::default()
    };

    let mut sched_a = Scheduler::new(Policy::Srpt, Box::new(OraclePredictor));
    let mut e_a = engines(2, 8 << 30);
    let plain = run_serving(&cfg, &trace, &mut e_a, &mut sched_a).unwrap();

    let mut sched_b = Scheduler::new(Policy::Srpt, Box::new(OraclePredictor));
    let mut e_b = engines(2, 8 << 30);
    let telemetry = TelemetrySink::new(2);
    let observed = CoordinatorBuilder::from_config(cfg)
        .sink(Box::new(telemetry.clone()))
        .build(&trace, &mut e_b, &mut sched_b)
        .unwrap()
        .run_to_completion()
        .unwrap();

    assert_eq!(plain.records, observed.records);
    assert_eq!(plain.makespan_ms, observed.makespan_ms);
    assert_eq!(plain.total_preemptions, observed.total_preemptions);
    assert_eq!(plain.sched_iterations, observed.sched_iterations);

    // and the sink saw the whole run, split by tenant
    telemetry.with_state(|st| {
        let finished: u64 = st.tenants.values().map(|t| t.finished).sum();
        assert_eq!(finished, 50);
        assert_eq!(st.tenants["paid"].finished
                       + st.tenants["free"].finished, 50);
        for t in st.tenants.values() {
            assert_eq!(t.jct_ms.count(), t.finished);
            assert_eq!(t.active, 0, "everything finished");
            let p50 = t.jct_ms.p50();
            assert!(p50 >= t.jct_ms.min() && p50 <= t.jct_ms.max());
        }
        let node_tokens: u64 = st.nodes.iter().map(|n| n.tokens).sum();
        let record_tokens: u64 =
            plain.records.iter().map(|r| r.tokens as u64).sum();
        assert_eq!(node_tokens, record_tokens,
                   "window token events must cover every generated token");
    });

    // the snapshot renders per-tenant labels mid-pipeline formats
    let text = telemetry.render_prometheus();
    assert!(text.contains("elis_tenant_jct_ms{tenant=\"paid\",quantile=\"0.99\"}"));
    assert!(text.contains("# TYPE elis_node_tokens_total counter"));
}

#[test]
fn streaming_ingest_mid_run_admits_exactly_once() {
    let corpus = Corpus::synthetic(100, 51);
    let mut gen = RequestGenerator::fabrix(3.0, 51);
    let trace = gen.trace(&corpus, 20);
    let mut sched = Scheduler::new(Policy::Srpt, Box::new(OraclePredictor));
    let mut e = engines(1, 8 << 30);
    let counter = SharedCounter::new();
    let mut coord = CoordinatorBuilder::new()
        .max_iterations(1_000_000)
        .sink(Box::new(counter.clone()))
        .build(&trace, &mut e, &mut sched)
        .unwrap();

    // run until half the preloaded jobs finished, then stream two more in:
    // one future arrival and one out-of-order arrival already in the past
    while coord.finished_jobs() < 10 {
        coord.step().unwrap();
    }
    let now = coord.now();
    let mk = |id: u64, arrival_ms: f64| TraceRequest {
        id,
        arrival_ms,
        prompt: vec![5; 12],
        total_len: 30,
        topic: 0,
        tenant: Some("late".into()),
    };
    coord.push_request(&mk(100, now + 500.0));
    coord.push_request(&mk(101, 0.0));
    assert_eq!(coord.total_jobs(), 22);
    assert!(!coord.is_done());

    while !coord.step().unwrap().done {}
    let r = coord.report();
    assert_eq!(r.n(), 22, "streamed jobs must be scheduled and finish");
    let c = counter.snapshot();
    assert_eq!(c.admitted, 22, "each job admitted exactly once");
    assert_eq!(c.finished, 22, "each job finished exactly once");

    let streamed: Vec<_> = r
        .records
        .iter()
        .filter(|rec| rec.tenant.as_deref() == Some("late"))
        .collect();
    assert_eq!(streamed.len(), 2, "both streamed jobs counted exactly once");
    for rec in streamed {
        assert_eq!(rec.tokens, 30);
        assert!(rec.finish_ms >= rec.arrival_ms);
        assert!(rec.finish_ms >= now, "streamed work completes after push");
    }
}

/// Counts engine evictions between consecutive window-done events.
#[derive(Default, Clone)]
struct EvictionsPerWindow(Rc<RefCell<(u64, Vec<u64>)>>);

impl EventSink for EvictionsPerWindow {
    fn on_job_preempted(&mut self, _job: JobId, _node: usize, _now_ms: f64) {
        self.0.borrow_mut().0 += 1;
    }

    fn on_window_done(&mut self, _node: usize, _batch: &[JobId],
                      _tokens: usize, _service_ms: f64, _now_ms: f64) {
        let mut inner = self.0.borrow_mut();
        let count = inner.0;
        inner.0 = 0;
        inner.1.push(count);
    }
}

#[test]
fn max_per_iteration_bounds_evictions_per_window() {
    // regression for the previously-ignored PreemptionPolicy knob: with a
    // starved KV pool and max_per_iteration=1, no window may evict twice
    let corpus = Corpus::synthetic(200, 11);
    let mut gen = RequestGenerator::fabrix(5.0, 11);
    let trace = gen.trace(&corpus, 40);
    let mut sched = Scheduler::new(Policy::Srpt, Box::new(OraclePredictor));
    let cfg = ServeConfig {
        preemption: PreemptionPolicy {
            enabled: true,
            max_preemptions_per_job: 100,
            max_per_iteration: 1,
        },
        max_iterations: 5_000_000,
        ..Default::default()
    };
    let evictions = EvictionsPerWindow::default();
    let mut e: Vec<Box<dyn Engine>> = vec![Box::new(SimEngine::new(
        profile(2000.0), 50, 4, 40 * 16 * (1 << 20)))];
    let r = CoordinatorBuilder::from_config(cfg)
        .sink(Box::new(evictions.clone()))
        .build(&trace, &mut e, &mut sched)
        .unwrap()
        .run_to_completion()
        .unwrap();
    assert_eq!(r.n(), 40, "all jobs finish under the eviction cap");
    assert!(r.total_preemptions > 0, "tiny pool must still preempt");
    let per_window = evictions.0.borrow().1.clone();
    assert_eq!(per_window.iter().sum::<u64>(), r.total_preemptions);
    assert!(per_window.iter().all(|&c| c <= 1),
            "cap violated: {per_window:?}");
}

// ---------------------------------------------------------------------------
// incremental scheduling core (persistent per-node order index, PR 4)
// ---------------------------------------------------------------------------

fn assert_reports_identical(a: &ServeReport, b: &ServeReport) {
    assert_eq!(a.records, b.records, "per-job records must be identical");
    assert_eq!(a.makespan_ms, b.makespan_ms);
    assert_eq!(a.total_preemptions, b.total_preemptions);
    assert_eq!(a.sched_iterations, b.sched_iterations);
}

fn predictor_for(policy: Policy, seed: u64) -> Box<dyn LengthPredictor> {
    match policy {
        Policy::Isrtf => Box::new(SurrogatePredictor::calibrated(seed)),
        Policy::Sjf => Box::new(FrozenOracle),
        _ => Box::new(OraclePredictor),
    }
}

const TINY_KV: usize = 40 * 16 * (1 << 20); // ~40 blocks: heavy preemption

#[test]
fn incremental_index_matches_full_rebuild_for_all_policies() {
    // the tentpole acceptance guard: the persistent index and the classic
    // per-window rebuild must produce bit-identical virtual-clock reports
    // for every policy — including under engine preemption pressure and
    // with anti-starvation aging folded into the keys
    let all = [Policy::Fcfs, Policy::Sjf, Policy::Isrtf, Policy::Srpt,
               Policy::Mlfq];
    let mut cases: Vec<(Policy, usize, f64)> =
        all.iter().map(|&p| (p, 8usize << 30, 0.0)).collect();
    cases.push((Policy::Srpt, TINY_KV, 0.0));
    cases.push((Policy::Isrtf, TINY_KV, 0.0));
    cases.push((Policy::Srpt, 8 << 30, 10.0));
    cases.push((Policy::Isrtf, 8 << 30, 10.0));
    for (policy, kv, aging) in cases {
        let corpus = Corpus::synthetic(300, 71);
        let mut gen = RequestGenerator::fabrix(4.0, 71);
        let trace = gen.trace(&corpus, 50);
        let cfg = ServeConfig {
            workers: 2,
            max_iterations: 5_000_000,
            seed: 71,
            ..Default::default()
        };
        let run = |rebuild: bool| {
            let mut sched = Scheduler::new(policy, predictor_for(policy, 71))
                .with_aging(aging);
            let mut e: Vec<Box<dyn Engine>> = (0..2)
                .map(|_| Box::new(SimEngine::new(profile(2000.0), 50, 4, kv))
                     as Box<dyn Engine>)
                .collect();
            CoordinatorBuilder::from_config(cfg.clone())
                .full_rebuild(rebuild)
                .build(&trace, &mut e, &mut sched)
                .unwrap()
                .run_to_completion()
                .unwrap()
        };
        let inc = run(false);
        let reb = run(true);
        assert_eq!(inc.n(), 50, "{policy:?} kv={kv} aging={aging}");
        if kv == TINY_KV {
            assert!(inc.total_preemptions > 0,
                    "tiny pool must preempt ({policy:?})");
        }
        assert_reports_identical(&inc, &reb);
    }
}

/// Records every formed batch (node, job ids in priority order) so two
/// runs can be compared dispatch-by-dispatch.
#[derive(Default, Clone)]
struct BatchLog(Rc<RefCell<Vec<(usize, Vec<u64>)>>>);

impl EventSink for BatchLog {
    fn on_batch_formed(&mut self, node: usize, jobs: &[JobId], _now_ms: f64) {
        self.0
            .borrow_mut()
            .push((node, jobs.iter().map(|j| j.raw()).collect()));
    }
}

#[test]
fn prop_incremental_matches_rebuild_with_streaming() {
    // differential property test: random traces, random mid-run streamed
    // admissions, completions and preemptions driven through both dispatch
    // paths for all five policies — batch-by-batch dispatch orders and
    // final reports must be identical
    use elis::testing::prop;
    prop::check("incremental-vs-rebuild", 10, |g| {
        let policy = *g.pick(&[Policy::Fcfs, Policy::Sjf, Policy::Isrtf,
                               Policy::Srpt, Policy::Mlfq]);
        let aging = if policy != Policy::Mlfq && g.bool(0.3) {
            g.f64_in(1.0, 15.0)
        } else {
            0.0
        };
        let workers = g.usize_in(1, 3);
        let seed = g.usize_in(1, 10_000) as u64;
        let n = g.usize_in(10, 30);
        let rps = g.f64_in(2.0, 8.0);
        let kv = if g.bool(0.35) { TINY_KV } else { 8 << 30 };
        let budget = *g.pick(&[2usize, 3, 100]);
        let corpus = Corpus::synthetic(200, seed);
        let mut gen = RequestGenerator::fabrix(rps, seed);
        let trace = gen.trace(&corpus, n);
        let n_push = g.usize_in(0, 4);
        let pushes: Vec<(u64, TraceRequest)> = (0..n_push)
            .map(|k| {
                (g.usize_in(1, 40) as u64, TraceRequest {
                    id: 10_000 + k as u64,
                    arrival_ms: g.f64_in(0.0, 20_000.0),
                    prompt: vec![5; g.usize_in(4, 24)],
                    total_len: g.usize_in(5, 300),
                    topic: 0,
                    tenant: None,
                })
            })
            .collect();
        let cfg = ServeConfig {
            workers,
            max_batch: g.usize_in(2, 4),
            preemption: PreemptionPolicy {
                enabled: true,
                max_preemptions_per_job: budget,
                max_per_iteration: usize::MAX,
            },
            max_iterations: 2_000_000,
            seed,
            ..Default::default()
        };

        let run = |rebuild: bool| {
            let mut sched = Scheduler::new(policy,
                                           predictor_for(policy, seed))
                .with_aging(aging);
            let mut e: Vec<Box<dyn Engine>> = (0..workers)
                .map(|_| Box::new(SimEngine::new(profile(2000.0), 50, 4, kv))
                     as Box<dyn Engine>)
                .collect();
            let log = BatchLog::default();
            let mut coord = CoordinatorBuilder::from_config(cfg.clone())
                .full_rebuild(rebuild)
                .sink(Box::new(log.clone()))
                .build(&trace, &mut e, &mut sched)
                .unwrap();
            let mut next_push = 0usize;
            let mut steps: u64 = 0;
            while !coord.is_done() || next_push < pushes.len() {
                while next_push < pushes.len()
                    && pushes[next_push].0 <= steps
                {
                    coord.push_request(&pushes[next_push].1);
                    next_push += 1;
                }
                coord.step().unwrap();
                steps += 1;
                assert!(steps < 1_000_000, "did not converge");
            }
            (coord.report(), log.0.borrow().clone())
        };
        let (ra, la) = run(false);
        let (rb, lb) = run(true);
        assert_eq!(ra.n(), n + n_push, "every job (incl. streamed) finishes");
        assert_eq!(la, lb,
                   "dispatch orders must match ({policy:?} aging={aging} \
                    kv={kv} workers={workers})");
        assert_reports_identical(&ra, &rb);
    });
}

#[test]
fn zero_preemption_budget_skips_victim_ranking_and_matches() {
    // max_per_iteration == 0 can never evict (the engine checks the budget
    // before its ranking), so dispatch skips building the ranking — and on
    // an uncontended pool the schedule must match an uncapped run exactly
    let corpus = Corpus::synthetic(200, 83);
    let mut gen = RequestGenerator::fabrix(3.0, 83);
    let trace = gen.trace(&corpus, 40);
    let run = |cap: usize| {
        let mut sched = Scheduler::new(Policy::Srpt, Box::new(OraclePredictor));
        let cfg = ServeConfig {
            preemption: PreemptionPolicy {
                enabled: true,
                max_preemptions_per_job: 3,
                max_per_iteration: cap,
            },
            max_iterations: 5_000_000,
            ..Default::default()
        };
        let mut e = engines(1, 8 << 30);
        run_serving(&cfg, &trace, &mut e, &mut sched).unwrap()
    };
    let frozen = run(0);
    let uncapped = run(usize::MAX);
    assert_eq!(frozen.n(), 40);
    assert_eq!(frozen.total_preemptions, 0);
    if uncapped.total_preemptions == 0 {
        // same pool, no evictions either way: skipping the ranking must
        // not perturb the schedule
        assert_reports_identical(&frozen, &uncapped);
    }
}

// ---------------------------------------------------------------------------
// shaped incremental dispatch + dispatch shards (PR 9)
// ---------------------------------------------------------------------------

/// One of the three foldable shaper shapes under test: the SLO policy, the
/// WFQ fairness shaper, or WFQ composed over SLO.  Each run must get its
/// own [`TelemetrySink`] so live pressure/lead state is fed only by that
/// run's events.
fn shaper_for(kind: usize, telemetry: &TelemetrySink)
              -> Box<dyn PriorityShaper> {
    let slo = SloSpec::new(60_000.0).tenant("paid", 4_000.0);
    match kind {
        0 => Box::new(SloPolicy::new(telemetry, slo)),
        1 => Box::new(WfqPolicy::new(telemetry).weight("paid", 3.0)),
        _ => Box::new(
            WfqPolicy::new(telemetry)
                .weight("paid", 3.0)
                .over(Box::new(SloPolicy::new(telemetry, slo)))),
    }
}

#[test]
fn shaped_incremental_matches_rebuild_for_all_shapers() {
    // the PR 9 tentpole guard: with a foldable shaper registered, the
    // persistent shaped index (per-tenant lanes, epoch-gated re-keys) and
    // the classic per-window rebuild must produce bit-identical reports
    // and batch-by-batch dispatch orders — including under preemption
    // pressure and with aging folded into the base keys
    let cases: [(Policy, f64, usize); 5] = [
        (Policy::Fcfs, 0.0, 8 << 30),
        (Policy::Isrtf, 0.0, 8 << 30),
        (Policy::Srpt, 0.0, 8 << 30),
        (Policy::Srpt, 10.0, 8 << 30),
        (Policy::Srpt, 0.0, TINY_KV),
    ];
    for kind in 0..3usize {
        for &(policy, aging, kv) in &cases {
            let corpus = Corpus::synthetic(300, 91);
            let mut gen = RequestGenerator::fabrix(4.0, 91);
            let mut trace = gen.trace(&corpus, 50);
            elis::workload::assign_tenants(
                &mut trace, &[("paid".into(), 1), ("free".into(), 2)]);
            let cfg = ServeConfig {
                workers: 2,
                max_iterations: 5_000_000,
                seed: 91,
                ..Default::default()
            };
            let run = |rebuild: bool| {
                let mut sched =
                    Scheduler::new(policy, predictor_for(policy, 91))
                        .with_aging(aging);
                let mut e: Vec<Box<dyn Engine>> = (0..2)
                    .map(|_| Box::new(
                        SimEngine::new(profile(2000.0), 50, 4, kv))
                         as Box<dyn Engine>)
                    .collect();
                let telemetry = TelemetrySink::new(2);
                let log = BatchLog::default();
                let r = CoordinatorBuilder::from_config(cfg.clone())
                    .full_rebuild(rebuild)
                    .sink(Box::new(telemetry.clone()))
                    .sink(Box::new(log.clone()))
                    .priority_shaper(shaper_for(kind, &telemetry))
                    .build(&trace, &mut e, &mut sched)
                    .unwrap()
                    .run_to_completion()
                    .unwrap();
                (r, log.0.borrow().clone())
            };
            let (inc, linc) = run(false);
            let (reb, lreb) = run(true);
            assert_eq!(inc.n(), 50,
                       "kind={kind} {policy:?} aging={aging} kv={kv}");
            assert_eq!(linc, lreb,
                       "shaped dispatch orders must match \
                        (kind={kind} {policy:?} aging={aging} kv={kv})");
            assert_reports_identical(&inc, &reb);
        }
    }
}

#[test]
fn prop_shaped_incremental_matches_rebuild_with_streaming() {
    // differential property test for the shaped index: random traces with
    // tenant tags, random shaper shape (SLO / WFQ / composed), random
    // preemption budgets, and random mid-run streamed admissions — the
    // incremental and rebuild paths must agree batch by batch
    use elis::testing::prop;
    prop::check("shaped-incremental-vs-rebuild", 8, |g| {
        let kind = g.usize_in(0, 2);
        let policy = *g.pick(&[Policy::Fcfs, Policy::Srpt, Policy::Isrtf]);
        let aging = if g.bool(0.3) { g.f64_in(1.0, 15.0) } else { 0.0 };
        let workers = g.usize_in(1, 3);
        let seed = g.usize_in(1, 10_000) as u64;
        let n = g.usize_in(10, 30);
        let rps = g.f64_in(2.0, 8.0);
        let kv = if g.bool(0.35) { TINY_KV } else { 8 << 30 };
        let budget = *g.pick(&[2usize, 100]);
        let corpus = Corpus::synthetic(200, seed);
        let mut gen = RequestGenerator::fabrix(rps, seed);
        let mut trace = gen.trace(&corpus, n);
        elis::workload::assign_tenants(
            &mut trace, &[("paid".into(), 1), ("free".into(), 2)]);
        let n_push = g.usize_in(0, 4);
        let pushes: Vec<(u64, TraceRequest)> = (0..n_push)
            .map(|k| {
                (g.usize_in(1, 40) as u64, TraceRequest {
                    id: 10_000 + k as u64,
                    arrival_ms: g.f64_in(0.0, 20_000.0),
                    prompt: vec![5; g.usize_in(4, 24)],
                    total_len: g.usize_in(5, 300),
                    topic: 0,
                    // "burst" never appears in the preload: exercises a
                    // tenant lane born mid-run
                    tenant: Some(
                        (*g.pick(&["paid", "free", "burst"])).to_string()),
                })
            })
            .collect();
        let cfg = ServeConfig {
            workers,
            max_batch: g.usize_in(2, 4),
            preemption: PreemptionPolicy {
                enabled: true,
                max_preemptions_per_job: budget,
                max_per_iteration: usize::MAX,
            },
            max_iterations: 2_000_000,
            seed,
            ..Default::default()
        };

        let run = |rebuild: bool| {
            let mut sched = Scheduler::new(policy,
                                           predictor_for(policy, seed))
                .with_aging(aging);
            let mut e: Vec<Box<dyn Engine>> = (0..workers)
                .map(|_| Box::new(SimEngine::new(profile(2000.0), 50, 4, kv))
                     as Box<dyn Engine>)
                .collect();
            let telemetry = TelemetrySink::new(workers);
            let log = BatchLog::default();
            let mut coord = CoordinatorBuilder::from_config(cfg.clone())
                .full_rebuild(rebuild)
                .sink(Box::new(telemetry.clone()))
                .sink(Box::new(log.clone()))
                .priority_shaper(shaper_for(kind, &telemetry))
                .build(&trace, &mut e, &mut sched)
                .unwrap();
            let mut next_push = 0usize;
            let mut steps: u64 = 0;
            while !coord.is_done() || next_push < pushes.len() {
                while next_push < pushes.len()
                    && pushes[next_push].0 <= steps
                {
                    coord.push_request(&pushes[next_push].1);
                    next_push += 1;
                }
                coord.step().unwrap();
                steps += 1;
                assert!(steps < 1_000_000, "did not converge");
            }
            (coord.report(), log.0.borrow().clone())
        };
        let (ra, la) = run(false);
        let (rb, lb) = run(true);
        assert_eq!(ra.n(), n + n_push, "every job (incl. streamed) finishes");
        assert_eq!(la, lb,
                   "shaped dispatch orders must match (kind={kind} \
                    {policy:?} aging={aging} kv={kv} workers={workers})");
        assert_reports_identical(&ra, &rb);
    });
}

#[test]
fn dispatch_shards_leave_reports_identical() {
    // sharded planning acceptance: per-node plans fan out across shard
    // threads but apply serially in node order, so the schedule — and the
    // whole report — must be bit-identical at any shard count, shaped or
    // not (0 = auto-size from the machine)
    let corpus = Corpus::synthetic(300, 93);
    let mut gen = RequestGenerator::fabrix(6.0, 93);
    let mut trace = gen.trace(&corpus, 60);
    elis::workload::assign_tenants(
        &mut trace, &[("paid".into(), 1), ("free".into(), 2)]);
    let cfg = ServeConfig {
        workers: 4,
        max_iterations: 5_000_000,
        seed: 93,
        ..Default::default()
    };
    let run = |shards: usize, shaped: bool| {
        let mut sched = Scheduler::new(Policy::Srpt, Box::new(OraclePredictor))
            .with_aging(5.0);
        let mut e = engines(4, 8 << 30);
        let telemetry = TelemetrySink::new(4);
        let log = BatchLog::default();
        let mut b = CoordinatorBuilder::from_config(cfg.clone())
            .dispatch_shards(shards)
            .sink(Box::new(telemetry.clone()))
            .sink(Box::new(log.clone()));
        if shaped {
            b = b.priority_shaper(Box::new(
                WfqPolicy::new(&telemetry).weight("paid", 3.0)));
        }
        let r = b.build(&trace, &mut e, &mut sched)
            .unwrap()
            .run_to_completion()
            .unwrap();
        (r, log.0.borrow().clone())
    };
    for shaped in [false, true] {
        let (r1, l1) = run(1, shaped);
        assert_eq!(r1.n(), 60);
        for shards in [2usize, 8, 0] {
            let (rn, ln) = run(shards, shaped);
            assert_eq!(l1, ln,
                       "batch orders must match at {shards} shards \
                        (shaped={shaped})");
            assert_reports_identical(&r1, &rn);
        }
    }
}

#[test]
fn shedding_slo_policy_keeps_rebuild_path_and_completes() {
    // shed_after is an age cutoff — not affine in `now` — so a shedding
    // SLO policy must refuse to fold (no incremental shaped index) and
    // dispatch stays on the rebuild reference path; the run still
    // completes every job and a shard request is silently ignored there
    let trace = skewed_two_tenant_trace();
    let telemetry = TelemetrySink::with_slo(1, paid_free_slo());
    let policy = SloPolicy::new(&telemetry, paid_free_slo()).shed_after(3.0);
    assert!(policy.as_folded().is_none(),
            "an age-shedding policy must not claim a folded view");
    let mut sched = Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
    let mut e = engines(1, 8 << 30);
    let cfg = ServeConfig { max_iterations: 1_000_000, ..Default::default() };
    let r = CoordinatorBuilder::from_config(cfg)
        .sink(Box::new(telemetry.clone()))
        .priority_shaper(Box::new(policy))
        .dispatch_shards(8)
        .build(&trace, &mut e, &mut sched)
        .unwrap()
        .run_to_completion()
        .unwrap();
    assert_eq!(r.n(), 12, "rebuild path with shedding still finishes all");
}

#[test]
fn deterministic_given_seed() {
    let a = run(Policy::Isrtf, 2, 3.0, 50, 31);
    let b = run(Policy::Isrtf, 2, 3.0, 50, 31);
    assert_eq!(a.n(), b.n());
    assert!((a.avg_jct_s() - b.avg_jct_s()).abs() < 1e-9);
    assert_eq!(a.sched_iterations, b.sched_iterations);
}

#[test]
fn higher_rps_multiple_worsens_jct() {
    let low = run(Policy::Fcfs, 1, 1.0, 60, 37);
    let high = run(Policy::Fcfs, 1, 5.0, 60, 37);
    assert!(high.avg_jct_s() > low.avg_jct_s());
}

// ---------------------------------------------------------------------------
// JCT attribution + shadow counterfactual (PR 8)
// ---------------------------------------------------------------------------

/// Run one seeded trace with an [`AttributionSink`] registered and return
/// (report, sink) so callers can cross-check the two accountings.
fn run_attributed(policy: Policy, predictor: Box<dyn LengthPredictor>,
                  workers: usize, rps: f64, n: usize, seed: u64,
                  preemption: PreemptionPolicy, kv_bytes: usize)
                  -> (ServeReport, AttributionSink) {
    let corpus = Corpus::synthetic(400, seed);
    let mut gen = RequestGenerator::fabrix(rps, seed);
    let trace = gen.trace(&corpus, n);
    let mut sched = Scheduler::new(policy, predictor);
    let cfg = ServeConfig {
        workers,
        preemption,
        max_iterations: 5_000_000,
        seed,
        ..Default::default()
    };
    let sink = AttributionSink::default();
    let mut e = engines(workers, kv_bytes);
    let report = CoordinatorBuilder::from_config(cfg)
        .sink(Box::new(sink.clone()))
        .build(&trace, &mut e, &mut sched)
        .unwrap()
        .run_to_completion()
        .unwrap();
    (report, sink)
}

#[test]
fn prop_attribution_components_sum_to_jct() {
    // the tentpole invariant, end to end: for random traces under every
    // policy shape — FCFS, oracle SRPT, ISRTF with a noisy predictor,
    // and a KV pool tiny enough to force preemptions — each finished
    // job's five-way breakdown reproduces its report JCT within 1 ms,
    // and execution never exceeds measured service
    let cases: Vec<(Policy, Box<dyn Fn(u64) -> Box<dyn LengthPredictor>>,
                    PreemptionPolicy, usize)> = vec![
        (Policy::Fcfs, Box::new(|_| Box::new(OraclePredictor)),
         PreemptionPolicy::default(), 8 << 30),
        (Policy::Srpt, Box::new(|_| Box::new(OraclePredictor)),
         PreemptionPolicy::default(), 8 << 30),
        (Policy::Isrtf,
         Box::new(|s| Box::new(SurrogatePredictor::calibrated(s))),
         PreemptionPolicy::default(), 8 << 30),
        // a 100 MiB pool forces evictions (cf. the preemption test above)
        (Policy::Srpt, Box::new(|_| Box::new(OraclePredictor)),
         PreemptionPolicy { enabled: true, max_preemptions_per_job: 3,
                            max_per_iteration: usize::MAX },
         100 << 20),
    ];
    for (policy, predictor_for, preemption, kv) in &cases {
        for seed in [11u64, 23, 59] {
            let (report, sink) = run_attributed(
                *policy, predictor_for(seed), 2, 3.0, 50, seed,
                preemption.clone(), *kv);
            assert_eq!(report.n(), 50);
            assert_eq!(sink.finished_len(), 50);
            for rec in &report.records {
                let ex = sink
                    .explain(rec.id)
                    .unwrap_or_else(|| panic!("job {} has no explain \
                                               record", rec.id));
                let total = ex.breakdown.total_ms();
                assert!(
                    (total - rec.jct_ms).abs() < 1.0,
                    "{:?} seed {seed} job {}: breakdown {total} != jct {}",
                    policy, rec.id, rec.jct_ms
                );
                assert!(ex.breakdown.execution_ms
                            <= rec.service_ms + 1e-6,
                        "execution cannot exceed measured service");
                let b = ex.breakdown;
                for part in [b.queueing_ms, b.hol_blocking_ms,
                             b.preemption_stall_ms, b.failover_stall_ms,
                             b.execution_ms] {
                    assert!(part >= 0.0, "components are non-negative");
                }
            }
        }
    }
}

#[test]
fn shadow_replay_is_deterministic_and_fcfs_counterfactual_is_positive() {
    // acceptance: under ISRTF the FCFS counterfactual must report a
    // positive saved ratio (the paper's 19.6% claim, measured live), and
    // two identical runs must produce bit-identical shadow aggregates
    let run_shadow = |mode: ShadowMode| {
        let corpus = Corpus::synthetic(400, 101);
        let mut gen = RequestGenerator::fabrix(5.0, 101);
        let trace = gen.trace(&corpus, 80);
        let mut sched = Scheduler::new(
            Policy::Isrtf, Box::new(SurrogatePredictor::calibrated(101)));
        let cfg = ServeConfig {
            workers: 1,
            max_iterations: 5_000_000,
            seed: 101,
            ..Default::default()
        };
        let shadow = ShadowScheduler::new(mode, 512);
        let mut e = engines(1, 8 << 30);
        CoordinatorBuilder::from_config(cfg)
            .sink(Box::new(shadow.clone()))
            .build(&trace, &mut e, &mut sched)
            .unwrap()
            .run_to_completion()
            .unwrap();
        shadow.snapshot()
    };
    let a = run_shadow(ShadowMode::Fcfs);
    let b = run_shadow(ShadowMode::Fcfs);
    assert_eq!(a.compared, 80);
    assert_eq!(a.compared, b.compared);
    assert_eq!(a.sum_shadow_ms.to_bits(), b.sum_shadow_ms.to_bits(),
               "shadow replay must be bit-deterministic");
    assert_eq!(a.sum_real_ms.to_bits(), b.sum_real_ms.to_bits());
    assert_eq!(a.delta_ms.count(), b.delta_ms.count());
    assert!(a.saved_ratio > 0.0,
            "ISRTF should beat its FCFS counterfactual under load: \
             real {} vs shadow {}", a.sum_real_ms, a.sum_shadow_ms);
}

// ---------------------------------------------------------------------------
// online learning-to-rank predictor (PR 10)
// ---------------------------------------------------------------------------

/// Length-skewed trace whose prompt *content* encodes the response length
/// (`total = 5 + v/4` for repeated token id `v`) while the prompt *length*
/// is uncorrelated noise.  A scalar plen-based learner cannot rank it; a
/// content-reading learner can.  The quadratic skew makes short responses
/// common and long ones rare — the regime where SRPT-style ordering pays.
fn content_coded_trace(n: usize, seed: u64, gap_ms: f64) -> Vec<TraceRequest> {
    let mut rng = Pcg64::new(seed);
    (0..n as u64)
        .map(|i| {
            let r = rng.below(1984) as f64 / 1984.0;
            let v = 16 + (1900.0 * r * r) as i32;
            let plen = 8 + rng.below(32) as usize;
            TraceRequest {
                id: i,
                arrival_ms: i as f64 * gap_ms,
                prompt: vec![v; plen],
                total_len: 5 + v as usize / 4,
                topic: 0,
                tenant: None,
            }
        })
        .collect()
}

fn run_rank_trace(trace: &[TraceRequest],
                  predictor: Box<dyn LengthPredictor>)
                  -> (ServeReport, f64) {
    let cfg = ServeConfig {
        workers: 1,
        max_iterations: 5_000_000,
        seed: 7,
        ..Default::default()
    };
    let mut sched = Scheduler::new(Policy::Isrtf, predictor);
    let mut e = engines(1, 8 << 30);
    let telemetry = TelemetrySink::new(1);
    let report = CoordinatorBuilder::from_config(cfg)
        .sink(Box::new(telemetry.clone()))
        .build(trace, &mut e, &mut sched)
        .unwrap()
        .run_to_completion()
        .unwrap();
    let tau = telemetry.with_state(|st| st.predictor.kendall.tau());
    (report, tau)
}

#[test]
fn online_rank_predictor_beats_heuristic_on_content_coded_trace() {
    // acceptance: on a skewed synthetic trace, ISRTF driven by the online
    // RankPredictor must reach a higher live Kendall-τ than the
    // plen-regression heuristic after warm-up AND yield a lower mean JCT.
    // The heuristic's predicted totals collapse to ~EWMA for every job
    // still under the running mean, so its live τ is capped well below a
    // learner that reads the content code.
    let trace = content_coded_trace(260, 97, 250.0);
    let (rank_report, rank_tau) =
        run_rank_trace(&trace, Box::new(RankPredictor::new(7)));
    let (heur_report, heur_tau) =
        run_rank_trace(&trace, Box::new(HeuristicPredictor::new()));
    assert_eq!(rank_report.n(), 260);
    assert_eq!(heur_report.n(), 260);
    assert!(rank_tau.is_finite() && heur_tau.is_finite(),
            "live τ must be populated: rank {rank_tau} heur {heur_tau}");
    assert!(rank_tau > heur_tau + 0.05,
            "rank τ {rank_tau:.3} must clear heuristic τ {heur_tau:.3}");
    assert!(rank_tau > 0.5, "rank τ {rank_tau:.3} too low after warm-up");
    assert!(rank_report.avg_jct_s() < heur_report.avg_jct_s(),
            "rank JCT {} must beat heuristic JCT {}",
            rank_report.avg_jct_s(), heur_report.avg_jct_s());
}

#[test]
fn rank_predictor_runs_are_bit_identical_across_reruns() {
    // determinism: fixed-seed rank runs must be bit-identical, and the
    // incremental index must match the classic per-window rebuild even
    // though the two paths call predict() a different number of times —
    // predict is pure; training happens only on the completion path,
    // whose order both paths share.
    let trace = content_coded_trace(60, 41, 200.0);
    let run = |rebuild: bool| {
        let cfg = ServeConfig {
            workers: 2,
            max_iterations: 5_000_000,
            seed: 41,
            ..Default::default()
        };
        let mut sched = Scheduler::new(Policy::Isrtf,
                                       Box::new(RankPredictor::new(41)));
        let mut e = engines(2, 8 << 30);
        CoordinatorBuilder::from_config(cfg)
            .full_rebuild(rebuild)
            .build(&trace, &mut e, &mut sched)
            .unwrap()
            .run_to_completion()
            .unwrap()
    };
    let a = run(false);
    let b = run(false);
    assert_reports_identical(&a, &b);
    let reb = run(true);
    assert_eq!(a.n(), 60);
    assert_reports_identical(&a, &reb);
}

#[test]
fn swapping_heuristic_for_rank_preserves_completed_job_set() {
    // safety: the predictor reorders service, it must never change *what*
    // completes — on the plentiful-KV path and under tiny-pool preemption
    // pressure, on both dispatch paths
    let trace = content_coded_trace(50, 53, 150.0);
    let completed = |kv: usize, rebuild: bool,
                     predictor: Box<dyn LengthPredictor>| {
        let cfg = ServeConfig {
            workers: 2,
            max_iterations: 5_000_000,
            seed: 53,
            ..Default::default()
        };
        let mut sched = Scheduler::new(Policy::Isrtf, predictor);
        let mut e: Vec<Box<dyn Engine>> = (0..2)
            .map(|_| Box::new(SimEngine::new(profile(2000.0), 50, 4, kv))
                 as Box<dyn Engine>)
            .collect();
        let r = CoordinatorBuilder::from_config(cfg)
            .full_rebuild(rebuild)
            .build(&trace, &mut e, &mut sched)
            .unwrap()
            .run_to_completion()
            .unwrap();
        let mut ids: Vec<u64> = r.records.iter().map(|rec| rec.id).collect();
        ids.sort_unstable();
        ids
    };
    for kv in [8usize << 30, TINY_KV] {
        for rebuild in [false, true] {
            let heur = completed(kv, rebuild, Box::new(HeuristicPredictor::new()));
            let rank = completed(kv, rebuild, Box::new(RankPredictor::new(53)));
            assert_eq!(heur.len(), 50, "kv={kv} rebuild={rebuild}");
            assert_eq!(heur, rank,
                       "completed sets diverged (kv={kv} rebuild={rebuild})");
        }
    }
}

#[test]
fn prop_rank_predictor_converges_on_monotone_workloads() {
    // satellite property: after N random-order completions of a workload
    // whose content id is monotone in response length, the predicted
    // ordering reaches Kendall-τ ≥ 0.8 against ground truth — under
    // shuffled arrival order and with tied lengths present
    use elis::testing::prop;
    prop::check("rank-converges", 8, |g| {
        let seed = g.usize_in(1, 10_000) as u64;
        let n_items = g.usize_in(12, 24);
        let rounds = g.usize_in(25, 50);
        // monotone catalogue: higher content id => longer response; prompt
        // length is independent noise
        let mut items: Vec<(Vec<i32>, usize)> = (0..n_items)
            .map(|k| {
                let v = 40 + 80 * k as i32;
                let plen = 6 + g.usize_in(0, 20);
                (vec![v; plen], 5 + v as usize / 4)
            })
            .collect();
        // a duplicated item yields exactly tied lengths in the eval set
        let dup = items[n_items / 2].clone();
        items.push(dup);
        let mut p = RankPredictor::new(seed);
        let mut order = Pcg64::new(seed ^ 0x5351);
        for _ in 0..rounds {
            for _ in 0..items.len() {
                let pick = order.below(items.len() as u64) as usize;
                let (prompt, total) = &items[pick];
                let response = vec![prompt[0]; *total];
                p.observe_rich(&ObservedCompletion {
                    prompt,
                    response: &response,
                    total_len: *total,
                });
            }
        }
        let queries: Vec<PredictQuery<'_>> = items
            .iter()
            .enumerate()
            .map(|(i, (prompt, total))| PredictQuery {
                job_id: i as u64,
                prompt,
                gen_suffix: &[],
                generated: 0,
                true_total: *total,
            })
            .collect();
        let preds = p.predict(&queries);
        let truths: Vec<f64> =
            items.iter().map(|(_, t)| *t as f64).collect();
        let tau = kendall_tau(&preds, &truths);
        assert!(tau >= 0.8,
                "τ {tau:.3} after {} completions (seed {seed})",
                rounds * items.len());
    });
}
