//! `elis loadgen` — a dependency-free load harness for `elis serve`.
//!
//! The paper's industrial claim is interactive serving at scale; this
//! module measures it from the *client* side of the wire: it drives many
//! concurrent `POST /v1/generate` connections against a live frontend
//! and reports TTFT / TPOT / JCT percentiles as users would see them
//! (socket to socket, including admission queueing and HTTP overhead),
//! not as the coordinator accounts them internally.
//!
//! Two drive modes:
//!
//! * **closed-loop** (`rps == 0.0`, the default): `streams` worker
//!   threads each hold one keep-alive connection and issue streaming
//!   requests back to back until the deadline.  Concurrency is exact —
//!   `--streams 1000` *is* 1000 concurrent streams — which is what the
//!   CI smoke asserts.
//! * **open-loop** (`rps > 0.0`): a spawner thread draws exponential
//!   interarrival gaps (Poisson process) and launches one thread per
//!   request, shedding client-side beyond `max_in_flight` — arrival
//!   pressure independent of server latency, the honest way to measure
//!   an overloaded server.
//!
//! Every sample is measured with `Instant` on the request thread; the
//! sketches are P² estimators ([`QuantileSketch`]), so memory stays O(1)
//! per metric no matter how long the run is.
//!
//! [`run`] is callable from tests; the `elis loadgen` subcommand wraps
//! it and writes the report as `BENCH_serve.json`.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::cluster::SseDecoder;
use crate::telemetry::{Breakdown, QuantileSketch};
use crate::util::json::Json;

/// Everything `elis loadgen` can be told from the CLI.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// frontend address, `host:port`
    pub target: String,
    /// how long to drive load
    pub duration: Duration,
    /// closed-loop: concurrent streaming connections
    pub streams: usize,
    /// open-loop arrival rate in requests/second; `0.0` = closed-loop
    pub rps: f64,
    /// open-loop: shed client-side beyond this many in-flight requests
    /// (`0` = unbounded)
    pub max_in_flight: usize,
    /// `total_len` sent with every request (the response length: the
    /// sim engine generates exactly this many tokens)
    pub total_len: usize,
    /// `prompt_len` sent with every request
    pub prompt_len: usize,
    /// round-robin tenant labels; empty = no tenant field
    pub tenants: Vec<String>,
    /// `stream: true` requests (SSE) vs `wait: true` (single JSON reply)
    pub stream: bool,
    /// RNG seed for open-loop interarrival draws
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> LoadgenConfig {
        LoadgenConfig {
            target: "127.0.0.1:8080".to_string(),
            duration: Duration::from_secs(10),
            streams: 8,
            rps: 0.0,
            max_in_flight: 0,
            total_len: 120,
            prompt_len: 16,
            tenants: Vec::new(),
            stream: true,
            seed: 1,
        }
    }
}

/// Client-side measurements for one finished run.
#[derive(Debug)]
pub struct LoadReport {
    /// requests put on the wire
    pub sent: u64,
    /// requests that reached a terminal success (done event / 200 JSON)
    pub ok: u64,
    /// transport or protocol failures
    pub errors: u64,
    /// `429` responses from front-door admission control
    pub rejected: u64,
    /// open-loop requests never sent because `max_in_flight` was hit
    pub shed: u64,
    /// token ids received across all streams
    pub tokens_streamed: u64,
    /// time to first token chunk, ms (streaming mode only)
    pub ttft_ms: QuantileSketch,
    /// time per output token after the first chunk, ms
    pub tpot_ms: QuantileSketch,
    /// request completion time, ms
    pub jct_ms: QuantileSketch,
    /// wall time the run actually took
    pub elapsed_s: f64,
    /// peak concurrent in-flight requests observed
    pub peak_in_flight: u64,
    /// `(jct_ms, trace_id)` of the slowest requests, slowest first —
    /// feed the ids to the server's `/debug/trace?job=<id>` to see where
    /// the tail latency went
    pub trace_sample: Vec<(f64, u64)>,
    /// replies that carried a server-side JCT breakdown object
    pub breakdown_count: u64,
    /// component-wise sums of those breakdowns (ms); divide by
    /// `breakdown_count` for the fleet-average attribution
    pub breakdown_sum: Breakdown,
}

impl LoadReport {
    /// The `BENCH_serve.json` document.
    pub fn to_json(&self, cfg: &LoadgenConfig) -> Json {
        let sketch = |s: &QuantileSketch| {
            Json::obj(vec![
                ("count", Json::Num(s.count() as f64)),
                ("mean", Json::Num(if s.count() > 0 { s.mean() } else { 0.0 })),
                ("p50", Json::Num(if s.count() > 0 { s.p50() } else { 0.0 })),
                ("p90", Json::Num(if s.count() > 0 { s.p90() } else { 0.0 })),
                ("p99", Json::Num(if s.count() > 0 { s.p99() } else { 0.0 })),
            ])
        };
        Json::obj(vec![
            ("bench", Json::Str("serve".into())),
            ("mode", Json::Str(
                if cfg.rps > 0.0 { "open-loop" } else { "closed-loop" }
                    .into(),
            )),
            ("target", Json::Str(cfg.target.clone())),
            ("streams", Json::Num(cfg.streams as f64)),
            ("rps", Json::Num(cfg.rps)),
            ("streaming", Json::Bool(cfg.stream)),
            ("total_len", Json::Num(cfg.total_len as f64)),
            ("duration_s", Json::Num(cfg.duration.as_secs_f64())),
            ("elapsed_s", Json::Num(self.elapsed_s)),
            ("sent", Json::Num(self.sent as f64)),
            ("ok", Json::Num(self.ok as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("tokens_streamed", Json::Num(self.tokens_streamed as f64)),
            ("peak_in_flight", Json::Num(self.peak_in_flight as f64)),
            ("ttft_ms", sketch(&self.ttft_ms)),
            ("tpot_ms", sketch(&self.tpot_ms)),
            ("jct_ms", sketch(&self.jct_ms)),
            ("trace_sample", Json::Arr(
                self.trace_sample
                    .iter()
                    .map(|&(jct_ms, trace_id)| Json::obj(vec![
                        ("jct_ms", Json::Num(jct_ms)),
                        ("trace_id", Json::Num(trace_id as f64)),
                    ]))
                    .collect(),
            )),
            ("breakdown", {
                let n = (self.breakdown_count as f64).max(1.0);
                let b = &self.breakdown_sum;
                Json::obj(vec![
                    ("count", Json::Num(self.breakdown_count as f64)),
                    ("queueing_ms", Json::Num(b.queueing_ms / n)),
                    ("hol_blocking_ms", Json::Num(b.hol_blocking_ms / n)),
                    ("preemption_stall_ms",
                     Json::Num(b.preemption_stall_ms / n)),
                    ("failover_stall_ms",
                     Json::Num(b.failover_stall_ms / n)),
                    ("execution_ms", Json::Num(b.execution_ms / n)),
                ])
            }),
        ])
    }
}

/// How many of the slowest requests' trace ids the report keeps — enough
/// to paste into `/debug/trace?job=<id>` after a run, small enough to
/// stay out of the way in `BENCH_serve.json`.
const TRACE_SAMPLE: usize = 5;

/// One finished request's client-side timings.
struct Sample {
    ttft_ms: f64,
    jct_ms: f64,
    tokens: u64,
    /// server-assigned trace id (the job id), when the reply carried one
    trace_id: Option<u64>,
    /// server-side JCT attribution, when the reply carried one
    breakdown: Option<Breakdown>,
}

/// Parse a reply's `breakdown` object into component milliseconds;
/// `None` when the field is absent or null (attribution disabled).
fn parse_breakdown(j: &Json) -> Option<Breakdown> {
    let b = j.get("breakdown")?;
    let f = |k: &str| b.get(k).and_then(Json::as_f64).unwrap_or(0.0);
    b.as_obj().map(|_| Breakdown {
        queueing_ms: f("queueing_ms"),
        hol_blocking_ms: f("hol_blocking_ms"),
        preemption_stall_ms: f("preemption_stall_ms"),
        failover_stall_ms: f("failover_stall_ms"),
        execution_ms: f("execution_ms"),
    })
}

/// Shared counters the request threads bump as they go.
#[derive(Default)]
struct Counters {
    sent: AtomicU64,
    ok: AtomicU64,
    errors: AtomicU64,
    rejected: AtomicU64,
    shed: AtomicU64,
    tokens: AtomicU64,
    in_flight: AtomicUsize,
    peak: AtomicUsize,
}

impl Counters {
    fn enter(&self) -> usize {
        let now = self.in_flight.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak.fetch_max(now, Ordering::Relaxed);
        now
    }

    fn exit(&self) {
        self.in_flight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Drive the configured load and gather the report.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadReport> {
    if cfg.rps <= 0.0 && cfg.streams == 0 {
        bail!("closed-loop mode needs --streams >= 1");
    }
    let counters = Arc::new(Counters::default());
    let (sample_tx, sample_rx) = channel::<Sample>();
    let start = Instant::now();
    let deadline = start + cfg.duration;

    let handles: Vec<JoinHandle<()>> = if cfg.rps > 0.0 {
        spawn_open_loop(cfg, &counters, &sample_tx, deadline)
    } else {
        spawn_closed_loop(cfg, &counters, &sample_tx, deadline)
    };
    drop(sample_tx); // the receiver drains until the last clone is gone

    let mut ttft = QuantileSketch::new();
    let mut tpot = QuantileSketch::new();
    let mut jct = QuantileSketch::new();
    let mut slowest: Vec<(f64, u64)> = Vec::new();
    let mut breakdown_count = 0u64;
    let mut breakdown_sum = Breakdown::default();
    let prune = |v: &mut Vec<(f64, u64)>| {
        v.sort_by(|a, b| b.0.total_cmp(&a.0));
        v.truncate(TRACE_SAMPLE);
    };
    for s in sample_rx.iter() {
        if s.ttft_ms.is_finite() {
            ttft.add(s.ttft_ms);
            if s.tokens > 1 {
                tpot.add((s.jct_ms - s.ttft_ms) / (s.tokens - 1) as f64);
            }
        }
        jct.add(s.jct_ms);
        if let Some(b) = s.breakdown {
            breakdown_count += 1;
            breakdown_sum.queueing_ms += b.queueing_ms;
            breakdown_sum.hol_blocking_ms += b.hol_blocking_ms;
            breakdown_sum.preemption_stall_ms += b.preemption_stall_ms;
            breakdown_sum.failover_stall_ms += b.failover_stall_ms;
            breakdown_sum.execution_ms += b.execution_ms;
        }
        if let Some(id) = s.trace_id {
            slowest.push((s.jct_ms, id));
            if slowest.len() > 256 {
                prune(&mut slowest);
            }
        }
    }
    prune(&mut slowest);
    for h in handles {
        let _ = h.join();
    }

    Ok(LoadReport {
        sent: counters.sent.load(Ordering::Relaxed),
        ok: counters.ok.load(Ordering::Relaxed),
        errors: counters.errors.load(Ordering::Relaxed),
        rejected: counters.rejected.load(Ordering::Relaxed),
        shed: counters.shed.load(Ordering::Relaxed),
        tokens_streamed: counters.tokens.load(Ordering::Relaxed),
        ttft_ms: ttft,
        tpot_ms: tpot,
        jct_ms: jct,
        elapsed_s: start.elapsed().as_secs_f64(),
        peak_in_flight: counters.peak.load(Ordering::Relaxed) as u64,
        trace_sample: slowest,
        breakdown_count,
        breakdown_sum,
    })
}

/// Closed-loop: `streams` threads, each looping requests on one
/// keep-alive connection until the deadline.
fn spawn_closed_loop(cfg: &LoadgenConfig, counters: &Arc<Counters>,
                     sample_tx: &Sender<Sample>, deadline: Instant)
                     -> Vec<JoinHandle<()>> {
    (0..cfg.streams.max(1))
        .map(|i| {
            let cfg = cfg.clone();
            let counters = counters.clone();
            let tx = sample_tx.clone();
            std::thread::Builder::new()
                .name(format!("elis-loadgen-{i}"))
                .spawn(move || {
                    let mut conn: Option<TcpStream> = None;
                    let mut seq = 0u64;
                    while Instant::now() < deadline {
                        let stream = match conn.take() {
                            Some(s) => s,
                            None => match connect(&cfg.target) {
                                Ok(s) => s,
                                Err(_) => {
                                    counters
                                        .errors
                                        .fetch_add(1, Ordering::Relaxed);
                                    std::thread::sleep(
                                        Duration::from_millis(50),
                                    );
                                    continue;
                                }
                            },
                        };
                        counters.enter();
                        let kept = one_request(
                            stream, &cfg, i as u64, seq, &counters, &tx,
                            deadline,
                        );
                        counters.exit();
                        conn = kept;
                        seq += 1;
                    }
                })
                .expect("spawning loadgen thread")
        })
        .collect()
}

/// Open-loop: a Poisson spawner launching one thread per request.
fn spawn_open_loop(cfg: &LoadgenConfig, counters: &Arc<Counters>,
                   sample_tx: &Sender<Sample>, deadline: Instant)
                   -> Vec<JoinHandle<()>> {
    let cfg = cfg.clone();
    let counters = counters.clone();
    let tx = sample_tx.clone();
    let spawner = std::thread::Builder::new()
        .name("elis-loadgen-spawn".to_string())
        .spawn(move || {
            let mut rng = Xorshift64::new(cfg.seed);
            let mut workers: Vec<JoinHandle<()>> = Vec::new();
            let mut seq = 0u64;
            while Instant::now() < deadline {
                // exponential interarrival gap for a Poisson process
                let gap_s = -rng.uniform().ln() / cfg.rps;
                let wake = Instant::now()
                    + Duration::from_secs_f64(gap_s.clamp(0.0, 10.0));
                while Instant::now() < wake {
                    if Instant::now() >= deadline {
                        break;
                    }
                    std::thread::sleep(Duration::from_micros(200));
                }
                if Instant::now() >= deadline {
                    break;
                }
                if cfg.max_in_flight > 0
                    && counters.in_flight.load(Ordering::Relaxed)
                        >= cfg.max_in_flight
                {
                    counters.shed.fetch_add(1, Ordering::Relaxed);
                    seq += 1;
                    continue;
                }
                let cfg2 = cfg.clone();
                let counters2 = counters.clone();
                let tx2 = tx.clone();
                let n = seq;
                seq += 1;
                if workers.len() % 64 == 0 {
                    workers.retain(|w| !w.is_finished());
                }
                let spawned = std::thread::Builder::new()
                    .name("elis-loadgen-req".to_string())
                    .spawn(move || {
                        counters2.enter();
                        match connect(&cfg2.target) {
                            Ok(s) => {
                                one_request(s, &cfg2, n, 0, &counters2,
                                            &tx2, deadline);
                            }
                            Err(_) => {
                                counters2
                                    .errors
                                    .fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        counters2.exit();
                    });
                match spawned {
                    Ok(h) => workers.push(h),
                    Err(_) => {
                        counters.shed.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            for w in workers {
                let _ = w.join();
            }
        })
        .expect("spawning loadgen spawner thread");
    vec![spawner]
}

fn connect(target: &str) -> std::io::Result<TcpStream> {
    let stream = TcpStream::connect(target)?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    stream.set_write_timeout(Some(Duration::from_secs(10)))?;
    Ok(stream)
}

/// Issue one `/v1/generate` on `stream`, record its sample, and return
/// the connection if it is still reusable (keep-alive).
fn one_request(mut stream: TcpStream, cfg: &LoadgenConfig, worker: u64,
               seq: u64, counters: &Counters, tx: &Sender<Sample>,
               deadline: Instant) -> Option<TcpStream> {
    let tenant = if cfg.tenants.is_empty() {
        String::new()
    } else {
        let t = &cfg.tenants[(worker as usize + seq as usize)
            % cfg.tenants.len()];
        format!(r#","tenant":"{t}""#)
    };
    let mode = if cfg.stream { r#""stream":true"# } else { r#""wait":true"# };
    let body = format!(
        r#"{{{mode},"total_len":{},"prompt_len":{},"topic":{}{tenant}}}"#,
        cfg.total_len,
        cfg.prompt_len,
        (worker + seq) % 8,
    );
    let request = format!(
        "POST /v1/generate HTTP/1.1\r\nHost: {}\r\n\
         Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{}",
        cfg.target,
        body.len(),
        body
    );
    let t0 = Instant::now();
    if stream.write_all(request.as_bytes()).is_err() {
        counters.errors.fetch_add(1, Ordering::Relaxed);
        return None;
    }
    counters.sent.fetch_add(1, Ordering::Relaxed);

    let head = match read_head(&mut stream) {
        Ok(h) => h,
        Err(_) => {
            counters.errors.fetch_add(1, Ordering::Relaxed);
            return None;
        }
    };
    if head.status == 429 {
        counters.rejected.fetch_add(1, Ordering::Relaxed);
        // consume the (small) body so the connection can be reused
        return drain_body(stream, &head);
    }
    if head.status != 200 {
        counters.errors.fetch_add(1, Ordering::Relaxed);
        return drain_body(stream, &head);
    }
    if head.chunked {
        read_sse(stream, &head, t0, counters, tx, deadline)
    } else {
        read_json_reply(stream, &head, t0, counters, tx)
    }
}

/// Drain one chunked SSE response, timing TTFT (first token chunk) and
/// JCT (`done` event).
fn read_sse(mut stream: TcpStream, head: &HeadInfo, t0: Instant,
            counters: &Counters, tx: &Sender<Sample>, deadline: Instant)
            -> Option<TcpStream> {
    let mut dec = SseDecoder::default();
    let mut events = dec.push(&head.leftover);
    let mut buf = [0u8; 4096];
    let mut ttft = f64::NAN;
    let mut tokens = 0u64;
    let mut trace_id = None;
    let hard_stop = deadline + Duration::from_secs(30);
    loop {
        for ev in events.drain(..) {
            match ev.name.as_deref() {
                None => {
                    // token chunk: count ids in the "tokens" array
                    let n = Json::parse(&ev.data)
                        .ok()
                        .and_then(|j| {
                            j.get("tokens").and_then(Json::as_i32_vec)
                        })
                        .map_or(0, |v| v.len() as u64);
                    if n > 0 && !ttft.is_finite() {
                        ttft = t0.elapsed().as_secs_f64() * 1e3;
                    }
                    tokens += n;
                    counters.tokens.fetch_add(n, Ordering::Relaxed);
                }
                Some("done") => {
                    counters.ok.fetch_add(1, Ordering::Relaxed);
                    let breakdown = Json::parse(&ev.data)
                        .ok()
                        .and_then(|j| parse_breakdown(&j));
                    let _ = tx.send(Sample {
                        ttft_ms: ttft,
                        jct_ms: t0.elapsed().as_secs_f64() * 1e3,
                        tokens,
                        trace_id,
                        breakdown,
                    });
                    // the server leaves the connection reusable after
                    // the terminating chunk
                    return Some(stream);
                }
                Some("accepted") => {
                    trace_id = Json::parse(&ev.data)
                        .ok()
                        .and_then(|j| {
                            j.get("trace_id").and_then(Json::as_usize)
                        })
                        .map(|id| id as u64);
                }
                Some(_) => { /* error markers */ }
            }
        }
        if dec.is_done() {
            // stream ended without a done event (server-side error)
            counters.errors.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        if Instant::now() > hard_stop {
            counters.errors.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        match stream.read(&mut buf) {
            Ok(0) => {
                counters.errors.fetch_add(1, Ordering::Relaxed);
                return None;
            }
            Ok(n) => events = dec.push(&buf[..n]),
            Err(_) => {
                counters.errors.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        }
    }
}

/// Read one fixed-length JSON reply (`wait: true` mode).
fn read_json_reply(mut stream: TcpStream, head: &HeadInfo, t0: Instant,
                   counters: &Counters, tx: &Sender<Sample>)
                   -> Option<TcpStream> {
    let want = head.content_length.unwrap_or(0);
    let mut body = head.leftover.clone();
    let mut buf = [0u8; 4096];
    while body.len() < want {
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => body.extend_from_slice(&buf[..n]),
            Err(_) => {
                counters.errors.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        }
    }
    let jct = t0.elapsed().as_secs_f64() * 1e3;
    let parsed = std::str::from_utf8(&body)
        .ok()
        .and_then(|t| Json::parse(t).ok());
    let tokens = parsed
        .as_ref()
        .and_then(|j| j.get("tokens").and_then(Json::as_usize))
        .unwrap_or(0) as u64;
    let trace_id = parsed
        .as_ref()
        .and_then(|j| j.get("trace_id").and_then(Json::as_usize))
        .map(|id| id as u64);
    let breakdown = parsed.as_ref().and_then(parse_breakdown);
    counters.ok.fetch_add(1, Ordering::Relaxed);
    counters.tokens.fetch_add(tokens, Ordering::Relaxed);
    let _ = tx.send(Sample { ttft_ms: f64::NAN, jct_ms: jct, tokens,
                             trace_id, breakdown });
    if head.keep_alive { Some(stream) } else { None }
}

/// Parsed response head plus whatever body bytes rode in with it.
struct HeadInfo {
    status: u16,
    content_length: Option<usize>,
    chunked: bool,
    keep_alive: bool,
    leftover: Vec<u8>,
}

/// Read until the end of the response headers; body bytes already read
/// come back in `leftover`.
fn read_head(stream: &mut TcpStream) -> Result<HeadInfo> {
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 1024];
    let head_end = loop {
        if let Some(pos) =
            buf.windows(4).position(|w| w == b"\r\n\r\n")
        {
            break pos;
        }
        if buf.len() > 64 << 10 {
            bail!("response head exceeds 64 KiB");
        }
        let n = stream.read(&mut chunk).context("reading response head")?;
        if n == 0 {
            bail!("connection closed before response head completed");
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).into_owned();
    let leftover = buf[head_end + 4..].to_vec();
    let mut lines = head.lines();
    let status: u16 = lines
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|s| s.parse().ok())
        .context("unparseable status line")?;
    let mut content_length = None;
    let mut chunked = false;
    let mut keep_alive = true;
    for line in lines {
        let Some((name, value)) = line.split_once(':') else { continue };
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim().to_ascii_lowercase();
        match name.as_str() {
            "content-length" => content_length = value.parse().ok(),
            "transfer-encoding" => chunked = value.contains("chunked"),
            "connection" => keep_alive = !value.contains("close"),
            _ => {}
        }
    }
    Ok(HeadInfo { status, content_length, chunked, keep_alive, leftover })
}

/// Consume a fixed-length body so the connection stays framed; returns
/// the connection if reusable.
fn drain_body(mut stream: TcpStream, head: &HeadInfo)
              -> Option<TcpStream> {
    let want = head.content_length.unwrap_or(0);
    let mut got = head.leftover.len();
    let mut buf = [0u8; 1024];
    while got < want {
        match stream.read(&mut buf) {
            Ok(0) => return None,
            Ok(n) => got += n,
            Err(_) => return None,
        }
    }
    if head.keep_alive { Some(stream) } else { None }
}

/// Tiny xorshift64 PRNG — deterministic interarrival draws without any
/// external crate.
struct Xorshift64 {
    state: u64,
}

impl Xorshift64 {
    fn new(seed: u64) -> Xorshift64 {
        Xorshift64 { state: seed.max(1) }
    }

    fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.state = x;
        x
    }

    /// Uniform in (0, 1] — never exactly 0, so `ln()` stays finite.
    fn uniform(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64 + 1.0) / (1u64 << 53) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xorshift_uniform_is_in_unit_interval_and_deterministic() {
        let mut a = Xorshift64::new(42);
        let mut b = Xorshift64::new(42);
        for _ in 0..10_000 {
            let u = a.uniform();
            assert!(u > 0.0 && u <= 1.0, "{u}");
            assert!((u - b.uniform()).abs() < 1e-18);
        }
        let mut c = Xorshift64::new(7);
        assert!((a.uniform() - c.uniform()).abs() > 0.0,
                "different seeds should diverge");
    }

    #[test]
    fn head_parser_splits_status_headers_and_leftover() {
        // parse off a real socket so the signature stays TcpStream
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            s.write_all(
                b"HTTP/1.1 429 Too Many Requests\r\n\
                  Retry-After: 2\r\nContent-Length: 5\r\n\
                  Connection: close\r\n\r\nnope\n",
            )
            .unwrap();
        });
        let mut client = TcpStream::connect(addr).unwrap();
        let head = read_head(&mut client).unwrap();
        writer.join().unwrap();
        assert_eq!(head.status, 429);
        assert_eq!(head.content_length, Some(5));
        assert!(!head.chunked);
        assert!(!head.keep_alive);
        assert_eq!(head.leftover, b"nope\n");
    }

    #[test]
    fn report_json_has_the_bench_serve_schema() {
        let cfg = LoadgenConfig::default();
        let mut report = LoadReport {
            sent: 10,
            ok: 9,
            errors: 1,
            rejected: 3,
            shed: 0,
            tokens_streamed: 900,
            ttft_ms: QuantileSketch::new(),
            tpot_ms: QuantileSketch::new(),
            jct_ms: QuantileSketch::new(),
            elapsed_s: 5.0,
            peak_in_flight: 8,
            trace_sample: vec![(912.0, 4), (555.0, 9)],
            breakdown_count: 9,
            breakdown_sum: Breakdown {
                queueing_ms: 900.0,
                hol_blocking_ms: 450.0,
                preemption_stall_ms: 0.0,
                failover_stall_ms: 0.0,
                execution_ms: 1800.0,
            },
        };
        for i in 0..100 {
            report.ttft_ms.add(10.0 + i as f64);
            report.jct_ms.add(100.0 + i as f64);
        }
        let j = report.to_json(&cfg);
        assert_eq!(j.get("bench").and_then(Json::as_str), Some("serve"));
        assert_eq!(j.get("tokens_streamed").and_then(Json::as_usize),
                   Some(900));
        let ttft = j.get("ttft_ms").expect("ttft object");
        assert_eq!(ttft.get("count").and_then(Json::as_usize), Some(100));
        let p99 = ttft.get("p99").and_then(Json::as_f64).unwrap();
        assert!(p99 > 90.0 && p99 <= 110.0, "{p99}");
        // empty sketches render zeros, not NaN (JSON has no NaN)
        let tpot = j.get("tpot_ms").expect("tpot object");
        assert_eq!(tpot.get("p50").and_then(Json::as_f64), Some(0.0));
        // the slowest-request sample rides along, slowest first
        let Some(Json::Arr(sample)) = j.get("trace_sample") else {
            panic!("trace_sample must be an array");
        };
        assert_eq!(sample.len(), 2);
        assert_eq!(sample[0].get("trace_id").and_then(Json::as_usize),
                   Some(4));
        assert_eq!(sample[0].get("jct_ms").and_then(Json::as_f64),
                   Some(912.0));
        // the breakdown block reports per-request component means
        let b = j.get("breakdown").expect("breakdown object");
        assert_eq!(b.get("count").and_then(Json::as_usize), Some(9));
        assert_eq!(b.get("queueing_ms").and_then(Json::as_f64), Some(100.0));
        assert_eq!(b.get("hol_blocking_ms").and_then(Json::as_f64),
                   Some(50.0));
        assert_eq!(b.get("execution_ms").and_then(Json::as_f64), Some(200.0));
        // and the whole document round-trips through the parser
        let text = j.to_string();
        assert!(Json::parse(&text).is_ok(), "{text}");
    }

    #[test]
    fn breakdown_parser_reads_reply_objects_and_rejects_null() {
        let j = Json::parse(
            r#"{"jct_ms":30,"breakdown":{"queueing_ms":20,
                "hol_blocking_ms":2,"preemption_stall_ms":0,
                "failover_stall_ms":0,"execution_ms":8,"total_ms":30}}"#,
        )
        .unwrap();
        let b = parse_breakdown(&j).expect("object parses");
        assert_eq!(b.queueing_ms, 20.0);
        assert_eq!(b.execution_ms, 8.0);
        // attribution disabled server-side: breakdown rides as null
        let off = Json::parse(r#"{"jct_ms":30,"breakdown":null}"#).unwrap();
        assert!(parse_breakdown(&off).is_none());
        assert!(parse_breakdown(&Json::parse("{}").unwrap()).is_none());
    }
}
