//! Hash tokenizer for the runnable examples.
//!
//! The served TinyGPT is a synthetic workload (its weights are random), so
//! the tokenizer only needs to be deterministic and invertible-ish: words
//! hash into the model's vocab via FNV-1a, and ids render back as readable
//! placeholders.  Corpus prompts (the benchmark path) are already token
//! arrays and bypass this module.

/// ids 0..RESERVED-1 are reserved (0 = PAD, 1 = BOS), matching
/// `python/compile/data.py`.
pub const RESERVED: u32 = 16;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    vocab: u32,
}

impl Tokenizer {
    pub fn new(vocab: usize) -> Tokenizer {
        assert!(vocab as u32 > RESERVED * 2);
        Tokenizer { vocab: vocab as u32 }
    }

    fn hash_word(&self, w: &str) -> i32 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in w.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        (RESERVED + (h % (self.vocab - RESERVED) as u64) as u32) as i32
    }

    /// Encode text: lowercase whitespace/punctuation split, BOS-prefixed.
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut out = vec![1i32]; // BOS
        for word in text
            .split(|c: char| c.is_whitespace() || ",.;:!?\"'()".contains(c))
            .filter(|w| !w.is_empty())
        {
            out.push(self.hash_word(&word.to_lowercase()));
        }
        out
    }

    /// Decode ids to placeholder text.
    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .map(|&id| match id {
                0 => "<pad>".to_string(),
                1 => "<bos>".to_string(),
                i if (i as u32) < RESERVED => format!("<r{i}>"),
                i => format!("w{i}"),
            })
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn vocab(&self) -> usize {
        self.vocab as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_in_range() {
        let t = Tokenizer::new(2048);
        let a = t.encode("Hello, world! hello");
        let b = t.encode("hello world hello");
        assert_eq!(a, b, "case/punctuation insensitive");
        assert_eq!(a[0], 1);
        assert!(a.iter().skip(1).all(|&id| (RESERVED as i32..2048).contains(&id)));
        assert_eq!(a[1], a[3], "same word, same id");
    }

    #[test]
    fn empty_text_is_bos_only() {
        let t = Tokenizer::new(2048);
        assert_eq!(t.encode("   "), vec![1]);
    }

    #[test]
    fn decode_readable() {
        let t = Tokenizer::new(2048);
        let s = t.decode(&[1, 0, 100]);
        assert!(s.contains("<bos>") && s.contains("<pad>") && s.contains("w100"));
    }

    #[test]
    fn different_words_usually_differ() {
        let t = Tokenizer::new(2048);
        let ids: Vec<i32> = ["alpha", "beta", "gamma", "delta", "epsilon"]
            .iter()
            .map(|w| t.encode(w)[1])
            .collect();
        let mut uniq = ids.clone();
        uniq.sort();
        uniq.dedup();
        assert!(uniq.len() >= 4);
    }
}
