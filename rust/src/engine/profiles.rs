//! Served-model performance profiles — the timing/memory substitute for
//! running OPT/LLaMA on A100s.
//!
//! The paper's Table 4 gives each model's average request latency on an
//! A100; Appendix A gives KV footprints via the batch size at which vLLM
//! first preempts.  From those anchors we derive a per-window service-time
//! model for the discrete-event engine:
//!
//!   window_time = prefill_cost (first window only)
//!              + window_tokens × tpot × (1 + batch_penalty × (batch − 1))
//!
//! TPOT is anchored so that a request with the corpus' mean output length
//! at batch 1 matches Table 4's average latency.  The batch penalty models
//! the memory-bound decode regime (mild slowdown as batch grows).

use crate::runtime::manifest::ServedModelMeta;

/// Average output length (tokens) of the evaluation corpus — anchor for
/// translating Table 4 request latency into per-token time.
pub const MEAN_OUTPUT_TOKENS: f64 = 120.0;
/// Prefill : decode per-token cost ratio (prompt tokens process in parallel).
pub const PREFILL_FACTOR: f64 = 6.0;
/// Per-extra-batch-slot slowdown of a decode step.
pub const BATCH_PENALTY: f64 = 0.06;
/// A100 80 GB HBM.
pub const GPU_MEM_BYTES: usize = 80 * (1 << 30);

#[derive(Debug, Clone)]
pub struct ModelProfile {
    pub name: String,
    pub abbrev: String,
    pub params_b: f64,
    /// paper Table 4 average request latency (ms)
    pub avg_latency_ms: f64,
    /// derived: decode time per token at batch 1 (ms)
    pub tpot_ms: f64,
    /// derived: prefill cost for an average prompt (ms)
    pub prefill_ms: f64,
    pub kv_bytes_per_token: usize,
    /// paper Table 6: memory limit fraction used in preemption profiling
    pub mem_limit_frac: f64,
    /// paper Table 6: observed min preempting batch size (reference value)
    pub preempt_batch_ref: usize,
}

impl ModelProfile {
    pub fn from_meta(m: &ServedModelMeta) -> ModelProfile {
        // avg_latency ≈ prefill + tpot × mean_out  with prefill modelled as
        // PREFILL_FACTOR token-times.
        let tpot = m.avg_latency_ms / (MEAN_OUTPUT_TOKENS + PREFILL_FACTOR);
        ModelProfile {
            name: m.name.clone(),
            abbrev: m.abbrev.clone(),
            params_b: m.params_b,
            avg_latency_ms: m.avg_latency_ms,
            tpot_ms: tpot,
            prefill_ms: tpot * PREFILL_FACTOR,
            kv_bytes_per_token: m.kv_bytes_per_token,
            mem_limit_frac: m.mem_limit_frac,
            preempt_batch_ref: m.preempt_batch,
        }
    }

    /// Service time of one scheduling window (ms).
    /// `fresh` slots pay the prefill cost; decode costs scale with tokens
    /// and the batch-size penalty.
    pub fn window_ms(&self, batch: usize, window_tokens: usize, fresh: usize) -> f64 {
        if batch == 0 {
            return 0.0;
        }
        let penalty = 1.0 + BATCH_PENALTY * (batch as f64 - 1.0);
        let decode = window_tokens as f64 * self.tpot_ms * penalty;
        let prefill = if fresh > 0 { self.prefill_ms * penalty } else { 0.0 };
        prefill + decode
    }

    /// Full-request latency at batch 1 (sanity anchor for Table 4).
    pub fn request_latency_ms(&self, out_tokens: usize) -> f64 {
        self.prefill_ms + out_tokens as f64 * self.tpot_ms
    }

    /// KV budget on one GPU after weights, honouring vLLM's memory limit.
    pub fn kv_budget_bytes(&self, mem_limit_frac: f64) -> usize {
        let weights = (self.params_b * 2e9) as usize; // fp16 weights
        let budget = (GPU_MEM_BYTES as f64 * mem_limit_frac) as usize;
        budget.saturating_sub(weights)
    }

    /// Default 5-model set from the manifest metadata.
    pub fn all(metas: &[ServedModelMeta]) -> Vec<ModelProfile> {
        metas.iter().map(ModelProfile::from_meta).collect()
    }

    pub fn find<'a>(profiles: &'a [ModelProfile], abbrev: &str) -> Option<&'a ModelProfile> {
        profiles.iter().find(|p| p.abbrev == abbrev)
    }
}

/// The paper's average-request-rate anchor (§6.2):
/// AVG.RequestRate = 1000 / AVG.Latency × batch_size   [requests/s]
pub fn avg_request_rate(profile: &ModelProfile, batch: usize) -> f64 {
    1000.0 / profile.avg_latency_ms * batch as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lam13() -> ModelProfile {
        ModelProfile::from_meta(&ServedModelMeta {
            name: "LlaMA2-13B".into(),
            abbrev: "lam13".into(),
            params_b: 13.0,
            avg_latency_ms: 8610.2,
            kv_bytes_per_token: 2 * 2 * 40 * 40 * 128,
            preempt_batch: 120,
            mem_limit_frac: 0.9,
        })
    }

    #[test]
    fn latency_anchor_roundtrip() {
        let p = lam13();
        let lat = p.request_latency_ms(MEAN_OUTPUT_TOKENS as usize);
        assert!((lat - p.avg_latency_ms).abs() / p.avg_latency_ms < 0.01,
                "anchor broken: {lat} vs {}", p.avg_latency_ms);
    }

    #[test]
    fn window_time_scales_with_batch() {
        let p = lam13();
        let w1 = p.window_ms(1, 50, 0);
        let w4 = p.window_ms(4, 50, 0);
        assert!(w4 > w1);
        assert!(w4 < w1 * 4.0, "decode is memory-bound, not linear in batch");
        assert_eq!(p.window_ms(0, 50, 0), 0.0);
    }

    #[test]
    fn prefill_only_on_fresh() {
        let p = lam13();
        assert!(p.window_ms(2, 50, 1) > p.window_ms(2, 50, 0));
    }

    #[test]
    fn request_rate_matches_paper_equation() {
        let p = lam13();
        // paper: 120 / 8.61 s ≈ 13.9 rps at batch 120
        let rate = avg_request_rate(&p, 120);
        assert!((rate - 13.9).abs() < 0.1, "rate {rate}");
    }

    #[test]
    fn kv_budget_positive_under_table6_limits() {
        let p = lam13();
        let b = p.kv_budget_bytes(0.9);
        assert!(b > 10 << 30, "lam13@90% should leave >10GB for KV, got {b}");
        // 13B fp16 weights = 26 GB > 30% of 80 GB: budget collapses
        assert_eq!(p.kv_budget_bytes(0.3), 0);
    }
}
