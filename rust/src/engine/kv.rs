//! Paged KV-cache block manager — the vLLM memory substrate.
//!
//! vLLM (the paper's execution engine) allocates the KV cache in fixed-size
//! blocks; a request is *preempted* when a decode step needs a block and the
//! pool is exhausted (paper §3.4 / Appendix A).  This module reproduces that
//! accounting: block granularity, per-sequence growth, utilization, and the
//! out-of-memory signal that triggers preemption, ordered by priority.

use std::collections::BTreeMap;

/// Tokens per KV block (vLLM default granularity).
pub const BLOCK_TOKENS: usize = 16;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SeqId(pub u64);

#[derive(Debug, Clone)]
struct SeqAlloc {
    tokens: usize,
    blocks: usize,
}

#[derive(Debug, Clone)]
pub struct BlockManager {
    /// bytes of KV cache per token (model-dependent, fp16 × 2 × layers × d)
    pub bytes_per_token: usize,
    pub total_blocks: usize,
    free_blocks: usize,
    seqs: BTreeMap<u64, SeqAlloc>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocOutcome {
    Ok,
    /// the pool cannot serve the growth; caller must preempt someone
    OutOfMemory { needed_blocks: usize },
}

impl BlockManager {
    /// Build from a device memory budget (e.g. 80 GB × vLLM memory limit ×
    /// the fraction left after weights).
    pub fn from_memory(kv_budget_bytes: usize, bytes_per_token: usize) -> Self {
        let block_bytes = bytes_per_token * BLOCK_TOKENS;
        let total_blocks = (kv_budget_bytes / block_bytes.max(1)).max(1);
        BlockManager {
            bytes_per_token,
            total_blocks,
            free_blocks: total_blocks,
            seqs: BTreeMap::new(),
        }
    }

    pub fn with_blocks(total_blocks: usize, bytes_per_token: usize) -> Self {
        BlockManager {
            bytes_per_token,
            total_blocks,
            free_blocks: total_blocks,
            seqs: BTreeMap::new(),
        }
    }

    fn blocks_for(tokens: usize) -> usize {
        tokens.div_ceil(BLOCK_TOKENS)
    }

    /// Register a sequence with its prompt already in the cache.
    pub fn admit(&mut self, seq: SeqId, prompt_tokens: usize) -> AllocOutcome {
        debug_assert!(!self.seqs.contains_key(&seq.0), "seq already admitted");
        let need = Self::blocks_for(prompt_tokens.max(1));
        if need > self.free_blocks {
            return AllocOutcome::OutOfMemory { needed_blocks: need - self.free_blocks };
        }
        self.free_blocks -= need;
        self.seqs.insert(seq.0, SeqAlloc { tokens: prompt_tokens.max(1), blocks: need });
        AllocOutcome::Ok
    }

    /// Grow a sequence by `n` decoded tokens; may need new blocks.
    pub fn grow(&mut self, seq: SeqId, n: usize) -> AllocOutcome {
        let alloc = match self.seqs.get_mut(&seq.0) {
            Some(a) => a,
            None => return AllocOutcome::Ok, // unknown seq: nothing to track
        };
        let new_tokens = alloc.tokens + n;
        let need_total = Self::blocks_for(new_tokens);
        let extra = need_total.saturating_sub(alloc.blocks);
        if extra > self.free_blocks {
            return AllocOutcome::OutOfMemory { needed_blocks: extra - self.free_blocks };
        }
        self.free_blocks -= extra;
        alloc.tokens = new_tokens;
        alloc.blocks = need_total;
        AllocOutcome::Ok
    }

    /// Release a sequence (finished or preempted — vLLM recompute-style
    /// preemption drops the whole allocation).
    pub fn release(&mut self, seq: SeqId) -> bool {
        if let Some(a) = self.seqs.remove(&seq.0) {
            self.free_blocks += a.blocks;
            true
        } else {
            false
        }
    }

    pub fn resident(&self, seq: SeqId) -> bool {
        self.seqs.contains_key(&seq.0)
    }

    pub fn seq_tokens(&self, seq: SeqId) -> usize {
        self.seqs.get(&seq.0).map(|a| a.tokens).unwrap_or(0)
    }

    pub fn free_blocks(&self) -> usize {
        self.free_blocks
    }

    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free_blocks
    }

    pub fn used_bytes(&self) -> usize {
        self.used_blocks() * self.bytes_per_token * BLOCK_TOKENS
    }

    pub fn utilization(&self) -> f64 {
        self.used_blocks() as f64 / self.total_blocks as f64
    }

    pub fn resident_seqs(&self) -> usize {
        self.seqs.len()
    }

    /// Invariant check used by property tests.
    pub fn check_invariants(&self) {
        let held: usize = self.seqs.values().map(|a| a.blocks).sum();
        assert_eq!(held + self.free_blocks, self.total_blocks,
                   "block accounting leak");
        for a in self.seqs.values() {
            assert_eq!(a.blocks, Self::blocks_for(a.tokens));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    #[test]
    fn admit_grow_release_roundtrip() {
        let mut m = BlockManager::with_blocks(10, 100);
        assert_eq!(m.admit(SeqId(1), 20), AllocOutcome::Ok); // 2 blocks
        assert_eq!(m.free_blocks(), 8);
        assert_eq!(m.grow(SeqId(1), 12), AllocOutcome::Ok); // 32 tokens -> 2 blocks
        assert_eq!(m.free_blocks(), 8);
        assert_eq!(m.grow(SeqId(1), 1), AllocOutcome::Ok); // 33 tokens -> 3 blocks
        assert_eq!(m.free_blocks(), 7);
        assert!(m.release(SeqId(1)));
        assert_eq!(m.free_blocks(), 10);
        assert!(!m.release(SeqId(1)));
    }

    #[test]
    fn oom_on_admit_and_grow() {
        let mut m = BlockManager::with_blocks(2, 100);
        assert_eq!(m.admit(SeqId(1), 16), AllocOutcome::Ok); // 1 block
        assert_eq!(
            m.admit(SeqId(2), 32),
            AllocOutcome::OutOfMemory { needed_blocks: 1 }
        );
        assert_eq!(m.admit(SeqId(2), 16), AllocOutcome::Ok);
        assert_eq!(
            m.grow(SeqId(1), 16),
            AllocOutcome::OutOfMemory { needed_blocks: 1 }
        );
        // release 2 then grow succeeds
        m.release(SeqId(2));
        assert_eq!(m.grow(SeqId(1), 16), AllocOutcome::Ok);
    }

    #[test]
    fn from_memory_sizing() {
        // 1 MB budget, 1 KB per token -> 1024 tokens -> 64 blocks
        let m = BlockManager::from_memory(1 << 20, 1 << 10);
        assert_eq!(m.total_blocks, 64);
    }

    #[test]
    fn utilization_and_bytes() {
        let mut m = BlockManager::with_blocks(4, 10);
        m.admit(SeqId(1), 16);
        assert!((m.utilization() - 0.25).abs() < 1e-12);
        assert_eq!(m.used_bytes(), 10 * BLOCK_TOKENS);
    }

    #[test]
    fn prop_accounting_never_leaks() {
        prop::check("kv-accounting", 200, |g| {
            let total = g.usize_in(4, 64);
            let mut m = BlockManager::with_blocks(total, 100);
            let mut live: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..g.usize_in(10, 60) {
                match g.usize_in(0, 2) {
                    0 => {
                        next_id += 1;
                        if m.admit(SeqId(next_id), g.usize_in(1, 100)) == AllocOutcome::Ok {
                            live.push(next_id);
                        }
                    }
                    1 => {
                        if !live.is_empty() {
                            let idx = g.usize_in(0, live.len() - 1);
                            let _ = m.grow(SeqId(live[idx]), g.usize_in(1, 60));
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let idx = g.usize_in(0, live.len() - 1);
                            let id = live.swap_remove(idx);
                            assert!(m.release(SeqId(id)));
                        }
                    }
                }
                m.check_invariants();
            }
            for id in live {
                m.release(SeqId(id));
            }
            assert_eq!(m.free_blocks(), total);
        });
    }
}
