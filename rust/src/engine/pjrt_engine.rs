//! Real execution engine: TinyGPT prefill/decode-window HLOs via PJRT.
//!
//! Holds per-sequence KV caches host-side between windows (batch
//! composition changes every scheduling iteration under ISRTF, so the KV
//! must be re-batched per window).  Preemption here uses vLLM's *swap*
//! semantics — KV moves out of the (accounted) device pool but survives on
//! the host — whereas the sim engine models *recompute*; the coordinator
//! treats both identically.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::runtime::{HostTensor, LoadedModel, Manifest, Runtime, WeightStore};

use super::kv::{AllocOutcome, BlockManager, SeqId};
use super::{pick_exe_batch, Engine, SeqSpec, SeqWindowOut, WindowOutcome};

struct PjrtSeq {
    prompt: Vec<i32>,     // unpadded, truncated to prompt_max
    prompt_len: usize,
    target_total: usize,
    generated: Vec<i32>,  // generated tokens (includes the prefill token)
    /// host KV of shape (L, 2, H, S, Dh), present after first prefill
    kv: Option<Vec<f32>>,
    /// KV slots filled = prompt_len + generated.len() - 1 (last token's KV
    /// is written by the *next* decode step)
    resident: bool,
}

/// KV geometry derived from the manifest.
#[derive(Debug, Clone, Copy)]
struct KvGeom {
    l: usize,
    h: usize,
    s: usize,
    dh: usize,
}

impl KvGeom {
    fn plane(&self) -> usize {
        self.h * self.s * self.dh
    }

    fn seq_elems(&self) -> usize {
        self.l * 2 * self.plane()
    }
}

pub struct PjrtEngine {
    prefill: BTreeMap<usize, LoadedModel>,
    decode: BTreeMap<usize, LoadedModel>,
    geom: KvGeom,
    prompt_max: usize,
    window: usize,
    max_batch: usize,
    vocab: usize,
    seqs: BTreeMap<u64, PjrtSeq>,
    blocks: BlockManager,
    priority_order: Vec<u64>,
    /// PreemptionPolicy::max_per_iteration — evictions allowed per window
    preempt_cap: usize,
    /// evictions so far in the current window
    window_preemptions: usize,
    pub total_preemptions: u64,
    /// cumulative ms spent inside PJRT execute (vs host re-batching)
    pub exec_ms: f64,
    pub host_ms: f64,
}

impl PjrtEngine {
    /// Load all compiled batch sizes from the artifacts.
    pub fn load(rt: Arc<Runtime>, manifest: &Manifest, store: &WeightStore,
                max_resident_tokens: usize) -> Result<PjrtEngine> {
        let mc = &manifest.model;
        if mc.n_heads == 0 || mc.d_model == 0 {
            bail!("manifest model_config incomplete");
        }
        let geom = KvGeom {
            l: mc.n_layers,
            h: mc.n_heads,
            s: mc.max_seq,
            dh: mc.d_model / mc.n_heads,
        };
        let mut prefill = BTreeMap::new();
        let mut decode = BTreeMap::new();
        for &b in &manifest.batch_sizes {
            prefill.insert(
                b,
                LoadedModel::load(rt.clone(), manifest, store,
                                  &format!("model.prefill.b{b}"), None)?,
            );
            decode.insert(
                b,
                LoadedModel::load(rt.clone(), manifest, store,
                                  &format!("model.decode.b{b}"), None)?,
            );
        }
        let max_batch = *manifest.batch_sizes.iter().max().unwrap_or(&4);
        // KV accounting: bytes_per_token for the tiny model (f32)
        let bytes_per_token = geom.l * 2 * geom.h * geom.dh * 4;
        Ok(PjrtEngine {
            prefill,
            decode,
            geom,
            prompt_max: mc.prompt_max,
            window: manifest.window_size,
            max_batch,
            vocab: mc.vocab,
            seqs: BTreeMap::new(),
            blocks: BlockManager::from_memory(
                max_resident_tokens * bytes_per_token, bytes_per_token),
            priority_order: Vec::new(),
            preempt_cap: usize::MAX,
            window_preemptions: 0,
            total_preemptions: 0,
            exec_ms: 0.0,
            host_ms: 0.0,
        })
    }

    fn compiled_sizes(&self) -> Vec<usize> {
        self.decode.keys().copied().collect()
    }

    fn ensure_blocks(&mut self, id: u64, tokens: usize,
                     protect: &[u64], preempted: &mut Vec<u64>) -> bool {
        loop {
            let outcome = if self.blocks.resident(SeqId(id)) {
                AllocOutcome::Ok
            } else {
                self.blocks.admit(SeqId(id), tokens)
            };
            match outcome {
                AllocOutcome::Ok => return true,
                AllocOutcome::OutOfMemory { .. } => {
                    // per-window eviction budget (§3.4 frequency control)
                    if self.window_preemptions >= self.preempt_cap {
                        return false;
                    }
                    let victim = self
                        .priority_order
                        .iter()
                        .rev()
                        .copied()
                        .find(|v| !protect.contains(v)
                              && self.seqs.get(v).map(|s| s.resident).unwrap_or(false));
                    match victim {
                        Some(v) => {
                            self.evict(v);
                            self.total_preemptions += 1;
                            self.window_preemptions += 1;
                            preempted.push(v);
                        }
                        None => return false,
                    }
                }
            }
        }
    }

    /// Prefill a group of fresh sequences (no KV yet).
    fn prefill_group(&mut self, ids: &[u64]) -> Result<()> {
        if ids.is_empty() {
            return Ok(());
        }
        let exe_b = pick_exe_batch(&self.compiled_sizes(), ids.len());
        let exe = self
            .prefill
            .get(&exe_b)
            .ok_or_else(|| anyhow!("no prefill exe b{exe_b}"))?;
        let mut tokens = vec![0i32; exe_b * self.prompt_max];
        let mut lengths = vec![1i32; exe_b]; // pad slots: length 1 (safe)
        for (slot, &id) in ids.iter().enumerate() {
            let s = &self.seqs[&id];
            for (j, &t) in s.prompt.iter().enumerate() {
                tokens[slot * self.prompt_max + j] = t;
            }
            lengths[slot] = s.prompt_len as i32;
        }
        let out = exe.execute(&[
            HostTensor::I32(tokens),
            HostTensor::I32(lengths),
        ])?;
        let kv = out[0].as_f32()?;
        let first = out[1].as_i32()?;
        let g = self.geom;
        for (slot, &id) in ids.iter().enumerate() {
            let mut seq_kv = vec![0f32; g.seq_elems()];
            // batch layout (L,2,B,H,S,Dh) -> seq layout (L,2,H,S,Dh)
            for lt in 0..g.l * 2 {
                let src = (lt * exe_b + slot) * g.plane();
                let dst = lt * g.plane();
                seq_kv[dst..dst + g.plane()]
                    .copy_from_slice(&kv[src..src + g.plane()]);
            }
            let s = self.seqs.get_mut(&id).unwrap();
            s.kv = Some(seq_kv);
            s.generated.push(first[slot].rem_euclid(self.vocab as i32));
        }
        Ok(())
    }

    /// Decode one window for a chunk (≤ max compiled batch) of resident seqs.
    fn decode_chunk(&mut self, ids: &[u64]) -> Result<Vec<SeqWindowOut>> {
        let exe_b = pick_exe_batch(&self.compiled_sizes(), ids.len());
        let g = self.geom;
        let mut kv = vec![0f32; g.l * 2 * exe_b * g.plane()];
        let mut lengths = vec![0i32; exe_b];
        let mut last_token = vec![0i32; exe_b];
        let mut active = vec![0i32; exe_b];
        let t_host = Instant::now();
        for (slot, &id) in ids.iter().enumerate() {
            let s = &self.seqs[&id];
            let seq_kv = s.kv.as_ref().expect("decode_chunk on fresh seq");
            for lt in 0..g.l * 2 {
                let dst = (lt * exe_b + slot) * g.plane();
                let src = lt * g.plane();
                kv[dst..dst + g.plane()]
                    .copy_from_slice(&seq_kv[src..src + g.plane()]);
            }
            lengths[slot] = (s.prompt_len + s.generated.len() - 1) as i32;
            last_token[slot] = *s.generated.last().unwrap();
            active[slot] = 1;
        }
        self.host_ms += t_host.elapsed().as_secs_f64() * 1e3;

        let exe = self
            .decode
            .get(&exe_b)
            .ok_or_else(|| anyhow!("no decode exe b{exe_b}"))?;
        let t_exec = Instant::now();
        let out = exe.execute(&[
            HostTensor::F32(kv),
            HostTensor::I32(lengths),
            HostTensor::I32(last_token),
            HostTensor::I32(active),
        ])?;
        self.exec_ms += t_exec.elapsed().as_secs_f64() * 1e3;

        let t_host = Instant::now();
        let new_kv = out[0].as_f32()?;
        let toks = out[1].as_i32()?;
        let mut results = Vec::with_capacity(ids.len());
        for (slot, &id) in ids.iter().enumerate() {
            let s = self.seqs.get_mut(&id).unwrap();
            let seq_kv = s.kv.as_mut().unwrap();
            for lt in 0..g.l * 2 {
                let src = (lt * exe_b + slot) * g.plane();
                let dst = lt * g.plane();
                seq_kv[dst..dst + g.plane()]
                    .copy_from_slice(&new_kv[src..src + g.plane()]);
            }
            let window_toks = &toks[slot * self.window..(slot + 1) * self.window];
            let remaining = s.target_total.saturating_sub(s.generated.len());
            let take = remaining.min(self.window);
            let new_tokens: Vec<i32> = window_toks[..take].to_vec();
            s.generated.extend_from_slice(&new_tokens);
            let done = s.generated.len() >= s.target_total;
            results.push(SeqWindowOut { id, new_tokens, done });
        }
        self.host_ms += t_host.elapsed().as_secs_f64() * 1e3;
        Ok(results)
    }

    /// Full decoded text (token ids) of a sequence.
    pub fn response(&self, id: u64) -> Option<&[i32]> {
        self.seqs.get(&id).map(|s| s.generated.as_slice())
    }
}

impl Engine for PjrtEngine {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn admit(&mut self, seq: SeqSpec) -> Result<()> {
        if self.seqs.contains_key(&seq.id) {
            bail!("seq {} already admitted", seq.id);
        }
        let mut prompt = seq.prompt;
        prompt.truncate(self.prompt_max);
        // Failover re-admission: the already-generated prefix is folded
        // into the *prompt*, so the prefill writes KV for every position
        // the decode window will attend to, and the target shrinks by
        // the tokens already delivered — every token this engine emits
        // is genuinely new (the coordinator appends `new_tokens` after
        // its own copy of the prefix).  Seeding `generated` instead
        // would leave the resume positions without KV and re-emit a
        // stale token through the fresh-output fixup in `run_window`.
        let resumed = seq.resume.len();
        prompt.extend_from_slice(&seq.resume);
        if prompt.len() > self.prompt_max {
            // once resuming, the most recent context matters most
            let cut = prompt.len() - self.prompt_max;
            prompt.drain(..cut);
        }
        if prompt.is_empty() {
            prompt.push(1);
        }
        let prompt_len = prompt.len();
        self.seqs.insert(
            seq.id,
            PjrtSeq {
                prompt,
                prompt_len,
                target_total: seq.target_total.saturating_sub(resumed).max(1),
                generated: Vec::new(),
                kv: None,
                resident: false,
            },
        );
        Ok(())
    }

    fn run_window(&mut self, seq_ids: &[u64]) -> Result<WindowOutcome> {
        if seq_ids.len() > self.max_batch {
            bail!("batch {} exceeds max {}", seq_ids.len(), self.max_batch);
        }
        let t0 = Instant::now();
        self.window_preemptions = 0;
        let mut preempted = Vec::new();

        // account KV blocks + mark resident
        let mut staged: Vec<u64> = Vec::with_capacity(seq_ids.len());
        for &id in seq_ids {
            let (tokens, known) = match self.seqs.get(&id) {
                Some(s) => (s.prompt_len + s.generated.len() + self.window, true),
                None => (0, false),
            };
            if !known {
                bail!("seq {id} not admitted");
            }
            if self.ensure_blocks(id, tokens, seq_ids, &mut preempted) {
                self.seqs.get_mut(&id).unwrap().resident = true;
                staged.push(id);
            }
        }

        // prefill the fresh ones
        let fresh: Vec<u64> = staged
            .iter()
            .copied()
            .filter(|id| self.seqs[id].kv.is_none())
            .collect();
        for group in fresh.chunks(self.max_batch) {
            self.prefill_group(group)?;
        }

        // decode everyone still needing tokens (a prefill token may already
        // have completed a target_total == 1 sequence)
        let mut outputs: Vec<SeqWindowOut> = Vec::with_capacity(staged.len());
        let mut decode_ids: Vec<u64> = Vec::new();
        for &id in &staged {
            let s = &self.seqs[&id];
            if s.generated.len() >= s.target_total {
                outputs.push(SeqWindowOut {
                    id,
                    new_tokens: s.generated.clone(),
                    done: true,
                });
            } else {
                decode_ids.push(id);
            }
        }
        for chunk in decode_ids.chunks(self.max_batch) {
            outputs.extend(self.decode_chunk(chunk)?);
        }

        // fresh seqs' outputs must include their prefill token
        for o in outputs.iter_mut() {
            if fresh.contains(&o.id) && !o.done {
                let first = self.seqs[&o.id].generated
                    [self.seqs[&o.id].generated.len() - o.new_tokens.len() - 1];
                o.new_tokens.insert(0, first);
            }
        }

        preempted.dedup();
        Ok(WindowOutcome {
            outputs,
            service_ms: t0.elapsed().as_secs_f64() * 1e3,
            preempted,
        })
    }

    fn set_priority_order(&mut self, order: &[u64]) {
        self.priority_order = order.to_vec();
    }

    fn set_preemption_cap(&mut self, cap: usize) {
        self.preempt_cap = cap;
    }

    fn remove(&mut self, seq_id: u64) {
        self.blocks.release(SeqId(seq_id));
        self.seqs.remove(&seq_id);
    }

    fn evict(&mut self, seq_id: u64) {
        // swap semantics: KV stays host-side, device blocks released
        self.blocks.release(SeqId(seq_id));
        if let Some(s) = self.seqs.get_mut(&seq_id) {
            s.resident = false;
        }
    }

    fn generated(&self, seq_id: u64) -> usize {
        self.seqs.get(&seq_id).map(|s| s.generated.len()).unwrap_or(0)
    }

    fn is_resident(&self, seq_id: u64) -> bool {
        self.seqs.get(&seq_id).map(|s| s.resident).unwrap_or(false)
    }

    fn kv_utilization(&self) -> f64 {
        self.blocks.utilization()
    }

    fn describe(&self) -> String {
        format!(
            "PjrtEngine[TinyGPT L{} H{} S{} window={} batches={:?}]",
            self.geom.l, self.geom.h, self.geom.s, self.window,
            self.compiled_sizes()
        )
    }
}
