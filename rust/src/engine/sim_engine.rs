//! Discrete-event vLLM model calibrated to the paper's testbed.
//!
//! Timing comes from [`profiles::ModelProfile`] (anchored to Table 4);
//! memory comes from [`kv::BlockManager`] sized by the model's KV bytes per
//! token and the vLLM memory limit (Appendix A).  Preemption follows the
//! paper's patched-vLLM semantics: when a decode step cannot get a block,
//! the lowest-priority resident sequence is evicted (recompute style: KV
//! dropped, generated tokens kept; resuming pays a recompute prefill).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use super::kv::{AllocOutcome, BlockManager, SeqId};
use super::profiles::ModelProfile;
use super::{Engine, SeqSpec, SeqWindowOut, WindowOutcome};

#[derive(Debug, Clone)]
struct SimSeq {
    prompt_len: usize,
    target_total: usize,
    topic: usize,
    generated: usize,
    resident: bool,
    /// windows where this seq was recomputed after preemption (stats)
    recomputes: usize,
}

pub struct SimEngine {
    profile: ModelProfile,
    window_size: usize,
    max_batch: usize,
    blocks: BlockManager,
    seqs: BTreeMap<u64, SimSeq>,
    /// coordinator-provided priority order, highest first
    priority_order: Vec<u64>,
    /// PreemptionPolicy::max_per_iteration — evictions allowed per window
    preempt_cap: usize,
    /// evictions so far in the current window
    window_preemptions: usize,
    pub total_preemptions: u64,
    pub total_recompute_tokens: u64,
}

impl SimEngine {
    pub fn new(profile: ModelProfile, window_size: usize, max_batch: usize,
               kv_budget_bytes: usize) -> SimEngine {
        let blocks = BlockManager::from_memory(
            kv_budget_bytes.max(1), profile.kv_bytes_per_token);
        SimEngine {
            profile,
            window_size,
            max_batch,
            blocks,
            seqs: BTreeMap::new(),
            priority_order: Vec::new(),
            preempt_cap: usize::MAX,
            window_preemptions: 0,
            total_preemptions: 0,
            total_recompute_tokens: 0,
        }
    }

    /// Convenience: budget from the profile's Table 6 memory-limit fraction.
    pub fn with_profile_budget(profile: ModelProfile, window_size: usize,
                               max_batch: usize) -> SimEngine {
        let budget = profile.kv_budget_bytes(profile.mem_limit_frac);
        Self::new(profile, window_size, max_batch, budget)
    }

    pub fn profile(&self) -> &ModelProfile {
        &self.profile
    }

    /// Evict the lowest-priority resident sequence not in `protect`.
    /// Returns the victim id if one was found and the per-window eviction
    /// budget (`PreemptionPolicy::max_per_iteration`) is not exhausted.
    fn preempt_victim(&mut self, protect: &[u64]) -> Option<u64> {
        if self.window_preemptions >= self.preempt_cap {
            return None;
        }
        // priority_order is highest-first; walk from the back
        let victim = self
            .priority_order
            .iter()
            .rev()
            .copied()
            .find(|id| {
                !protect.contains(id)
                    && self.seqs.get(id).map(|s| s.resident).unwrap_or(false)
            })
            .or_else(|| {
                // fall back to any resident seq not protected (e.g. ids the
                // coordinator never ranked)
                self.seqs
                    .iter()
                    .rev()
                    .find(|(id, s)| s.resident && !protect.contains(id))
                    .map(|(id, _)| *id)
            })?;
        self.do_evict(victim);
        self.total_preemptions += 1;
        self.window_preemptions += 1;
        Some(victim)
    }

    fn do_evict(&mut self, id: u64) {
        if let Some(s) = self.seqs.get_mut(&id) {
            if s.resident {
                self.blocks.release(SeqId(id));
                s.resident = false;
            }
        }
    }

    /// Make `id` resident, preempting others if necessary.  Returns tokens
    /// recomputed (prefill cost proxy) and preempted ids, or None if the
    /// sequence cannot fit even after evicting everyone else.
    fn ensure_resident(&mut self, id: u64, protect: &[u64],
                       preempted: &mut Vec<u64>) -> Option<usize> {
        let (need_tokens, was_resident) = match self.seqs.get(&id) {
            Some(s) => (s.prompt_len + s.generated, s.resident),
            None => return None,
        };
        if was_resident {
            return Some(0);
        }
        loop {
            match self.blocks.admit(SeqId(id), need_tokens) {
                AllocOutcome::Ok => break,
                AllocOutcome::OutOfMemory { .. } => {
                    match self.preempt_victim(protect) {
                        Some(v) => preempted.push(v),
                        None => return None,
                    }
                }
            }
        }
        let s = self.seqs.get_mut(&id).unwrap();
        s.resident = true;
        let recompute = if s.generated > 0 {
            s.recomputes += 1;
            s.generated
        } else {
            0
        };
        self.total_recompute_tokens += recompute as u64;
        Some(recompute)
    }
}

impl Engine for SimEngine {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn admit(&mut self, seq: SeqSpec) -> Result<()> {
        if self.seqs.contains_key(&seq.id) {
            bail!("seq {} already admitted", seq.id);
        }
        self.seqs.insert(
            seq.id,
            SimSeq {
                prompt_len: seq.prompt.len().max(1),
                target_total: seq.target_total,
                topic: seq.topic,
                // a failover re-admission resumes where the lost engine
                // left off; the deterministic content formula makes the
                // continuation identical to an uninterrupted run
                generated: seq.resume.len().min(seq.target_total),
                resident: false,
                recomputes: 0,
            },
        );
        Ok(())
    }

    fn run_window(&mut self, seq_ids: &[u64]) -> Result<WindowOutcome> {
        if seq_ids.len() > self.max_batch {
            bail!("batch {} exceeds max {}", seq_ids.len(), self.max_batch);
        }
        self.window_preemptions = 0;
        let mut preempted = Vec::new();
        let mut fresh = 0usize;
        let mut active: Vec<u64> = Vec::with_capacity(seq_ids.len());
        let mut recompute_tokens = 0usize;

        // stage KV for every scheduled sequence (prefill / recompute)
        for &id in seq_ids {
            if !self.seqs.contains_key(&id) {
                bail!("seq {id} not admitted");
            }
            let was_resident = self.seqs[&id].resident;
            match self.ensure_resident(id, seq_ids, &mut preempted) {
                Some(rc) => {
                    if !was_resident {
                        fresh += 1;
                        recompute_tokens += rc;
                    }
                    active.push(id);
                }
                None => {
                    // cannot fit even alone: skip this window
                }
            }
        }

        // decode: each active seq produces up to `window` tokens
        let mut outputs = Vec::with_capacity(active.len());
        let mut decoded_max = 0usize;
        let mut evicted_from_batch: Vec<u64> = Vec::new();
        for idx in 0..active.len() {
            let id = active[idx];
            if evicted_from_batch.contains(&id) {
                continue; // lost its blocks to a higher-priority batch member
            }
            // growth may itself preempt *other* seqs
            let (gen_now, done) = {
                let s = &self.seqs[&id];
                let remaining = s.target_total.saturating_sub(s.generated);
                let n = remaining.min(self.window_size);
                (n, remaining <= self.window_size)
            };
            let mut grown = 0usize;
            while grown < gen_now {
                match self.blocks.grow(SeqId(id), 1) {
                    AllocOutcome::Ok => grown += 1,
                    AllocOutcome::OutOfMemory { .. } => {
                        // prefer non-batch victims ...
                        let mut protect = active.clone();
                        protect.push(id);
                        if let Some(v) = self.preempt_victim(&protect) {
                            preempted.push(v);
                            continue;
                        }
                        // ... then lower-priority batch members (vLLM
                        // shrinks the running batch under KV pressure)
                        let protect_head: Vec<u64> =
                            active[..=idx].to_vec();
                        match self.preempt_victim(&protect_head) {
                            Some(v) => {
                                preempted.push(v);
                                evicted_from_batch.push(v);
                            }
                            None => break, // pool smaller than this one job
                        }
                    }
                }
            }
            let s = self.seqs.get_mut(&id).unwrap();
            s.generated += grown;
            decoded_max = decoded_max.max(grown);
            let done = done && grown == gen_now;
            // synthetic token stream with the content signal the
            // predictor was trained on (mirrors python response_token)
            let start = s.generated - grown;
            let (total, topic) = (s.target_total, s.topic);
            let new_tokens: Vec<i32> = (0..grown)
                .map(|k| super::sim_response_token(start + k, total, topic, 2048))
                .collect();
            outputs.push(SeqWindowOut { id, new_tokens, done });
        }

        // any scheduled-but-unstageable seq reports an empty output
        for &id in seq_ids {
            if !active.contains(&id) {
                outputs.push(SeqWindowOut { id, new_tokens: Vec::new(), done: false });
            }
        }

        // service time: calibrated profile; recompute counts as extra prefill
        let mut service_ms = self
            .profile
            .window_ms(active.len(), decoded_max, fresh);
        if recompute_tokens > 0 {
            service_ms += self.profile.prefill_ms
                * (recompute_tokens as f64 / self.profile.tpot_ms.max(1e-9) / 1000.0).min(1.0);
        }

        // drop preempted duplicates, keep order
        preempted.dedup();
        Ok(WindowOutcome { outputs, service_ms, preempted })
    }

    fn set_priority_order(&mut self, order: &[u64]) {
        self.priority_order = order.to_vec();
    }

    fn set_preemption_cap(&mut self, cap: usize) {
        self.preempt_cap = cap;
    }

    fn remove(&mut self, seq_id: u64) {
        self.do_evict(seq_id);
        self.seqs.remove(&seq_id);
    }

    fn evict(&mut self, seq_id: u64) {
        self.do_evict(seq_id);
    }

    fn generated(&self, seq_id: u64) -> usize {
        self.seqs.get(&seq_id).map(|s| s.generated).unwrap_or(0)
    }

    fn is_resident(&self, seq_id: u64) -> bool {
        self.seqs.get(&seq_id).map(|s| s.resident).unwrap_or(false)
    }

    fn kv_utilization(&self) -> f64 {
        self.blocks.utilization()
    }

    fn describe(&self) -> String {
        format!(
            "SimEngine[{} tpot={:.2}ms blocks={} batch<={}]",
            self.profile.abbrev, self.profile.tpot_ms,
            self.blocks.total_blocks, self.max_batch
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ServedModelMeta;

    fn profile() -> ModelProfile {
        ModelProfile::from_meta(&ServedModelMeta {
            name: "LlaMA2-13B".into(),
            abbrev: "lam13".into(),
            params_b: 13.0,
            avg_latency_ms: 8610.2,
            kv_bytes_per_token: 2 * 2 * 40 * 40 * 128,
            preempt_batch: 120,
            mem_limit_frac: 0.9,
        })
    }

    fn engine_with_blocks(blocks: usize) -> SimEngine {
        let p = profile();
        let bpt = p.kv_bytes_per_token;
        let mut e = SimEngine::new(p, 50, 8, 1);
        e.blocks = BlockManager::with_blocks(blocks, bpt);
        e
    }

    fn spec(id: u64, prompt: usize, total: usize) -> SeqSpec {
        SeqSpec { id, prompt: vec![7; prompt], target_total: total , topic: 0,
                  resume: Vec::new() }
    }

    #[test]
    fn generates_window_then_finishes() {
        let mut e = engine_with_blocks(10_000);
        e.admit(spec(1, 10, 80)).unwrap();
        let w1 = e.run_window(&[1]).unwrap();
        assert_eq!(w1.outputs[0].new_tokens.len(), 50);
        assert!(!w1.outputs[0].done);
        assert!(w1.service_ms > 0.0);
        let w2 = e.run_window(&[1]).unwrap();
        assert_eq!(w2.outputs[0].new_tokens.len(), 30);
        assert!(w2.outputs[0].done);
        assert_eq!(e.generated(1), 80);
    }

    #[test]
    fn service_time_uses_profile() {
        let mut e = engine_with_blocks(10_000);
        e.admit(spec(1, 10, 500)).unwrap();
        let w = e.run_window(&[1]).unwrap();
        let expect = e.profile().window_ms(1, 50, 1);
        assert!((w.service_ms - expect).abs() < 1e-9);
    }

    #[test]
    fn preempts_lowest_priority_on_oom() {
        // tiny pool: only ~4 blocks (64 tokens)
        let mut e = engine_with_blocks(4);
        e.admit(spec(1, 30, 100)).unwrap(); // 2 blocks
        e.admit(spec(2, 30, 100)).unwrap(); // 2 blocks
        e.set_priority_order(&[1, 2]);      // 2 is lowest priority
        let w = e.run_window(&[1]).unwrap();
        // growing seq 1 by 50 tokens forces eviction of seq 2 (if resident)
        // first stage seq1 resident (2 blocks free ok) ...
        assert!(e.is_resident(1));
        let _ = w;
        // now admit 2 into the batch as well: staging preempts nobody else,
        // but growth will OOM and must not evict batch members
        let w2 = e.run_window(&[2]).unwrap();
        // seq 1 (not in batch, lowest-rank resident after 2 protected) gets evicted
        assert!(w2.preempted.contains(&1) || !e.is_resident(1));
    }

    #[test]
    fn eviction_keeps_progress_and_recomputes() {
        let mut e = engine_with_blocks(10_000);
        e.admit(spec(1, 10, 200)).unwrap();
        e.run_window(&[1]).unwrap();
        assert_eq!(e.generated(1), 50);
        e.evict(1);
        assert!(!e.is_resident(1));
        assert_eq!(e.generated(1), 50, "progress survives preemption");
        let w = e.run_window(&[1]).unwrap();
        assert_eq!(e.generated(1), 100);
        assert!(w.service_ms > e.profile().window_ms(1, 50, 0),
                "recompute pays a prefill-like cost");
    }

    #[test]
    fn remove_releases_memory() {
        let mut e = engine_with_blocks(8);
        e.admit(spec(1, 30, 100)).unwrap();
        e.run_window(&[1]).unwrap();
        let used = e.blocks.used_blocks();
        assert!(used > 0);
        e.remove(1);
        assert_eq!(e.blocks.used_blocks(), 0);
        assert_eq!(e.generated(1), 0);
    }

    #[test]
    fn rejects_oversized_batch_and_unknown_seq() {
        let mut e = engine_with_blocks(100);
        assert!(e.run_window(&[99]).is_err());
        let mut big = engine_with_blocks(100);
        big.max_batch = 1;
        big.admit(spec(1, 5, 60)).unwrap();
        big.admit(spec(2, 5, 60)).unwrap();
        assert!(big.run_window(&[1, 2]).is_err());
    }

    #[test]
    fn preemption_cap_limits_evictions_per_window() {
        // pool of 4 blocks (64 tokens), window of 1 token: two resident
        // seqs fill the pool; staging + growing two new higher-priority
        // seqs wants two evictions in one window
        let run_contended = |cap: usize| {
            let p = profile();
            let bpt = p.kv_bytes_per_token;
            let mut e = SimEngine::new(p, 1, 8, 1);
            e.blocks = BlockManager::with_blocks(4, bpt);
            e.admit(spec(1, 16, 20)).unwrap();
            e.admit(spec(2, 16, 20)).unwrap();
            e.set_priority_order(&[1, 2]);
            let warm = e.run_window(&[1, 2]).unwrap();
            assert!(warm.preempted.is_empty(), "{:?}", warm.preempted);
            e.admit(spec(3, 16, 20)).unwrap();
            e.admit(spec(4, 16, 20)).unwrap();
            e.set_priority_order(&[3, 4, 1, 2]);
            e.set_preemption_cap(cap);
            e.run_window(&[3, 4]).unwrap()
        };
        let uncapped = run_contended(usize::MAX);
        assert!(uncapped.preempted.len() >= 2,
                "contention must evict both residents: {:?}",
                uncapped.preempted);
        let capped = run_contended(1);
        assert_eq!(capped.preempted.len(), 1,
                   "cap=1 must bound evictions per window: {:?}",
                   capped.preempted);
    }

    #[test]
    fn duplicate_admit_rejected() {
        let mut e = engine_with_blocks(100);
        e.admit(spec(1, 5, 60)).unwrap();
        assert!(e.admit(spec(1, 5, 60)).is_err());
    }
}
