//! Execution engines — the vLLM substitute behind each backend worker.
//!
//! Two interchangeable implementations of [`Engine`]:
//! * [`pjrt_engine::PjrtEngine`] — the real path: prefill/decode-window HLO
//!   executables (TinyGPT + Pallas attention) run via PJRT; used by the
//!   end-to-end examples and hot-path benches.
//! * [`sim_engine::SimEngine`] — discrete-event model of vLLM on an A100,
//!   calibrated to paper Table 4 latencies and Appendix A KV footprints;
//!   used by the scheduling experiments (Fig 5/6/7, Table 5/6) that need
//!   7B–13B-model timing a single CPU core cannot produce.
//!
//! Both share the paged-KV accounting in [`kv`] and the same preemption
//! semantics (drop KV, keep generated tokens, recompute on resume) so the
//! coordinator code path is identical.

pub mod kv;
pub mod pjrt_engine;
pub mod profiles;
pub mod sim_engine;
pub mod tokenizer;

use anyhow::Result;

/// A sequence (job) registered with an engine.
#[derive(Debug, Clone)]
pub struct SeqSpec {
    pub id: u64,
    pub prompt: Vec<i32>,
    /// ground-truth response length (benchmark-style fixed output length;
    /// the engine stops the sequence once it has generated this many)
    pub target_total: usize,
    /// corpus topic (drives the sim engine's content signal)
    pub topic: usize,
    /// Response tokens already generated in a previous life of this
    /// sequence — empty for fresh admissions.  Set by the coordinator
    /// when a job is re-admitted to a *different* engine after its worker
    /// pod was lost: the new engine continues from `resume.len()` (same
    /// drop-KV / keep-progress / recompute-on-resume semantics as
    /// preemption, but across engines), so the job's total output equals
    /// a run that never failed over.
    pub resume: Vec<i32>,
}

// ---------------------------------------------------------------------------
// Synthetic response-content signal — MUST mirror python/compile/data.py
// (`response_token`): tokens come from a band keyed to the response's
// length bucket, switching to a closing band near the end.  The predictor
// is trained on streams built with this exact formula, so the sim engine's
// generated suffixes are in-distribution at serving time (paper §3.3:
// partial output feeds back into the predictor).
// ---------------------------------------------------------------------------
pub const N_BUCKETS: usize = 16;
pub const BAND_WIDTH: usize = 16;
pub const CLOSING_TOKENS: usize = 25;

pub fn length_bucket(total: usize) -> usize {
    let x = (total.max(5) as f64 / 5.0).log2();
    (x.max(0.0) as usize).min(N_BUCKETS - 1)
}

pub fn sim_response_token(i: usize, total: usize, topic: usize,
                          vocab: usize) -> i32 {
    let band_start = if total.saturating_sub(i) <= CLOSING_TOKENS {
        vocab - BAND_WIDTH
    } else {
        vocab - BAND_WIDTH * (2 + length_bucket(total))
    };
    (band_start + (i * 7 + topic * 3) % BAND_WIDTH) as i32
}

/// Per-sequence result of one scheduling window.
#[derive(Debug, Clone)]
pub struct SeqWindowOut {
    pub id: u64,
    pub new_tokens: Vec<i32>,
    pub done: bool,
}

/// Result of executing one 50-token scheduling iteration.
#[derive(Debug, Clone)]
pub struct WindowOutcome {
    pub outputs: Vec<SeqWindowOut>,
    /// service time in ms — virtual (sim) or measured wall time (pjrt)
    pub service_ms: f64,
    /// sequences evicted by the engine due to KV OOM during this window
    pub preempted: Vec<u64>,
}

/// The backend execution engine interface (one instance per worker).
///
/// `Send` is required so the cluster runtime
/// ([`cluster::pool`](crate::cluster::pool)) can move each engine onto its
/// own worker-pool OS thread, mirroring the paper's one-vLLM-per-pod
/// deployment.  The usage pattern is strictly thread-confined: an engine
/// is moved to exactly one thread at spawn and every subsequent call
/// happens on that thread, so even handle types that must not be *shared*
/// across threads are safe here — they only need to survive the one-time
/// move.
///
/// Caveat for swapping `vendor/xla` for the real bindings: if those
/// handle types are `!Send`, either construct the engine *inside* its
/// worker thread (the shape the planned per-pod network split takes
/// anyway) or wrap the handles with a justification that matches the
/// thread-confined usage above — do not weaken this bound, the cluster
/// runtime depends on it.
pub trait Engine: Send {
    /// Largest decode batch the engine will accept per window.
    fn max_batch(&self) -> usize;

    /// Register a new sequence (prefill runs lazily on its first window).
    fn admit(&mut self, seq: SeqSpec) -> Result<()>;

    /// Execute one window for `seq_ids` (priority order, highest first).
    /// Sequences without resident KV are prefetched (prefill / recompute)
    /// as part of the window.
    fn run_window(&mut self, seq_ids: &[u64]) -> Result<WindowOutcome>;

    /// Update the engine's global priority order (highest first) — used to
    /// pick preemption victims, mirroring the paper's configurable-priority
    /// patch to vLLM.
    fn set_priority_order(&mut self, order: &[u64]);

    /// Cap on engine-initiated preemptions per window
    /// (`PreemptionPolicy::max_per_iteration`, paper §3.4 frequency
    /// control).  Engines that cannot preempt may ignore it.
    fn set_preemption_cap(&mut self, _cap: usize) {}

    /// Drop a sequence entirely (finished or cancelled).
    fn remove(&mut self, seq_id: u64);

    /// Coordinator-driven preemption: drop KV, keep progress.
    fn evict(&mut self, seq_id: u64);

    /// Tokens generated so far for a sequence (0 if unknown).
    fn generated(&self, seq_id: u64) -> usize;

    /// Whether the sequence currently holds KV blocks.
    fn is_resident(&self, seq_id: u64) -> bool;

    /// KV pool utilization in [0, 1].
    fn kv_utilization(&self) -> f64;

    /// Human-readable engine description for logs.
    fn describe(&self) -> String;
}

/// Pick the AOT executable batch size for `n` sequences (smallest compiled
/// batch ≥ n; falls back to the largest available).
pub fn pick_exe_batch(compiled: &[usize], n: usize) -> usize {
    let mut sizes: Vec<usize> = compiled.to_vec();
    sizes.sort_unstable();
    for &s in &sizes {
        if s >= n {
            return s;
        }
    }
    *sizes.last().expect("no compiled batch sizes")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exe_batch_selection() {
        let c = [1, 2, 4];
        assert_eq!(pick_exe_batch(&c, 1), 1);
        assert_eq!(pick_exe_batch(&c, 2), 2);
        assert_eq!(pick_exe_batch(&c, 3), 4);
        assert_eq!(pick_exe_batch(&c, 4), 4);
        assert_eq!(pick_exe_batch(&c, 9), 4); // caller must chunk
    }
}
