//! HTTP/1.1 frontend on `std::net` — no dependencies.
//!
//! The paper deploys the frontend scheduler as a Kubernetes Deployment
//! with an HTTP port (§5); this module is that service surface for the
//! in-process cluster runtime:
//!
//! * `GET /healthz` — liveness probe (the k8s manifests' port 8080);
//!   the body also reports the dead-worker count so probes see a
//!   degraded fleet before it empties.
//! * `GET /metrics` — Prometheus text exposition, snapshotted live from
//!   the shared [`TelemetrySink`] (thread-safe — handler threads render
//!   while the serving loop appends events).
//! * `POST /v1/generate` — admit a JSON request into the *running*
//!   coordinator via [`Coordinator::push_request`].  Body fields (all
//!   optional): `prompt` (array of token ids) or `prompt_len`,
//!   `total_len`, `topic`, `tenant`, `arrival_ms` (defaults to "now";
//!   trusted only within the trailing [`MAX_BACKDATE_MS`], anything else
//!   is re-stamped), `wait` (block until the job finishes and report
//!   its stats), and `stream` (hold the connection open and forward
//!   token chunks as server-sent events the moment each scheduling
//!   window applies — the paper's interactive serving path).
//!
//! Connections are handled by one thread each (streams pin a thread for
//! their whole lifetime, so a fixed pool would cap concurrent streams);
//! an accept-side `max_conns` bound sheds excess load with 503.
//! Connections are keep-alive by default, so one client socket can carry
//! many `/v1/generate` calls back to back.
//!
//! The front door applies admission control *before* anything reaches
//! the serving loop: a per-tenant token bucket ([`Admission`]) plus a
//! bounded pending-admission queue, both shedding with
//! `429 Retry-After` so overload never wedges the coordinator.
//!
//! The serving loop stays single-threaded and lock-free: handlers never
//! touch the [`Coordinator`].  They enqueue [`ApiRequest`]s on an mpsc
//! channel; the loop driving the coordinator calls [`ApiBridge::pump`]
//! between steps to admit them, and a [`StreamNotifier`] sink resolves
//! `wait`ing handlers and feeds `stream`ing ones.
//!
//! [`Coordinator`]: crate::coordinator::Coordinator
//! [`Coordinator::push_request`]: crate::coordinator::Coordinator::push_request

use std::collections::HashMap;
use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener,
               TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::events::{EventSink, FinishStats, JobMeta};
use crate::coordinator::Coordinator;
use crate::telemetry::{AttributionSink, FlightRecorder, FrontendStats,
                       TelemetrySink};
use crate::util::json::Json;
use crate::workload::TraceRequest;

/// Maximum accepted request body (1 MiB).
const MAX_BODY: usize = 1 << 20;
/// Maximum accepted header block (16 KiB).
const MAX_HEADER: usize = 16 << 10;
/// How far in the past a client-supplied `arrival_ms` may lie before it
/// is re-stamped with the live clock (see [`ApiBridge::pump`]).
pub const MAX_BACKDATE_MS: f64 = 60_000.0;

// ---------------------------------------------------------------------------
// serving-loop side: admission bridge + stream notifier
// ---------------------------------------------------------------------------

/// One `POST /v1/generate`, en route from a handler thread to the loop
/// driving the coordinator.
pub struct ApiRequest {
    pub request: TraceRequest,
    /// hold the HTTP response until the job finishes
    pub wait: bool,
    /// forward per-window token chunks as they are generated
    pub stream: bool,
    /// where the handler thread blocks for its reply
    pub reply: Sender<GenerateReply>,
}

/// Reply to one [`ApiRequest`].
#[derive(Debug, Clone, PartialEq)]
pub enum GenerateReply {
    /// admitted; the job runs asynchronously (`wait: false`), or the
    /// stream head for a `stream: true` request
    Accepted { job_id: u64 },
    /// one scheduling window's worth of tokens (`stream: true` only)
    Chunk { job_id: u64, tokens: Vec<i32> },
    /// finished end-to-end (`wait: true` or `stream: true` terminal)
    Finished { job_id: u64, tokens: usize, jct_ms: f64,
               token_ids: Vec<i32> },
    /// the serving loop is exiting (e.g. `--idle-exit-ms` fired) and will
    /// not run this job; the handler answers 503 instead of holding the
    /// connection until its timeout
    ShuttingDown,
}

/// One registered handler awaiting job events.
struct Waiter {
    tx: Sender<GenerateReply>,
    /// streaming handlers get per-window [`GenerateReply::Chunk`]s;
    /// waiting handlers accumulate tokens into `acc` for the final reply
    streaming: bool,
    acc: Vec<i32>,
}

type Waiters = Arc<Mutex<HashMap<u64, Waiter>>>;

/// The serving loop's end of the admission channel.  Call
/// [`pump`](Self::pump) between coordinator steps.
pub struct ApiBridge {
    rx: Receiver<ApiRequest>,
    waiters: Waiters,
    stats: Arc<FrontendStats>,
}

impl ApiBridge {
    /// Create the channel pair: the `Sender` goes into the [`Gateway`]
    /// (handler threads), the bridge stays with the serving loop.
    pub fn channel() -> (Sender<ApiRequest>, ApiBridge) {
        let (tx, rx) = channel();
        let bridge = ApiBridge {
            rx,
            waiters: Waiters::default(),
            stats: Arc::new(FrontendStats::default()),
        };
        (tx, bridge)
    }

    /// Shared front-door counters; hand a clone to the [`Gateway`] and
    /// attach one to the telemetry sink for `/metrics` exposition.
    pub fn frontend_stats(&self) -> Arc<FrontendStats> {
        self.stats.clone()
    }

    /// The [`EventSink`] that resolves `wait`ing handlers and feeds
    /// `stream`ing ones; register it on the same coordinator this bridge
    /// pumps into.
    pub fn completion_sink(&self) -> StreamNotifier {
        StreamNotifier { waiters: self.waiters.clone() }
    }

    /// Drain every pending API admission into the coordinator (non-
    /// blocking).  Requests are stamped with the coordinator's *live*
    /// time (`admission_now_ms` — the wall clock in wall mode, since
    /// `now()` goes stale while the loop idles) unless they carry an
    /// `arrival_ms` within the trailing [`MAX_BACKDATE_MS`]: a future
    /// stamp would park the job forever (wedging `is_done()` and any
    /// idle-exit logic) and an ancient one fabricates a huge JCT that
    /// pollutes the latency sketches and SLO accounting.  Returns how
    /// many were admitted.
    pub fn pump(&mut self, coord: &mut Coordinator<'_>) -> usize {
        let mut admitted = 0;
        while let Ok(mut req) = self.rx.try_recv() {
            // the handler incremented the depth when it queued; tests
            // that inject ApiRequests directly never did, hence the
            // saturating decrement
            let _ = self
                .stats
                .queue_depth
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                    v.checked_sub(1)
                });
            let now = coord.admission_now_ms();
            let a = req.request.arrival_ms;
            if !a.is_finite() || a < 0.0 || a > now
                || a < now - MAX_BACKDATE_MS
            {
                req.request.arrival_ms = now;
            }
            let id = coord.push_request(&req.request);
            if req.wait || req.stream {
                if req.stream {
                    // ack the stream so the handler can write the
                    // response head before the first chunk lands
                    let _ = req.reply.send(GenerateReply::Accepted {
                        job_id: id.raw(),
                    });
                }
                self.waiters.lock().unwrap().insert(
                    id.raw(),
                    Waiter {
                        tx: req.reply,
                        streaming: req.stream,
                        acc: Vec::new(),
                    },
                );
            } else {
                // a dropped receiver just means the handler timed out
                let _ = req.reply.send(GenerateReply::Accepted {
                    job_id: id.raw(),
                });
            }
            admitted += 1;
        }
        admitted
    }

    /// Shutdown drain: answer every queued admission *and* every still-
    /// registered handler with [`GenerateReply::ShuttingDown`], so held
    /// connections (waiters and streams alike) get a terminal answer
    /// instead of hanging out their timeout when the serving loop exits
    /// (`--idle-exit-ms` racing a `wait: true` generate).  Call after
    /// the serving loop's last `pump`, before `HttpServer::shutdown`;
    /// returns how many requests were answered.
    pub fn drain_shutdown(&mut self) -> usize {
        let mut n = 0;
        while let Ok(req) = self.rx.try_recv() {
            let _ = self
                .stats
                .queue_depth
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                    v.checked_sub(1)
                });
            let _ = req.reply.send(GenerateReply::ShuttingDown);
            n += 1;
        }
        for (_, w) in self.waiters.lock().unwrap().drain() {
            let _ = w.tx.send(GenerateReply::ShuttingDown);
            n += 1;
        }
        n
    }
}

/// [`EventSink`] bridging coordinator job events into HTTP replies:
/// resolves `wait: true` generate calls on finish and forwards each
/// window's token payload to `stream: true` handlers as it applies.
pub struct StreamNotifier {
    waiters: Waiters,
}

/// Backwards-compatible name from before streaming existed.
pub type CompletionNotifier = StreamNotifier;

impl EventSink for StreamNotifier {
    fn on_job_tokens(&mut self, job: &JobMeta<'_>, _node: usize,
                     tokens: &[i32], _now_ms: f64) {
        let mut w = self.waiters.lock().unwrap();
        if let Some(waiter) = w.get_mut(&job.id.raw()) {
            if waiter.streaming {
                let _ = waiter.tx.send(GenerateReply::Chunk {
                    job_id: job.id.raw(),
                    tokens: tokens.to_vec(),
                });
            } else {
                waiter.acc.extend_from_slice(tokens);
            }
        }
    }

    fn on_job_finished(&mut self, job: &JobMeta<'_>, _node: usize,
                       stats: &FinishStats, _now_ms: f64) {
        if let Some(w) = self.waiters.lock().unwrap().remove(&job.id.raw()) {
            let _ = w.tx.send(GenerateReply::Finished {
                job_id: job.id.raw(),
                tokens: stats.tokens,
                jct_ms: stats.jct_ms,
                token_ids: w.acc,
            });
        }
    }
}

// ---------------------------------------------------------------------------
// front-door admission control
// ---------------------------------------------------------------------------

/// Knobs for the front-door shedder.  `Default` disables everything
/// (unlimited rate, unbounded queue).
#[derive(Debug, Clone, Default)]
pub struct AdmissionConfig {
    /// sustained requests/second across all tenants; `0.0` = unlimited
    pub rps: f64,
    /// token-bucket burst size (requests admitted back-to-back)
    pub burst: f64,
    /// pending-admission queue bound; `0` = unbounded
    pub queue_cap: usize,
    /// per-tenant weights (the `--tenants` spec): each tenant's rate is
    /// `rps * weight / total_weight`; unknown tenants get weight 1
    pub tenant_weights: Vec<(String, u32)>,
}

struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Per-tenant token-bucket rate limiter (cheap to clone; buckets are
/// shared).  Pure front-door: runs entirely on handler threads.
#[derive(Clone)]
pub struct Admission {
    cfg: Arc<AdmissionConfig>,
    buckets: Arc<Mutex<HashMap<String, Bucket>>>,
}

impl Admission {
    pub fn new(cfg: AdmissionConfig) -> Admission {
        Admission {
            cfg: Arc::new(cfg),
            buckets: Arc::new(Mutex::new(HashMap::new())),
        }
    }

    /// No rate limit, no queue bound.
    pub fn unlimited() -> Admission {
        Admission::new(AdmissionConfig::default())
    }

    /// Pending-admission queue bound (`0` = unbounded).
    pub fn queue_cap(&self) -> usize {
        self.cfg.queue_cap
    }

    /// This tenant's sustained rate in requests/second.
    fn rate_for(&self, tenant: &str) -> f64 {
        if self.cfg.tenant_weights.is_empty() {
            return self.cfg.rps;
        }
        let total: u64 = self
            .cfg
            .tenant_weights
            .iter()
            .map(|(_, w)| u64::from(*w))
            .sum();
        let weight = self
            .cfg
            .tenant_weights
            .iter()
            .find(|(t, _)| t == tenant)
            .map_or(1, |(_, w)| u64::from(*w));
        self.cfg.rps * weight as f64 / total.max(1) as f64
    }

    /// Try to take one token from `tenant`'s bucket.  `Ok(())` admits;
    /// `Err(after_s)` sheds with a suggested retry delay in seconds.
    pub fn try_admit(&self, tenant: &str) -> std::result::Result<(), f64> {
        if self.cfg.rps <= 0.0 {
            return Ok(());
        }
        let rate = self.rate_for(tenant).max(1e-9);
        let burst = self.cfg.burst.max(1.0);
        let now = Instant::now();
        let mut buckets = self.buckets.lock().unwrap();
        let b = buckets
            .entry(tenant.to_string())
            .or_insert_with(|| Bucket { tokens: burst, last: now });
        let dt = now.duration_since(b.last).as_secs_f64();
        b.last = now;
        b.tokens = (b.tokens + dt * rate).min(burst);
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            Ok(())
        } else {
            Err((1.0 - b.tokens) / rate)
        }
    }
}

// ---------------------------------------------------------------------------
// handler side: shared context + server
// ---------------------------------------------------------------------------

/// Everything a handler thread needs (cheap to clone; one per thread).
#[derive(Clone)]
pub struct Gateway {
    /// `/metrics` source; `None` renders 503 (no telemetry configured)
    pub telemetry: Option<TelemetrySink>,
    /// admission channel into the serving loop
    pub api_tx: Sender<ApiRequest>,
    /// how long a `wait: true` generate may block before 504
    pub wait_timeout: Duration,
    /// front-door rate limiter + queue bound
    pub admission: Admission,
    /// shed / queue-depth / stream gauges (share with [`ApiBridge`])
    pub stats: Arc<FrontendStats>,
    /// flight recorder behind `GET /debug/trace`; `None` renders 503
    pub trace: Option<FlightRecorder>,
    /// JCT attribution behind `GET /debug/explain` and the `breakdown`
    /// objects in `wait: true` replies / SSE `done` events; `None`
    /// renders 503 and omits the reply fields
    pub explain: Option<AttributionSink>,
    /// server start, for the `/healthz` uptime field
    pub started: Instant,
}

/// Decrements the active-connection counter when a handler exits, even
/// on panic.
struct ConnSlot(Arc<AtomicUsize>);

impl Drop for ConnSlot {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The listening server: an accept thread spawning one handler thread
/// per connection (streaming responses pin a thread for their whole
/// lifetime), bounded by `max_conns`.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:8080"`, port 0 for ephemeral) and
    /// serve with at most `max_conns` concurrent connections; excess
    /// connections are answered 503 and closed.
    pub fn serve(addr: &str, gateway: Gateway, max_conns: usize)
                 -> Result<HttpServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding HTTP frontend to {addr}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::default();
        let active = Arc::new(AtomicUsize::new(0));
        let max_conns = max_conns.max(1);

        let stop_flag = stop.clone();
        let conn_reg = conns.clone();
        let accept = std::thread::Builder::new()
            .name("elis-http-accept".to_string())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop_flag.load(Ordering::SeqCst) {
                        return;
                    }
                    let Ok(mut stream) = conn else { continue };
                    if active.load(Ordering::SeqCst) >= max_conns {
                        // reap finished handlers before giving up
                        conn_reg.lock().unwrap().retain(|j| !j.is_finished());
                        if active.load(Ordering::SeqCst) >= max_conns {
                            let _ = Response::text(
                                503,
                                "connection limit reached\n",
                            )
                            .write_to(&mut stream, false);
                            continue;
                        }
                    }
                    active.fetch_add(1, Ordering::SeqCst);
                    let slot = ConnSlot(active.clone());
                    let gw = gateway.clone();
                    let spawned = std::thread::Builder::new()
                        .name("elis-http-conn".to_string())
                        .spawn(move || {
                            let _slot = slot;
                            handle_connection(stream, &gw);
                        });
                    match spawned {
                        Ok(join) => {
                            let mut reg = conn_reg.lock().unwrap();
                            reg.retain(|j| !j.is_finished());
                            reg.push(join);
                        }
                        Err(_) => { /* slot dropped by move; shed */ }
                    }
                }
            })
            .expect("spawning HTTP accept thread");

        Ok(HttpServer { addr, stop, accept: Some(accept), conns })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Graceful shutdown: stop accepting, join every live handler.
    /// Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // unblock the accept loop with one throwaway connection; a
        // wildcard bind (0.0.0.0 / [::]) is not connectable on every
        // platform, so poke loopback on the bound port instead
        let mut poke = self.addr;
        if poke.ip().is_unspecified() {
            poke.set_ip(match self.addr {
                SocketAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                SocketAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
            });
        }
        let poked =
            TcpStream::connect_timeout(&poke, Duration::from_secs(1)).is_ok();
        if !poked {
            // the poke could not land (firewalled self-connect?): leave
            // the threads parked — the stop flag retires the accept loop
            // on the next real connection — rather than hanging shutdown
            return;
        }
        if let Some(join) = self.accept.take() {
            let _ = join.join();
        }
        let drained: Vec<_> = self.conns.lock().unwrap().drain(..).collect();
        for join in drained {
            let _ = join.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// request / response plumbing
// ---------------------------------------------------------------------------

struct Request {
    method: String,
    path: String,
    body: Vec<u8>,
    /// client asked to close after this response (HTTP/1.0 default, or
    /// `Connection: close`)
    close: bool,
}

struct Response {
    status: u16,
    content_type: &'static str,
    body: String,
    extra: Vec<(&'static str, String)>,
}

impl Response {
    fn text(status: u16, body: &str) -> Response {
        Response { status, content_type: "text/plain; charset=utf-8",
                   body: body.to_string(), extra: Vec::new() }
    }

    fn json(status: u16, body: Json) -> Response {
        Response { status, content_type: "application/json",
                   body: format!("{body}\n"), extra: Vec::new() }
    }

    fn with_header(mut self, name: &'static str, value: String) -> Response {
        self.extra.push((name, value));
        self
    }

    fn write_to(&self, stream: &mut TcpStream, keep_alive: bool)
                -> std::io::Result<()> {
        let reason = match self.status {
            200 => "OK",
            202 => "Accepted",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            504 => "Gateway Timeout",
            _ => "Unknown",
        };
        let mut head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n",
            self.status, reason, self.content_type, self.body.len()
        );
        for (name, value) in &self.extra {
            head.push_str(name);
            head.push_str(": ");
            head.push_str(value);
            head.push_str("\r\n");
        }
        head.push_str(if keep_alive {
            "Connection: keep-alive\r\n\r\n"
        } else {
            "Connection: close\r\n\r\n"
        });
        stream.write_all(head.as_bytes())?;
        stream.write_all(self.body.as_bytes())?;
        stream.flush()
    }
}

/// Parse one HTTP/1.1 request (request line, headers, Content-Length
/// body) off a reader.  Generic for testability.
///
/// Returns `Ok(None)` on a clean end-of-stream before any request byte
/// (the keep-alive peer closed, or idled past the read timeout) —
/// callers close quietly instead of answering 400.
///
/// The reader is hard-capped at `MAX_HEADER + MAX_BODY` + slack *before*
/// any line parsing: `read_line` buffers until a newline, so without the
/// cap a single newline-free request line could grow memory without
/// bound regardless of the per-line checks below.
fn read_request(reader: impl Read) -> Result<Option<Request>> {
    let mut reader =
        BufReader::new(reader.take((MAX_HEADER + MAX_BODY + 1024) as u64));
    let mut line = String::new();
    match reader.read_line(&mut line) {
        Ok(0) => return Ok(None),
        Ok(_) => {}
        Err(e) if matches!(
            e.kind(),
            ErrorKind::WouldBlock
                | ErrorKind::TimedOut
                | ErrorKind::ConnectionReset
                | ErrorKind::ConnectionAborted
                | ErrorKind::UnexpectedEof
        ) => return Ok(None),
        Err(e) => return Err(e).context("reading request line"),
    }
    if line.trim().is_empty() {
        bail!("empty request line");
    }
    if line.len() > MAX_HEADER {
        bail!("request line exceeds {} bytes", MAX_HEADER);
    }
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .filter(|m| !m.is_empty())
        .ok_or_else(|| anyhow!("empty request line"))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| anyhow!("request line has no path"))?
        .to_string();
    // HTTP/1.0 closes by default; 1.1 keeps alive by default
    let mut close = parts.next() == Some("HTTP/1.0");

    let mut content_length = 0usize;
    let mut header_bytes = line.len();
    loop {
        let mut header = String::new();
        if reader.read_line(&mut header).context("reading header")? == 0 {
            break; // EOF before blank line: tolerate bodyless requests
        }
        header_bytes += header.len();
        if header_bytes > MAX_HEADER {
            bail!("header block exceeds {} bytes", MAX_HEADER);
        }
        let trimmed = header.trim();
        if trimmed.is_empty() {
            break;
        }
        if let Some((name, value)) = trimmed.split_once(':') {
            let name = name.trim();
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("bad content-length"))?;
            } else if name.eq_ignore_ascii_case("connection") {
                let v = value.trim().to_ascii_lowercase();
                if v.contains("close") {
                    close = true;
                } else if v.contains("keep-alive") {
                    close = false;
                }
            }
        }
    }
    if content_length > MAX_BODY {
        bail!("body of {} bytes exceeds {} limit", content_length, MAX_BODY);
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).context("reading body")?;
    Ok(Some(Request { method, path, body, close }))
}

fn handle_connection(mut stream: TcpStream, gw: &Gateway) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
    loop {
        let request = match read_request(&mut stream) {
            Ok(Some(r)) => r,
            Ok(None) => return, // clean close / idle keep-alive timeout
            Err(e) => {
                let resp =
                    Response::text(400, &format!("bad request: {e:#}\n"));
                let _ = resp.write_to(&mut stream, false);
                return;
            }
        };
        let keep = !request.close;
        let ok = if request.method == "POST"
            && request.path == "/v1/generate"
        {
            handle_generate(&request.body, gw, &mut stream, keep)
        } else {
            route(&request, gw).write_to(&mut stream, keep).is_ok()
        };
        if !keep || !ok {
            return;
        }
    }
}

fn route(req: &Request, gw: &Gateway) -> Response {
    // /debug/trace carries its filter in the query string, so it routes
    // by prefix rather than through the exact-path match below
    if req.method == "GET" {
        if let Some(query) = match_path(&req.path, "/debug/trace") {
            return debug_trace(query, gw);
        }
        if let Some(query) = match_path(&req.path, "/debug/explain") {
            return debug_explain(query, gw);
        }
    }
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => healthz(gw),
        ("GET", "/metrics") => match &gw.telemetry {
            Some(sink) => Response {
                status: 200,
                // Prometheus text exposition format version
                content_type: "text/plain; version=0.0.4",
                body: sink.render_prometheus(),
                extra: Vec::new(),
            },
            None => Response::text(503, "no telemetry sink configured\n"),
        },
        ("GET" | "POST" | "HEAD" | "DELETE" | "PUT", _) => {
            Response::text(404, "not found\n")
        }
        _ => Response::text(405, "method not allowed\n"),
    }
}

/// Match `path` against `route`, allowing a trailing `?query`.  Returns
/// the query string (without the `?`) on a match, `Some(None)` for the
/// bare route, `None` for no match.
fn match_path<'a>(path: &'a str, route: &str) -> Option<Option<&'a str>> {
    let rest = path.strip_prefix(route)?;
    if rest.is_empty() {
        Some(None)
    } else {
        rest.strip_prefix('?').map(Some)
    }
}

/// `GET /healthz`: structured probe body.  200 while any worker can make
/// progress (`degraded` once failover has marked some dead), 503 only
/// when every worker is gone — so k8s restarts the frontend exactly when
/// it can no longer serve, not on the first pod loss.
fn healthz(gw: &Gateway) -> Response {
    let (dead, in_flight, nodes) = match &gw.telemetry {
        Some(t) => t.with_state(|st| {
            (st.workers_dead(),
             st.nodes.iter().map(|n| n.active).sum::<u64>(),
             st.nodes.len())
        }),
        None => (0, 0, 0),
    };
    let all_dead = nodes > 0 && dead == nodes;
    let status = if all_dead {
        "dead"
    } else if dead > 0 {
        "degraded"
    } else {
        "ok"
    };
    Response::json(
        if all_dead { 503 } else { 200 },
        Json::obj(vec![
            ("status", Json::Str(status.into())),
            ("workers_dead", Json::Num(dead as f64)),
            ("jobs_in_flight", Json::Num(in_flight as f64)),
            ("uptime_s", Json::Num(gw.started.elapsed().as_secs_f64())),
        ]),
    )
}

/// `GET /debug/trace[?job=<id>]`: the flight recorder's timeline as
/// Chrome trace-event JSON (load in Perfetto / `chrome://tracing`).
fn debug_trace(query: Option<&str>, gw: &Gateway) -> Response {
    let Some(rec) = &gw.trace else {
        return Response::text(503, "tracing is not enabled\n");
    };
    let mut job = None;
    for pair in query.unwrap_or("").split('&') {
        if let Some(v) = pair.strip_prefix("job=") {
            match v.parse::<u64>() {
                Ok(id) => job = Some(id),
                Err(_) => {
                    return Response::text(
                        400, "job must be a numeric trace id\n");
                }
            }
        }
    }
    Response::json(200, rec.render_chrome(job))
}

/// `GET /debug/explain?job=<id>`: a finished job's JCT attribution —
/// queueing / head-of-line blocking / preemption stall / failover stall /
/// execution, components summing to the JCT — plus its identity facts
/// (tenant, node, tokens, predicted length, window count).
fn debug_explain(query: Option<&str>, gw: &Gateway) -> Response {
    let Some(explain) = &gw.explain else {
        return Response::text(503, "attribution is not enabled\n");
    };
    let mut job = None;
    for pair in query.unwrap_or("").split('&') {
        if let Some(v) = pair.strip_prefix("job=") {
            match v.parse::<u64>() {
                Ok(id) => job = Some(id),
                Err(_) => {
                    return Response::text(
                        400, "job must be a numeric job id\n");
                }
            }
        }
    }
    let Some(job) = job else {
        return Response::text(
            400, "missing required query parameter: job=<id>\n");
    };
    match explain.explain_json(job) {
        Some(doc) => Response::json(200, doc),
        None => Response::json(
            404,
            Json::obj(vec![
                ("error",
                 Json::Str("job not finished (or evicted from the \
                            explain ring)".into())),
                ("job_id", Json::Num(job as f64)),
            ]),
        ),
    }
}

/// Build the [`TraceRequest`] a `POST /v1/generate` body describes.
/// Exposed for the CLI and tests.
pub fn trace_request_from_json(j: &Json) -> Result<TraceRequest> {
    let total_len = j
        .get("total_len")
        .and_then(Json::as_usize)
        .unwrap_or(50)
        .max(1);
    let prompt = match j.get("prompt") {
        Some(p) => p
            .as_i32_vec()
            .ok_or_else(|| anyhow!("'prompt' must be an array of token ids"))?,
        None => {
            let n = j
                .get("prompt_len")
                .and_then(Json::as_usize)
                .unwrap_or(16)
                .clamp(1, 4096);
            // deterministic filler tokens, small ids
            (0..n).map(|i| (i % 97) as i32 + 3).collect()
        }
    };
    let tenant = j.get("tenant").and_then(Json::as_str).map(str::to_string);
    let topic = j.get("topic").and_then(Json::as_usize).unwrap_or(0);
    // NaN = "stamp with the coordinator's now" (ApiBridge::pump)
    let arrival_ms = j
        .get("arrival_ms")
        .and_then(Json::as_f64)
        .unwrap_or(f64::NAN);
    Ok(TraceRequest { id: 0, arrival_ms, prompt, total_len, topic, tenant })
}

/// Handle one `POST /v1/generate`; returns whether the connection is
/// still usable for the next keep-alive request.
fn handle_generate(body: &[u8], gw: &Gateway, stream: &mut TcpStream,
                   keep: bool) -> bool {
    let fail = |resp: Response, stream: &mut TcpStream, keep: bool| {
        resp.write_to(stream, keep).is_ok()
    };
    let text = match std::str::from_utf8(body) {
        Ok(t) => t,
        Err(_) => {
            return fail(Response::text(400, "body is not utf-8\n"),
                        stream, keep)
        }
    };
    let parsed =
        match Json::parse(if text.trim().is_empty() { "{}" } else { text }) {
            Ok(j) => j,
            Err(e) => {
                return fail(Response::text(400, &format!("bad json: {e}\n")),
                            stream, keep)
            }
        };
    let request = match trace_request_from_json(&parsed) {
        Ok(r) => r,
        Err(e) => {
            return fail(Response::text(400, &format!("bad request: {e}\n")),
                        stream, keep)
        }
    };
    let wait = parsed.get("wait").and_then(Json::as_bool).unwrap_or(false);
    let streaming =
        parsed.get("stream").and_then(Json::as_bool).unwrap_or(false);
    let tenant = request
        .tenant
        .clone()
        .unwrap_or_else(|| crate::telemetry::DEFAULT_TENANT.to_string());

    // reserve a pending-admission queue slot *before* spending a rate
    // token, so shed requests never burn bucket capacity
    let cap = gw.admission.queue_cap();
    let depth = gw.stats.queue_depth.fetch_add(1, Ordering::Relaxed) + 1;
    if cap > 0 && depth as usize > cap {
        gw.stats.queue_depth.fetch_sub(1, Ordering::Relaxed);
        gw.stats.rejected_total.fetch_add(1, Ordering::Relaxed);
        let resp = Response::text(429, "admission queue is full\n")
            .with_header("Retry-After", "1".to_string());
        return fail(resp, stream, keep);
    }
    if let Err(after) = gw.admission.try_admit(&tenant) {
        gw.stats.queue_depth.fetch_sub(1, Ordering::Relaxed);
        gw.stats.rejected_total.fetch_add(1, Ordering::Relaxed);
        let secs = (after.ceil() as u64).max(1);
        let resp = Response::text(429, "rate limit exceeded\n")
            .with_header("Retry-After", secs.to_string());
        return fail(resp, stream, keep);
    }

    let (reply_tx, reply_rx) = channel();
    let api = ApiRequest { request, wait, stream: streaming,
                           reply: reply_tx };
    if gw.api_tx.send(api).is_err() {
        gw.stats.queue_depth.fetch_sub(1, Ordering::Relaxed);
        return fail(Response::text(503, "serving loop is not running\n"),
                    stream, keep);
    }

    if streaming {
        return stream_reply(gw, &reply_rx, stream, keep);
    }

    // non-wait admissions are acked by the next pump(); give them a
    // generous bound anyway so a stalled loop surfaces as 504, not a hang
    let timeout =
        if wait { gw.wait_timeout } else { Duration::from_secs(10) };
    let resp = match recv_terminal(&reply_rx, timeout) {
        Ok(GenerateReply::Accepted { job_id }) => Response::json(
            202,
            Json::obj(vec![
                ("job_id", Json::Num(job_id as f64)),
                ("status", Json::Str("accepted".into())),
                // the job id doubles as the trace id: feed it to
                // /debug/trace?job=<id> for the span timeline
                ("trace_id", Json::Num(job_id as f64)),
            ]),
        ),
        Ok(GenerateReply::Finished { job_id, tokens, jct_ms, token_ids }) => {
            // the attribution sink is registered ahead of the completion
            // notifier, so by the time this reply fires the breakdown for
            // the finished job is already folded
            let breakdown = gw
                .explain
                .as_ref()
                .and_then(|e| e.breakdown_json(job_id))
                .unwrap_or(Json::Null);
            Response::json(
                200,
                Json::obj(vec![
                    ("job_id", Json::Num(job_id as f64)),
                    ("status", Json::Str("finished".into())),
                    ("tokens", Json::Num(tokens as f64)),
                    ("jct_ms", Json::Num(jct_ms)),
                    ("breakdown", breakdown),
                    ("token_ids", token_array(&token_ids)),
                    ("trace_id", Json::Num(job_id as f64)),
                ]),
            )
        }
        Ok(GenerateReply::Chunk { .. }) => {
            // unreachable: chunks only flow to streaming waiters
            Response::text(500, "unexpected chunk on non-stream request\n")
        }
        Ok(GenerateReply::ShuttingDown)
        | Err(RecvTimeoutError::Disconnected) => {
            // the serving loop exited (idle-exit / teardown): terminal
            // answer, never a held connection
            Response::text(503, "server is shutting down\n")
        }
        Err(RecvTimeoutError::Timeout) => {
            Response::text(504, "timed out waiting for the job\n")
        }
    };
    fail(resp, stream, keep)
}

/// Like `recv_timeout` but skips any stray `Chunk`s (a request that
/// raced from streaming registration to a plain reply path).
fn recv_terminal(rx: &Receiver<GenerateReply>, timeout: Duration)
                 -> std::result::Result<GenerateReply, RecvTimeoutError> {
    let deadline = Instant::now() + timeout;
    loop {
        let left = deadline.saturating_duration_since(Instant::now());
        let got = rx.recv_timeout(left)?;
        if !matches!(got, GenerateReply::Chunk { .. }) {
            return Ok(got);
        }
    }
}

fn token_array(tokens: &[i32]) -> Json {
    Json::Arr(tokens.iter().map(|&t| Json::Num(t as f64)).collect())
}

/// Drive a `stream: true` response: wait for the admission ack, write
/// the SSE head, then forward chunks until the finish event.  Streaming
/// responses always close the connection (`Transfer-Encoding: chunked`
/// is terminated explicitly, but clients treat event streams as
/// one-shot); returns whether the connection may be reused.
fn stream_reply(gw: &Gateway, rx: &Receiver<GenerateReply>,
                stream: &mut TcpStream, keep: bool) -> bool {
    let head = match rx.recv_timeout(Duration::from_secs(10)) {
        Ok(GenerateReply::Accepted { job_id }) => job_id,
        Ok(GenerateReply::ShuttingDown)
        | Err(RecvTimeoutError::Disconnected) => {
            return Response::text(503, "server is shutting down\n")
                .write_to(stream, keep)
                .is_ok();
        }
        Err(RecvTimeoutError::Timeout) => {
            return Response::text(504, "timed out awaiting admission\n")
                .write_to(stream, keep)
                .is_ok();
        }
        Ok(_) => {
            return Response::text(500, "unexpected reply ordering\n")
                .write_to(stream, keep)
                .is_ok();
        }
    };
    gw.stats.streams_active.fetch_add(1, Ordering::Relaxed);
    let ok = stream_events(rx, stream, gw.wait_timeout, head, keep,
                           gw.explain.as_ref());
    gw.stats.streams_active.fetch_sub(1, Ordering::Relaxed);
    ok && keep
}

/// Write the chunked SSE body for one admitted job.  Returns false if
/// the connection must close (write failure or abnormal termination).
fn stream_events(rx: &Receiver<GenerateReply>, stream: &mut TcpStream,
                 timeout: Duration, job_id: u64, keep: bool,
                 explain: Option<&AttributionSink>) -> bool {
    let conn = if keep { "keep-alive" } else { "close" };
    let head = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
         Cache-Control: no-cache\r\nTransfer-Encoding: chunked\r\n\
         Connection: {conn}\r\n\r\n"
    );
    if stream.write_all(head.as_bytes()).is_err() {
        return false;
    }
    let accepted = Json::obj(vec![
        ("job_id", Json::Num(job_id as f64)),
        ("trace_id", Json::Num(job_id as f64)),
    ]);
    if write_chunk(stream, &sse_event(Some("accepted"), &accepted.to_string()))
        .is_err()
    {
        return false;
    }
    loop {
        match rx.recv_timeout(timeout) {
            Ok(GenerateReply::Chunk { job_id, tokens }) => {
                let data = Json::obj(vec![
                    ("job_id", Json::Num(job_id as f64)),
                    ("tokens", token_array(&tokens)),
                ]);
                if write_chunk(stream, &sse_event(None, &data.to_string()))
                    .is_err()
                {
                    return false;
                }
            }
            Ok(GenerateReply::Finished { job_id, tokens, jct_ms, .. }) => {
                let breakdown = explain
                    .and_then(|e| e.breakdown_json(job_id))
                    .unwrap_or(Json::Null);
                let data = Json::obj(vec![
                    ("job_id", Json::Num(job_id as f64)),
                    ("status", Json::Str("finished".into())),
                    ("tokens", Json::Num(tokens as f64)),
                    ("jct_ms", Json::Num(jct_ms)),
                    ("breakdown", breakdown),
                ]);
                let ok = write_chunk(
                    stream,
                    &sse_event(Some("done"), &data.to_string()),
                )
                .and_then(|()| stream.write_all(b"0\r\n\r\n"))
                .and_then(|()| stream.flush());
                return ok.is_ok();
            }
            Ok(GenerateReply::Accepted { .. }) => {
                // duplicate ack: ignore
            }
            Ok(GenerateReply::ShuttingDown)
            | Err(RecvTimeoutError::Disconnected) => {
                let _ = write_chunk(
                    stream,
                    &sse_event(
                        Some("error"),
                        r#"{"error":"server is shutting down"}"#,
                    ),
                );
                let _ = stream.write_all(b"0\r\n\r\n");
                let _ = stream.flush();
                return false;
            }
            Err(RecvTimeoutError::Timeout) => {
                let _ = write_chunk(
                    stream,
                    &sse_event(Some("error"), r#"{"error":"timed out"}"#),
                );
                let _ = stream.write_all(b"0\r\n\r\n");
                let _ = stream.flush();
                return false;
            }
        }
    }
}

/// One HTTP chunk (`Transfer-Encoding: chunked` framing).
fn write_chunk(stream: &mut TcpStream, payload: &str)
               -> std::io::Result<()> {
    write!(stream, "{:x}\r\n{}\r\n", payload.len(), payload)?;
    stream.flush()
}

/// One server-sent event (`event:` line optional, then `data:`).
fn sse_event(name: Option<&str>, data: &str) -> String {
    match name {
        Some(n) => format!("event: {n}\ndata: {data}\n\n"),
        None => format!("data: {data}\n\n"),
    }
}

// ---------------------------------------------------------------------------
// client-side SSE/chunked decoder (loadgen + tests)
// ---------------------------------------------------------------------------

/// One decoded server-sent event.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SseEvent {
    /// the `event:` field, if present
    pub name: Option<String>,
    /// the `data:` payload (multiple data lines joined with `\n`)
    pub data: String,
}

/// Incremental decoder for a chunked-transfer SSE body.  Feed raw bytes
/// as they arrive off the socket (any split — mid chunk header, mid
/// payload); complete events come back out.  Used by `elis loadgen` and
/// the integration tests.
#[derive(Debug, Default)]
pub struct SseDecoder {
    /// undecoded chunked-framing bytes
    raw: Vec<u8>,
    /// de-chunked event-stream body
    body: String,
    done: bool,
}

impl SseDecoder {
    /// Feed bytes; returns every event completed by this read.
    pub fn push(&mut self, bytes: &[u8]) -> Vec<SseEvent> {
        self.raw.extend_from_slice(bytes);
        loop {
            if self.done {
                break;
            }
            // chunk-size line: hex length, optional ;extensions, CRLF
            let Some(eol) = find_crlf(&self.raw) else { break };
            let size_line =
                String::from_utf8_lossy(&self.raw[..eol]).into_owned();
            let hex = size_line
                .split(';')
                .next()
                .unwrap_or("")
                .trim()
                .to_string();
            let Ok(size) = usize::from_str_radix(&hex, 16) else {
                // unparseable framing: stop consuming
                break;
            };
            if size == 0 {
                self.done = true;
                break;
            }
            // need the full payload + trailing CRLF before consuming
            let need = eol + 2 + size + 2;
            if self.raw.len() < need {
                break;
            }
            let payload = &self.raw[eol + 2..eol + 2 + size];
            self.body.push_str(&String::from_utf8_lossy(payload));
            self.raw.drain(..need);
        }
        self.take_events()
    }

    /// The terminating zero-length chunk has been seen.
    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Split completed (`\n\n`-terminated) events off the body.
    fn take_events(&mut self) -> Vec<SseEvent> {
        let mut out = Vec::new();
        while let Some(end) = self.body.find("\n\n") {
            let block: String = self.body.drain(..end + 2).collect();
            let mut name = None;
            let mut data = Vec::new();
            for line in block.lines() {
                if let Some(v) = line.strip_prefix("event:") {
                    name = Some(v.trim().to_string());
                } else if let Some(v) = line.strip_prefix("data:") {
                    data.push(v.trim_start().to_string());
                }
            }
            if name.is_some() || !data.is_empty() {
                out.push(SseEvent { name, data: data.join("\n") });
            }
        }
        out
    }
}

fn find_crlf(buf: &[u8]) -> Option<usize> {
    buf.windows(2).position(|w| w == b"\r\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_request_line_headers_and_body() {
        let raw = "POST /v1/generate HTTP/1.1\r\nHost: x\r\n\
                   Content-Length: 11\r\n\r\nhello world";
        let req = read_request(raw.as_bytes()).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert_eq!(req.body, b"hello world");
        assert!(!req.close, "HTTP/1.1 defaults to keep-alive");
    }

    #[test]
    fn tolerates_missing_body_and_rejects_garbage() {
        let req = read_request("GET /healthz HTTP/1.1\r\n\r\n".as_bytes())
            .unwrap()
            .unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
        assert!(read_request("".as_bytes()).unwrap().is_none(),
                "clean EOF is not an error");
        assert!(read_request("\r\n".as_bytes()).is_err());
        assert!(read_request("GET\r\n\r\n".as_bytes()).is_err());
        let huge = format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
                           MAX_BODY + 1);
        assert!(read_request(huge.as_bytes()).is_err());
    }

    #[test]
    fn connection_close_and_http10_are_detected() {
        let req = read_request(
            "GET / HTTP/1.1\r\nConnection: close\r\n\r\n".as_bytes(),
        )
        .unwrap()
        .unwrap();
        assert!(req.close);
        let req = read_request("GET / HTTP/1.0\r\n\r\n".as_bytes())
            .unwrap()
            .unwrap();
        assert!(req.close, "HTTP/1.0 defaults to close");
        let req = read_request(
            "GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n".as_bytes(),
        )
        .unwrap()
        .unwrap();
        assert!(!req.close, "explicit keep-alive overrides 1.0 default");
    }

    #[test]
    fn generate_json_defaults_and_overrides() {
        let r = trace_request_from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(r.total_len, 50);
        assert_eq!(r.prompt.len(), 16);
        assert!(r.tenant.is_none());
        assert!(!r.arrival_ms.is_finite(), "unset arrival means 'now'");

        let j = Json::parse(
            r#"{"prompt":[5,6,7],"total_len":30,"tenant":"paid",
                "topic":2,"arrival_ms":125.5}"#,
        )
        .unwrap();
        let r = trace_request_from_json(&j).unwrap();
        assert_eq!(r.prompt, vec![5, 6, 7]);
        assert_eq!(r.total_len, 30);
        assert_eq!(r.tenant.as_deref(), Some("paid"));
        assert_eq!(r.topic, 2);
        assert!((r.arrival_ms - 125.5).abs() < 1e-9);

        let bad = Json::parse(r#"{"prompt":"nope"}"#).unwrap();
        assert!(trace_request_from_json(&bad).is_err());
    }

    #[test]
    fn response_has_content_length_and_reason() {
        // write through a real socket pair to exercise write_to
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let mut out = String::new();
            s.read_to_string(&mut out).unwrap();
            out
        });
        let (mut server_side, _) = listener.accept().unwrap();
        Response::json(202, Json::obj(vec![("job_id", Json::Num(7.0))]))
            .with_header("Retry-After", "2".to_string())
            .write_to(&mut server_side, false)
            .unwrap();
        drop(server_side);
        let got = client.join().unwrap();
        assert!(got.starts_with("HTTP/1.1 202 Accepted\r\n"), "{got}");
        assert!(got.contains("Content-Type: application/json"), "{got}");
        assert!(got.contains("Retry-After: 2\r\n"), "{got}");
        assert!(got.contains("Connection: close\r\n"), "{got}");
        assert!(got.contains("\"job_id\":7"), "{got}");
        let len_line = got
            .lines()
            .find(|l| l.starts_with("Content-Length: "))
            .expect("content-length header");
        let n: usize = len_line["Content-Length: ".len()..].parse().unwrap();
        let body = got.split("\r\n\r\n").nth(1).unwrap();
        assert_eq!(n, body.len());
    }

    #[test]
    fn admission_bucket_sheds_and_refills_per_tenant() {
        // burst of 2: two immediate admits, third shed with a retry hint
        let adm = Admission::new(AdmissionConfig {
            rps: 10.0,
            burst: 2.0,
            queue_cap: 0,
            tenant_weights: Vec::new(),
        });
        assert!(adm.try_admit("a").is_ok());
        assert!(adm.try_admit("a").is_ok());
        let after = adm.try_admit("a").unwrap_err();
        assert!(after > 0.0 && after <= 0.11, "retry hint ~0.1s: {after}");
        // a different tenant has its own bucket
        assert!(adm.try_admit("b").is_ok());

        // rps = 0 disables the limiter entirely
        let open = Admission::unlimited();
        for _ in 0..1000 {
            assert!(open.try_admit("x").is_ok());
        }

        // weighted split: paid gets 3/4 of the rate, free 1/4, unknown
        // tenants weight 1 (here 1/4)
        let weighted = Admission::new(AdmissionConfig {
            rps: 8.0,
            burst: 1.0,
            queue_cap: 0,
            tenant_weights: vec![("paid".into(), 3), ("free".into(), 1)],
        });
        assert!((weighted.rate_for("paid") - 6.0).abs() < 1e-9);
        assert!((weighted.rate_for("free") - 2.0).abs() < 1e-9);
        assert!((weighted.rate_for("mystery") - 2.0).abs() < 1e-9);
    }

    #[test]
    fn sse_decoder_reassembles_split_chunked_reads() {
        // two events across three chunks, fed one byte at a time
        let e1 = sse_event(Some("accepted"), r#"{"job_id":1}"#);
        let e2 = sse_event(None, r#"{"tokens":[1,2,3]}"#);
        let mut wire = Vec::new();
        for part in [&e1[..7], &e1[7..], &e2[..]] {
            wire.extend_from_slice(
                format!("{:x}\r\n{}\r\n", part.len(), part).as_bytes(),
            );
        }
        wire.extend_from_slice(b"0\r\n\r\n");

        let mut dec = SseDecoder::default();
        let mut events = Vec::new();
        for b in &wire {
            events.extend(dec.push(std::slice::from_ref(b)));
        }
        assert!(dec.is_done());
        assert_eq!(events.len(), 2, "{events:?}");
        assert_eq!(events[0].name.as_deref(), Some("accepted"));
        assert_eq!(events[0].data, r#"{"job_id":1}"#);
        assert!(events[1].name.is_none());
        assert_eq!(events[1].data, r#"{"tokens":[1,2,3]}"#);

        // the same wire in one gulp decodes identically
        let mut dec2 = SseDecoder::default();
        let all = dec2.push(&wire);
        assert!(dec2.is_done());
        assert_eq!(all, events);
    }

    #[test]
    fn match_path_splits_route_and_query() {
        assert_eq!(match_path("/debug/trace", "/debug/trace"), Some(None));
        assert_eq!(match_path("/debug/trace?job=3", "/debug/trace"),
                   Some(Some("job=3")));
        assert_eq!(match_path("/debug/tracex", "/debug/trace"), None);
        assert_eq!(match_path("/metrics", "/debug/trace"), None);
    }

    fn test_gateway() -> Gateway {
        let (tx, _bridge) = ApiBridge::channel();
        Gateway {
            telemetry: Some(TelemetrySink::new(2)),
            api_tx: tx,
            wait_timeout: Duration::from_secs(1),
            admission: Admission::unlimited(),
            stats: Arc::new(FrontendStats::default()),
            trace: Some(FlightRecorder::default()),
            explain: Some(AttributionSink::default()),
            started: Instant::now(),
        }
    }

    #[test]
    fn healthz_reports_structure_and_degrades_to_503() {
        let gw = test_gateway();
        let resp = healthz(&gw);
        assert_eq!(resp.status, 200);
        let j = Json::parse(&resp.body).unwrap();
        assert_eq!(j.get("status").and_then(Json::as_str), Some("ok"));
        assert_eq!(j.get("workers_dead").and_then(Json::as_usize), Some(0));
        assert_eq!(j.get("jobs_in_flight").and_then(Json::as_usize),
                   Some(0));
        assert!(j.get("uptime_s").and_then(Json::as_f64).unwrap() >= 0.0);

        // one of two workers dead: degraded but still 200
        let mut sink = gw.telemetry.clone().unwrap();
        sink.on_worker_lost(0, 1, 10.0);
        let resp = healthz(&gw);
        assert_eq!(resp.status, 200);
        let j = Json::parse(&resp.body).unwrap();
        assert_eq!(j.get("status").and_then(Json::as_str), Some("degraded"));

        // every worker dead: the probe must fail
        sink.on_worker_lost(1, 0, 11.0);
        let resp = healthz(&gw);
        assert_eq!(resp.status, 503);
        let j = Json::parse(&resp.body).unwrap();
        assert_eq!(j.get("status").and_then(Json::as_str), Some("dead"));
        assert_eq!(j.get("workers_dead").and_then(Json::as_usize), Some(2));
    }

    #[test]
    fn debug_trace_routes_render_and_validate() {
        let gw = test_gateway();
        let resp = debug_trace(None, &gw);
        assert_eq!(resp.status, 200);
        let j = Json::parse(&resp.body).unwrap();
        assert!(j.get("traceEvents").is_some(), "{}", resp.body);

        let resp = debug_trace(Some("job=17"), &gw);
        assert_eq!(resp.status, 200);
        assert_eq!(debug_trace(Some("job=frog"), &gw).status, 400);

        let mut bare = test_gateway();
        bare.trace = None;
        assert_eq!(debug_trace(None, &bare).status, 503);
    }

    #[test]
    fn sse_event_formats_with_and_without_name() {
        assert_eq!(sse_event(Some("done"), "{}"),
                   "event: done\ndata: {}\n\n");
        assert_eq!(sse_event(None, "x"), "data: x\n\n");
    }

    #[test]
    fn debug_explain_validates_and_serves_breakdowns() {
        use crate::coordinator::{
            EventSink, FinishStats, JobId, JobMeta, WindowEvents,
            WindowJobEvent,
        };
        let gw = test_gateway();
        // parameter validation before any job exists
        assert_eq!(debug_explain(None, &gw).status, 400);
        assert_eq!(debug_explain(Some("job=frog"), &gw).status, 400);
        assert_eq!(debug_explain(Some("job=9"), &gw).status, 404);

        // finish one job through the sink, then explain it over HTTP
        let mut sink = gw.explain.clone().unwrap();
        let job = JobMeta {
            id: JobId::from_raw(9),
            tenant: Some("paid"),
            arrival_ms: 0.0,
            prompt_len: 4,
            total_len: 8,
        };
        sink.on_job_admitted(&job, 0, 0.0);
        sink.on_window_applied(&WindowEvents {
            node: 0,
            batch: &[job.id],
            events: &[WindowJobEvent::Finished {
                job: job.clone(),
                stats: FinishStats {
                    jct_ms: 30.0,
                    ttft_ms: Some(22.0),
                    queue_delay_ms: 20.0,
                    service_ms: 10.0,
                    tokens: 4,
                    predicted_total: Some(8.0),
                },
            }],
            tokens: 4,
            service_ms: 10.0,
            now_ms: 30.0,
            pod: None,
        });
        let resp = debug_explain(Some("job=9"), &gw);
        assert_eq!(resp.status, 200, "{}", resp.body);
        let j = Json::parse(&resp.body).unwrap();
        assert_eq!(j.get("job_id").and_then(Json::as_usize), Some(9));
        assert_eq!(j.get("tenant").and_then(Json::as_str), Some("paid"));
        let b = j.get("breakdown").expect("breakdown object");
        assert!(
            (b.get("total_ms").and_then(Json::as_f64).unwrap() - 30.0).abs()
                < 1e-6
        );

        let mut bare = test_gateway();
        bare.explain = None;
        assert_eq!(debug_explain(Some("job=9"), &bare).status, 503);
    }
}
