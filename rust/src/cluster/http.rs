//! Minimal HTTP/1.1 frontend on `std::net` — no dependencies.
//!
//! The paper deploys the frontend scheduler as a Kubernetes Deployment
//! with an HTTP port (§5); this module is that service surface for the
//! in-process cluster runtime:
//!
//! * `GET /healthz` — liveness probe (the k8s manifests' port 8080).
//! * `GET /metrics` — Prometheus text exposition, snapshotted live from
//!   the shared [`TelemetrySink`] (thread-safe — handler threads render
//!   while the serving loop appends events).
//! * `POST /v1/generate` — admit a JSON request into the *running*
//!   coordinator via [`Coordinator::push_request`].  Body fields (all
//!   optional): `prompt` (array of token ids) or `prompt_len`,
//!   `total_len`, `topic`, `tenant`, `arrival_ms` (defaults to "now";
//!   trusted only within the trailing [`MAX_BACKDATE_MS`], anything else
//!   is re-stamped), and `wait` (block until the job finishes and report
//!   its stats).
//!
//! Connections are handled by a small thread pool; [`HttpServer::shutdown`]
//! stops accepting, drains the handler threads, and joins everything
//! (also run on drop).
//!
//! The serving loop stays single-threaded and lock-free: handlers never
//! touch the [`Coordinator`].  They enqueue [`ApiRequest`]s on an mpsc
//! channel; the loop driving the coordinator calls [`ApiBridge::pump`]
//! between steps to admit them, and a [`CompletionNotifier`] sink resolves
//! `wait`ing handlers when their job finishes.
//!
//! [`Coordinator`]: crate::coordinator::Coordinator
//! [`Coordinator::push_request`]: crate::coordinator::Coordinator::push_request

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener,
               TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::events::{EventSink, FinishStats, JobMeta};
use crate::coordinator::Coordinator;
use crate::telemetry::TelemetrySink;
use crate::util::json::Json;
use crate::workload::TraceRequest;

/// Maximum accepted request body (1 MiB).
const MAX_BODY: usize = 1 << 20;
/// Maximum accepted header block (16 KiB).
const MAX_HEADER: usize = 16 << 10;
/// How far in the past a client-supplied `arrival_ms` may lie before it
/// is re-stamped with the live clock (see [`ApiBridge::pump`]).
pub const MAX_BACKDATE_MS: f64 = 60_000.0;

// ---------------------------------------------------------------------------
// serving-loop side: admission bridge + completion notifier
// ---------------------------------------------------------------------------

/// One `POST /v1/generate`, en route from a handler thread to the loop
/// driving the coordinator.
pub struct ApiRequest {
    pub request: TraceRequest,
    /// hold the HTTP response until the job finishes
    pub wait: bool,
    /// where the handler thread blocks for its reply
    pub reply: Sender<GenerateReply>,
}

/// Reply to one [`ApiRequest`].
#[derive(Debug, Clone, PartialEq)]
pub enum GenerateReply {
    /// admitted; the job runs asynchronously (`wait: false`)
    Accepted { job_id: u64 },
    /// finished end-to-end (`wait: true`)
    Finished { job_id: u64, tokens: usize, jct_ms: f64 },
    /// the serving loop is exiting (e.g. `--idle-exit-ms` fired) and will
    /// not run this job; the handler answers 503 instead of holding the
    /// connection until its timeout
    ShuttingDown,
}

type Waiters = Arc<Mutex<HashMap<u64, Sender<GenerateReply>>>>;

/// The serving loop's end of the admission channel.  Call
/// [`pump`](Self::pump) between coordinator steps.
pub struct ApiBridge {
    rx: Receiver<ApiRequest>,
    waiters: Waiters,
}

impl ApiBridge {
    /// Create the channel pair: the `Sender` goes into the [`Gateway`]
    /// (handler threads), the bridge stays with the serving loop.
    pub fn channel() -> (Sender<ApiRequest>, ApiBridge) {
        let (tx, rx) = channel();
        let bridge = ApiBridge { rx, waiters: Waiters::default() };
        (tx, bridge)
    }

    /// The [`EventSink`] that resolves `wait`ing handlers; register it on
    /// the same coordinator this bridge pumps into.
    pub fn completion_sink(&self) -> CompletionNotifier {
        CompletionNotifier { waiters: self.waiters.clone() }
    }

    /// Drain every pending API admission into the coordinator (non-
    /// blocking).  Requests are stamped with the coordinator's *live*
    /// time (`admission_now_ms` — the wall clock in wall mode, since
    /// `now()` goes stale while the loop idles) unless they carry an
    /// `arrival_ms` within the trailing [`MAX_BACKDATE_MS`]: a future
    /// stamp would park the job forever (wedging `is_done()` and any
    /// idle-exit logic) and an ancient one fabricates a huge JCT that
    /// pollutes the latency sketches and SLO accounting.  Returns how
    /// many were admitted.
    pub fn pump(&mut self, coord: &mut Coordinator<'_>) -> usize {
        let mut admitted = 0;
        while let Ok(mut req) = self.rx.try_recv() {
            let now = coord.admission_now_ms();
            let a = req.request.arrival_ms;
            if !a.is_finite() || a < 0.0 || a > now
                || a < now - MAX_BACKDATE_MS
            {
                req.request.arrival_ms = now;
            }
            let id = coord.push_request(&req.request);
            if req.wait {
                self.waiters
                    .lock()
                    .unwrap()
                    .insert(id.raw(), req.reply);
            } else {
                // a dropped receiver just means the handler timed out
                let _ = req.reply.send(GenerateReply::Accepted {
                    job_id: id.raw(),
                });
            }
            admitted += 1;
        }
        admitted
    }
}

impl ApiBridge {
    /// Shutdown drain: answer every queued admission *and* every still-
    /// `wait`ing handler with [`GenerateReply::ShuttingDown`], so held
    /// connections get a terminal 503 instead of hanging out their
    /// timeout when the serving loop exits (`--idle-exit-ms` racing a
    /// `wait: true` generate).  Call after the serving loop's last
    /// `pump`, before `HttpServer::shutdown`; returns how many requests
    /// were answered.
    pub fn drain_shutdown(&mut self) -> usize {
        let mut n = 0;
        while let Ok(req) = self.rx.try_recv() {
            let _ = req.reply.send(GenerateReply::ShuttingDown);
            n += 1;
        }
        for (_, tx) in self.waiters.lock().unwrap().drain() {
            let _ = tx.send(GenerateReply::ShuttingDown);
            n += 1;
        }
        n
    }
}

/// [`EventSink`] resolving `wait: true` generate calls on job finish.
pub struct CompletionNotifier {
    waiters: Waiters,
}

impl EventSink for CompletionNotifier {
    fn on_job_finished(&mut self, job: &JobMeta<'_>, _node: usize,
                       stats: &FinishStats, _now_ms: f64) {
        if let Some(tx) = self.waiters.lock().unwrap().remove(&job.id.raw()) {
            let _ = tx.send(GenerateReply::Finished {
                job_id: job.id.raw(),
                tokens: stats.tokens,
                jct_ms: stats.jct_ms,
            });
        }
    }
}

// ---------------------------------------------------------------------------
// handler side: shared context + server
// ---------------------------------------------------------------------------

/// Everything a handler thread needs (cheap to clone; one per thread).
#[derive(Clone)]
pub struct Gateway {
    /// `/metrics` source; `None` renders 503 (no telemetry configured)
    pub telemetry: Option<TelemetrySink>,
    /// admission channel into the serving loop
    pub api_tx: Sender<ApiRequest>,
    /// how long a `wait: true` generate may block before 504
    pub wait_timeout: Duration,
}

/// The listening server: an accept thread feeding a handler thread pool.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    handlers: Vec<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:8080"`, port 0 for ephemeral) and
    /// start `handler_threads` connection handlers.
    pub fn serve(addr: &str, gateway: Gateway, handler_threads: usize)
                 -> Result<HttpServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding HTTP frontend to {addr}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));

        let (conn_tx, conn_rx) = channel::<TcpStream>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let handlers = (0..handler_threads.max(1))
            .map(|i| {
                let rx = conn_rx.clone();
                let gw = gateway.clone();
                std::thread::Builder::new()
                    .name(format!("elis-http-{i}"))
                    .spawn(move || loop {
                        // hold the lock only while dequeuing
                        let conn = rx.lock().unwrap().recv();
                        match conn {
                            Ok(stream) => handle_connection(stream, &gw),
                            Err(_) => return, // accept loop gone
                        }
                    })
                    .expect("spawning HTTP handler thread")
            })
            .collect();

        let stop_flag = stop.clone();
        let accept = std::thread::Builder::new()
            .name("elis-http-accept".to_string())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop_flag.load(Ordering::SeqCst) {
                        return; // drops conn_tx -> handlers drain and exit
                    }
                    if let Ok(stream) = conn {
                        if conn_tx.send(stream).is_err() {
                            return;
                        }
                    }
                }
            })
            .expect("spawning HTTP accept thread");

        Ok(HttpServer { addr, stop, accept: Some(accept), handlers })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Graceful shutdown: stop accepting, finish queued connections, join
    /// every thread.  Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // unblock the accept loop with one throwaway connection; a
        // wildcard bind (0.0.0.0 / [::]) is not connectable on every
        // platform, so poke loopback on the bound port instead
        let mut poke = self.addr;
        if poke.ip().is_unspecified() {
            poke.set_ip(match self.addr {
                SocketAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                SocketAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
            });
        }
        let poked =
            TcpStream::connect_timeout(&poke, Duration::from_secs(1)).is_ok();
        if !poked {
            // the poke could not land (firewalled self-connect?): leave
            // the threads parked — the stop flag retires the accept loop
            // on the next real connection — rather than hanging shutdown
            return;
        }
        if let Some(join) = self.accept.take() {
            let _ = join.join();
        }
        // the accept thread has dropped conn_tx, so the handlers drain
        // their queue and exit
        for join in self.handlers.drain(..) {
            let _ = join.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// request / response plumbing
// ---------------------------------------------------------------------------

struct Request {
    method: String,
    path: String,
    body: Vec<u8>,
}

struct Response {
    status: u16,
    content_type: &'static str,
    body: String,
}

impl Response {
    fn text(status: u16, body: &str) -> Response {
        Response { status, content_type: "text/plain; charset=utf-8",
                   body: body.to_string() }
    }

    fn json(status: u16, body: Json) -> Response {
        Response { status, content_type: "application/json",
                   body: format!("{body}\n") }
    }

    fn write_to(&self, stream: &mut TcpStream) -> std::io::Result<()> {
        let reason = match self.status {
            200 => "OK",
            202 => "Accepted",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            503 => "Service Unavailable",
            504 => "Gateway Timeout",
            _ => "Unknown",
        };
        write!(
            stream,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{}",
            self.status, reason, self.content_type, self.body.len(), self.body
        )?;
        stream.flush()
    }
}

/// Parse one HTTP/1.1 request (request line, headers, Content-Length
/// body) off a reader.  Generic for testability.
///
/// The reader is hard-capped at `MAX_HEADER + MAX_BODY` + slack *before*
/// any line parsing: `read_line` buffers until a newline, so without the
/// cap a single newline-free request line could grow memory without
/// bound regardless of the per-line checks below.
fn read_request(reader: impl Read) -> Result<Request> {
    let mut reader =
        BufReader::new(reader.take((MAX_HEADER + MAX_BODY + 1024) as u64));
    let mut line = String::new();
    reader.read_line(&mut line).context("reading request line")?;
    if line.len() > MAX_HEADER {
        bail!("request line exceeds {} bytes", MAX_HEADER);
    }
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .filter(|m| !m.is_empty())
        .ok_or_else(|| anyhow!("empty request line"))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| anyhow!("request line has no path"))?
        .to_string();

    let mut content_length = 0usize;
    let mut header_bytes = line.len();
    loop {
        let mut header = String::new();
        if reader.read_line(&mut header).context("reading header")? == 0 {
            break; // EOF before blank line: tolerate bodyless requests
        }
        header_bytes += header.len();
        if header_bytes > MAX_HEADER {
            bail!("header block exceeds {} bytes", MAX_HEADER);
        }
        let trimmed = header.trim();
        if trimmed.is_empty() {
            break;
        }
        if let Some((name, value)) = trimmed.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("bad content-length"))?;
            }
        }
    }
    if content_length > MAX_BODY {
        bail!("body of {} bytes exceeds {} limit", content_length, MAX_BODY);
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).context("reading body")?;
    Ok(Request { method, path, body })
}

fn handle_connection(mut stream: TcpStream, gw: &Gateway) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
    let response = match read_request(&mut stream) {
        Ok(request) => route(&request, gw),
        Err(e) => Response::text(400, &format!("bad request: {e:#}\n")),
    };
    let _ = response.write_to(&mut stream);
}

fn route(req: &Request, gw: &Gateway) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => Response::text(200, "ok\n"),
        ("GET", "/metrics") => match &gw.telemetry {
            Some(sink) => Response {
                status: 200,
                // Prometheus text exposition format version
                content_type: "text/plain; version=0.0.4",
                body: sink.render_prometheus(),
            },
            None => Response::text(503, "no telemetry sink configured\n"),
        },
        ("POST", "/v1/generate") => handle_generate(&req.body, gw),
        ("GET" | "POST" | "HEAD" | "DELETE" | "PUT", _) => {
            Response::text(404, "not found\n")
        }
        _ => Response::text(405, "method not allowed\n"),
    }
}

/// Build the [`TraceRequest`] a `POST /v1/generate` body describes.
/// Exposed for the CLI and tests.
pub fn trace_request_from_json(j: &Json) -> Result<TraceRequest> {
    let total_len = j
        .get("total_len")
        .and_then(Json::as_usize)
        .unwrap_or(50)
        .max(1);
    let prompt = match j.get("prompt") {
        Some(p) => p
            .as_i32_vec()
            .ok_or_else(|| anyhow!("'prompt' must be an array of token ids"))?,
        None => {
            let n = j
                .get("prompt_len")
                .and_then(Json::as_usize)
                .unwrap_or(16)
                .clamp(1, 4096);
            // deterministic filler tokens, small ids
            (0..n).map(|i| (i % 97) as i32 + 3).collect()
        }
    };
    let tenant = j.get("tenant").and_then(Json::as_str).map(str::to_string);
    let topic = j.get("topic").and_then(Json::as_usize).unwrap_or(0);
    // NaN = "stamp with the coordinator's now" (ApiBridge::pump)
    let arrival_ms = j
        .get("arrival_ms")
        .and_then(Json::as_f64)
        .unwrap_or(f64::NAN);
    Ok(TraceRequest { id: 0, arrival_ms, prompt, total_len, topic, tenant })
}

fn handle_generate(body: &[u8], gw: &Gateway) -> Response {
    let text = match std::str::from_utf8(body) {
        Ok(t) => t,
        Err(_) => return Response::text(400, "body is not utf-8\n"),
    };
    let parsed = match Json::parse(if text.trim().is_empty() { "{}" } else { text }) {
        Ok(j) => j,
        Err(e) => return Response::text(400, &format!("bad json: {e}\n")),
    };
    let request = match trace_request_from_json(&parsed) {
        Ok(r) => r,
        Err(e) => return Response::text(400, &format!("bad request: {e}\n")),
    };
    let wait = parsed.get("wait").and_then(Json::as_bool).unwrap_or(false);

    let (reply_tx, reply_rx) = channel();
    let api = ApiRequest { request, wait, reply: reply_tx };
    if gw.api_tx.send(api).is_err() {
        return Response::text(503, "serving loop is not running\n");
    }
    // non-wait admissions are acked by the next pump(); give them a
    // generous bound anyway so a stalled loop surfaces as 504, not a hang
    let timeout = if wait { gw.wait_timeout } else { Duration::from_secs(10) };
    match reply_rx.recv_timeout(timeout) {
        Ok(GenerateReply::Accepted { job_id }) => Response::json(
            202,
            Json::obj(vec![
                ("job_id", Json::Num(job_id as f64)),
                ("status", Json::Str("accepted".into())),
            ]),
        ),
        Ok(GenerateReply::Finished { job_id, tokens, jct_ms }) => {
            Response::json(
                200,
                Json::obj(vec![
                    ("job_id", Json::Num(job_id as f64)),
                    ("status", Json::Str("finished".into())),
                    ("tokens", Json::Num(tokens as f64)),
                    ("jct_ms", Json::Num(jct_ms)),
                ]),
            )
        }
        Ok(GenerateReply::ShuttingDown)
        | Err(RecvTimeoutError::Disconnected) => {
            // the serving loop exited (idle-exit / teardown): terminal
            // answer, never a held connection
            Response::text(503, "server is shutting down\n")
        }
        Err(RecvTimeoutError::Timeout) => {
            Response::text(504, "timed out waiting for the job\n")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_request_line_headers_and_body() {
        let raw = "POST /v1/generate HTTP/1.1\r\nHost: x\r\n\
                   Content-Length: 11\r\n\r\nhello world";
        let req = read_request(raw.as_bytes()).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert_eq!(req.body, b"hello world");
    }

    #[test]
    fn tolerates_missing_body_and_rejects_garbage() {
        let req = read_request("GET /healthz HTTP/1.1\r\n\r\n".as_bytes())
            .unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
        assert!(read_request("\r\n".as_bytes()).is_err());
        assert!(read_request("GET\r\n\r\n".as_bytes()).is_err());
        let huge = format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
                           MAX_BODY + 1);
        assert!(read_request(huge.as_bytes()).is_err());
    }

    #[test]
    fn generate_json_defaults_and_overrides() {
        let r = trace_request_from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(r.total_len, 50);
        assert_eq!(r.prompt.len(), 16);
        assert!(r.tenant.is_none());
        assert!(!r.arrival_ms.is_finite(), "unset arrival means 'now'");

        let j = Json::parse(
            r#"{"prompt":[5,6,7],"total_len":30,"tenant":"paid",
                "topic":2,"arrival_ms":125.5}"#,
        )
        .unwrap();
        let r = trace_request_from_json(&j).unwrap();
        assert_eq!(r.prompt, vec![5, 6, 7]);
        assert_eq!(r.total_len, 30);
        assert_eq!(r.tenant.as_deref(), Some("paid"));
        assert_eq!(r.topic, 2);
        assert!((r.arrival_ms - 125.5).abs() < 1e-9);

        let bad = Json::parse(r#"{"prompt":"nope"}"#).unwrap();
        assert!(trace_request_from_json(&bad).is_err());
    }

    #[test]
    fn response_has_content_length_and_reason() {
        // write through a real socket pair to exercise write_to
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let mut out = String::new();
            s.read_to_string(&mut out).unwrap();
            out
        });
        let (mut server_side, _) = listener.accept().unwrap();
        Response::json(202, Json::obj(vec![("job_id", Json::Num(7.0))]))
            .write_to(&mut server_side)
            .unwrap();
        drop(server_side);
        let got = client.join().unwrap();
        assert!(got.starts_with("HTTP/1.1 202 Accepted\r\n"), "{got}");
        assert!(got.contains("Content-Type: application/json"), "{got}");
        assert!(got.contains("\"job_id\":7"), "{got}");
        let len_line = got
            .lines()
            .find(|l| l.starts_with("Content-Length: "))
            .expect("content-length header");
        let n: usize = len_line["Content-Length: ".len()..].parse().unwrap();
        let body = got.split("\r\n\r\n").nth(1).unwrap();
        assert_eq!(n, body.len());
    }
}
