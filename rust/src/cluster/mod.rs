//! Cluster runtime: the concurrency + network layer that turns the
//! offline reproduction into the deployable service the paper's
//! Kubernetes manifests describe (§5) — dependency-free, `std` only.
//!
//! * [`pool`] — [`WorkerPool`]: one OS thread per backend engine.
//!   Wall-clock dispatch sends each formed batch over a per-worker mpsc
//!   channel and completions drain from one shared channel, so
//!   multi-worker wall-clock runs genuinely overlap scheduling windows
//!   (previously every window executed inline and sequentially on one
//!   thread).  Virtual-clock runs keep the inline path and stay
//!   bit-identical.
//! * [`http`] — [`HttpServer`]: a minimal HTTP/1.1 frontend on
//!   `std::net::TcpListener` with keep-alive, one handler thread per
//!   connection (bounded by `max_conns`), and graceful shutdown.
//!   `GET /healthz` for probes (body carries the dead-worker count),
//!   `GET /metrics` for a live Prometheus scrape of the telemetry sink,
//!   and `POST /v1/generate` for admission into a running coordinator
//!   (via [`ApiBridge`] + `Coordinator::push_request`) — with
//!   `"stream": true` the response is a chunked server-sent-event
//!   stream of per-window token payloads fed by [`StreamNotifier`].
//!   The front door sheds overload before it reaches the serving loop:
//!   [`Admission`] is a per-tenant token bucket plus a bounded
//!   pending-admission queue, both answering `429 Retry-After`.
//!
//! * [`wire`] — the `WorkerCmd` / `WindowDone` protocol on the wire:
//!   length-prefixed JSON frames over `TcpStream` with a versioned
//!   hello/handshake carrying engine capabilities.
//! * [`remote`] — [`RemoteWorkerPool`]: the [`WorkerPool`] surface over
//!   registered TCP pod connections (per-worker writer threads, one
//!   shared completion reader, synthesized error replies on disconnect),
//!   plus [`run_worker`] — the backend-pod loop behind
//!   `elis worker --connect <addr>`.
//!
//! Both pools implement [`WorkerTransport`], so the coordinator's pooled
//! backend is the same code in-process and across machines.
//!
//! Wiring: `elis serve --listen <addr>` runs the frontend; adding
//! `--worker-listen <addr>` accepts pod registrations so `--workers` can
//! span machines (each pod runs `elis worker --connect`).  See
//! `examples/cluster_serve.rs` and `examples/distributed_serve.rs`.
//!
//! ```text
//!   HTTP clients ──> HttpServer (handler threads)
//!        │  /metrics ◀── TelemetrySink (shared, thread-safe)
//!        └─ /v1/generate ──> ApiBridge ──> Coordinator (serving loop)
//!                                              │ dispatch (WorkerTransport)
//!                          ┌───────────────────┴──────────────────┐
//!                          ▼                                      ▼
//!              WorkerPool threads                RemoteWorkerPool (TCP)
//!              (one engine each)             elis worker pods, one engine
//!                                            each, wire.rs framed JSON
//! ```

pub mod http;
pub mod pool;
pub mod remote;
pub mod wire;

pub use http::{Admission, AdmissionConfig, ApiBridge, ApiRequest,
               CompletionNotifier, Gateway, GenerateReply, HttpServer,
               SseDecoder, SseEvent, StreamNotifier};
pub use pool::{WindowDone, WorkerCmd, WorkerPool, WorkerTransport};
pub use remote::{run_worker, RemoteWorkerPool};
