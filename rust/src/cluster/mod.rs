//! Cluster runtime: the concurrency + network layer that turns the
//! offline reproduction into the deployable service the paper's
//! Kubernetes manifests describe (§5) — dependency-free, `std` only.
//!
//! * [`pool`] — [`WorkerPool`]: one OS thread per backend engine.
//!   Wall-clock dispatch sends each formed batch over a per-worker mpsc
//!   channel and completions drain from one shared channel, so
//!   multi-worker wall-clock runs genuinely overlap scheduling windows
//!   (previously every window executed inline and sequentially on one
//!   thread).  Virtual-clock runs keep the inline path and stay
//!   bit-identical.
//! * [`http`] — [`HttpServer`]: a minimal HTTP/1.1 frontend on
//!   `std::net::TcpListener` with a connection-handling thread pool and
//!   graceful shutdown.  `GET /healthz` for probes, `GET /metrics` for a
//!   live Prometheus scrape of the telemetry sink, and
//!   `POST /v1/generate` for streaming admission into a running
//!   coordinator (via [`ApiBridge`] + `Coordinator::push_request`).
//!
//! Wiring: `elis serve --listen <addr>` runs both; see
//! `examples/cluster_serve.rs` for the embedded-API shape.
//!
//! ```text
//!   HTTP clients ──> HttpServer (handler threads)
//!        │  /metrics ◀── TelemetrySink (shared, thread-safe)
//!        └─ /v1/generate ──> ApiBridge ──> Coordinator (serving loop)
//!                                              │ dispatch (mpsc)
//!                                              ▼
//!                                    WorkerPool threads (one engine each)
//! ```

pub mod http;
pub mod pool;

pub use http::{ApiBridge, ApiRequest, CompletionNotifier, Gateway,
               GenerateReply, HttpServer};
pub use pool::{WindowDone, WorkerCmd, WorkerPool};
