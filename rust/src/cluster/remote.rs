//! Distributed worker pods: the [`WorkerCmd`] / [`WindowDone`] protocol
//! over TCP (paper §5 — the frontend scheduler Deployment fronting a
//! StatefulSet of inference pods), `std`-only.
//!
//! Two halves:
//!
//! * **Coordinator side** — [`RemoteWorkerPool`]: the same surface as the
//!   in-process [`WorkerPool`](super::pool::WorkerPool) (both implement
//!   [`WorkerTransport`]), but each worker is a registered TCP connection
//!   instead of an OS thread.  [`RemoteWorkerPool::accept`] waits for `n`
//!   pods to register (versioned [`Hello`] handshake carrying engine
//!   capabilities).  Per worker, a *writer thread* serializes commands in
//!   dispatch order, and a *reader thread* feeds replies into the shared
//!   completion channel the coordinator drains.
//!
//! * **Pod side** — [`run_worker`]: the engine loop behind
//!   `elis worker --connect <addr>`: handshake, then apply command frames
//!   in order (the same [`run_cmd_window`] body the thread pool runs) and
//!   reply with one `WindowDone` frame per window.  Returns `Ok` when the
//!   coordinator closes the connection (orderly shutdown / scale-down).
//!
//! **Failure semantics** — the part the in-process pool never needed.  A
//! pod can vanish mid-window (OOM-kill, node loss, network partition).
//! The writer and reader threads share one in-flight slot per worker:
//! whichever side observes the broken connection first takes the slot and
//! synthesizes an **error [`WindowDone`]** carrying the window's `batch`
//! and `fresh` (attempted-admit) ids — exactly the reply shape an engine
//! error produces — so the coordinator's existing rollback path returns
//! the batch to the queue and wipes the partial admits, and its failover
//! path re-homes the dead pod's jobs onto survivors.  The slot also
//! guarantees *exactly one* reply per window: a genuine reply that lost
//! the race against the synthesized error is dropped, never double-
//! applied.  `worker_alive` reports the connection state, and
//! `synthesizes_disconnects` tells the coordinator it may wait for the
//! synthesized reply instead of failing fast.

use std::io::{BufReader, BufWriter, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::job::JobId;
use crate::coordinator::PodExec;
use crate::engine::Engine;

use super::pool::{run_cmd_window, WindowDone, WorkerCmd, WorkerTransport};
use super::wire::{self, Hello, MAX_FRAME, WIRE_VERSION};

/// How long a registering pod gets to complete the hello handshake.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);

/// The window currently awaiting a reply: `(echo batch, fresh admits)`.
type InFlightWindow = Option<(Vec<JobId>, Vec<u64>)>;

/// State shared between one worker's writer and reader threads.
struct Shared {
    alive: AtomicBool,
    /// Taking this slot is the exclusive right to answer the in-flight
    /// window — either with the pod's genuine reply or with a synthesized
    /// disconnect error — so exactly one `WindowDone` per `RunWindow`
    /// reaches the coordinator whatever order the connection dies in.
    in_flight: Mutex<InFlightWindow>,
}

struct RemoteWorker {
    /// `None` once shut down (closing the channel ends the writer loop)
    cmd_tx: Option<Sender<WorkerCmd>>,
    shared: Arc<Shared>,
    /// kept for shutdown: closing both directions unblocks the reader
    stream: TcpStream,
    max_batch: usize,
    describe: String,
    /// the pod declared trace support in its hello (old pods: false)
    trace_capable: bool,
    peer: String,
    writer: Option<JoinHandle<()>>,
    reader: Option<JoinHandle<()>>,
}

/// Owns the registered pod connections and the shared completion channel
/// — [`WorkerPool`](super::pool::WorkerPool)'s surface, over TCP.
pub struct RemoteWorkerPool {
    workers: Vec<RemoteWorker>,
    done_rx: Receiver<WindowDone>,
}

impl RemoteWorkerPool {
    /// Accept `n` pod registrations off `listener` (hello handshake,
    /// version check, capability capture), erring if they have not all
    /// registered within `timeout`.  Registration order assigns worker
    /// indices.  A connection that fails its handshake is logged and
    /// dropped without consuming a slot, so a port-scanner's probe cannot
    /// poison the pool.
    pub fn accept(listener: &TcpListener, n: usize, timeout: Duration)
                  -> Result<RemoteWorkerPool> {
        listener
            .set_nonblocking(true)
            .context("setting the worker listener non-blocking")?;
        let deadline = Instant::now() + timeout;
        let (done_tx, done_rx) = channel();
        let mut workers: Vec<RemoteWorker> = Vec::with_capacity(n);
        while workers.len() < n {
            match listener.accept() {
                Ok((stream, peer)) => {
                    let idx = workers.len();
                    match register(stream, idx, peer.to_string(),
                                   done_tx.clone()) {
                        Ok(w) => workers.push(w),
                        Err(e) => eprintln!(
                            "rejected worker registration from {peer}: {e:#}"
                        ),
                    }
                }
                Err(ref e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    if Instant::now() >= deadline {
                        bail!("timed out waiting for worker pods: {}/{} \
                               registered", workers.len(), n);
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => {
                    return Err(e).context("accepting a worker registration")
                }
            }
        }
        listener.set_nonblocking(false).ok();
        Ok(RemoteWorkerPool { workers, done_rx })
    }

    /// The registered pod's peer address (logs / `/metrics` labels).
    pub fn peer(&self, worker: usize) -> &str {
        &self.workers[worker].peer
    }
}

impl WorkerTransport for RemoteWorkerPool {
    fn workers(&self) -> usize {
        self.workers.len()
    }

    fn max_batch(&self, worker: usize) -> usize {
        self.workers[worker].max_batch
    }

    fn describe(&self, worker: usize) -> &str {
        &self.workers[worker].describe
    }

    fn send(&self, worker: usize, cmd: WorkerCmd) -> Result<()> {
        let w = &self.workers[worker];
        if !w.shared.alive.load(Ordering::SeqCst) {
            bail!("worker {worker} ({}) connection is gone", w.peer);
        }
        w.cmd_tx
            .as_ref()
            .ok_or_else(|| anyhow!("worker {worker} already shut down"))?
            .send(cmd)
            .map_err(|_| anyhow!("worker {worker} writer is gone"))
    }

    fn try_recv_done(&self) -> Option<WindowDone> {
        self.done_rx.try_recv().ok()
    }

    fn recv_done_timeout(&self, timeout: Duration) -> Option<WindowDone> {
        self.done_rx.recv_timeout(timeout).ok()
    }

    fn worker_alive(&self, worker: usize) -> bool {
        self.workers[worker].shared.alive.load(Ordering::SeqCst)
    }

    fn trace_capable(&self, worker: usize) -> bool {
        self.workers[worker].trace_capable
    }

    fn synthesizes_disconnects(&self) -> bool {
        true
    }
}

impl Drop for RemoteWorkerPool {
    fn drop(&mut self) {
        // close every command channel and socket first so all workers
        // wind down in parallel, then join
        for w in &mut self.workers {
            w.cmd_tx = None;
            let _ = w.stream.shutdown(Shutdown::Both);
        }
        for w in &mut self.workers {
            if let Some(join) = w.writer.take() {
                let _ = join.join();
            }
            if let Some(join) = w.reader.take() {
                let _ = join.join();
            }
        }
    }
}

/// Handshake one accepted connection and spawn its writer/reader threads.
fn register(stream: TcpStream, idx: usize, peer: String,
            done_tx: Sender<WindowDone>) -> Result<RemoteWorker> {
    // the accepted socket may inherit the listener's non-blocking mode on
    // some platforms; command I/O wants plain blocking semantics
    stream.set_nonblocking(false).context("clearing non-blocking")?;
    stream.set_nodelay(true).ok(); // windows are latency-sensitive
    stream
        .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
        .context("setting the handshake timeout")?;
    let mut hs = stream.try_clone().context("cloning for handshake")?;
    let hello = wire::server_handshake(&mut hs, idx)?;
    stream.set_read_timeout(None).context("clearing the read timeout")?;

    let shared = Arc::new(Shared {
        alive: AtomicBool::new(true),
        in_flight: Mutex::new(None),
    });
    let (cmd_tx, cmd_rx) = channel::<WorkerCmd>();
    let write_stream = stream.try_clone().context("cloning for writer")?;
    let read_stream = stream.try_clone().context("cloning for reader")?;
    let writer = std::thread::Builder::new()
        .name(format!("elis-remote-tx-{idx}"))
        .spawn({
            let shared = shared.clone();
            let done_tx = done_tx.clone();
            move || writer_main(idx, write_stream, cmd_rx, shared, done_tx)
        })
        .context("spawning the writer thread")?;
    let reader = std::thread::Builder::new()
        .name(format!("elis-remote-rx-{idx}"))
        .spawn({
            let shared = shared.clone();
            move || reader_main(idx, read_stream, shared, done_tx)
        })
        .context("spawning the reader thread")?;

    Ok(RemoteWorker {
        cmd_tx: Some(cmd_tx),
        shared,
        stream,
        max_batch: hello.max_batch.max(1),
        trace_capable: hello.trace,
        describe: hello.describe,
        peer,
        writer: Some(writer),
        reader: Some(reader),
    })
}

/// Take the worker's in-flight slot and synthesize the disconnect reply,
/// if the slot was still unanswered.  Called by whichever of the two
/// connection threads notices the break first; the `Mutex` take makes it
/// fire at most once per window.
fn synthesize_disconnect(idx: usize, shared: &Shared,
                         done_tx: &Sender<WindowDone>, what: &str) {
    let slot = shared.in_flight.lock().unwrap().take();
    if let Some((batch, fresh)) = slot {
        let _ = done_tx.send(WindowDone {
            worker: idx,
            batch,
            fresh,
            outcome: Err(anyhow!(
                "worker {idx} connection lost {what} with a window in flight"
            )),
            trace: None,
        });
    }
}

/// Writer thread: serialize commands in dispatch order.  Records every
/// `RunWindow` in the shared in-flight slot *before* writing, so a
/// connection cut between "command left the coordinator" and "reply
/// arrived" is always covered by a synthesized error reply.
fn writer_main(idx: usize, stream: TcpStream, cmd_rx: Receiver<WorkerCmd>,
               shared: Arc<Shared>, done_tx: Sender<WindowDone>) {
    let mut w = BufWriter::new(stream);
    while let Ok(cmd) = cmd_rx.recv() {
        if let WorkerCmd::RunWindow { admits, echo, .. } = &cmd {
            let fresh: Vec<u64> = admits.iter().map(|s| s.id).collect();
            *shared.in_flight.lock().unwrap() = Some((echo.clone(), fresh));
        }
        // Liveness re-check *after* recording the slot: the reader's
        // exit path (alive=false, then take-and-synthesize) may have run
        // while this command sat in the channel — and a first write
        // after peer death often "succeeds" into the socket buffer, so
        // the write error below cannot be relied on to catch it.  In
        // every interleaving exactly one side wins the Mutex take: if
        // the reader stored `false` before our load, we synthesize from
        // the just-recorded slot; otherwise the reader's take (which
        // happens after its store) finds the slot and synthesizes.
        if !shared.alive.load(Ordering::SeqCst) {
            synthesize_disconnect(idx, &shared, &done_tx, "while sending");
            return;
        }
        let payload = wire::encode_cmd(&cmd).to_string();
        let sent = wire::write_frame(&mut w, payload.as_bytes())
            .and_then(|()| w.flush().context("flushing a command frame"));
        if sent.is_err() {
            shared.alive.store(false, Ordering::SeqCst);
            synthesize_disconnect(idx, &shared, &done_tx, "while sending");
            return;
        }
    }
}

/// Reader thread: decode replies off the connection and forward them on
/// the shared completion channel.  A reply only forwards if it can claim
/// the in-flight slot (see [`synthesize_disconnect`] for the race it
/// guards).  EOF, a cut connection, or a protocol error all end the loop
/// and synthesize the disconnect reply for any still-open window.
fn reader_main(idx: usize, stream: TcpStream, shared: Arc<Shared>,
               done_tx: Sender<WindowDone>) {
    let mut r = BufReader::new(stream);
    loop {
        let payload = match wire::read_frame(&mut r, MAX_FRAME) {
            Ok(Some(p)) => p,
            Ok(None) | Err(_) => break,
        };
        match wire::decode_done(&payload, idx) {
            Ok(done) => {
                let claimed =
                    shared.in_flight.lock().unwrap().take().is_some();
                // an unclaimed reply lost the race against a synthesized
                // disconnect error: the coordinator already rolled the
                // window back, so applying it too would double-count
                if claimed && done_tx.send(done).is_err() {
                    return; // pool dropped
                }
            }
            Err(_) => break, // protocol violation: treat as a disconnect
        }
    }
    shared.alive.store(false, Ordering::SeqCst);
    synthesize_disconnect(idx, &shared, &done_tx, "before replying");
}

// ---------------------------------------------------------------------------
// pod side
// ---------------------------------------------------------------------------

/// The backend-pod half: run `engine` as a remote worker over `stream`.
/// Performs the hello handshake (announcing the engine's capabilities),
/// then applies command frames in order — the same
/// [`run_cmd_window`] body the in-process pool threads execute — replying
/// with exactly one `WindowDone` frame per window.  Returns `Ok(())` when
/// the coordinator closes the connection cleanly; errs on a version
/// mismatch, a cut connection, or a malformed frame.
///
/// This is what `elis worker --connect <addr> --engine sim` runs.
pub fn run_worker(stream: TcpStream, mut engine: Box<dyn Engine>)
                  -> Result<()> {
    stream.set_nodelay(true).ok();
    let hello = Hello {
        version: WIRE_VERSION,
        max_batch: engine.max_batch(),
        describe: engine.describe(),
        trace: true,
    };
    let mut hs = stream.try_clone().context("cloning for handshake")?;
    hs.set_read_timeout(Some(HANDSHAKE_TIMEOUT)).ok();
    let ack = wire::client_handshake(&mut hs, &hello)?;
    hs.set_read_timeout(None).ok();
    let worker = ack.worker;

    let mut reader =
        BufReader::new(stream.try_clone().context("cloning the reader")?);
    let mut writer = BufWriter::new(stream);
    loop {
        let payload = match wire::read_frame(&mut reader, MAX_FRAME)? {
            Some(p) => p,
            None => return Ok(()), // orderly coordinator shutdown
        };
        match wire::decode_cmd(&payload)? {
            WorkerCmd::SetPreemptionCap(cap) => engine.set_preemption_cap(cap),
            WorkerCmd::Remove(id) => engine.remove(id),
            WorkerCmd::RunWindow {
                admits, priority_order, batch, echo, trace,
            } => {
                let t0 = Instant::now();
                let (fresh, outcome) = run_cmd_window(
                    engine.as_mut(), admits, &priority_order, &batch);
                let trace = trace.map(|window| PodExec {
                    window,
                    exec_ms: t0.elapsed().as_secs_f64() * 1e3,
                    pid: std::process::id(),
                });
                let reply = wire::encode_done(&echo, &fresh, &outcome, &trace)
                    .to_string();
                wire::write_frame(&mut writer, reply.as_bytes())
                    .with_context(|| format!(
                        "worker {worker}: sending a window reply"))?;
                writer.flush().context("flushing a window reply")?;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::profiles::ModelProfile;
    use crate::engine::sim_engine::SimEngine;
    use crate::engine::SeqSpec;
    use crate::runtime::manifest::ServedModelMeta;

    fn sim_engine() -> Box<dyn Engine> {
        let profile = ModelProfile::from_meta(&ServedModelMeta {
            name: "test".into(),
            abbrev: "test".into(),
            params_b: 7.0,
            avg_latency_ms: 2000.0,
            kv_bytes_per_token: 1 << 20,
            preempt_batch: 0,
            mem_limit_frac: 0.9,
        });
        Box::new(SimEngine::new(profile, 50, 4, 8 << 30))
    }

    fn spec(id: u64, total: usize) -> SeqSpec {
        SeqSpec { id, prompt: vec![3; 8], target_total: total, topic: 0,
                  resume: Vec::new() }
    }

    #[test]
    fn remote_pool_runs_windows_over_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let pods: Vec<_> = (0..2)
            .map(|_| {
                std::thread::spawn(move || {
                    let stream = TcpStream::connect(addr).unwrap();
                    run_worker(stream, sim_engine()).unwrap();
                })
            })
            .collect();
        let pool = RemoteWorkerPool::accept(&listener, 2,
                                            Duration::from_secs(10))
            .unwrap();
        assert_eq!(WorkerTransport::workers(&pool), 2);
        assert_eq!(WorkerTransport::max_batch(&pool, 0), 4);
        assert!(WorkerTransport::describe(&pool, 1).contains("SimEngine"),
                "{}", WorkerTransport::describe(&pool, 1));
        assert!(pool.worker_alive(0) && pool.worker_alive(1));
        assert!(pool.trace_capable(0) && pool.trace_capable(1),
                "run_worker pods always announce trace support");

        for w in 0..2u64 {
            pool.send(w as usize, WorkerCmd::RunWindow {
                admits: vec![spec(w, 30)],
                priority_order: vec![w],
                batch: vec![w],
                echo: vec![JobId::from_raw(w)],
                trace: Some(w),
            }).unwrap();
        }
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..2 {
            let done = pool
                .recv_done_timeout(Duration::from_secs(10))
                .expect("window must complete over the wire");
            let outcome = done.outcome.expect("window must succeed");
            assert_eq!(done.batch.len(), 1);
            assert_eq!(done.batch[0].raw(), done.worker as u64);
            assert_eq!(outcome.outputs.len(), 1);
            assert!(!outcome.outputs[0].new_tokens.is_empty());
            let pod = done.trace
                .expect("trace-capable pods must echo a PodExec");
            assert_eq!(pod.window, done.worker as u64);
            assert_eq!(pod.pid, std::process::id(),
                       "loopback pods share our pid");
            assert!(pod.exec_ms >= 0.0);
            seen.insert(done.worker);
        }
        assert_eq!(seen.len(), 2, "both pods must have answered");
        assert!(pool.try_recv_done().is_none(),
                "exactly one reply per window");

        drop(pool); // closes the connections -> pods exit cleanly
        for pod in pods {
            pod.join().expect("pod thread must exit without error");
        }
    }

    #[test]
    fn mid_window_disconnect_synthesizes_an_error_reply() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        // a pod that registers, then drops the connection on its first
        // RunWindow without ever replying
        let pod = std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            let hello = Hello { version: WIRE_VERSION, max_batch: 1,
                                describe: "Vanishing".into(), trace: false };
            wire::client_handshake(&mut stream, &hello).unwrap();
            let mut r = BufReader::new(stream.try_clone().unwrap());
            loop {
                let payload =
                    wire::read_frame(&mut r, MAX_FRAME).unwrap().unwrap();
                if let WorkerCmd::RunWindow { .. } =
                    wire::decode_cmd(&payload).unwrap()
                {
                    stream.shutdown(Shutdown::Both).unwrap();
                    return;
                }
            }
        });
        let pool = RemoteWorkerPool::accept(&listener, 1,
                                            Duration::from_secs(10))
            .unwrap();
        pool.send(0, WorkerCmd::SetPreemptionCap(2)).unwrap();
        assert!(!pool.trace_capable(0),
                "a hello without the trace capability must read as such");
        pool.send(0, WorkerCmd::RunWindow {
            admits: vec![spec(9, 30)],
            priority_order: vec![9],
            batch: vec![9],
            echo: vec![JobId::from_raw(9)],
            trace: None,
        }).unwrap();
        let done = pool
            .recv_done_timeout(Duration::from_secs(10))
            .expect("the disconnect must synthesize a reply");
        assert_eq!(done.worker, 0);
        assert_eq!(done.batch, vec![JobId::from_raw(9)]);
        assert_eq!(done.fresh, vec![9], "rollback needs the admit list");
        assert!(done.trace.is_none(),
                "synthesized replies carry no pod-side timing");
        let err = done.outcome.expect_err("must be an error reply");
        assert!(err.to_string().contains("connection lost"), "{err:#}");
        // eventually observed dead; exactly one reply total
        let deadline = Instant::now() + Duration::from_secs(5);
        while pool.worker_alive(0) {
            assert!(Instant::now() < deadline, "worker must read as dead");
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(pool.try_recv_done().is_none());
        assert!(pool.send(0, WorkerCmd::Remove(9)).is_err(),
                "sends to a dead worker must err");
        pod.join().unwrap();
    }

    #[test]
    fn version_mismatch_is_refused_at_registration() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let pod = std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            let hello = Hello { version: WIRE_VERSION + 7, max_batch: 1,
                                describe: "OldPod".into(), trace: false };
            // the coordinator acks with its own version, then hangs up;
            // client_handshake reports the mismatch
            wire::client_handshake(&mut stream, &hello)
                .expect_err("mismatch must fail the worker side too")
        });
        let err = RemoteWorkerPool::accept(&listener, 1,
                                           Duration::from_millis(600))
            .expect_err("a lone bad registration cannot fill the pool");
        assert!(err.to_string().contains("0/1"), "{err:#}");
        let worker_err = pod.join().unwrap();
        assert!(worker_err.to_string().contains("version"), "{worker_err:#}");
    }
}
