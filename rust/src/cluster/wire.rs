//! Wire protocol for distributed worker pods — the `WorkerCmd` /
//! `WindowDone` channel of [`pool`](super::pool), put on a `TcpStream`.
//!
//! The paper deploys the backend engines as a StatefulSet of inference
//! pods behind the frontend scheduler (§5); this module is the protocol
//! between them, dependency-free and `std`-only:
//!
//! * **Framing** — every message is a 4-byte big-endian length prefix
//!   followed by that many bytes of UTF-8 JSON ([`write_frame`] /
//!   [`read_frame`]).  Frames above [`MAX_FRAME`] are rejected *before*
//!   any allocation, truncated frames surface as errors (never panics),
//!   and a clean EOF at a frame boundary reads as `None` so worker loops
//!   can tell an orderly coordinator shutdown from a mid-frame cut.
//! * **Handshake** — the worker opens with a [`Hello`] carrying the
//!   protocol [`WIRE_VERSION`] and its engine capabilities (`max_batch`,
//!   `describe`); the coordinator answers with a [`HelloAck`] assigning
//!   the worker index.  Version mismatches fail the registration on both
//!   sides ([`client_handshake`] / [`server_handshake`]).
//! * **Codec** — [`encode_cmd`]/[`decode_cmd`] for coordinator→worker
//!   commands ([`WorkerCmd::RunWindow`] bundles with admits, victim
//!   order, batch, and echo ids) and [`encode_done`]/[`decode_done`] for
//!   worker→coordinator replies, including error spills: an errored
//!   window travels as `{"err": "..."}` next to the `fresh` admit list so
//!   the coordinator can roll back partial admits exactly as it does for
//!   the in-process pool.
//!
//! Serialization is canonical (object keys are sorted by the JSON
//! writer), so encode→decode→encode is byte-identical — property-tested
//! below.  Ids ride as JSON numbers; the slab-allocated `JobId`/engine
//! ids stay far below the 2^53 integer-exactness bound of `f64`.

use std::io::{Read, Write};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::job::JobId;
use crate::coordinator::PodExec;
use crate::engine::{SeqSpec, SeqWindowOut, WindowOutcome};
use crate::util::json::Json;

use super::pool::{WindowDone, WorkerCmd};

/// Protocol version carried in the hello; bumped on any frame or codec
/// change so mixed deployments fail registration loudly instead of
/// mis-parsing windows.
pub const WIRE_VERSION: u32 = 1;

/// Hard cap on one frame's payload (64 MiB — a full `RunWindow` bundle
/// with book-length prompts stays well under this).
pub const MAX_FRAME: usize = 64 << 20;

// ---------------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------------

/// Write one length-prefixed frame.  The caller flushes (frames are
/// usually written through a `BufWriter`).
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        bail!("frame of {} bytes exceeds MAX_FRAME {}", payload.len(),
              MAX_FRAME);
    }
    w.write_all(&(payload.len() as u32).to_be_bytes())
        .context("writing frame length")?;
    w.write_all(payload).context("writing frame payload")?;
    Ok(())
}

/// Read one length-prefixed frame.  Returns `Ok(None)` on a clean EOF at
/// a frame boundary (the peer closed in an orderly way); errs on a
/// truncated prefix/payload or a length above `max_frame` — the length is
/// validated *before* the payload buffer is allocated, so an adversarial
/// prefix cannot balloon memory.
pub fn read_frame(r: &mut impl Read, max_frame: usize)
                  -> Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    // first byte by hand: a clean EOF here is a normal shutdown, an EOF
    // anywhere later is a cut connection
    let mut first = [0u8; 1];
    loop {
        match r.read(&mut first) {
            Ok(0) => return Ok(None),
            Ok(_) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e).context("reading frame length"),
        }
    }
    len_buf[0] = first[0];
    r.read_exact(&mut len_buf[1..]).context("reading frame length")?;
    let len = u32::from_be_bytes(len_buf) as usize;
    if len > max_frame {
        bail!("frame of {len} bytes exceeds the {max_frame} byte cap");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("reading frame payload")?;
    Ok(Some(payload))
}

// ---------------------------------------------------------------------------
// decode helpers (strict: malformed frames become errors, never panics)
// ---------------------------------------------------------------------------

fn field<'a>(j: &'a Json, key: &str) -> Result<&'a Json> {
    j.get(key).ok_or_else(|| anyhow!("missing field '{key}'"))
}

fn as_u64(j: &Json) -> Result<u64> {
    match j.as_f64() {
        Some(f) if f >= 0.0 && f.fract() == 0.0 && f < 9.0e15 => Ok(f as u64),
        _ => bail!("expected a non-negative integer, got {j}"),
    }
}

fn u64_field(j: &Json, key: &str) -> Result<u64> {
    as_u64(field(j, key)?)
}

fn u64_vec(j: &Json, key: &str) -> Result<Vec<u64>> {
    field(j, key)?
        .as_arr()
        .ok_or_else(|| anyhow!("field '{key}' must be an array"))?
        .iter()
        .map(as_u64)
        .collect()
}

fn i32_vec(j: &Json, key: &str) -> Result<Vec<i32>> {
    field(j, key)?
        .as_i32_vec()
        .ok_or_else(|| anyhow!("field '{key}' must be an array of numbers"))
}

fn str_field<'a>(j: &'a Json, key: &str) -> Result<&'a str> {
    field(j, key)?
        .as_str()
        .ok_or_else(|| anyhow!("field '{key}' must be a string"))
}

fn msg_type(j: &Json) -> Result<&str> {
    str_field(j, "type")
}

fn num(n: usize) -> Json {
    Json::Num(n as f64)
}

fn num_u64(n: u64) -> Json {
    Json::Num(n as f64)
}

fn u64_arr(v: &[u64]) -> Json {
    Json::Arr(v.iter().map(|&x| num_u64(x)).collect())
}

fn i32_arr(v: &[i32]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
}

// ---------------------------------------------------------------------------
// handshake
// ---------------------------------------------------------------------------

/// First frame on a fresh connection, worker → coordinator: protocol
/// version plus the engine capabilities the coordinator's batcher needs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hello {
    pub version: u32,
    /// the engine's `max_batch()` — bounds the windows the coordinator
    /// will form for this pod
    pub max_batch: usize,
    /// the engine's `describe()` — logs and `/metrics` labels
    pub describe: String,
    /// capability flag: the pod understands the optional trace fields on
    /// `run_window` and echoes an execute-span measurement on
    /// `window_done`.  Encoded only when set and decoded with a `false`
    /// default, so it rides *inside* [`WIRE_VERSION`] 1 — an old pod
    /// (no flag) still handshakes and simply never sees trace fields,
    /// and an old coordinator ignores the unknown key.
    pub trace: bool,
}

/// Coordinator's reply to a [`Hello`]: the version it speaks and the
/// worker index it assigned this pod.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HelloAck {
    pub version: u32,
    pub worker: usize,
}

pub fn encode_hello(h: &Hello) -> Json {
    let mut pairs = vec![
        ("type", Json::Str("hello".into())),
        ("version", num(h.version as usize)),
        ("max_batch", num(h.max_batch)),
        ("describe", Json::Str(h.describe.clone())),
    ];
    // omitted when unset: a trace-less hello is byte-identical to what a
    // pre-trace pod sends, which is exactly the compatibility claim
    if h.trace {
        pairs.push(("trace", Json::Bool(true)));
    }
    Json::obj(pairs)
}

pub fn decode_hello(payload: &[u8]) -> Result<Hello> {
    let j = parse_payload(payload)?;
    if msg_type(&j)? != "hello" {
        bail!("expected a hello frame, got '{}'", msg_type(&j)?);
    }
    Ok(Hello {
        version: u64_field(&j, "version")? as u32,
        max_batch: u64_field(&j, "max_batch")? as usize,
        describe: str_field(&j, "describe")?.to_string(),
        trace: j.get("trace").and_then(|t| t.as_bool()).unwrap_or(false),
    })
}

pub fn encode_hello_ack(a: &HelloAck) -> Json {
    Json::obj(vec![
        ("type", Json::Str("hello_ack".into())),
        ("version", num(a.version as usize)),
        ("worker", num(a.worker)),
    ])
}

pub fn decode_hello_ack(payload: &[u8]) -> Result<HelloAck> {
    let j = parse_payload(payload)?;
    if msg_type(&j)? != "hello_ack" {
        bail!("expected a hello_ack frame, got '{}'", msg_type(&j)?);
    }
    Ok(HelloAck {
        version: u64_field(&j, "version")? as u32,
        worker: u64_field(&j, "worker")? as usize,
    })
}

/// Worker side of the handshake: send the hello, await the ack, verify
/// the version.  Run this immediately after `TcpStream::connect`.
pub fn client_handshake<S: Read + Write>(stream: &mut S, hello: &Hello)
                                         -> Result<HelloAck> {
    write_frame(stream, encode_hello(hello).to_string().as_bytes())?;
    stream.flush().context("flushing hello")?;
    let payload = read_frame(stream, MAX_FRAME)?
        .ok_or_else(|| anyhow!("coordinator closed during handshake"))?;
    let ack = decode_hello_ack(&payload)?;
    if ack.version != hello.version {
        bail!("protocol version mismatch: worker speaks {}, coordinator {}",
              hello.version, ack.version);
    }
    Ok(ack)
}

/// Coordinator side of the handshake: read the worker's hello, verify
/// the version, assign it `worker` and ack.  Returns the hello so the
/// pool can record the pod's capabilities.
pub fn server_handshake<S: Read + Write>(stream: &mut S, worker: usize)
                                         -> Result<Hello> {
    let payload = read_frame(stream, MAX_FRAME)?
        .ok_or_else(|| anyhow!("worker closed during handshake"))?;
    let hello = decode_hello(&payload)?;
    if hello.version != WIRE_VERSION {
        // answer with our version anyway so the worker reports the
        // mismatch symmetrically, then refuse the registration
        let ack = HelloAck { version: WIRE_VERSION, worker };
        let _ = write_frame(stream, encode_hello_ack(&ack).to_string()
                            .as_bytes());
        let _ = stream.flush();
        bail!("protocol version mismatch: worker speaks {}, this \
               coordinator {}", hello.version, WIRE_VERSION);
    }
    let ack = HelloAck { version: WIRE_VERSION, worker };
    write_frame(stream, encode_hello_ack(&ack).to_string().as_bytes())?;
    stream.flush().context("flushing hello ack")?;
    Ok(hello)
}

// ---------------------------------------------------------------------------
// commands (coordinator -> worker)
// ---------------------------------------------------------------------------

fn encode_seq_spec(s: &SeqSpec) -> Json {
    Json::obj(vec![
        ("id", num_u64(s.id)),
        ("prompt", i32_arr(&s.prompt)),
        ("target_total", num(s.target_total)),
        ("topic", num(s.topic)),
        ("resume", i32_arr(&s.resume)),
    ])
}

fn decode_seq_spec(j: &Json) -> Result<SeqSpec> {
    Ok(SeqSpec {
        id: u64_field(j, "id")?,
        prompt: i32_vec(j, "prompt")?,
        target_total: u64_field(j, "target_total")? as usize,
        topic: u64_field(j, "topic")? as usize,
        resume: i32_vec(j, "resume")?,
    })
}

pub fn encode_cmd(cmd: &WorkerCmd) -> Json {
    match cmd {
        WorkerCmd::SetPreemptionCap(cap) => Json::obj(vec![
            ("type", Json::Str("set_preemption_cap".into())),
            ("cap", num(*cap)),
        ]),
        WorkerCmd::Remove(id) => Json::obj(vec![
            ("type", Json::Str("remove".into())),
            ("id", num_u64(*id)),
        ]),
        WorkerCmd::RunWindow { admits, priority_order, batch, echo,
                               trace } => {
            let mut pairs = vec![
                ("type", Json::Str("run_window".into())),
                ("admits",
                 Json::Arr(admits.iter().map(encode_seq_spec).collect())),
                ("priority_order", u64_arr(priority_order)),
                ("batch", u64_arr(batch)),
                ("echo",
                 Json::Arr(echo.iter()
                           .map(|id| num_u64(id.raw()))
                           .collect())),
            ];
            // omitted when absent, so untraced commands stay byte-
            // identical to the pre-trace wire format
            if let Some(t) = trace {
                pairs.push(("trace", num_u64(*t)));
            }
            Json::obj(pairs)
        }
    }
}

pub fn decode_cmd(payload: &[u8]) -> Result<WorkerCmd> {
    let j = parse_payload(payload)?;
    match msg_type(&j)? {
        "set_preemption_cap" => {
            Ok(WorkerCmd::SetPreemptionCap(u64_field(&j, "cap")? as usize))
        }
        "remove" => Ok(WorkerCmd::Remove(u64_field(&j, "id")?)),
        "run_window" => {
            let admits = field(&j, "admits")?
                .as_arr()
                .ok_or_else(|| anyhow!("'admits' must be an array"))?
                .iter()
                .map(decode_seq_spec)
                .collect::<Result<Vec<_>>>()?;
            Ok(WorkerCmd::RunWindow {
                admits,
                priority_order: u64_vec(&j, "priority_order")?,
                batch: u64_vec(&j, "batch")?,
                echo: u64_vec(&j, "echo")?
                    .into_iter()
                    .map(JobId::from_raw)
                    .collect(),
                trace: j.get("trace").map(as_u64).transpose()?,
            })
        }
        other => bail!("unknown command type '{other}'"),
    }
}

// ---------------------------------------------------------------------------
// replies (worker -> coordinator)
// ---------------------------------------------------------------------------

fn encode_outcome(o: &WindowOutcome) -> Json {
    Json::obj(vec![
        ("outputs", Json::Arr(o.outputs.iter().map(|out| Json::obj(vec![
            ("id", num_u64(out.id)),
            ("new_tokens", i32_arr(&out.new_tokens)),
            ("done", Json::Bool(out.done)),
        ])).collect())),
        ("service_ms", Json::Num(o.service_ms)),
        ("preempted", u64_arr(&o.preempted)),
    ])
}

fn decode_outcome(j: &Json) -> Result<WindowOutcome> {
    let outputs = field(j, "outputs")?
        .as_arr()
        .ok_or_else(|| anyhow!("'outputs' must be an array"))?
        .iter()
        .map(|out| {
            Ok(SeqWindowOut {
                id: u64_field(out, "id")?,
                new_tokens: i32_vec(out, "new_tokens")?,
                done: field(out, "done")?
                    .as_bool()
                    .ok_or_else(|| anyhow!("'done' must be a bool"))?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let service_ms = field(j, "service_ms")?
        .as_f64()
        .ok_or_else(|| anyhow!("'service_ms' must be a number"))?;
    Ok(WindowOutcome { outputs, service_ms, preempted: u64_vec(j, "preempted")? })
}

/// Encode one window reply.  An `Err` outcome travels as its rendered
/// message — the coordinator needs the text for its error, and the
/// `fresh` list next to it is what drives partial-admit rollback.
/// `trace` is the pod's execute-span measurement, present only when the
/// command asked for it (omitted-when-`None` keeps untraced replies
/// byte-identical to the pre-trace format).
pub fn encode_done(batch: &[JobId], fresh: &[u64],
                   outcome: &Result<WindowOutcome>,
                   trace: &Option<PodExec>) -> Json {
    let mut pairs = vec![
        ("type", Json::Str("window_done".into())),
        ("batch",
         Json::Arr(batch.iter().map(|id| num_u64(id.raw())).collect())),
        ("fresh", u64_arr(fresh)),
    ];
    match outcome {
        Ok(o) => pairs.push(("ok", encode_outcome(o))),
        Err(e) => pairs.push(("err", Json::Str(format!("{e:#}")))),
    }
    if let Some(t) = trace {
        pairs.push(("trace", Json::obj(vec![
            ("window", num_u64(t.window)),
            ("exec_ms", Json::Num(t.exec_ms)),
            ("pid", num_u64(t.pid as u64)),
        ])));
    }
    Json::obj(pairs)
}

/// Decode one window reply into the pool's [`WindowDone`] shape.
/// `worker` is the receiving connection's index — it never travels on
/// the wire (the socket identifies the pod).
pub fn decode_done(payload: &[u8], worker: usize) -> Result<WindowDone> {
    let j = parse_payload(payload)?;
    if msg_type(&j)? != "window_done" {
        bail!("expected a window_done frame, got '{}'", msg_type(&j)?);
    }
    let batch = u64_vec(&j, "batch")?
        .into_iter()
        .map(JobId::from_raw)
        .collect();
    let fresh = u64_vec(&j, "fresh")?;
    let outcome = match (j.get("ok"), j.get("err")) {
        (Some(ok), None) => Ok(decode_outcome(ok)?),
        (None, Some(err)) => Err(anyhow!(
            "{}",
            err.as_str().ok_or_else(|| anyhow!("'err' must be a string"))?
        )),
        _ => bail!("window_done needs exactly one of 'ok' / 'err'"),
    };
    let trace = match j.get("trace") {
        None => None,
        Some(t) => Some(PodExec {
            window: u64_field(t, "window")?,
            exec_ms: field(t, "exec_ms")?
                .as_f64()
                .ok_or_else(|| anyhow!("'exec_ms' must be a number"))?,
            pid: u64_field(t, "pid")? as u32,
        }),
    };
    Ok(WindowDone { worker, batch, fresh, outcome, trace })
}

fn parse_payload(payload: &[u8]) -> Result<Json> {
    let text = std::str::from_utf8(payload).context("frame is not UTF-8")?;
    Json::parse(text).map_err(|e| anyhow!("frame is not valid JSON: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    // ---- property tests: random values roundtrip byte-identically -------

    fn gen_u64(g: &mut prop::Gen) -> u64 {
        // keep ids inside f64's exact-integer range (slab ids are tiny in
        // practice; the codec documents the 2^53 bound)
        g.rng.next_u64() >> 12
    }

    fn gen_i32_vec(g: &mut prop::Gen, max_len: usize) -> Vec<i32> {
        let n = g.usize_in(0, max_len);
        (0..n).map(|_| g.rng.int_range(-40000, 40000) as i32).collect()
    }

    fn gen_spec(g: &mut prop::Gen) -> SeqSpec {
        SeqSpec {
            id: gen_u64(g),
            prompt: gen_i32_vec(g, 20),
            target_total: g.usize_in(0, 5000),
            topic: g.usize_in(0, 64),
            resume: gen_i32_vec(g, 20),
        }
    }

    fn gen_cmd(g: &mut prop::Gen) -> WorkerCmd {
        match g.usize_in(0, 2) {
            0 => WorkerCmd::SetPreemptionCap(g.usize_in(0, 1000)),
            1 => WorkerCmd::Remove(gen_u64(g)),
            _ => {
                let admits =
                    (0..g.usize_in(0, 5)).map(|_| gen_spec(g)).collect();
                WorkerCmd::RunWindow {
                    admits,
                    priority_order: (0..g.usize_in(0, 8))
                        .map(|_| gen_u64(g))
                        .collect(),
                    batch: (0..g.usize_in(0, 8))
                        .map(|_| gen_u64(g))
                        .collect(),
                    echo: (0..g.usize_in(0, 8))
                        .map(|_| JobId::from_raw(gen_u64(g)))
                        .collect(),
                    trace: if g.bool(0.5) { Some(gen_u64(g)) } else { None },
                }
            }
        }
    }

    // unicode-heavy strings for error spills / describe lines (tenant
    // names and engine descriptions are user-controlled text)
    fn gen_text(g: &mut prop::Gen) -> String {
        let pieces = ["tenant-α", "模型", "naïve", "🚀", "a\"b\\c",
                      "line\nbreak", "tab\tsep", "plain"];
        let n = g.usize_in(1, 4);
        (0..n).map(|_| *g.pick(&pieces)).collect::<Vec<_>>().join("/")
    }

    #[test]
    fn prop_cmd_roundtrips_byte_identically() {
        prop::check("wire-cmd-roundtrip", 200, |g| {
            let cmd = gen_cmd(g);
            let b1 = encode_cmd(&cmd).to_string();
            let decoded = decode_cmd(b1.as_bytes()).expect("decode");
            let b2 = encode_cmd(&decoded).to_string();
            assert_eq!(b1, b2, "cmd roundtrip changed bytes");
        });
    }

    #[test]
    fn prop_done_roundtrips_byte_identically() {
        prop::check("wire-done-roundtrip", 200, |g| {
            let batch: Vec<JobId> = (0..g.usize_in(0, 6))
                .map(|_| JobId::from_raw(gen_u64(g)))
                .collect();
            let fresh: Vec<u64> =
                (0..g.usize_in(0, 6)).map(|_| gen_u64(g)).collect();
            // half the cases are error spills (possibly unicode), half
            // real outcomes (possibly empty batches/outputs)
            let outcome: Result<WindowOutcome> = if g.bool(0.5) {
                Err(anyhow!("{}", gen_text(g)))
            } else {
                Ok(WindowOutcome {
                    outputs: (0..g.usize_in(0, 5))
                        .map(|_| SeqWindowOut {
                            id: gen_u64(g),
                            new_tokens: gen_i32_vec(g, 10),
                            done: g.bool(0.5),
                        })
                        .collect(),
                    service_ms: g.f64_in(0.0, 1e6),
                    preempted: (0..g.usize_in(0, 4))
                        .map(|_| gen_u64(g))
                        .collect(),
                })
            };
            let trace = if g.bool(0.5) {
                Some(PodExec {
                    window: gen_u64(g),
                    exec_ms: g.f64_in(0.0, 1e5),
                    pid: g.usize_in(0, 1 << 22) as u32,
                })
            } else {
                None
            };
            let b1 = encode_done(&batch, &fresh, &outcome, &trace).to_string();
            let decoded = decode_done(b1.as_bytes(), 3).expect("decode");
            assert_eq!(decoded.worker, 3);
            assert_eq!(decoded.trace, trace);
            let b2 = encode_done(&decoded.batch, &decoded.fresh,
                                 &decoded.outcome, &decoded.trace)
                .to_string();
            assert_eq!(b1, b2, "done roundtrip changed bytes");
        });
    }

    #[test]
    fn prop_hello_roundtrips_with_unicode_describe() {
        prop::check("wire-hello-roundtrip", 100, |g| {
            let hello = Hello {
                version: g.usize_in(0, 1000) as u32,
                max_batch: g.usize_in(1, 256),
                describe: gen_text(g),
                trace: g.bool(0.5),
            };
            let b1 = encode_hello(&hello).to_string();
            let decoded = decode_hello(b1.as_bytes()).expect("decode");
            assert_eq!(decoded, hello);
            assert_eq!(encode_hello(&decoded).to_string(), b1);
        });
    }

    #[test]
    fn pre_trace_frames_still_decode() {
        // frames exactly as a pre-trace peer writes them — no `trace`
        // keys anywhere — must decode with the trace fields defaulted off
        let hello = decode_hello(
            br#"{"describe":"SimEngine","max_batch":4,"type":"hello","version":1}"#,
        ).unwrap();
        assert!(!hello.trace, "missing capability flag means no tracing");
        let cmd = decode_cmd(
            br#"{"admits":[],"batch":[1],"echo":[1],"priority_order":[],"type":"run_window"}"#,
        ).unwrap();
        match cmd {
            WorkerCmd::RunWindow { trace, .. } => assert!(trace.is_none()),
            _ => panic!("expected RunWindow"),
        }
        let done = decode_done(
            br#"{"batch":[1],"fresh":[],"ok":{"outputs":[],"preempted":[],"service_ms":1.5},"type":"window_done"}"#,
            0,
        ).unwrap();
        assert!(done.trace.is_none());
    }

    // ---- framing: truncated / oversized / garbage are errors, not panics

    #[test]
    fn frame_roundtrip_and_clean_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r, MAX_FRAME).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut r, MAX_FRAME).unwrap().unwrap(), b"");
        assert!(read_frame(&mut r, MAX_FRAME).unwrap().is_none(),
                "clean EOF at a frame boundary must read as None");
    }

    #[test]
    fn truncated_frames_error_without_panicking() {
        // truncated length prefix
        let mut r: &[u8] = &[0, 0, 1];
        assert!(read_frame(&mut r, MAX_FRAME).is_err());
        // truncated payload
        let mut buf = Vec::new();
        write_frame(&mut buf, b"truncate me").unwrap();
        buf.truncate(buf.len() - 4);
        let mut r = &buf[..];
        assert!(read_frame(&mut r, MAX_FRAME).is_err());
    }

    #[test]
    fn oversized_frames_are_rejected_before_allocation() {
        // a 3 GiB claimed length must be refused by the cap check, not
        // attempted
        let mut buf = Vec::new();
        buf.extend_from_slice(&(3u32 << 30).to_be_bytes());
        buf.extend_from_slice(b"tiny");
        let mut r = &buf[..];
        let err = read_frame(&mut r, MAX_FRAME).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err:#}");
        // writer side refuses equally
        let huge = vec![0u8; MAX_FRAME + 1];
        assert!(write_frame(&mut Vec::new(), &huge).is_err());
    }

    #[test]
    fn frame_cap_boundary_is_exact() {
        // exactly MAX_FRAME roundtrips...
        let payload = vec![0x5au8; MAX_FRAME];
        let mut buf = Vec::new();
        write_frame(&mut buf, &payload).unwrap();
        let mut r = &buf[..];
        let got = read_frame(&mut r, MAX_FRAME).unwrap().unwrap();
        assert_eq!(got.len(), MAX_FRAME);
        assert!(got == payload, "64 MiB payload must roundtrip unchanged");

        // ...MAX_FRAME + 1 is refused by the writer...
        let over = vec![0u8; MAX_FRAME + 1];
        assert!(write_frame(&mut Vec::new(), &over).is_err());

        // ...and by the reader *before* allocation: hand it only the
        // 4-byte prefix claiming MAX_FRAME + 1 bytes — if the cap check
        // ran after allocation, read_exact would error on the missing
        // payload instead of the cap message
        let prefix = (MAX_FRAME as u32 + 1).to_be_bytes();
        let mut r: &[u8] = &prefix;
        let err = read_frame(&mut r, MAX_FRAME).unwrap_err();
        assert!(err.to_string().contains("cap"),
                "expected the cap error, got: {err:#}");
    }

    #[test]
    fn garbage_payloads_error_without_panicking() {
        for bad in [&b"not json"[..], b"{\"type\":42}",
                    b"{\"type\":\"nope\"}", b"{}", b"\xff\xfe",
                    b"{\"type\":\"window_done\"}",
                    b"{\"type\":\"window_done\",\"batch\":[],\"fresh\":[]}",
                    b"{\"type\":\"run_window\",\"admits\":3}"] {
            assert!(decode_cmd(bad).is_err(), "cmd {bad:?}");
            assert!(decode_done(bad, 0).is_err(), "done {bad:?}");
            assert!(decode_hello(bad).is_err(), "hello {bad:?}");
        }
        // ids outside f64's exact-integer range are refused, not rounded
        let big = format!("{{\"type\":\"remove\",\"id\":{}}}", 1u64 << 60);
        assert!(decode_cmd(big.as_bytes()).is_err());
    }

    #[test]
    fn handshake_agrees_over_an_in_memory_duplex() {
        // two half-pipes emulate the socket
        use std::collections::VecDeque;
        use std::sync::{Arc, Mutex};

        #[derive(Clone, Default)]
        struct Pipe(Arc<Mutex<VecDeque<u8>>>);
        impl Read for Pipe {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                let mut q = self.0.lock().unwrap();
                let n = buf.len().min(q.len());
                for b in buf.iter_mut().take(n) {
                    *b = q.pop_front().unwrap();
                }
                Ok(n)
            }
        }
        impl Write for Pipe {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend(buf);
                Ok(buf.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        struct Duplex {
            rx: Pipe,
            tx: Pipe,
        }
        impl Read for Duplex {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                self.rx.read(buf)
            }
        }
        impl Write for Duplex {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                self.tx.write(buf)
            }
            fn flush(&mut self) -> std::io::Result<()> {
                self.tx.flush()
            }
        }

        let (a, b) = (Pipe::default(), Pipe::default());
        let mut worker = Duplex { rx: a.clone(), tx: b.clone() };
        let mut coord = Duplex { rx: b, tx: a };

        let hello = Hello { version: WIRE_VERSION, max_batch: 8,
                            describe: "SimEngine[test]".into(), trace: true };
        // worker writes hello first; the in-memory pipes let us run the
        // two halves sequentially
        write_frame(&mut worker, encode_hello(&hello).to_string().as_bytes())
            .unwrap();
        let got = server_handshake(&mut coord, 5).unwrap();
        assert_eq!(got, hello);
        let ack_frame = read_frame(&mut worker, MAX_FRAME).unwrap().unwrap();
        let ack = decode_hello_ack(&ack_frame).unwrap();
        assert_eq!(ack, HelloAck { version: WIRE_VERSION, worker: 5 });

        // version mismatch is refused server-side
        let old = Hello { version: WIRE_VERSION + 1, ..hello };
        write_frame(&mut worker, encode_hello(&old).to_string().as_bytes())
            .unwrap();
        assert!(server_handshake(&mut coord, 6).is_err());
    }
}
