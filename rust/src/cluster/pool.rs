//! Threaded worker pool: one OS thread per backend engine.
//!
//! The paper's deployment (§5) runs each backend worker as its own pod; in
//! wall-clock mode this pool is the in-process equivalent — each engine is
//! moved onto a dedicated thread at spawn ([`Engine`] is `Send`; usage is
//! strictly thread-confined afterwards) and the coordinator talks to it
//! over `std::sync::mpsc` channels:
//!
//! * commands flow down a per-worker channel ([`WorkerCmd`]), so each
//!   worker sees its admissions, priority order, and windows in exact
//!   dispatch order;
//! * results flow back up one shared completion channel ([`WindowDone`]),
//!   which [`Coordinator::poll_completions`] drains without blocking —
//!   this is what lets a multi-worker wall-clock run genuinely overlap
//!   scheduling windows across threads instead of executing them inline
//!   and sequentially.
//!
//! Exactly one [`WindowDone`] answers every
//! [`WorkerCmd::RunWindow`]; the coordinator tracks in-flight windows per
//! worker off that invariant.  Dropping the pool closes the command
//! channels, which ends each worker loop, and joins every thread.
//!
//! [`Coordinator::poll_completions`]: crate::coordinator::Coordinator::poll_completions

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::coordinator::job::JobId;
use crate::coordinator::PodExec;
use crate::engine::{Engine, SeqSpec, WindowOutcome};

/// A command for one worker thread, sent in dispatch order.
pub enum WorkerCmd {
    /// Register fresh sequences, install the preemption-victim order, and
    /// execute one scheduling window.  Always answered by exactly one
    /// [`WindowDone`] on the shared completion channel.
    RunWindow {
        /// sequences not yet admitted to this engine (first window)
        admits: Vec<SeqSpec>,
        /// engine preemption-victim order, highest priority first
        priority_order: Vec<u64>,
        /// engine-layer sequence ids of the batch
        batch: Vec<u64>,
        /// coordinator-side ids echoed back with the outcome
        echo: Vec<JobId>,
        /// window span id for request-scoped tracing; when present the
        /// worker measures its own execute wall time and echoes it (plus
        /// its pid) back via [`WindowDone::trace`].  `None` when the
        /// worker didn't negotiate tracing (old pods keep working).
        trace: Option<u64>,
    },
    /// `PreemptionPolicy::max_per_iteration` (paper §3.4).
    SetPreemptionCap(usize),
    /// Drop a finished sequence's engine state.
    Remove(u64),
}

/// Result of one [`WorkerCmd::RunWindow`], delivered on the pool's shared
/// completion channel.
pub struct WindowDone {
    pub worker: usize,
    /// the `echo` ids from the command, in batch order
    pub batch: Vec<JobId>,
    /// engine-layer ids this command *tried* to admit (its `admits`) —
    /// on error the coordinator wipes exactly these from the engine so a
    /// retry can re-admit them cleanly
    pub fresh: Vec<u64>,
    /// the window outcome, or the admit/window error that aborted it
    pub outcome: Result<WindowOutcome>,
    /// the worker's own execute-span measurement, echoed only when the
    /// command carried a trace id (see [`WorkerCmd::RunWindow`])
    pub trace: Option<PodExec>,
}

/// The coordinator's view of a set of workers — whatever carries the
/// [`WorkerCmd`] / [`WindowDone`] protocol.  Two transports exist:
/// [`WorkerPool`] (per-worker OS threads + mpsc, this module) and
/// [`RemoteWorkerPool`](super::remote::RemoteWorkerPool) (per-pod
/// `TcpStream`s, the paper's §5 StatefulSet topology).  The coordinator's
/// pooled backend is written against this trait, so the dispatch and
/// completion paths are byte-for-byte the same code whichever side of the
/// network boundary the engines live on.
pub trait WorkerTransport: Send {
    fn workers(&self) -> usize;

    /// The engine's `max_batch`, captured at spawn/registration.
    fn max_batch(&self, worker: usize) -> usize;

    /// The engine's `describe()`, captured at spawn/registration.
    fn describe(&self, worker: usize) -> &str;

    /// Send a command to one worker.  Errs if the worker is gone.
    fn send(&self, worker: usize, cmd: WorkerCmd) -> Result<()>;

    /// Non-blocking drain of the next completed window, if any.
    fn try_recv_done(&self) -> Option<WindowDone>;

    /// Blocking drain with a timeout.
    fn recv_done_timeout(&self, timeout: Duration) -> Option<WindowDone>;

    /// Whether the worker can still answer commands.
    fn worker_alive(&self, worker: usize) -> bool;

    /// Whether a lost worker is *guaranteed* to surface as a synthesized
    /// error [`WindowDone`] for its in-flight window.  A transport that
    /// answers `true` (the TCP pool: its connection reader synthesizes the
    /// reply on disconnect) lets the coordinator wait for that reply and
    /// roll back cleanly; one that answers `false` (this thread pool: a
    /// panicked worker thread just vanishes) makes the coordinator fail
    /// fast instead of idling forever.
    fn synthesizes_disconnects(&self) -> bool {
        false
    }

    /// Whether the worker understands trace fields on
    /// [`WorkerCmd::RunWindow`] and will echo a [`PodExec`] measurement.
    /// In-process workers always do; the TCP pool overrides this with the
    /// capability the pod declared in its `Hello` (old pods: `false`).
    fn trace_capable(&self, _worker: usize) -> bool {
        true
    }
}

struct WorkerHandle {
    /// `None` once shut down (closing the channel ends the worker loop)
    cmd_tx: Option<Sender<WorkerCmd>>,
    max_batch: usize,
    describe: String,
    join: Option<JoinHandle<()>>,
}

/// Owns the worker threads and both channel ends the coordinator uses.
pub struct WorkerPool {
    workers: Vec<WorkerHandle>,
    done_rx: Receiver<WindowDone>,
}

impl WorkerPool {
    /// Move each engine onto its own named OS thread
    /// (`elis-worker-<i>`).  `engines[i]` becomes worker `i`'s backend.
    pub fn new(engines: Vec<Box<dyn Engine>>) -> WorkerPool {
        let (done_tx, done_rx) = channel();
        let workers = engines
            .into_iter()
            .enumerate()
            .map(|(i, engine)| {
                let (cmd_tx, cmd_rx) = channel();
                let done_tx = done_tx.clone();
                let max_batch = engine.max_batch();
                let describe = engine.describe();
                let join = std::thread::Builder::new()
                    .name(format!("elis-worker-{i}"))
                    .spawn(move || worker_main(i, engine, cmd_rx, done_tx))
                    .expect("spawning worker thread");
                WorkerHandle {
                    cmd_tx: Some(cmd_tx),
                    max_batch,
                    describe,
                    join: Some(join),
                }
            })
            .collect();
        WorkerPool { workers, done_rx }
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// The engine's `max_batch`, captured before the engine moved to its
    /// thread.
    pub fn max_batch(&self, worker: usize) -> usize {
        self.workers[worker].max_batch
    }

    /// The engine's `describe()`, captured before the move.
    pub fn describe(&self, worker: usize) -> &str {
        &self.workers[worker].describe
    }

    /// Send a command to one worker.  Errs if the worker thread is gone
    /// (panicked or already shut down).
    pub fn send(&self, worker: usize, cmd: WorkerCmd) -> Result<()> {
        self.workers[worker]
            .cmd_tx
            .as_ref()
            .ok_or_else(|| anyhow!("worker {worker} already shut down"))?
            .send(cmd)
            .map_err(|_| anyhow!("worker thread {worker} is gone"))
    }

    /// Non-blocking drain of the next completed window, if any.
    pub fn try_recv_done(&self) -> Option<WindowDone> {
        self.done_rx.try_recv().ok()
    }

    /// Blocking drain with a timeout (handy for tests and drivers that
    /// have nothing else to do while windows run).
    pub fn recv_done_timeout(&self, timeout: Duration) -> Option<WindowDone> {
        self.done_rx.recv_timeout(timeout).ok()
    }

    /// Whether the worker's thread is still running.  A thread that died
    /// (engine panic) can never answer an in-flight window — the
    /// coordinator uses this to fail fast instead of idling forever.
    pub fn worker_alive(&self, worker: usize) -> bool {
        self.workers[worker]
            .join
            .as_ref()
            .map(|j| !j.is_finished())
            .unwrap_or(false)
    }
}

impl WorkerTransport for WorkerPool {
    fn workers(&self) -> usize {
        WorkerPool::workers(self)
    }

    fn max_batch(&self, worker: usize) -> usize {
        WorkerPool::max_batch(self, worker)
    }

    fn describe(&self, worker: usize) -> &str {
        WorkerPool::describe(self, worker)
    }

    fn send(&self, worker: usize, cmd: WorkerCmd) -> Result<()> {
        WorkerPool::send(self, worker, cmd)
    }

    fn try_recv_done(&self) -> Option<WindowDone> {
        WorkerPool::try_recv_done(self)
    }

    fn recv_done_timeout(&self, timeout: Duration) -> Option<WindowDone> {
        WorkerPool::recv_done_timeout(self, timeout)
    }

    fn worker_alive(&self, worker: usize) -> bool {
        WorkerPool::worker_alive(self, worker)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // close every command channel first so all workers wind down in
        // parallel, then join
        for w in &mut self.workers {
            w.cmd_tx = None;
        }
        for w in &mut self.workers {
            if let Some(join) = w.join.take() {
                let _ = join.join();
            }
        }
    }
}

/// The worker-side body of one [`WorkerCmd::RunWindow`]: admit the fresh
/// sequences, install the victim order, execute the window.  Returns the
/// fresh (attempted-admit) ids alongside the outcome so the reply always
/// carries what a coordinator needs for partial-admit rollback.  Shared
/// by the in-process pool threads, the TCP worker loop
/// ([`run_worker`](super::remote::run_worker)), and test harnesses that
/// emulate a pod by hand.
pub fn run_cmd_window(engine: &mut dyn Engine, admits: Vec<SeqSpec>,
                      priority_order: &[u64], batch: &[u64])
                      -> (Vec<u64>, Result<WindowOutcome>) {
    let fresh: Vec<u64> = admits.iter().map(|s| s.id).collect();
    let mut admit_err = None;
    for spec in admits {
        if let Err(e) = engine.admit(spec) {
            admit_err = Some(e);
            break;
        }
    }
    let outcome = match admit_err {
        Some(e) => Err(e),
        None => {
            engine.set_priority_order(priority_order);
            engine.run_window(batch)
        }
    };
    (fresh, outcome)
}

/// Worker thread body: apply commands in order until the channel closes.
fn worker_main(idx: usize, mut engine: Box<dyn Engine>,
               cmd_rx: Receiver<WorkerCmd>, done_tx: Sender<WindowDone>) {
    while let Ok(cmd) = cmd_rx.recv() {
        match cmd {
            WorkerCmd::SetPreemptionCap(cap) => engine.set_preemption_cap(cap),
            WorkerCmd::Remove(id) => engine.remove(id),
            WorkerCmd::RunWindow { admits, priority_order, batch, echo,
                                   trace } => {
                let t0 = std::time::Instant::now();
                let (fresh, outcome) = run_cmd_window(engine.as_mut(), admits,
                                                      &priority_order, &batch);
                // echo the execute span only when asked; same-process
                // workers report the shared pid, which is still the
                // honest answer to "which process ran this window"
                let trace = trace.map(|window| PodExec {
                    window,
                    exec_ms: t0.elapsed().as_secs_f64() * 1e3,
                    pid: std::process::id(),
                });
                let done = WindowDone {
                    worker: idx,
                    batch: echo,
                    fresh,
                    outcome,
                    trace,
                };
                if done_tx.send(done).is_err() {
                    return; // pool dropped mid-window
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeSet;

    use super::*;
    use crate::engine::profiles::ModelProfile;
    use crate::engine::sim_engine::SimEngine;
    use crate::runtime::manifest::ServedModelMeta;

    fn sim_engines(n: usize) -> Vec<Box<dyn Engine>> {
        let profile = ModelProfile::from_meta(&ServedModelMeta {
            name: "test".into(),
            abbrev: "test".into(),
            params_b: 7.0,
            avg_latency_ms: 2000.0,
            kv_bytes_per_token: 1 << 20,
            preempt_batch: 0,
            mem_limit_frac: 0.9,
        });
        (0..n)
            .map(|_| {
                Box::new(SimEngine::new(profile.clone(), 50, 4, 8 << 30))
                    as Box<dyn Engine>
            })
            .collect()
    }

    fn spec(id: u64, total: usize) -> SeqSpec {
        SeqSpec { id, prompt: vec![3; 8], target_total: total, topic: 0,
                  resume: Vec::new() }
    }

    #[test]
    fn windows_run_on_worker_threads_and_echo_back() {
        let pool = WorkerPool::new(sim_engines(2));
        assert_eq!(pool.workers(), 2);
        assert_eq!(pool.max_batch(0), 4);
        assert!(pool.describe(0).contains("SimEngine"),
                "{}", pool.describe(0));
        for w in 0..2u64 {
            pool.send(w as usize, WorkerCmd::RunWindow {
                admits: vec![spec(w, 30)],
                priority_order: vec![w],
                batch: vec![w],
                echo: vec![JobId::from_raw(w)],
                trace: Some(w),
            }).unwrap();
        }
        let mut seen = BTreeSet::new();
        for _ in 0..2 {
            let done = pool
                .recv_done_timeout(Duration::from_secs(10))
                .expect("window must complete");
            let outcome = done.outcome.expect("window must succeed");
            assert_eq!(done.batch.len(), 1);
            assert_eq!(done.batch[0].raw(), done.worker as u64);
            assert_eq!(outcome.outputs.len(), 1);
            assert!(!outcome.outputs[0].new_tokens.is_empty());
            let pod = done.trace.expect("trace was requested");
            assert_eq!(pod.window, done.worker as u64);
            assert_eq!(pod.pid, std::process::id());
            assert!(pod.exec_ms >= 0.0);
            seen.insert(done.worker);
        }
        assert_eq!(seen.len(), 2, "both workers must have answered");
        assert!(pool.try_recv_done().is_none(), "exactly one reply per window");
    }

    #[test]
    fn admit_error_is_reported_not_lost() {
        let pool = WorkerPool::new(sim_engines(1));
        // admitting the same id twice errs inside the engine; the error
        // must come back as the window outcome
        pool.send(0, WorkerCmd::RunWindow {
            admits: vec![spec(7, 30), spec(7, 30)],
            priority_order: vec![7],
            batch: vec![7],
            echo: vec![JobId::from_raw(7)],
            trace: None,
        }).unwrap();
        let done = pool
            .recv_done_timeout(Duration::from_secs(10))
            .expect("an errored window still answers");
        assert!(done.outcome.is_err());
        assert!(done.trace.is_none(), "no trace requested, none echoed");
    }

    #[test]
    fn drop_joins_worker_threads() {
        let pool = WorkerPool::new(sim_engines(3));
        pool.send(2, WorkerCmd::SetPreemptionCap(1)).unwrap();
        drop(pool); // must not hang or panic
    }
}
