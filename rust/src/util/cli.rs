//! Tiny subcommand + flag parser (no clap offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and free
//! positional arguments.  Typed getters with defaults keep call sites short.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse raw args (without argv[0]); the first non-flag token becomes
    /// the subcommand.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--flag value` unless the next token is another flag
                    match iter.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = iter.next().unwrap();
                            out.flags.insert(stripped.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(stripped.to_string(), "true".to_string());
                        }
                    }
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// A flag that must be present (no sensible default exists, e.g. the
    /// coordinator address a worker pod connects to).  The error names
    /// the flag so the CLI surfaces `--connect is required`-style
    /// messages instead of a panic or silent fallback.
    pub fn require_str(&self, key: &str) -> anyhow::Result<&str> {
        self.opt_str(key)
            .ok_or_else(|| anyhow::anyhow!("--{key} <value> is required"))
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true" | "1" | "yes"))
    }

    /// Parse a flag through a fallible enum parser (e.g. `Policy::parse`,
    /// `LbStrategy::parse`), attributing the error to the flag instead of
    /// silently falling back to a default.
    pub fn parse_with<T>(
        &self, key: &str, default: &str,
        parse: impl Fn(&str) -> Result<T, String>,
    ) -> anyhow::Result<T> {
        let raw = self.str(key, default);
        parse(&raw).map_err(|e| anyhow::anyhow!("--{key}: {e}"))
    }

    /// A duration flag given in (possibly fractional) seconds, e.g.
    /// `--duration-s 2.5`.  Negative and unparseable values fall back to
    /// the default; `Duration::from_secs_f64` would panic on them.
    pub fn duration_s(&self, key: &str, default_s: f64) -> std::time::Duration {
        let s = self.f64(key, default_s);
        let s = if s.is_finite() && s >= 0.0 { s } else { default_s };
        std::time::Duration::from_secs_f64(s.max(0.0))
    }

    /// Comma-separated list flag, e.g. `--models opt13,lam13`.
    pub fn list(&self, key: &str) -> Vec<String> {
        self.flags
            .get(key)
            .map(|s| s.split(',').map(|p| p.trim().to_string()).filter(|p| !p.is_empty()).collect())
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("serve --workers 4 --scheduler isrtf --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.usize("workers", 1), 4);
        assert_eq!(a.str("scheduler", "fcfs"), "isrtf");
        assert!(a.bool("verbose"));
        assert!(!a.bool("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("bench --rps=2.5 --n=100");
        assert_eq!(a.f64("rps", 0.0), 2.5);
        assert_eq!(a.usize("n", 0), 100);
    }

    #[test]
    fn positional() {
        let a = parse("run file1 file2 --k 1");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["file1", "file2"]);
    }

    #[test]
    fn list_flag() {
        let a = parse("x --models opt13,lam13, vic");
        assert_eq!(a.list("models"), vec!["opt13", "lam13"]);
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.f64("missing", 1.5), 1.5);
        assert_eq!(a.str("missing", "d"), "d");
        assert!(a.opt_str("missing").is_none());
    }

    #[test]
    fn parse_with_reports_flag_name() {
        let a = parse("x --mode bogus");
        let ok: anyhow::Result<usize> = a.parse_with("mode", "fast", |s| {
            match s {
                "fast" => Ok(1),
                "slow" => Ok(2),
                _ => Err(format!("unknown mode '{s}' (valid: fast, slow)")),
            }
        });
        let err = format!("{:#}", ok.unwrap_err());
        assert!(err.contains("--mode") && err.contains("bogus"), "{err}");

        let dflt: usize = parse("x")
            .parse_with("mode", "slow", |s| if s == "slow" { Ok(2) } else {
                Err("nope".into())
            })
            .unwrap();
        assert_eq!(dflt, 2);
    }

    #[test]
    fn require_str_names_the_missing_flag() {
        let a = parse("worker --connect 10.0.0.5:7000");
        assert_eq!(a.require_str("connect").unwrap(), "10.0.0.5:7000");
        let err = format!("{:#}", a.require_str("engine").unwrap_err());
        assert!(err.contains("--engine"), "{err}");
    }

    #[test]
    fn duration_seconds_flag() {
        let a = parse("x --duration-s 2.5 --bad -1 --nan oops");
        assert_eq!(a.duration_s("duration-s", 1.0),
                   std::time::Duration::from_millis(2500));
        assert_eq!(a.duration_s("missing", 3.0),
                   std::time::Duration::from_secs(3));
        // negative and unparseable values fall back without panicking
        assert_eq!(a.duration_s("bad", 4.0),
                   std::time::Duration::from_secs(4));
        assert_eq!(a.duration_s("nan", 5.0),
                   std::time::Duration::from_secs(5));
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse("x --offset -3");
        // "-3" does not start with --, so it is consumed as the value
        assert_eq!(a.f64("offset", 0.0), -3.0);
    }
}
