//! Minimal JSON parser/serializer.
//!
//! The offline toolchain has no `serde_json`, so the artifact manifest,
//! corpus, configs and metric dumps go through this module.  It implements
//! the full JSON grammar (RFC 8259) with the one simplification that
//! numbers are held as `f64` (the manifest never carries integers above
//! 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------------------- accessors ----------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Panic-free path access: `j.at(&["weights", "model"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<f64>.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|j| j.as_f64()).collect()
    }

    pub fn as_i32_vec(&self) -> Option<Vec<i32>> {
        self.as_arr()?
            .iter()
            .map(|j| j.as_f64().map(|f| f as i32))
            .collect()
    }

    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }

    // ---------------------------- builders -----------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn arr_str(v: &[&str]) -> Json {
        Json::Arr(v.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // ---------------------------- parsing ------------------------------

    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let bytes = s.as_bytes();
        let mut p = Parser { b: bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|_| self.err("bad utf8 in number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(c);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // collect the full utf8 sequence
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad utf8")),
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump().ok_or_else(|| self.err("truncated utf8"))?;
                    }
                    let s = std::str::from_utf8(&self.b[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

// ------------------------------- writing -------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{}", b),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        write!(f, "{}", *n as i64)
                    } else {
                        write!(f, "{}", n)
                    }
                } else {
                    write!(f, "null") // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}", x)?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{}", v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{}", c)?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("  -1.5e3 ").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":"c"}],"d":null}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(), Some("c"));
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escapes() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
        // surrogate pair: U+1F600
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("😀".into())
        );
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,-3],"b":{"c":"x\"y"},"d":true,"e":null}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn vec_accessors() {
        let j = Json::parse("[1,2,3]").unwrap();
        assert_eq!(j.as_i32_vec().unwrap(), vec![1, 2, 3]);
        assert_eq!(j.as_f64_vec().unwrap(), vec![1.0, 2.0, 3.0]);
        assert_eq!(j.as_usize_vec().unwrap(), vec![1, 2, 3]);
    }
}
