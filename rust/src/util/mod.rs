//! Offline-toolchain substrates: JSON, CLI parsing, bench harness.
pub mod bench;
pub mod cli;
pub mod json;
