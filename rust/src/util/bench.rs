//! Benchmark harness (criterion substitute for the offline toolchain).
//!
//! `cargo bench` targets are plain `main()`s (harness = false) built on this
//! module: warmup, timed iterations, mean/p50/p99 reporting, and paper-style
//! table printing so each bench regenerates the rows of its table/figure.

use std::time::{Duration, Instant};

use crate::stats::summary::Percentiles;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<40} iters={:<6} mean={:>12?} p50={:>12?} p99={:>12?} min={:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p99, self.min
        );
    }
}

/// Time `f` repeatedly: `warmup` untimed runs, then up to `max_iters` timed
/// runs or until `budget` elapses (at least one timed run always happens).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, max_iters: usize,
                         budget: Duration, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Percentiles::new();
    let mut min = Duration::MAX;
    let mut max = Duration::ZERO;
    let mut total = Duration::ZERO;
    let started = Instant::now();
    let mut iters = 0;
    while iters < max_iters && (iters == 0 || started.elapsed() < budget) {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed();
        samples.add(dt.as_secs_f64());
        min = min.min(dt);
        max = max.max(dt);
        total += dt;
        iters += 1;
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean: Duration::from_secs_f64(total.as_secs_f64() / iters as f64),
        p50: Duration::from_secs_f64(samples.p50()),
        p99: Duration::from_secs_f64(samples.p99()),
        min,
        max,
    }
}

/// Quick single-shot wall-time measurement for long-running end-to-end
/// experiments (one serving run is one sample).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

// --------------------------- table printing ----------------------------

/// Fixed-width text table matching the paper's row/column layout.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line_len: usize = widths.iter().sum::<usize>() + 3 * widths.len() + 1;
        println!("\n== {} ==", self.title);
        let sep: String = "-".repeat(line_len);
        println!("{sep}");
        let hdr: Vec<String> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| format!("{:<w$}", h, w = widths[i]))
            .collect();
        println!("| {} |", hdr.join(" | "));
        println!("{sep}");
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            println!("| {} |", cells.join(" | "));
        }
        println!("{sep}");
    }
}

pub fn fmt_f(x: f64, digits: usize) -> String {
    format!("{:.*}", digits, x)
}

pub fn fmt_pct(x: f64) -> String {
    format!("{:+.2}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0;
        let r = bench("noop", 2, 10, Duration::from_secs(5), || n += 1);
        assert_eq!(r.iters, 10);
        assert_eq!(n, 12); // warmup + timed
        assert!(r.mean <= r.max && r.min <= r.mean);
    }

    #[test]
    fn bench_respects_budget() {
        let r = bench("sleepy", 0, 1000, Duration::from_millis(30), || {
            std::thread::sleep(Duration::from_millis(10))
        });
        assert!(r.iters < 1000);
        assert!(r.iters >= 1);
    }

    #[test]
    fn table_rows() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_f(1.23456, 2), "1.23");
        assert_eq!(fmt_pct(0.196), "+19.60%");
    }
}
