//! Online heuristic fallback predictor (no artifact required).
//!
//! remaining ≈ max(EWMA_total + slope × (prompt_len − mean_plen) − generated, 1)
//!
//! The totals EWMA and the prompt-length regression update from completion
//! feedback (`observe`), so the fallback self-calibrates to the live
//! workload — the "retraining based on log data" loop of the paper, in its
//! cheapest form.

use super::{LengthPredictor, PredictQuery};

pub struct HeuristicPredictor {
    ewma_total: f64,
    ewma_plen: f64,
    /// online covariance accumulators for the prompt-length slope
    n: f64,
    cov: f64,
    var: f64,
    alpha: f64,
}

impl Default for HeuristicPredictor {
    fn default() -> Self {
        Self::new()
    }
}

impl HeuristicPredictor {
    pub fn new() -> HeuristicPredictor {
        HeuristicPredictor {
            ewma_total: 120.0, // corpus-scale prior
            ewma_plen: 32.0,
            n: 0.0,
            cov: 0.0,
            var: 0.0,
            alpha: 0.05,
        }
    }

    fn slope(&self) -> f64 {
        if self.n < 8.0 || self.var <= 1e-9 {
            0.0
        } else {
            (self.cov / self.var).clamp(-10.0, 10.0)
        }
    }
}

impl LengthPredictor for HeuristicPredictor {
    fn predict(&mut self, queries: &[PredictQuery<'_>]) -> Vec<f64> {
        let slope = self.slope();
        queries
            .iter()
            .map(|q| {
                let total = self.ewma_total
                    + slope * (q.prompt.len() as f64 - self.ewma_plen);
                (total - q.generated as f64).max(1.0)
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "heuristic"
    }

    fn observe(&mut self, prompt_len: usize, total_len: usize) {
        let p = prompt_len as f64;
        let t = total_len as f64;
        // Deltas must be taken against the PRE-update means: updating first
        // shrinks every delta by (1-alpha) and biases the slope low.
        let dp = p - self.ewma_plen;
        let dt = t - self.ewma_total;
        self.ewma_total = (1.0 - self.alpha) * self.ewma_total + self.alpha * t;
        self.ewma_plen = (1.0 - self.alpha) * self.ewma_plen + self.alpha * p;
        self.n += 1.0;
        self.cov = (1.0 - self.alpha) * self.cov + self.alpha * dp * dt;
        self.var = (1.0 - self.alpha) * self.var + self.alpha * dp * dp;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::q;

    #[test]
    fn remaining_decreases_with_generated() {
        let mut p = HeuristicPredictor::new();
        let prompt = vec![5i32; 20];
        let a = p.predict(&[q(1, &prompt, 0, 0)])[0];
        let b = p.predict(&[q(1, &prompt, 100, 0)])[0];
        assert!(b < a);
        assert!(b >= 1.0);
    }

    #[test]
    fn observe_recalibrates_mean() {
        let mut p = HeuristicPredictor::new();
        for _ in 0..200 {
            p.observe(30, 300);
        }
        let prompt = vec![5i32; 30];
        let pred = p.predict(&[q(1, &prompt, 0, 0)])[0];
        assert!(pred > 250.0, "pred {pred} should approach 300");
    }

    #[test]
    fn slope_converges_to_linear_workload() {
        // total = 40 + 3 * plen exactly; the recovered slope must converge
        // to 3 (pre-fix, the (1-alpha) shrink on deltas biased it low).
        let mut p = HeuristicPredictor::new();
        let mut plen = 10usize;
        for _ in 0..600 {
            plen = 10 + (plen * 13 + 7) % 50; // deterministic spread 10..59
            p.observe(plen, 40 + 3 * plen);
        }
        let slope = p.slope();
        assert!(
            (slope - 3.0).abs() < 0.15,
            "slope {slope} must converge to the true slope 3"
        );
        // and the prediction itself should track the line
        let prompt = vec![5i32; 40];
        let pred = p.predict(&[q(1, &prompt, 0, 0)])[0];
        assert!((pred - 160.0).abs() < 20.0, "pred {pred} for plen 40");
    }

    #[test]
    fn never_negative() {
        let mut p = HeuristicPredictor::new();
        let prompt = vec![5i32; 4];
        let pred = p.predict(&[q(1, &prompt, 100_000, 0)])[0];
        assert!(pred >= 1.0);
    }
}
