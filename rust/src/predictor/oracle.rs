//! Oracle predictors — the paper's SJF upper bound.
//!
//! `OraclePredictor` returns the exact remaining length (SRPT when
//! refreshed per iteration).  `FrozenOracle` returns the exact *total*
//! regardless of progress, which is precisely the paper's SJF baseline:
//! priority fixed at arrival from profiled latency.

use super::{LengthPredictor, PredictQuery};

#[derive(Default)]
pub struct OraclePredictor;

impl LengthPredictor for OraclePredictor {
    fn predict(&mut self, queries: &[PredictQuery<'_>]) -> Vec<f64> {
        queries
            .iter()
            .map(|q| (q.true_total.saturating_sub(q.generated)).max(1) as f64)
            .collect()
    }

    fn name(&self) -> &'static str {
        "oracle-srpt"
    }
}

#[derive(Default)]
pub struct FrozenOracle;

impl LengthPredictor for FrozenOracle {
    fn predict(&mut self, queries: &[PredictQuery<'_>]) -> Vec<f64> {
        queries.iter().map(|q| q.true_total.max(1) as f64).collect()
    }

    fn name(&self) -> &'static str {
        "oracle-sjf"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::q;

    #[test]
    fn oracle_tracks_progress() {
        let mut o = OraclePredictor;
        let prompt = vec![1i32; 4];
        assert_eq!(o.predict(&[q(1, &prompt, 0, 120)])[0], 120.0);
        assert_eq!(o.predict(&[q(1, &prompt, 50, 120)])[0], 70.0);
        assert_eq!(o.predict(&[q(1, &prompt, 200, 120)])[0], 1.0);
    }

    #[test]
    fn frozen_oracle_ignores_progress() {
        let mut o = FrozenOracle;
        let prompt = vec![1i32; 4];
        assert_eq!(o.predict(&[q(1, &prompt, 0, 120)])[0], 120.0);
        assert_eq!(o.predict(&[q(1, &prompt, 100, 120)])[0], 120.0);
    }
}
