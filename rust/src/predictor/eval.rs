//! Predictor evaluation on the exported held-out step dataset
//! (`artifacts/predictor_test.json`) — drives Table 2 and Fig 2b benches.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::stats::fit::{regression_metrics, RegressionMetrics};
use crate::telemetry::shadow::{replay_jcts, ReplayJob, ShadowMode};
use crate::util::json::Json;

use super::{LengthPredictor, PredictQuery};

/// Rank-sufficiency metrics: how good is a predictor *as an ordering
/// source* for ISRTF, independent of its absolute token error.
#[derive(Debug, Clone, Copy)]
pub struct RankMetrics {
    /// tie-corrected Kendall τ-b between predictions and truth
    pub tau: f64,
    /// fraction of truth-ordered pairs the predictions order correctly
    /// (prediction ties score half)
    pub pairwise_acc: f64,
    /// (mean JCT when scheduling by predicted order − mean JCT under the
    /// oracle SRPT order) / oracle mean JCT, replayed through the shadow-
    /// scheduler machinery with all jobs arriving at t=0
    pub jct_regret: f64,
    pub n: usize,
}

/// Tie-corrected Kendall τ-b over the paired samples.  NaN when fewer than
/// two samples or when either side is entirely tied.
pub fn kendall_tau(pred: &[f64], truth: &[f64]) -> f64 {
    let n = pred.len().min(truth.len());
    if n < 2 {
        return f64::NAN;
    }
    let (mut conc, mut disc) = (0i64, 0i64);
    let (mut tie_pred_only, mut tie_truth_only) = (0i64, 0i64);
    for i in 0..n {
        for j in (i + 1)..n {
            let dp = pred[i] - pred[j];
            let dt = truth[i] - truth[j];
            if dp == 0.0 && dt == 0.0 {
                continue; // tied in both: excluded from both denominators
            } else if dp == 0.0 {
                tie_pred_only += 1;
            } else if dt == 0.0 {
                tie_truth_only += 1;
            } else if (dp > 0.0) == (dt > 0.0) {
                conc += 1;
            } else {
                disc += 1;
            }
        }
    }
    let denom_pred = (conc + disc + tie_truth_only) as f64;
    let denom_truth = (conc + disc + tie_pred_only) as f64;
    let denom = (denom_pred * denom_truth).sqrt();
    if denom <= 0.0 {
        return f64::NAN;
    }
    (conc - disc) as f64 / denom
}

/// Fraction of truth-strictly-ordered pairs the predictions order the same
/// way; a prediction tie on such a pair scores 0.5.  NaN if the truth has
/// no strictly ordered pair.
pub fn pairwise_accuracy(pred: &[f64], truth: &[f64]) -> f64 {
    let n = pred.len().min(truth.len());
    let mut pairs = 0u64;
    let mut credit = 0.0f64;
    for i in 0..n {
        for j in (i + 1)..n {
            let dt = truth[i] - truth[j];
            if dt == 0.0 {
                continue;
            }
            pairs += 1;
            let dp = pred[i] - pred[j];
            if dp == 0.0 {
                credit += 0.5;
            } else if (dp > 0.0) == (dt > 0.0) {
                credit += 1.0;
            }
        }
    }
    if pairs == 0 {
        f64::NAN
    } else {
        credit / pairs as f64
    }
}

/// Mean JCT realized by seating jobs (all arriving at t=0, service = true
/// remaining length) in the given index order on `slots` parallel slots.
fn mean_jct_in_order(order: &[usize], truth: &[f64], slots: usize) -> f64 {
    let jobs: Vec<ReplayJob> = order
        .iter()
        .map(|&i| ReplayJob {
            id: i as u64,
            arrival_ms: 0.0,
            service_ms: truth[i].max(0.0),
        })
        .collect();
    let jcts = replay_jcts(ShadowMode::Fcfs, &jobs, slots);
    if jcts.is_empty() {
        return f64::NAN;
    }
    jcts.iter().map(|(_, jct)| jct).sum::<f64>() / jcts.len() as f64
}

/// Realized-JCT regret of scheduling by `pred` instead of by `truth`
/// (lower-first in both cases), normalized by the oracle mean JCT.  Zero
/// for any prediction that orders like the truth; maximal for the exactly
/// inverted ordering.
pub fn jct_regret(pred: &[f64], truth: &[f64], slots: usize) -> f64 {
    let n = pred.len().min(truth.len());
    if n == 0 {
        return f64::NAN;
    }
    let mut by_pred: Vec<usize> = (0..n).collect();
    by_pred.sort_by(|&a, &b| pred[a].total_cmp(&pred[b]).then(a.cmp(&b)));
    let mut by_truth: Vec<usize> = (0..n).collect();
    by_truth.sort_by(|&a, &b| truth[a].total_cmp(&truth[b]).then(a.cmp(&b)));
    let predicted = mean_jct_in_order(&by_pred, truth, slots);
    let oracle = mean_jct_in_order(&by_truth, truth, slots);
    if oracle <= 0.0 {
        return 0.0;
    }
    (predicted - oracle) / oracle
}

/// Bundle the three rank metrics for one prediction vector.
pub fn rank_metrics(pred: &[f64], truth: &[f64], slots: usize) -> RankMetrics {
    RankMetrics {
        tau: kendall_tau(pred, truth),
        pairwise_acc: pairwise_accuracy(pred, truth),
        jct_regret: jct_regret(pred, truth, slots),
        n: pred.len().min(truth.len()),
    }
}

#[derive(Debug, Clone)]
pub struct StepDataset {
    /// combined inputs as python built them (cross-check reference)
    pub tokens: Vec<Vec<i32>>,
    pub prompt_len: Vec<usize>,
    /// raw parts, the form the serving path sees
    pub raw_prompt: Vec<Vec<i32>>,
    pub suffix: Vec<Vec<i32>>,
    pub gen_count: Vec<usize>,
    pub step: Vec<usize>,
    pub target: Vec<f64>,
}

impl StepDataset {
    pub fn load(artifacts: &Path) -> Result<StepDataset> {
        let path = artifacts.join("predictor_test.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}"))?;
        let j = Json::parse(&text).context("parsing predictor_test.json")?;
        let tokens = j
            .get("tokens")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing tokens"))?
            .iter()
            .map(|row| row.as_i32_vec().ok_or_else(|| anyhow!("bad token row")))
            .collect::<Result<Vec<_>>>()?;
        let get_usize = |k: &str| -> Result<Vec<usize>> {
            j.get(k)
                .and_then(Json::as_usize_vec)
                .ok_or_else(|| anyhow!("missing {k}"))
        };
        let rows = |k: &str| -> Result<Vec<Vec<i32>>> {
            j.get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing {k}"))?
                .iter()
                .map(|row| row.as_i32_vec().ok_or_else(|| anyhow!("bad {k} row")))
                .collect()
        };
        let ds = StepDataset {
            tokens,
            prompt_len: get_usize("prompt_len")?,
            raw_prompt: rows("raw_prompt")?,
            suffix: rows("suffix")?,
            gen_count: get_usize("gen_count")?,
            step: get_usize("step")?,
            target: j
                .get("target")
                .and_then(Json::as_f64_vec)
                .ok_or_else(|| anyhow!("missing target"))?,
        };
        let n = ds.target.len();
        if ds.tokens.len() != n || ds.step.len() != n || ds.gen_count.len() != n
            || ds.raw_prompt.len() != n || ds.suffix.len() != n {
            anyhow::bail!("ragged predictor_test.json");
        }
        Ok(ds)
    }

    pub fn len(&self) -> usize {
        self.target.len()
    }

    pub fn is_empty(&self) -> bool {
        self.target.is_empty()
    }

    fn queries(&self, idx: &[usize]) -> Vec<PredictQuery<'_>> {
        idx.iter()
            .map(|&i| PredictQuery {
                job_id: i as u64,
                prompt: &self.raw_prompt[i],
                gen_suffix: &self.suffix[i],
                generated: self.gen_count[i],
                // targets are remaining lengths; total = remaining + generated
                true_total: self.gen_count[i] + self.target[i] as usize,
            })
            .collect()
    }

    /// Cross-check: rust `build_input` must reproduce python's combined
    /// tokens for every exported row.
    pub fn verify_input_construction(&self, prompt_max: usize) -> Result<()> {
        for i in 0..self.len() {
            let (seq, len) = super::build_input(
                &self.raw_prompt[i], &self.suffix[i], prompt_max);
            if seq != self.tokens[i] || len != self.prompt_len[i] {
                anyhow::bail!(
                    "input construction mismatch at row {i}: rust len {len} \
                     vs python {}", self.prompt_len[i]);
            }
        }
        Ok(())
    }

    /// Overall MAE / RMSE / R² (Table 2 row).
    pub fn evaluate(&self, p: &mut dyn LengthPredictor, limit: usize)
                    -> RegressionMetrics {
        let n = self.len().min(limit);
        let idx: Vec<usize> = (0..n).collect();
        let preds = p.predict(&self.queries(&idx));
        let truth: Vec<f64> = idx.iter().map(|&i| self.target[i]).collect();
        regression_metrics(&preds, &truth)
    }

    /// Rank-sufficiency metrics (Kendall τ-b, pairwise accuracy, realized-
    /// JCT regret on `slots` replay slots) over the first `limit` rows.
    pub fn evaluate_rank(&self, p: &mut dyn LengthPredictor, limit: usize,
                         slots: usize) -> RankMetrics {
        let n = self.len().min(limit);
        let idx: Vec<usize> = (0..n).collect();
        let preds = p.predict(&self.queries(&idx));
        let truth: Vec<f64> = idx.iter().map(|&i| self.target[i]).collect();
        rank_metrics(&preds, &truth, slots)
    }

    /// Per-iteration-step MAE (Fig 2b series).
    pub fn evaluate_by_step(&self, p: &mut dyn LengthPredictor, limit: usize,
                            max_step: usize) -> Vec<(usize, RegressionMetrics)> {
        let mut out = Vec::new();
        for step in 0..=max_step {
            let idx: Vec<usize> = (0..self.len())
                .filter(|&i| self.step[i] == step)
                .take(limit)
                .collect();
            if idx.len() < 10 {
                continue;
            }
            let preds = p.predict(&self.queries(&idx));
            let truth: Vec<f64> = idx.iter().map(|&i| self.target[i]).collect();
            out.push((step, regression_metrics(&preds, &truth)));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::oracle::OraclePredictor;

    fn tiny() -> StepDataset {
        StepDataset {
            tokens: vec![vec![5, 6, 7, 0]; 40],
            prompt_len: vec![3; 40],
            raw_prompt: vec![vec![5, 6, 7]; 40],
            suffix: vec![vec![]; 40],
            gen_count: (0..40).map(|i| (i % 4) * 50).collect(),
            step: (0..40).map(|i| i % 4).collect(),
            target: (0..40).map(|i| 200.0 - ((i % 4) * 50) as f64).collect(),
        }
    }

    #[test]
    fn oracle_scores_perfectly() {
        let ds = tiny();
        let m = ds.evaluate(&mut OraclePredictor, usize::MAX);
        assert!(m.mae < 1e-9);
        assert!((m.r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn by_step_grouping() {
        let ds = tiny();
        let per = ds.evaluate_by_step(&mut OraclePredictor, usize::MAX, 3);
        assert_eq!(per.len(), 4);
        for (_, m) in per {
            assert_eq!(m.n, 10);
        }
    }

    #[test]
    fn oracle_rank_is_perfect() {
        // the oracle orders exactly like the truth: τ = 1, every ordered
        // pair correct, and zero realized-JCT regret
        let ds = tiny();
        let m = ds.evaluate_rank(&mut OraclePredictor, usize::MAX, 1);
        assert!((m.tau - 1.0).abs() < 1e-12, "tau {}", m.tau);
        assert!((m.pairwise_acc - 1.0).abs() < 1e-12);
        assert!(m.jct_regret.abs() < 1e-12, "regret {}", m.jct_regret);
        assert_eq!(m.n, 40);
    }

    #[test]
    fn inverted_oracle_is_maximally_wrong() {
        let truth: Vec<f64> = (0..12).map(|i| 10.0 + 3.0 * i as f64).collect();
        let inverted: Vec<f64> = truth.iter().map(|t| -t).collect();
        assert!((kendall_tau(&inverted, &truth) + 1.0).abs() < 1e-12);
        assert!(pairwise_accuracy(&inverted, &truth).abs() < 1e-12);
        let regret = jct_regret(&inverted, &truth, 1);
        // the inverted ordering is longest-first — the worst possible
        // ordering for mean JCT, so no other ordering can regret more
        let mut worst: Vec<usize> = (0..truth.len()).collect();
        worst.sort_by(|&a, &b| truth[b].total_cmp(&truth[a]));
        let mut best: Vec<usize> = (0..truth.len()).collect();
        best.sort_by(|&a, &b| truth[a].total_cmp(&truth[b]));
        let expected = (mean_jct_in_order(&worst, &truth, 1)
            - mean_jct_in_order(&best, &truth, 1))
            / mean_jct_in_order(&best, &truth, 1);
        assert!(regret > 0.5, "regret {regret}");
        assert!((regret - expected).abs() < 1e-12,
                "regret {regret} vs maximal {expected}");
    }

    #[test]
    fn rank_metrics_handle_ties() {
        // all-tied truth: τ and pairwise accuracy are undefined (NaN),
        // regret is exactly zero (any order yields the same JCT multiset)
        let truth = vec![50.0; 8];
        let pred: Vec<f64> = (0..8).map(|i| i as f64).collect();
        assert!(kendall_tau(&pred, &truth).is_nan());
        assert!(pairwise_accuracy(&pred, &truth).is_nan());
        assert!(jct_regret(&pred, &truth, 1).abs() < 1e-12);

        // partial prediction ties on strictly ordered truth: half credit
        let truth2 = vec![1.0, 2.0];
        let pred2 = vec![5.0, 5.0];
        assert!((pairwise_accuracy(&pred2, &truth2) - 0.5).abs() < 1e-12);
        // τ-b: the only pair is pred-tied, so the pred side of the
        // denominator is empty → undefined (NaN), matching τ-b's 0/0
        assert!(kendall_tau(&pred2, &truth2).is_nan());

        // tiny fixture has heavy truth ties (10 rows per level): a
        // predictor constant within levels but ordered across them still
        // scores τ = 1 under tie correction
        let ds = tiny();
        let pred3: Vec<f64> = ds.target.iter().map(|t| t / 10.0).collect();
        assert!((kendall_tau(&pred3, &ds.target) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn two_slot_replay_reduces_regret() {
        // with as many slots as jobs there is no queueing: every ordering
        // realizes the same JCTs, so regret collapses to zero
        let truth: Vec<f64> = (0..6).map(|i| 10.0 + i as f64).collect();
        let inverted: Vec<f64> = truth.iter().map(|t| -t).collect();
        let serial = jct_regret(&inverted, &truth, 1);
        let wide = jct_regret(&inverted, &truth, truth.len());
        assert!(serial > 0.0);
        assert!(wide.abs() < 1e-12, "no-queue regret {wide}");
    }
}
