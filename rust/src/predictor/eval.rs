//! Predictor evaluation on the exported held-out step dataset
//! (`artifacts/predictor_test.json`) — drives Table 2 and Fig 2b benches.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::stats::fit::{regression_metrics, RegressionMetrics};
use crate::util::json::Json;

use super::{LengthPredictor, PredictQuery};

#[derive(Debug, Clone)]
pub struct StepDataset {
    /// combined inputs as python built them (cross-check reference)
    pub tokens: Vec<Vec<i32>>,
    pub prompt_len: Vec<usize>,
    /// raw parts, the form the serving path sees
    pub raw_prompt: Vec<Vec<i32>>,
    pub suffix: Vec<Vec<i32>>,
    pub gen_count: Vec<usize>,
    pub step: Vec<usize>,
    pub target: Vec<f64>,
}

impl StepDataset {
    pub fn load(artifacts: &Path) -> Result<StepDataset> {
        let path = artifacts.join("predictor_test.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}"))?;
        let j = Json::parse(&text).context("parsing predictor_test.json")?;
        let tokens = j
            .get("tokens")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing tokens"))?
            .iter()
            .map(|row| row.as_i32_vec().ok_or_else(|| anyhow!("bad token row")))
            .collect::<Result<Vec<_>>>()?;
        let get_usize = |k: &str| -> Result<Vec<usize>> {
            j.get(k)
                .and_then(Json::as_usize_vec)
                .ok_or_else(|| anyhow!("missing {k}"))
        };
        let rows = |k: &str| -> Result<Vec<Vec<i32>>> {
            j.get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing {k}"))?
                .iter()
                .map(|row| row.as_i32_vec().ok_or_else(|| anyhow!("bad {k} row")))
                .collect()
        };
        let ds = StepDataset {
            tokens,
            prompt_len: get_usize("prompt_len")?,
            raw_prompt: rows("raw_prompt")?,
            suffix: rows("suffix")?,
            gen_count: get_usize("gen_count")?,
            step: get_usize("step")?,
            target: j
                .get("target")
                .and_then(Json::as_f64_vec)
                .ok_or_else(|| anyhow!("missing target"))?,
        };
        let n = ds.target.len();
        if ds.tokens.len() != n || ds.step.len() != n || ds.gen_count.len() != n
            || ds.raw_prompt.len() != n || ds.suffix.len() != n {
            anyhow::bail!("ragged predictor_test.json");
        }
        Ok(ds)
    }

    pub fn len(&self) -> usize {
        self.target.len()
    }

    pub fn is_empty(&self) -> bool {
        self.target.is_empty()
    }

    fn queries(&self, idx: &[usize]) -> Vec<PredictQuery<'_>> {
        idx.iter()
            .map(|&i| PredictQuery {
                job_id: i as u64,
                prompt: &self.raw_prompt[i],
                gen_suffix: &self.suffix[i],
                generated: self.gen_count[i],
                // targets are remaining lengths; total = remaining + generated
                true_total: self.gen_count[i] + self.target[i] as usize,
            })
            .collect()
    }

    /// Cross-check: rust `build_input` must reproduce python's combined
    /// tokens for every exported row.
    pub fn verify_input_construction(&self, prompt_max: usize) -> Result<()> {
        for i in 0..self.len() {
            let (seq, len) = super::build_input(
                &self.raw_prompt[i], &self.suffix[i], prompt_max);
            if seq != self.tokens[i] || len != self.prompt_len[i] {
                anyhow::bail!(
                    "input construction mismatch at row {i}: rust len {len} \
                     vs python {}", self.prompt_len[i]);
            }
        }
        Ok(())
    }

    /// Overall MAE / RMSE / R² (Table 2 row).
    pub fn evaluate(&self, p: &mut dyn LengthPredictor, limit: usize)
                    -> RegressionMetrics {
        let n = self.len().min(limit);
        let idx: Vec<usize> = (0..n).collect();
        let preds = p.predict(&self.queries(&idx));
        let truth: Vec<f64> = idx.iter().map(|&i| self.target[i]).collect();
        regression_metrics(&preds, &truth)
    }

    /// Per-iteration-step MAE (Fig 2b series).
    pub fn evaluate_by_step(&self, p: &mut dyn LengthPredictor, limit: usize,
                            max_step: usize) -> Vec<(usize, RegressionMetrics)> {
        let mut out = Vec::new();
        for step in 0..=max_step {
            let idx: Vec<usize> = (0..self.len())
                .filter(|&i| self.step[i] == step)
                .take(limit)
                .collect();
            if idx.len() < 10 {
                continue;
            }
            let preds = p.predict(&self.queries(&idx));
            let truth: Vec<f64> = idx.iter().map(|&i| self.target[i]).collect();
            out.push((step, regression_metrics(&preds, &truth)));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::oracle::OraclePredictor;

    fn tiny() -> StepDataset {
        StepDataset {
            tokens: vec![vec![5, 6, 7, 0]; 40],
            prompt_len: vec![3; 40],
            raw_prompt: vec![vec![5, 6, 7]; 40],
            suffix: vec![vec![]; 40],
            gen_count: (0..40).map(|i| (i % 4) * 50).collect(),
            step: (0..40).map(|i| i % 4).collect(),
            target: (0..40).map(|i| 200.0 - ((i % 4) * 50) as f64).collect(),
        }
    }

    #[test]
    fn oracle_scores_perfectly() {
        let ds = tiny();
        let m = ds.evaluate(&mut OraclePredictor, usize::MAX);
        assert!(m.mae < 1e-9);
        assert!((m.r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn by_step_grouping() {
        let ds = tiny();
        let per = ds.evaluate_by_step(&mut OraclePredictor, usize::MAX, 3);
        assert_eq!(per.len(), 4);
        for (_, m) in per {
            assert_eq!(m.n, 10);
        }
    }
}
