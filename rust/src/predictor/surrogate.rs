//! Statistical twin of the HLO predictor for large-scale simulations.
//!
//! Running the real encoder at every priority refresh of a 50-worker,
//! thousands-of-jobs virtual-time sweep would make the *simulator* predictor
//! -bound.  The surrogate reproduces the HLO predictor's error *statistics*
//! instead: multiplicative log-normal error on the true remaining length,
//! with per-job deterministic noise that **shrinks geometrically with the
//! iteration index** — the paper's Fig 2b property (MAE falls as steps
//! progress).  Calibrate `sigma0` so step-0 MAE matches the measured
//! artifact metrics (see bench_table2_predictor).

use crate::stats::rng::Pcg64;

use super::{LengthPredictor, PredictQuery};

pub struct SurrogatePredictor {
    /// log-space error std-dev at step 0
    pub sigma0: f64,
    /// per-step multiplicative shrink of sigma (Fig 2b slope)
    pub decay: f64,
    seed: u64,
}

impl SurrogatePredictor {
    pub fn new(sigma0: f64, decay: f64, seed: u64) -> SurrogatePredictor {
        SurrogatePredictor { sigma0, decay, seed }
    }

    /// Default calibration ≈ the trained artifact (MAE/mean ratio ~0.45 at
    /// step 0, improving with iterations).
    pub fn calibrated(seed: u64) -> SurrogatePredictor {
        SurrogatePredictor::new(0.55, 0.8, seed)
    }

    /// Replace the desk profile with a noise profile fitted from live
    /// mispredict telemetry (`PredictorStats::surrogate_calibration` —
    /// per-step |log error| sketches → `sigma0 · decay^step`).  Clamps
    /// keep a sparse fit from producing a growing or degenerate profile.
    pub fn recalibrate(&mut self, sigma0: f64, decay: f64) {
        self.sigma0 = sigma0.clamp(0.0, 5.0);
        self.decay = decay.clamp(0.05, 1.0);
    }

    fn noise(&self, job_id: u64, step: usize) -> f64 {
        // deterministic per (job, step): stable across refreshes in the
        // same iteration, fresh information each iteration
        let mut rng = Pcg64::new(
            self.seed ^ job_id.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (step as u64).wrapping_mul(0xD1B5_4A32_D192_ED03),
        );
        let sigma = self.sigma0 * self.decay.powi(step as i32);
        crate::stats::dist::normal(&mut rng, 0.0, sigma)
    }
}

impl LengthPredictor for SurrogatePredictor {
    fn predict(&mut self, queries: &[PredictQuery<'_>]) -> Vec<f64> {
        queries
            .iter()
            .map(|q| {
                let remaining = q.true_total.saturating_sub(q.generated).max(1) as f64;
                let step = q.generated / 50;
                (remaining * self.noise(q.job_id, step).exp()).max(1.0)
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "isrtf-surrogate"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::q;
    use crate::stats::fit::regression_metrics;

    #[test]
    fn deterministic_within_step() {
        let mut s = SurrogatePredictor::calibrated(1);
        let prompt = vec![1i32; 8];
        let a = s.predict(&[q(7, &prompt, 50, 200)])[0];
        let b = s.predict(&[q(7, &prompt, 50, 200)])[0];
        assert_eq!(a, b);
    }

    #[test]
    fn error_shrinks_with_iterations() {
        let mut s = SurrogatePredictor::calibrated(2);
        let prompt = vec![1i32; 8];
        let mut mae_step: Vec<f64> = Vec::new();
        for step in 0..4 {
            let gen = step * 50;
            let mut preds = Vec::new();
            let mut truths = Vec::new();
            for job in 0..400u64 {
                let total = 250 + (job % 100) as usize;
                let p = s.predict(&[q(job, &prompt, gen, total)])[0];
                preds.push(p);
                truths.push((total - gen) as f64);
            }
            mae_step.push(regression_metrics(&preds, &truths).mae);
        }
        assert!(mae_step[3] < mae_step[0] * 0.6,
                "MAE must fall with steps: {mae_step:?}");
    }

    #[test]
    fn recalibrate_reshapes_error_profile() {
        // shrink the live profile: the recalibrated surrogate's step-0
        // error must fall accordingly, and clamps must hold
        let prompt = vec![1i32; 8];
        let mae0 = |s: &mut SurrogatePredictor| {
            let mut preds = Vec::new();
            let mut truths = Vec::new();
            for job in 0..400u64 {
                let total = 250 + (job % 100) as usize;
                preds.push(s.predict(&[q(job, &prompt, 0, total)])[0]);
                truths.push(total as f64);
            }
            regression_metrics(&preds, &truths).mae
        };
        let mut desk = SurrogatePredictor::calibrated(5);
        let mut live = SurrogatePredictor::calibrated(5);
        live.recalibrate(0.1, 0.9);
        assert!((live.sigma0 - 0.1).abs() < 1e-12);
        assert!((live.decay - 0.9).abs() < 1e-12);
        assert!(mae0(&mut live) < mae0(&mut desk) * 0.5,
                "a 5x tighter sigma0 must shrink step-0 MAE");
        live.recalibrate(99.0, -3.0);
        assert!((live.sigma0 - 5.0).abs() < 1e-12, "sigma0 clamp");
        assert!((live.decay - 0.05).abs() < 1e-12, "decay clamp");
    }

    #[test]
    fn unbiased_ordering_signal() {
        // jobs with much shorter remaining must usually rank first
        let mut s = SurrogatePredictor::calibrated(3);
        let prompt = vec![1i32; 8];
        let mut correct = 0;
        for job in 0..200u64 {
            let short = s.predict(&[q(job * 2, &prompt, 0, 30)])[0];
            let long = s.predict(&[q(job * 2 + 1, &prompt, 0, 400)])[0];
            if short < long {
                correct += 1;
            }
        }
        assert!(correct > 180, "ordering accuracy {correct}/200");
    }
}
