//! Online learning-to-rank predictor (ROADMAP item 1; "Efficient LLM
//! Scheduling by Learning to Rank", Fu et al.).
//!
//! ISRTF consumes an *ordering*, not absolute lengths — so instead of
//! regressing tokens, this predictor trains a linear score over cheap
//! prompt/suffix features with **pairwise logistic (RankNet-style) updates**
//! from completion feedback: for two observed completions with remaining
//! lengths `r_a`, `r_b`, the model is pushed toward
//! `sigma(s_a - s_b) = P(r_a > r_b)`.  A small magnitude anchor additionally
//! regresses the score toward `ln(remaining)`, so the exported value stays a
//! token count the telemetry abs-error sketches and `generated + remaining`
//! folding can consume.
//!
//! Unlike [`super::heuristic::HeuristicPredictor`] (prompt *length* only),
//! the feature vector reads prompt/suffix *content* tails, so workloads
//! where the prompt text encodes the response length are learnable online.
//!
//! Determinism: all sampling happens in `observe_rich` from a seeded
//! [`Pcg64`]; `predict` is pure (no rng, no state mutation), so the
//! incremental and rebuild dispatch paths — which may query the predictor a
//! different number of times — stay bit-identical.

use crate::stats::rng::Pcg64;

use super::{LengthPredictor, ObservedCompletion, PredictQuery, SUFFIX_MAX};

/// Number of features in the linear score.
pub const NUM_FEATURES: usize = 8;

/// Ring-buffer capacity of retained training examples.
const BUFFER_CAP: usize = 256;
/// Pairwise comparisons per fresh example.
const PAIRS_PER_EXAMPLE: usize = 8;
/// Generated-level samples drawn from each completion (0, T/4, T/2, 3T/4).
const LEVELS: usize = 4;

/// Pairwise logistic learning rate.
const ETA_PAIR: f64 = 0.08;
/// Magnitude-anchor (log-target regression) learning rate.
const ETA_ANCHOR: f64 = 0.04;
/// Token-id normalization scale (matches the TinyGPT vocab magnitude).
const ID_SCALE: f64 = 2048.0;

#[derive(Clone, Copy)]
struct Example {
    phi: [f64; NUM_FEATURES],
    /// ln(remaining tokens at this generated level)
    log_target: f64,
}

pub struct RankPredictor {
    w: [f64; NUM_FEATURES],
    buf: Vec<Example>,
    /// next ring slot to overwrite once `buf` is full
    cursor: usize,
    rng: Pcg64,
    observed: u64,
}

fn tail_mean(tokens: &[i32], k: usize) -> f64 {
    let start = tokens.len().saturating_sub(k);
    let tail = &tokens[start..];
    if tail.is_empty() {
        return 0.0;
    }
    let sum: f64 = tail.iter().map(|&t| t as f64).sum();
    sum / tail.len() as f64 / ID_SCALE
}

/// Feature map shared by `predict` and training — MUST stay identical on
/// both paths or the learned weights stop transferring to live queries.
fn features(prompt: &[i32], suffix: &[i32], generated: usize)
            -> [f64; NUM_FEATURES] {
    let plen = prompt.len() as f64;
    let prompt_mean = if prompt.is_empty() {
        0.0
    } else {
        prompt.iter().map(|&t| t as f64).sum::<f64>() / plen / ID_SCALE
    };
    let last = suffix.last().map(|&t| t as f64 / ID_SCALE).unwrap_or(0.0);
    [
        1.0,
        (1.0 + plen).ln() / 8.0,
        (plen / 64.0).min(4.0),
        (1.0 + generated as f64).ln() / 8.0,
        prompt_mean,
        tail_mean(prompt, SUFFIX_MAX),
        tail_mean(suffix, SUFFIX_MAX),
        last,
    ]
}

fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

impl RankPredictor {
    pub fn new(seed: u64) -> RankPredictor {
        let mut w = [0.0; NUM_FEATURES];
        // start at the corpus-scale prior exp(w0) ~= 120 tokens, matching
        // HeuristicPredictor's cold-start mean
        w[0] = 120f64.ln();
        RankPredictor {
            w,
            buf: Vec::with_capacity(BUFFER_CAP),
            cursor: 0,
            rng: Pcg64::new(seed ^ 0x7261_6E6B_7072_6564), // "rankpred"
            observed: 0,
        }
    }

    fn score(&self, phi: &[f64; NUM_FEATURES]) -> f64 {
        self.w.iter().zip(phi.iter()).map(|(w, f)| w * f).sum()
    }

    /// Completions observed so far (each yields up to [`LEVELS`] examples).
    pub fn observations(&self) -> u64 {
        self.observed
    }

    #[cfg(test)]
    pub(crate) fn weights(&self) -> &[f64; NUM_FEATURES] {
        &self.w
    }

    fn push(&mut self, ex: Example) {
        if self.buf.len() < BUFFER_CAP {
            self.buf.push(ex);
        } else {
            self.buf[self.cursor] = ex;
            self.cursor = (self.cursor + 1) % BUFFER_CAP;
        }
    }

    fn train_one(&mut self, ex: &Example) {
        // magnitude anchor: pull the score toward ln(remaining) so the
        // exported value stays a usable token estimate
        let s = self.score(&ex.phi);
        let g = ETA_ANCHOR * (ex.log_target - s);
        for (w, f) in self.w.iter_mut().zip(ex.phi.iter()) {
            *w += g * f;
        }
        // pairwise logistic updates vs sampled retained examples
        if self.buf.is_empty() {
            return;
        }
        for _ in 0..PAIRS_PER_EXAMPLE {
            let pick = self.rng.below(self.buf.len() as u64) as usize;
            let other = self.buf[pick];
            // target P(ex longer than other); 0.5 encodes a tie
            let target = if ex.log_target > other.log_target + 1e-12 {
                1.0
            } else if ex.log_target + 1e-12 < other.log_target {
                0.0
            } else {
                0.5
            };
            let margin = self.score(&ex.phi) - self.score(&other.phi);
            let g = ETA_PAIR * (target - sigmoid(margin));
            for i in 0..NUM_FEATURES {
                self.w[i] += g * (ex.phi[i] - other.phi[i]);
            }
        }
    }
}

impl LengthPredictor for RankPredictor {
    fn predict(&mut self, queries: &[PredictQuery<'_>]) -> Vec<f64> {
        // Pure: no rng draw, no weight/buffer mutation — dispatch paths may
        // call this a different number of times and must agree bit-exactly.
        queries
            .iter()
            .map(|q| {
                let phi = features(q.prompt, q.gen_suffix, q.generated);
                self.score(&phi).clamp(0.0, 9.0).exp().max(1.0)
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "rank"
    }

    fn observe_rich(&mut self, c: &ObservedCompletion<'_>) {
        let total = c.total_len.max(1);
        self.observed += 1;
        let gen_len = c.response.len();
        let mut prev_g = usize::MAX;
        for k in 0..LEVELS {
            let g = gen_len * k / LEVELS;
            // dedup short completions that collapse to the same level
            if g == prev_g {
                continue;
            }
            prev_g = g;
            let frac_gen = total * k / LEVELS;
            let remaining = (total - frac_gen).max(1);
            let ex = Example {
                phi: features(c.prompt, &c.response[..g], frac_gen),
                log_target: (remaining as f64).ln(),
            };
            self.train_one(&ex);
            self.push(ex);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::eval::kendall_tau;

    fn completion(prompt: Vec<i32>, total: usize) -> (Vec<i32>, Vec<i32>) {
        // response tokens loosely follow the prompt's content register
        let fill = prompt.first().copied().unwrap_or(7);
        (prompt, vec![fill; total])
    }

    /// prompt content (a single repeated token id) encodes the length
    fn content_coded(v: i32) -> (Vec<i32>, usize) {
        let plen = 8 + (v as usize % 13); // plen uncorrelated with length
        (vec![v; plen], 5 + v as usize / 4)
    }

    #[test]
    fn cold_start_is_prior_scale() {
        let mut p = RankPredictor::new(1);
        let prompt = vec![100i32; 16];
        let out = p.predict(&[crate::predictor::q(1, &prompt, 0, 0)])[0];
        assert!(out > 20.0 && out < 600.0, "cold-start pred {out}");
    }

    #[test]
    fn predict_is_pure() {
        let mut p = RankPredictor::new(2);
        for v in (16..400).step_by(7) {
            let (prompt, total) = content_coded(v);
            let (prompt, response) = completion(prompt, total);
            p.observe_rich(&ObservedCompletion {
                prompt: &prompt,
                response: &response,
                total_len: total,
            });
        }
        let prompt = vec![123i32; 10];
        let q = crate::predictor::q(9, &prompt, 0, 0);
        let a = p.predict(&[q.clone()])[0];
        // extra predict calls in between must not perturb later answers
        for _ in 0..17 {
            p.predict(&[q.clone()]);
        }
        let b = p.predict(&[q.clone()])[0];
        assert_eq!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn learns_content_coded_lengths() {
        let mut p = RankPredictor::new(3);
        let mut rng = Pcg64::new(42);
        for _ in 0..500 {
            let v = 16 + rng.below(1984) as i32;
            let (prompt, total) = content_coded(v);
            let (prompt, response) = completion(prompt, total);
            p.observe_rich(&ObservedCompletion {
                prompt: &prompt,
                response: &response,
                total_len: total,
            });
        }
        let mut preds = Vec::new();
        let mut truths = Vec::new();
        for v in (16..2000).step_by(37) {
            let (prompt, total) = content_coded(v);
            preds.push(p.predict(&[crate::predictor::q(0, &prompt, 0, 0)])[0]);
            truths.push(total as f64);
        }
        let tau = kendall_tau(&preds, &truths);
        assert!(tau > 0.85, "learned ordering tau {tau}");
    }

    #[test]
    fn observe_rich_deterministic() {
        let run = || {
            let mut p = RankPredictor::new(11);
            for v in (16..600).step_by(11) {
                let (prompt, total) = content_coded(v);
                let (prompt, response) = completion(prompt, total);
                p.observe_rich(&ObservedCompletion {
                    prompt: &prompt,
                    response: &response,
                    total_len: total,
                });
            }
            *p.weights()
        };
        let (a, b) = (run(), run());
        for i in 0..NUM_FEATURES {
            assert_eq!(a[i].to_bits(), b[i].to_bits(), "weight {i} diverged");
        }
    }
}
