//! PJRT-backed predictor: the trained BGE-substitute artifact.

use std::sync::Arc;

use anyhow::Result;

use crate::runtime::{HostTensor, LoadedModel, Manifest, Runtime, WeightStore};

use super::{LengthPredictor, PredictQuery};

pub struct HloPredictor {
    model: LoadedModel,
    batch: usize,
    prompt_max: usize,
    pub calls: u64,
}

impl HloPredictor {
    /// `weights_group`: `predictor_trained` (default) or `predictor_init`
    /// (the Table 2 "pre-trained" baseline).
    pub fn load(rt: Arc<Runtime>, manifest: &Manifest, store: &WeightStore,
                weights_group: Option<&str>) -> Result<HloPredictor> {
        let name = format!("predictor.b{}", manifest.predictor_batch);
        let model = LoadedModel::load(rt, manifest, store, &name, weights_group)?;
        Ok(HloPredictor {
            model,
            batch: manifest.predictor_batch,
            prompt_max: manifest.predictor_prompt_max,
            calls: 0,
        })
    }

    /// Raw batched forward: returns (pred_remaining, pooled embeddings).
    pub fn forward(&mut self, queries: &[PredictQuery<'_>])
                   -> Result<(Vec<f64>, Vec<Vec<f32>>)> {
        let mut preds = Vec::with_capacity(queries.len());
        let mut embeds = Vec::with_capacity(queries.len());
        for chunk in queries.chunks(self.batch) {
            let b = self.batch;
            let mut tokens = vec![0i32; b * self.prompt_max];
            let mut plen = vec![1i32; b];
            let mut gen = vec![0f32; b];
            for (i, qr) in chunk.iter().enumerate() {
                // combined input: prompt + SEP + generated suffix (§3.3)
                let (seq, n) = super::build_input(
                    qr.prompt, qr.gen_suffix, self.prompt_max);
                tokens[i * self.prompt_max..(i + 1) * self.prompt_max]
                    .copy_from_slice(&seq);
                plen[i] = n.max(1) as i32;
                gen[i] = qr.generated as f32;
            }
            let out = self.model.execute(&[
                HostTensor::I32(tokens),
                HostTensor::I32(plen),
                HostTensor::F32(gen),
            ])?;
            self.calls += 1;
            let pred = out[0].as_f32()?;
            let pooled = out[1].as_f32()?;
            let d = pooled.len() / b;
            for i in 0..chunk.len() {
                preds.push(pred[i] as f64);
                embeds.push(pooled[i * d..(i + 1) * d].to_vec());
            }
        }
        Ok((preds, embeds))
    }

    /// Pooled embeddings only (Fig 1 cluster analysis).
    pub fn embed(&mut self, prompts: &[Vec<i32>]) -> Result<Vec<Vec<f32>>> {
        let queries: Vec<PredictQuery<'_>> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| PredictQuery {
                job_id: i as u64,
                prompt: p.as_slice(),
                gen_suffix: &[],
                generated: 0,
                true_total: 0,
            })
            .collect();
        Ok(self.forward(&queries)?.1)
    }

    pub fn avg_call_ms(&self) -> f64 {
        self.model.avg_exec_ms()
    }
}

impl LengthPredictor for HloPredictor {
    fn predict(&mut self, queries: &[PredictQuery<'_>]) -> Vec<f64> {
        match self.forward(queries) {
            // clamp: a remaining-length prediction below half a window is
            // still "almost done" — keep it positive so SRTF ordering works
            Ok((preds, _)) => preds.into_iter().map(|p| p.max(1.0)).collect(),
            Err(e) => {
                // fallback (paper motivation: never let the predictor take
                // the serving loop down)
                eprintln!("[predictor] HLO failure, falling back to flat: {e:#}");
                vec![100.0; queries.len()]
            }
        }
    }

    fn name(&self) -> &'static str {
        "isrtf-hlo"
    }
}
