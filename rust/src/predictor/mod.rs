//! Response-length predictors — the priority source for ISRTF (paper §4.2).
//!
//! The scheduler is predictor-agnostic (paper: "modular architecture ...
//! model-agnostic"); four implementations share the [`LengthPredictor`]
//! trait:
//!
//! * [`hlo::HloPredictor`] — the real thing: the AOT-compiled BGE-substitute
//!   encoder + 8 FC layers, executed via PJRT.
//! * [`heuristic::HeuristicPredictor`] — fallback when no artifact is
//!   available: online EWMA of observed lengths + prompt-length regression.
//! * [`surrogate::SurrogatePredictor`] — statistical twin of the HLO
//!   predictor (noise calibrated to its measured error, shrinking per
//!   iteration like Fig 2b); used by large-scale simulations where running
//!   the encoder per refresh would dominate the virtual-time experiment.
//! * [`oracle::OraclePredictor`] — perfect knowledge; turns ISRTF into the
//!   SRPT upper bound and SJF when frozen at step 0.
//! * [`rank::RankPredictor`] — online learning-to-rank: pairwise logistic
//!   updates from completion feedback over cheap prompt/suffix features;
//!   optimizes the *ordering* ISRTF actually consumes.

pub mod eval;
pub mod heuristic;
pub mod hlo;
pub mod oracle;
pub mod rank;
pub mod surrogate;

/// One prediction query (a job at a scheduling-iteration boundary).
#[derive(Debug, Clone)]
pub struct PredictQuery<'a> {
    pub job_id: u64,
    pub prompt: &'a [i32],
    /// tail of the generated response (the paper feeds partial output back
    /// into the predictor each iteration, §3.3)
    pub gen_suffix: &'a [i32],
    /// tokens generated so far (k × window)
    pub generated: usize,
    /// ground-truth total response length — ONLY oracle/surrogate read this
    pub true_total: usize,
}

// Predictor input layout — MUST mirror python/compile/data.py exactly:
// prompt[:PROMPT_KEEP] + SEP + suffix[-SUFFIX_MAX:], zero-padded.
pub const SEP_ID: i32 = 3;
pub const PROMPT_KEEP: usize = 47;
pub const SUFFIX_MAX: usize = 16;

/// Build the combined predictor input (returns padded tokens + valid len).
pub fn build_input(prompt: &[i32], suffix: &[i32], prompt_max: usize)
                   -> (Vec<i32>, usize) {
    let mut seq: Vec<i32> = Vec::with_capacity(prompt_max);
    seq.extend_from_slice(&prompt[..prompt.len().min(PROMPT_KEEP)]);
    seq.push(SEP_ID);
    let tail_start = suffix.len().saturating_sub(SUFFIX_MAX);
    seq.extend_from_slice(&suffix[tail_start..]);
    seq.truncate(prompt_max);
    let len = seq.len();
    seq.resize(prompt_max, 0);
    (seq, len)
}

/// A finished job, as seen by the completion-feedback path: the full prompt
/// and response token streams plus the realized total length.  Predictors
/// that learn from *content* (e.g. [`rank::RankPredictor`]) read the token
/// slices; length-only learners fall back to the scalar [`LengthPredictor::
/// observe`] via the default `observe_rich`.
#[derive(Debug, Clone, Copy)]
pub struct ObservedCompletion<'a> {
    pub prompt: &'a [i32],
    pub response: &'a [i32],
    /// realized total response length in tokens (== response.len() on the
    /// live path; sims may report the trace's total instead)
    pub total_len: usize,
}

/// Predicts the number of response tokens still to come.
pub trait LengthPredictor {
    /// Batched prediction of *remaining* tokens for each query.
    fn predict(&mut self, queries: &[PredictQuery<'_>]) -> Vec<f64>;

    fn name(&self) -> &'static str;

    /// Observed completion feedback (jobs' true lengths as they finish) —
    /// lets online predictors re-calibrate, mirroring the paper's
    /// retrain-from-logs loop.
    fn observe(&mut self, _prompt_len: usize, _total_len: usize) {}

    /// Rich completion feedback carrying the full token streams.  The
    /// coordinator calls this (not `observe`) on job finish; the default
    /// degrades to the scalar `observe` so existing predictors are
    /// unaffected.
    fn observe_rich(&mut self, c: &ObservedCompletion<'_>) {
        self.observe(c.prompt.len(), c.total_len);
    }
}

#[cfg(test)]
pub(crate) fn q(job_id: u64, prompt: &[i32], generated: usize,
                true_total: usize) -> PredictQuery<'_> {
    PredictQuery { job_id, prompt, gen_suffix: &[], generated, true_total }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_input_layout() {
        let prompt: Vec<i32> = (100..160).collect(); // 60 tokens
        let suffix: Vec<i32> = (2000..2030).collect(); // 30 tokens
        let (seq, len) = build_input(&prompt, &suffix, 64);
        assert_eq!(seq.len(), 64);
        assert_eq!(len, 64); // 47 + 1 + 16
        assert_eq!(&seq[..47], &prompt[..47]);
        assert_eq!(seq[47], SEP_ID);
        assert_eq!(&seq[48..64], &suffix[14..30]); // last 16
    }

    #[test]
    fn build_input_short_prompt_no_suffix() {
        let prompt = [5, 6, 7];
        let (seq, len) = build_input(&prompt, &[], 64);
        assert_eq!(len, 4);
        assert_eq!(&seq[..4], &[5, 6, 7, SEP_ID]);
        assert!(seq[4..].iter().all(|&t| t == 0));
    }
}
