//! PJRT runtime: manifest + weight loading + HLO execution.
//!
//! This is the only module that touches the `xla` crate.  Everything above
//! it (engine, coordinator) works with [`client::HostTensor`]s.
pub mod client;
pub mod manifest;
pub mod weights;

pub use client::{HostTensor, LoadedModel, Runtime};
pub use manifest::{default_artifacts_dir, DType, Manifest};
pub use weights::WeightStore;
