//! Weight-blob loader: raw little-endian tensors exported by `aot.py`.
//!
//! Each weight group (`model`, `predictor_trained`, `predictor_init`) is a
//! directory of `NNN_name.bin` files listed — in argument order — by the
//! manifest.  Order matters: the HLO's parameters are positional.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::client::HostTensor;
use super::manifest::{DType, Manifest, TensorSpec};

pub struct WeightStore {
    groups: BTreeMap<String, Vec<(TensorSpec, HostTensor)>>,
}

impl WeightStore {
    /// Load every weight group referenced by the manifest.
    pub fn load(manifest: &Manifest) -> Result<WeightStore> {
        let mut groups = BTreeMap::new();
        for (group, entries) in &manifest.weights {
            let mut tensors = Vec::with_capacity(entries.len());
            for e in entries {
                let path = manifest.root.join(&e.file);
                let bytes = std::fs::read(&path)
                    .with_context(|| format!("reading weight blob {path:?}"))?;
                let want = e.spec.n_elems() * e.spec.dtype.size_bytes();
                if bytes.len() != want {
                    bail!(
                        "{}: blob is {} bytes, expected {} ({:?} {:?})",
                        e.file,
                        bytes.len(),
                        want,
                        e.spec.shape,
                        e.spec.dtype
                    );
                }
                tensors.push((e.spec.clone(), decode(&bytes, e.spec.dtype)));
            }
            groups.insert(group.clone(), tensors);
        }
        Ok(WeightStore { groups })
    }

    /// Load only the named groups (saves memory when a binary needs one).
    pub fn load_groups(manifest: &Manifest, names: &[&str]) -> Result<WeightStore> {
        let mut sub = Manifest::clone(manifest);
        sub.weights.retain(|k, _| names.contains(&k.as_str()));
        Self::load(&sub)
    }

    pub fn group(&self, name: &str) -> Result<&[(TensorSpec, HostTensor)]> {
        self.groups
            .get(name)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow::anyhow!("weight group {name} not loaded"))
    }

    pub fn group_names(&self) -> Vec<&str> {
        self.groups.keys().map(|s| s.as_str()).collect()
    }

    pub fn total_bytes(&self) -> usize {
        self.groups
            .values()
            .flat_map(|v| v.iter())
            .map(|(s, _)| s.n_elems() * s.dtype.size_bytes())
            .sum()
    }
}

fn decode(bytes: &[u8], dtype: DType) -> HostTensor {
    match dtype {
        DType::F32 => HostTensor::F32(
            bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        ),
        DType::I32 => HostTensor::I32(
            bytes
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_f32_le() {
        let mut bytes = Vec::new();
        for v in [1.0f32, -2.5, 0.0] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let t = decode(&bytes, DType::F32);
        assert_eq!(t.as_f32().unwrap(), &[1.0, -2.5, 0.0]);
    }

    #[test]
    fn decode_i32_le() {
        let mut bytes = Vec::new();
        for v in [7i32, -9, 1 << 20] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let t = decode(&bytes, DType::I32);
        assert_eq!(t.as_i32().unwrap(), &[7, -9, 1 << 20]);
    }
}
