//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime.  Parses `artifacts/manifest.json` into typed structs.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype {other}"),
        }
    }

    pub fn size_bytes(&self) -> usize {
        4
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn n_elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
            shape: j
                .get("shape")
                .and_then(Json::as_usize_vec)
                .ok_or_else(|| anyhow!("tensor missing shape"))?,
            dtype: DType::parse(
                j.get("dtype").and_then(Json::as_str).unwrap_or("f32"),
            )?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub spec: TensorSpec,
    pub file: String,
}

#[derive(Debug, Clone)]
pub struct ExecutableSpec {
    pub name: String,
    pub hlo_file: String,
    pub weights_group: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Debug, Clone)]
pub struct ModelConfigMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub max_seq: usize,
    pub prompt_max: usize,
    pub n_params: usize,
}

#[derive(Debug, Clone)]
pub struct ServedModelMeta {
    pub name: String,
    pub abbrev: String,
    pub params_b: f64,
    pub avg_latency_ms: f64,
    pub kv_bytes_per_token: usize,
    pub preempt_batch: usize,
    pub mem_limit_frac: f64,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub window_size: usize,
    pub batch_sizes: Vec<usize>,
    pub predictor_batch: usize,
    pub model: ModelConfigMeta,
    pub predictor_prompt_max: usize,
    pub gamma_alpha: f64,
    pub gamma_beta: f64,
    pub executables: BTreeMap<String, ExecutableSpec>,
    pub weights: BTreeMap<String, Vec<WeightEntry>>,
    pub served_models: Vec<ServedModelMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts` first)"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        Self::from_json(dir, &j)
    }

    pub fn from_json(dir: &Path, j: &Json) -> Result<Manifest> {
        let mc = j.get("model_config").ok_or_else(|| anyhow!("missing model_config"))?;
        let model = ModelConfigMeta {
            vocab: mc.get("vocab").and_then(Json::as_usize).unwrap_or(0),
            d_model: mc.get("d_model").and_then(Json::as_usize).unwrap_or(0),
            n_layers: mc.get("n_layers").and_then(Json::as_usize).unwrap_or(0),
            n_heads: mc.get("n_heads").and_then(Json::as_usize).unwrap_or(0),
            max_seq: mc.get("max_seq").and_then(Json::as_usize).unwrap_or(0),
            prompt_max: mc.get("prompt_max").and_then(Json::as_usize).unwrap_or(0),
            n_params: mc.get("n_params").and_then(Json::as_usize).unwrap_or(0),
        };

        let mut executables = BTreeMap::new();
        for (name, e) in j
            .get("executables")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("missing executables"))?
        {
            let inputs = e
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: missing inputs"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .get("outputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: missing outputs"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            executables.insert(
                name.clone(),
                ExecutableSpec {
                    name: name.clone(),
                    hlo_file: e
                        .get("hlo")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("{name}: missing hlo"))?
                        .to_string(),
                    weights_group: e
                        .get("weights")
                        .and_then(Json::as_str)
                        .unwrap_or("")
                        .to_string(),
                    inputs,
                    outputs,
                },
            );
        }

        let mut weights = BTreeMap::new();
        for (group, arr) in j
            .get("weights")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("missing weights"))?
        {
            let entries = arr
                .as_arr()
                .ok_or_else(|| anyhow!("weights group {group} not an array"))?
                .iter()
                .map(|e| {
                    Ok(WeightEntry {
                        spec: TensorSpec::from_json(e)?,
                        file: e
                            .get("file")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("weight missing file"))?
                            .to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            weights.insert(group.clone(), entries);
        }

        let served_models = j
            .get("served_models")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .map(|m| ServedModelMeta {
                name: m.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                abbrev: m.get("abbrev").and_then(Json::as_str).unwrap_or("").to_string(),
                params_b: m.get("params_b").and_then(Json::as_f64).unwrap_or(0.0),
                avg_latency_ms: m.get("avg_latency_ms").and_then(Json::as_f64).unwrap_or(0.0),
                kv_bytes_per_token: m
                    .get("kv_bytes_per_token")
                    .and_then(Json::as_usize)
                    .unwrap_or(0),
                preempt_batch: m.get("preempt_batch").and_then(Json::as_usize).unwrap_or(0),
                mem_limit_frac: m.get("mem_limit_frac").and_then(Json::as_f64).unwrap_or(0.9),
            })
            .collect();

        Ok(Manifest {
            root: dir.to_path_buf(),
            window_size: j.get("window_size").and_then(Json::as_usize).unwrap_or(50),
            batch_sizes: j
                .get("batch_sizes")
                .and_then(Json::as_usize_vec)
                .unwrap_or_else(|| vec![1, 2, 4]),
            predictor_batch: j.get("predictor_batch").and_then(Json::as_usize).unwrap_or(8),
            model,
            predictor_prompt_max: j
                .at(&["predictor_config", "prompt_max"])
                .and_then(Json::as_usize)
                .unwrap_or(64),
            gamma_alpha: j.get("gamma_alpha").and_then(Json::as_f64).unwrap_or(0.73),
            gamma_beta: j.get("gamma_beta").and_then(Json::as_f64).unwrap_or(10.41),
            executables,
            weights,
            served_models,
        })
    }

    pub fn executable(&self, name: &str) -> Result<&ExecutableSpec> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow!("executable {name} not in manifest"))
    }

    pub fn hlo_path(&self, exe: &ExecutableSpec) -> PathBuf {
        self.root.join(&exe.hlo_file)
    }
}

/// Locate the artifacts directory: $ELIS_ARTIFACTS or ./artifacts upward.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("ELIS_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> Json {
        Json::parse(
            r#"{
            "window_size": 50,
            "batch_sizes": [1,2,4],
            "predictor_batch": 8,
            "model_config": {"vocab":2048,"d_model":256,"n_layers":4,
                             "n_heads":4,"max_seq":576,"prompt_max":64,
                             "n_params":1000},
            "predictor_config": {"prompt_max": 64},
            "gamma_alpha": 0.73, "gamma_beta": 10.41,
            "executables": {
              "model.decode.b4": {
                 "hlo": "model.decode.b4.hlo.txt",
                 "weights": "model",
                 "inputs": [{"name":"kv","shape":[4,2,4,4,576,64],"dtype":"f32"}],
                 "outputs": [{"name":"tokens","shape":[4,50],"dtype":"i32"}]
              }
            },
            "weights": {
              "model": [{"name":"tok_emb","file":"weights/model/000.bin",
                         "shape":[2048,256],"dtype":"f32"}]
            },
            "served_models": [
               {"name":"LlaMA2-13B","abbrev":"lam13","params_b":13,
                "avg_latency_ms":8610.2,"kv_bytes_per_token":1000,
                "preempt_batch":120,"mem_limit_frac":0.9}
            ]
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json(Path::new("/tmp/x"), &sample_manifest()).unwrap();
        assert_eq!(m.window_size, 50);
        assert_eq!(m.model.vocab, 2048);
        let e = m.executable("model.decode.b4").unwrap();
        assert_eq!(e.inputs[0].shape, vec![4, 2, 4, 4, 576, 64]);
        assert_eq!(e.inputs[0].dtype, DType::F32);
        assert_eq!(e.outputs[0].dtype, DType::I32);
        assert_eq!(m.weights["model"][0].spec.n_elems(), 2048 * 256);
        assert_eq!(m.served_models[0].preempt_batch, 120);
    }

    #[test]
    fn missing_executable_errors() {
        let m = Manifest::from_json(Path::new("/tmp/x"), &sample_manifest()).unwrap();
        assert!(m.executable("nope").is_err());
    }

    #[test]
    fn dtype_parse() {
        assert!(DType::parse("f32").is_ok());
        assert!(DType::parse("f64").is_err());
    }
}
