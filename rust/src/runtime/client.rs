//! PJRT runtime: load HLO-text artifacts, bind weights, execute.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1, CPU PJRT) behind a small API
//! the engine layer uses on the request path.  One `Runtime` per process;
//! one `LoadedModel` per (executable, weight-group) pair.  Weights are
//! uploaded to the device **once** at load time (`PjRtBuffer`s) and reused
//! by every `execute_b` call, so the request path only transfers the small
//! dynamic inputs (tokens / KV handles).

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{DType, ExecutableSpec, Manifest, TensorSpec};
use super::weights::WeightStore;

/// Host-side tensor passed into / received from an executable.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(_) => DType::F32,
            HostTensor::I32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn zeros(spec: &TensorSpec) -> HostTensor {
        match spec.dtype {
            DType::F32 => HostTensor::F32(vec![0.0; spec.n_elems()]),
            DType::I32 => HostTensor::I32(vec![0; spec.n_elems()]),
        }
    }
}

/// Process-wide PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Arc<Runtime>> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Arc::new(Runtime { client }))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text artifact.
    pub fn load_hlo(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))
    }

    fn upload(&self, t: &HostTensor, shape: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(match t {
            HostTensor::F32(v) => {
                self.client.buffer_from_host_buffer::<f32>(v, shape, None)?
            }
            HostTensor::I32(v) => {
                self.client.buffer_from_host_buffer::<i32>(v, shape, None)?
            }
        })
    }
}

/// A compiled executable with its weights resident on the device.
pub struct LoadedModel {
    pub spec: ExecutableSpec,
    rt: Arc<Runtime>,
    exe: xla::PjRtLoadedExecutable,
    weight_buffers: Vec<xla::PjRtBuffer>,
    /// cumulative execute() wall time, for profiling
    pub exec_calls: std::cell::Cell<u64>,
    pub exec_nanos: std::cell::Cell<u128>,
}

impl LoadedModel {
    /// Load `exe_name` from the manifest, compiling the HLO and uploading
    /// the given weight group (defaults to the manifest's group).
    pub fn load(
        rt: Arc<Runtime>,
        manifest: &Manifest,
        store: &WeightStore,
        exe_name: &str,
        weights_group: Option<&str>,
    ) -> Result<LoadedModel> {
        let spec = manifest.executable(exe_name)?.clone();
        let exe = rt.load_hlo(&manifest.hlo_path(&spec))?;
        let group = weights_group.unwrap_or(&spec.weights_group);
        let tensors = store.group(group)?;
        let mut weight_buffers = Vec::with_capacity(tensors.len());
        for (spec_w, tensor) in tensors {
            weight_buffers.push(rt.upload(tensor, &spec_w.shape)?);
        }
        Ok(LoadedModel {
            spec,
            rt,
            exe,
            weight_buffers,
            exec_calls: std::cell::Cell::new(0),
            exec_nanos: std::cell::Cell::new(0),
        })
    }

    /// Execute with dynamic inputs (device-resident weights prepended).
    /// Inputs must match `spec.inputs` order/shape/dtype.
    pub fn execute(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let t0 = Instant::now();
        let mut input_buffers = Vec::with_capacity(inputs.len());
        for (t, spec) in inputs.iter().zip(&self.spec.inputs) {
            if t.dtype() != spec.dtype || t.len() != spec.n_elems() {
                bail!(
                    "{}: input {} mismatch (got {} elems {:?}, want {} {:?})",
                    self.spec.name,
                    spec.name,
                    t.len(),
                    t.dtype(),
                    spec.n_elems(),
                    spec.dtype
                );
            }
            input_buffers.push(self.rt.upload(t, &spec.shape)?);
        }
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(self.weight_buffers.len() + input_buffers.len());
        args.extend(self.weight_buffers.iter());
        args.extend(input_buffers.iter());

        let result = self.exe.execute_b(&args)?;
        let lit = result[0][0].to_literal_sync()?;
        self.exec_calls.set(self.exec_calls.get() + 1);
        self.exec_nanos
            .set(self.exec_nanos.get() + t0.elapsed().as_nanos());
        // aot.py lowers with return_tuple=True: a single tuple of outputs.
        let parts = lit.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.spec.name,
                self.spec.outputs.len(),
                parts.len()
            );
        }
        parts
            .iter()
            .zip(&self.spec.outputs)
            .map(|(l, s)| from_literal(l, s))
            .collect()
    }

    pub fn avg_exec_ms(&self) -> f64 {
        let calls = self.exec_calls.get();
        if calls == 0 {
            return 0.0;
        }
        self.exec_nanos.get() as f64 / calls as f64 / 1e6
    }
}

fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<HostTensor> {
    let t = match spec.dtype {
        DType::F32 => HostTensor::F32(lit.to_vec::<f32>()?),
        DType::I32 => HostTensor::I32(lit.to_vec::<i32>()?),
    };
    if t.len() != spec.n_elems() {
        bail!(
            "output {}: expected {} elems, got {}",
            spec.name,
            spec.n_elems(),
            t.len()
        );
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::TensorSpec;

    fn spec(shape: &[usize], dtype: DType) -> TensorSpec {
        TensorSpec { name: "t".into(), shape: shape.to_vec(), dtype }
    }

    #[test]
    fn host_tensor_zeroes_and_accessors() {
        let t = HostTensor::zeros(&spec(&[2, 3], DType::F32));
        assert_eq!(t.len(), 6);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
        let t = HostTensor::zeros(&spec(&[4], DType::I32));
        assert_eq!(t.as_i32().unwrap(), &[0; 4]);
    }

    #[test]
    fn dtype_roundtrip() {
        assert_eq!(HostTensor::F32(vec![1.0]).dtype(), DType::F32);
        assert_eq!(HostTensor::I32(vec![1]).dtype(), DType::I32);
    }
}
