//! Online summary statistics and percentile digests for metrics reporting.

/// Welford online mean/variance plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let d = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += d * n2 / n;
        self.m2 += other.m2 + d * d * n1 * n2 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exact percentile digest (stores samples; fine at benchmark scale).
#[derive(Debug, Clone, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn new() -> Self {
        Percentiles { xs: Vec::new(), sorted: true }
    }

    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Linear-interpolated quantile, q in [0, 1].
    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let q = q.clamp(0.0, 1.0);
        let pos = q * (self.xs.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let w = pos - lo as f64;
            self.xs[lo] * (1.0 - w) + self.xs[hi] * w
        }
    }

    pub fn p50(&mut self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            f64::NAN
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }
}

/// Histogram with fixed-width bins for distribution dumps (Fig 4 PDF plot).
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub width: f64,
    pub counts: Vec<u64>,
    pub total: u64,
    pub out_of_range: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram {
            lo,
            width: (hi - lo) / bins as f64,
            counts: vec![0; bins],
            total: 0,
            out_of_range: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        let idx = ((x - self.lo) / self.width).floor();
        if idx >= 0.0 && (idx as usize) < self.counts.len() {
            self.counts[idx as usize] += 1;
            self.total += 1;
        } else {
            self.out_of_range += 1;
        }
    }

    /// Empirical density of bin i (normalised so ∑ density·width == kept
    /// fraction).
    pub fn density(&self, i: usize) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.counts[i] as f64 / ((self.total + self.out_of_range) as f64 * self.width)
    }

    pub fn bin_center(&self, i: usize) -> f64 {
        self.lo + (i as f64 + 0.5) * self.width
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn summary_merge_equals_sequential() {
        let data: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Summary::new();
        for &x in &data {
            all.add(x);
        }
        let mut a = Summary::new();
        let mut b = Summary::new();
        for &x in &data[..37] {
            a.add(x);
        }
        for &x in &data[37..] {
            b.add(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.var() - all.var()).abs() < 1e-9);
        assert_eq!(a.count(), all.count());
    }

    #[test]
    fn percentiles() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.add(i as f64);
        }
        assert!((p.p50() - 50.5).abs() < 1e-9);
        assert!((p.quantile(0.0) - 1.0).abs() < 1e-12);
        assert!((p.quantile(1.0) - 100.0).abs() < 1e-12);
        assert!(p.p99() > 98.0);
    }

    #[test]
    fn percentile_single_element() {
        let mut p = Percentiles::new();
        p.add(7.0);
        assert_eq!(p.p50(), 7.0);
        assert_eq!(p.p99(), 7.0);
    }

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(100.0);
        assert_eq!(h.total, 10);
        assert_eq!(h.out_of_range, 2);
        assert!(h.counts.iter().all(|&c| c == 1));
        assert!((h.bin_center(0) - 0.5).abs() < 1e-12);
    }
}
