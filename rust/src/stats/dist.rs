//! Distribution samplers and densities.
//!
//! The paper's workload generator samples request inter-arrival times from
//! Gamma(α=0.73, β=10.41) fitted to the FabriX trace (Fig 4); the Poisson /
//! exponential alternatives are the baselines it compares against.  All
//! samplers are built on `Pcg64` (no rand_distr offline).

use super::rng::Pcg64;

/// Standard normal via Marsaglia polar method.
pub fn normal(rng: &mut Pcg64, mean: f64, std: f64) -> f64 {
    loop {
        let u = rng.range_f64(-1.0, 1.0);
        let v = rng.range_f64(-1.0, 1.0);
        let s = u * u + v * v;
        if s > 0.0 && s < 1.0 {
            let z = u * (-2.0 * s.ln() / s).sqrt();
            return mean + std * z;
        }
    }
}

/// Exponential with the given mean.
pub fn exponential(rng: &mut Pcg64, mean: f64) -> f64 {
    -mean * (1.0 - rng.f64()).ln()
}

/// Gamma(shape α, scale β) via Marsaglia–Tsang, with the Johnk boost for
/// α < 1 (the FabriX fit has α = 0.73, so this path matters).
pub fn gamma(rng: &mut Pcg64, shape: f64, scale: f64) -> f64 {
    assert!(shape > 0.0 && scale > 0.0);
    if shape < 1.0 {
        // boost: X ~ Gamma(α+1), U^(1/α) * X ~ Gamma(α)
        let x = gamma(rng, shape + 1.0, 1.0);
        let u: f64 = rng.f64().max(f64::MIN_POSITIVE);
        return scale * x * u.powf(1.0 / shape);
    }
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let z = normal(rng, 0.0, 1.0);
        let v = 1.0 + c * z;
        if v <= 0.0 {
            continue;
        }
        let v3 = v * v * v;
        let u: f64 = rng.f64().max(f64::MIN_POSITIVE);
        if u.ln() < 0.5 * z * z + d - d * v3 + d * v3.ln() {
            return scale * d * v3;
        }
    }
}

/// Log-normal with parameters of the underlying normal.
pub fn lognormal(rng: &mut Pcg64, mu: f64, sigma: f64) -> f64 {
    normal(rng, mu, sigma).exp()
}

/// Poisson(λ) — Knuth for small λ, PTRS-lite (normal approx + correction)
/// for large λ.
pub fn poisson(rng: &mut Pcg64, lambda: f64) -> u64 {
    assert!(lambda >= 0.0);
    if lambda < 30.0 {
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= rng.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    } else {
        // normal approximation with continuity correction — adequate for
        // workload generation at high rates
        let x = normal(rng, lambda, lambda.sqrt());
        x.max(0.0).round() as u64
    }
}

// ----------------------------- densities -------------------------------

/// ln Γ(x) — Lanczos approximation (g=7, n=9).
pub fn ln_gamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Digamma ψ(x) via asymptotic series with recurrence shift.
pub fn digamma(mut x: f64) -> f64 {
    let mut result = 0.0;
    while x < 6.0 {
        result -= 1.0 / x;
        x += 1.0;
    }
    let inv = 1.0 / x;
    let inv2 = inv * inv;
    result + x.ln() - 0.5 * inv
        - inv2 * (1.0 / 12.0 - inv2 * (1.0 / 120.0 - inv2 / 252.0))
}

/// Trigamma ψ'(x).
pub fn trigamma(mut x: f64) -> f64 {
    let mut result = 0.0;
    while x < 6.0 {
        result += 1.0 / (x * x);
        x += 1.0;
    }
    let inv = 1.0 / x;
    let inv2 = inv * inv;
    result + inv * (1.0 + 0.5 * inv + inv2 * (1.0 / 6.0 - inv2 * (1.0 / 30.0 - inv2 / 42.0)))
}

/// Gamma(α, β) log-density.
pub fn gamma_logpdf(x: f64, shape: f64, scale: f64) -> f64 {
    if x <= 0.0 {
        return f64::NEG_INFINITY;
    }
    (shape - 1.0) * x.ln() - x / scale - ln_gamma(shape) - shape * scale.ln()
}

/// Exponential(mean) log-density (the interval view of a Poisson process).
pub fn exp_logpdf(x: f64, mean: f64) -> f64 {
    if x < 0.0 {
        return f64::NEG_INFINITY;
    }
    -mean.ln() - x / mean
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moments(samples: &[f64]) -> (f64, f64) {
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
        (mean, var)
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(1);
        let s: Vec<f64> = (0..50_000).map(|_| normal(&mut r, 3.0, 2.0)).collect();
        let (m, v) = moments(&s);
        assert!((m - 3.0).abs() < 0.05, "mean {m}");
        assert!((v - 4.0).abs() < 0.15, "var {v}");
    }

    #[test]
    fn gamma_moments_alpha_below_one() {
        // the FabriX regime: α < 1
        let (a, b) = (0.73, 10.41);
        let mut r = Pcg64::new(2);
        let s: Vec<f64> = (0..100_000).map(|_| gamma(&mut r, a, b)).collect();
        let (m, v) = moments(&s);
        assert!((m - a * b).abs() / (a * b) < 0.03, "mean {m} vs {}", a * b);
        assert!((v - a * b * b).abs() / (a * b * b) < 0.06, "var {v}");
        assert!(s.iter().all(|x| *x >= 0.0));
    }

    #[test]
    fn gamma_moments_alpha_above_one() {
        let (a, b) = (4.0, 2.0);
        let mut r = Pcg64::new(3);
        let s: Vec<f64> = (0..50_000).map(|_| gamma(&mut r, a, b)).collect();
        let (m, v) = moments(&s);
        assert!((m - 8.0).abs() < 0.1);
        assert!((v - 16.0).abs() < 0.5);
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg64::new(4);
        let s: Vec<f64> = (0..50_000).map(|_| exponential(&mut r, 5.0)).collect();
        let (m, _) = moments(&s);
        assert!((m - 5.0).abs() < 0.1);
    }

    #[test]
    fn poisson_small_lambda() {
        let mut r = Pcg64::new(5);
        let s: Vec<f64> = (0..50_000).map(|_| poisson(&mut r, 3.5) as f64).collect();
        let (m, v) = moments(&s);
        assert!((m - 3.5).abs() < 0.05);
        assert!((v - 3.5).abs() < 0.15);
    }

    #[test]
    fn poisson_large_lambda() {
        let mut r = Pcg64::new(6);
        let s: Vec<f64> = (0..20_000).map(|_| poisson(&mut r, 200.0) as f64).collect();
        let (m, _) = moments(&s);
        assert!((m - 200.0).abs() < 1.0);
    }

    #[test]
    fn ln_gamma_known_values() {
        assert!((ln_gamma(1.0)).abs() < 1e-10);
        assert!((ln_gamma(2.0)).abs() < 1e-10);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-9);
        assert!((ln_gamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-9);
    }

    #[test]
    fn digamma_recurrence() {
        // ψ(x+1) = ψ(x) + 1/x
        for &x in &[0.3, 0.73, 1.0, 2.5, 10.0] {
            let lhs = digamma(x + 1.0);
            let rhs = digamma(x) + 1.0 / x;
            assert!((lhs - rhs).abs() < 1e-8, "x={x}");
        }
    }

    #[test]
    fn trigamma_known() {
        // ψ'(1) = π²/6
        let expect = std::f64::consts::PI.powi(2) / 6.0;
        assert!((trigamma(1.0) - expect).abs() < 1e-7);
    }

    #[test]
    fn gamma_logpdf_integrates_to_one() {
        // crude Riemann check
        let (a, b) = (0.73, 10.41);
        let dx = 0.01;
        let total: f64 = (1..200_000)
            .map(|i| (i as f64 * dx, gamma_logpdf(i as f64 * dx, a, b).exp()))
            .map(|(_, p)| p * dx)
            .sum();
        assert!((total - 1.0).abs() < 0.01, "total {total}");
    }
}
