//! RNG, distributions, fitting, and summary statistics substrate.
pub mod dist;
pub mod fit;
pub mod rng;
pub mod summary;
