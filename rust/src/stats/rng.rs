//! PCG64 pseudo-random generator.
//!
//! The offline toolchain ships only `rand_core`, so the generator and every
//! distribution sampler are implemented here.  PCG-XSL-RR 128/64 (the same
//! algorithm behind numpy's default_rng) — fast, 2^128 period, and good
//! statistical quality for workload simulation.

#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        // splitmix the seed into 128-bit state/stream
        let mut sm = SplitMix64 { s: seed };
        let state = ((sm.next() as u128) << 64) | sm.next() as u128;
        let inc = (((sm.next() as u128) << 64) | sm.next() as u128) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_add(state);
        rng.next_u64();
        rng
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        // XSL-RR output function
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    /// Sample k distinct indices from 0..n (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

struct SplitMix64 {
    s: u64,
}

impl SplitMix64 {
    fn next(&mut self) -> u64 {
        self.s = self.s.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.s;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Pcg64::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Pcg64::new(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn int_range_bounds() {
        let mut r = Pcg64::new(11);
        for _ in 0..1000 {
            let x = r.int_range(-3, 3);
            assert!((-3..=3).contains(&x));
        }
        assert_eq!(r.int_range(5, 5), 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(13);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Pcg64::new(21);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
