//! Distribution fitting + regression metrics.
//!
//! `fit_gamma` reproduces the paper's Fig 4 analysis: MLE of Gamma shape and
//! scale on inter-arrival samples via Newton iteration on the digamma
//! equation.  `fit_exponential` is the Poisson-process alternative the paper
//! rejects; log-likelihood comparison decides the winner.

use super::dist::{digamma, exp_logpdf, gamma_logpdf, trigamma};

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GammaFit {
    pub shape: f64,
    pub scale: f64,
    pub loglik: f64,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExpFit {
    pub mean: f64,
    pub loglik: f64,
}

/// MLE Gamma fit.  Solves ln(α) − ψ(α) = ln(mean) − mean(ln x) by Newton,
/// starting from the Minka closed-form approximation.
pub fn fit_gamma(samples: &[f64]) -> Option<GammaFit> {
    let xs: Vec<f64> = samples.iter().copied().filter(|x| *x > 0.0).collect();
    if xs.len() < 8 {
        return None;
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let mean_ln = xs.iter().map(|x| x.ln()).sum::<f64>() / n;
    let s = mean.ln() - mean_ln;
    if s <= 0.0 {
        return None; // degenerate (all samples equal)
    }
    // Minka initialisation
    let mut alpha = (3.0 - s + ((s - 3.0).powi(2) + 24.0 * s).sqrt()) / (12.0 * s);
    for _ in 0..60 {
        let f = alpha.ln() - digamma(alpha) - s;
        let fp = 1.0 / alpha - trigamma(alpha);
        let step = f / fp;
        let next = alpha - step;
        let next = if next <= 0.0 { alpha / 2.0 } else { next };
        if (next - alpha).abs() < 1e-12 * alpha.max(1.0) {
            alpha = next;
            break;
        }
        alpha = next;
    }
    let scale = mean / alpha;
    let loglik = xs.iter().map(|x| gamma_logpdf(*x, alpha, scale)).sum();
    Some(GammaFit { shape: alpha, scale, loglik })
}

/// MLE exponential fit (a Poisson arrival process seen through intervals).
pub fn fit_exponential(samples: &[f64]) -> Option<ExpFit> {
    let xs: Vec<f64> = samples.iter().copied().filter(|x| *x >= 0.0).collect();
    if xs.is_empty() {
        return None;
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    if mean <= 0.0 {
        return None;
    }
    let loglik = xs.iter().map(|x| exp_logpdf(*x, mean)).sum();
    Some(ExpFit { mean, loglik })
}

/// Akaike information criterion (lower is better).
pub fn aic(loglik: f64, k_params: usize) -> f64 {
    2.0 * k_params as f64 - 2.0 * loglik
}

// ------------------------- regression metrics --------------------------

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RegressionMetrics {
    pub mae: f64,
    pub rmse: f64,
    pub r2: f64,
    pub n: usize,
}

/// MAE / RMSE / R² (paper Table 2 metrics).
pub fn regression_metrics(pred: &[f64], truth: &[f64]) -> RegressionMetrics {
    assert_eq!(pred.len(), truth.len());
    assert!(!pred.is_empty());
    let n = pred.len() as f64;
    let mae = pred
        .iter()
        .zip(truth)
        .map(|(p, t)| (p - t).abs())
        .sum::<f64>()
        / n;
    let mse = pred
        .iter()
        .zip(truth)
        .map(|(p, t)| (p - t).powi(2))
        .sum::<f64>()
        / n;
    let mean_t = truth.iter().sum::<f64>() / n;
    let ss_tot: f64 = truth.iter().map(|t| (t - mean_t).powi(2)).sum();
    let ss_res: f64 = pred.iter().zip(truth).map(|(p, t)| (p - t).powi(2)).sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { f64::NAN };
    RegressionMetrics { mae, rmse: mse.sqrt(), r2, n: pred.len() }
}

/// Ordinary least squares y = a + b·x; returns (a, b).
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for (xi, yi) in x.iter().zip(y) {
        sxy += (xi - mx) * (yi - my);
        sxx += (xi - mx) * (xi - mx);
    }
    let b = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    (my - b * mx, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::dist::gamma;
    use crate::stats::rng::Pcg64;

    #[test]
    fn gamma_fit_recovers_fabrix_params() {
        // the paper's fitted parameters
        let (a, b) = (0.73, 10.41);
        let mut r = Pcg64::new(17);
        let samples: Vec<f64> = (0..200_000).map(|_| gamma(&mut r, a, b)).collect();
        let fit = fit_gamma(&samples).unwrap();
        assert!((fit.shape - a).abs() < 0.02, "shape {}", fit.shape);
        assert!((fit.scale - b).abs() < 0.35, "scale {}", fit.scale);
    }

    #[test]
    fn gamma_beats_exponential_on_gamma_data() {
        let mut r = Pcg64::new(18);
        let samples: Vec<f64> = (0..50_000).map(|_| gamma(&mut r, 0.73, 10.41)).collect();
        let g = fit_gamma(&samples).unwrap();
        let e = fit_exponential(&samples).unwrap();
        assert!(g.loglik > e.loglik, "gamma {} vs exp {}", g.loglik, e.loglik);
        assert!(aic(g.loglik, 2) < aic(e.loglik, 1));
    }

    #[test]
    fn exponential_ties_on_exponential_data() {
        // Gamma(1, β) == Exp(β): fitted shape should be ~1
        let mut r = Pcg64::new(19);
        let samples: Vec<f64> = (0..100_000).map(|_| gamma(&mut r, 1.0, 4.0)).collect();
        let g = fit_gamma(&samples).unwrap();
        assert!((g.shape - 1.0).abs() < 0.03, "shape {}", g.shape);
    }

    #[test]
    fn fit_rejects_degenerate() {
        assert!(fit_gamma(&[2.0; 100]).is_none());
        assert!(fit_gamma(&[1.0, 2.0]).is_none());
        assert!(fit_exponential(&[]).is_none());
    }

    #[test]
    fn regression_metrics_perfect() {
        let y = [1.0, 2.0, 3.0];
        let m = regression_metrics(&y, &y);
        assert_eq!(m.mae, 0.0);
        assert_eq!(m.rmse, 0.0);
        assert!((m.r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn regression_metrics_mean_predictor_r2_zero() {
        let truth = [1.0, 2.0, 3.0, 4.0];
        let pred = [2.5; 4];
        let m = regression_metrics(&pred, &truth);
        assert!(m.r2.abs() < 1e-12);
        assert!(m.rmse >= m.mae);
    }

    #[test]
    fn linear_fit_exact() {
        let x = [0.0, 1.0, 2.0, 3.0];
        let y = [1.0, 3.0, 5.0, 7.0];
        let (a, b) = linear_fit(&x, &y);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
    }
}
