//! L3 coordinator — the paper's system contribution.
//!
//! Components map 1:1 onto Figure 3 / Algorithm 1 of the paper:
//! * [`job`] — the frontend's internal request record.
//! * [`scheduler`] — FCFS / SJF / **ISRTF** / SRPT / MLFQ priority policies.
//! * [`priority_buffer`] — per-node priority queues.
//! * [`batcher`] — window batching (prompts sent once).
//! * [`load_balancer`] — min-load greedy assignment over global state `G`.
//! * [`preemption`] — frequency control + starvation guard (§3.4).
//! * [`frontend`] — the serving loop tying it together, in virtual or wall
//!   clock mode.

pub mod batcher;
pub mod frontend;
pub mod job;
pub mod load_balancer;
pub mod preemption;
pub mod priority_buffer;
pub mod scheduler;

pub use frontend::{run_serving, ClockMode, ServeConfig};
pub use job::{Job, JobState};
pub use load_balancer::{GlobalState, LbStrategy, LoadBalancer};
pub use preemption::PreemptionPolicy;
pub use scheduler::{Policy, Scheduler};
