//! L3 coordinator — the paper's system contribution, exposed as a stepped,
//! event-driven API.
//!
//! The centre of the layer is [`serving::Coordinator`]: it owns the job
//! table, per-node queues, load balancer, priority buffer, batcher, and
//! preemption policy, and decomposes Algorithm 1 into composable steps
//! (`ingest` → `poll_completions` → `dispatch` → `step` →
//! `run_to_completion`).  Construction goes through
//! [`CoordinatorBuilder`], which extends [`ServeConfig`] with
//! [`EventSink`] observers so metrics, logging, and scheduling-policy
//! experiments can watch the loop without modifying it.
//!
//! Components map 1:1 onto Figure 3 / Algorithm 1 of the paper:
//! * [`job`] — [`JobId`]-keyed dense [`JobTable`] of request records.
//! * [`scheduler`] — FCFS / SJF / **ISRTF** / SRPT / MLFQ priority policies
//!   (aged per-window keys for the rebuild path, time-invariant folded
//!   keys for the incremental index).
//! * [`priority_buffer`] — per-node priority queues with a fully
//!   deterministic (priority, arrival, id) order; persistent across
//!   windows in the default incremental dispatch mode, with per-tenant
//!   [`TenantQueues`] lanes when a foldable shaper keys the index.
//! * [`shards`] — the persistent [`DispatchShards`] planner pool behind
//!   `--dispatch-shards`: per-node plan work fans out, apply stays
//!   serial, reports stay bit-identical at any shard count.
//! * [`batcher`] — window batching (prompts sent once).
//! * [`load_balancer`] — min-load greedy assignment over global state `G`.
//! * [`preemption`] — frequency control + starvation guard (§3.4).
//! * [`events`] — the observer hooks (admitted / batch / window /
//!   finished / preempted).
//! * [`serving`] — the stepped coordinator tying it together, in virtual
//!   or wall clock mode.
//! * [`frontend`] — compatibility wrapper: the original [`run_serving`]
//!   one-call entry point and the Fig 7 peak-rate search.

pub mod batcher;
pub mod events;
pub mod frontend;
pub mod job;
pub mod load_balancer;
pub mod preemption;
pub mod priority_buffer;
pub mod scheduler;
pub mod serving;
pub mod shards;

pub use events::{DecisionRecord, EventCounter, EventSink, FinishStats,
                 JobMeta, PodExec, SharedCounter, WindowEvents,
                 WindowJobEvent};
pub use frontend::{peak_rps_search, run_serving};
pub use job::{Job, JobId, JobState, JobTable};
pub use load_balancer::{GlobalState, LbStrategy, LoadBalancer};
pub use preemption::PreemptionPolicy;
pub use priority_buffer::TenantQueues;
pub use scheduler::{FoldedShaper, Policy, PriorityShaper, Scheduler};
pub use shards::DispatchShards;
pub use serving::{ClockMode, Coordinator, CoordinatorBuilder, ServeConfig,
                  StepOutcome};
