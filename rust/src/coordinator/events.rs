//! Observer hooks for the stepped [`Coordinator`](super::Coordinator) API.
//!
//! An [`EventSink`] receives a callback at every state transition of the
//! serving loop: job admitted, batch formed, window done, job finished,
//! job preempted.  Sinks are registered on the
//! [`CoordinatorBuilder`](super::CoordinatorBuilder) and called
//! synchronously from inside the loop, so they see events in exact causal
//! order with the coordinator's own timestamps (virtual or wall ms).
//!
//! This is the extension point the ROADMAP's follow-on scenarios hang off:
//! SLO-aware scheduling (watch per-job latency as windows complete),
//! streaming admission control (watch queue growth at admit time),
//! multi-tenant fairness accounting, structured logging, and live metrics
//! export — none of which need to touch the serving loop itself.

use super::job::JobId;

/// Receiver for coordinator lifecycle events.  All methods default to
/// no-ops; implement only what you need.  Times are coordinator time
/// (virtual ms in [`ClockMode::Virtual`](super::ClockMode), wall ms since
/// serving start otherwise).
pub trait EventSink {
    /// A job arrived and was assigned to `node` by the load balancer.
    fn on_job_admitted(&mut self, _job: JobId, _node: usize, _now_ms: f64) {}

    /// A batch was formed for `node` (jobs in priority order) and is about
    /// to execute one scheduling window.
    fn on_batch_formed(&mut self, _node: usize, _jobs: &[JobId],
                       _now_ms: f64) {}

    /// A scheduling window completed on `node` after `service_ms`.
    fn on_window_done(&mut self, _node: usize, _batch: &[JobId],
                      _service_ms: f64, _now_ms: f64) {}

    /// A job produced its full response; `jct_ms` is its completion time.
    fn on_job_finished(&mut self, _job: JobId, _node: usize, _jct_ms: f64,
                       _now_ms: f64) {}

    /// The engine evicted a job's KV during the last window.
    fn on_job_preempted(&mut self, _job: JobId, _node: usize, _now_ms: f64) {}
}

/// Counts every event kind — handy for tests, sanity checks, and quick
/// telemetry without a metrics stack.
#[derive(Debug, Default, Clone)]
pub struct EventCounter {
    pub admitted: u64,
    pub batches: u64,
    pub windows: u64,
    pub finished: u64,
    pub preempted: u64,
}

impl EventSink for EventCounter {
    fn on_job_admitted(&mut self, _job: JobId, _node: usize, _now_ms: f64) {
        self.admitted += 1;
    }

    fn on_batch_formed(&mut self, _node: usize, _jobs: &[JobId],
                       _now_ms: f64) {
        self.batches += 1;
    }

    fn on_window_done(&mut self, _node: usize, _batch: &[JobId],
                      _service_ms: f64, _now_ms: f64) {
        self.windows += 1;
    }

    fn on_job_finished(&mut self, _job: JobId, _node: usize, _jct_ms: f64,
                       _now_ms: f64) {
        self.finished += 1;
    }

    fn on_job_preempted(&mut self, _job: JobId, _node: usize, _now_ms: f64) {
        self.preempted += 1;
    }
}

/// Shared-cell wrapper so a caller can keep reading a sink it handed to the
/// builder (sinks are boxed into the coordinator).
#[derive(Debug, Default, Clone)]
pub struct SharedCounter(std::rc::Rc<std::cell::RefCell<EventCounter>>);

impl SharedCounter {
    pub fn new() -> SharedCounter {
        SharedCounter::default()
    }

    pub fn snapshot(&self) -> EventCounter {
        self.0.borrow().clone()
    }
}

impl EventSink for SharedCounter {
    fn on_job_admitted(&mut self, job: JobId, node: usize, now_ms: f64) {
        self.0.borrow_mut().on_job_admitted(job, node, now_ms);
    }

    fn on_batch_formed(&mut self, node: usize, jobs: &[JobId], now_ms: f64) {
        self.0.borrow_mut().on_batch_formed(node, jobs, now_ms);
    }

    fn on_window_done(&mut self, node: usize, batch: &[JobId],
                      service_ms: f64, now_ms: f64) {
        self.0.borrow_mut().on_window_done(node, batch, service_ms, now_ms);
    }

    fn on_job_finished(&mut self, job: JobId, node: usize, jct_ms: f64,
                       now_ms: f64) {
        self.0.borrow_mut().on_job_finished(job, node, jct_ms, now_ms);
    }

    fn on_job_preempted(&mut self, job: JobId, node: usize, now_ms: f64) {
        self.0.borrow_mut().on_job_preempted(job, node, now_ms);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_counts() {
        let mut c = EventCounter::default();
        c.on_job_admitted(JobId::new(0), 0, 0.0);
        c.on_job_admitted(JobId::new(1), 0, 1.0);
        c.on_batch_formed(0, &[JobId::new(0)], 2.0);
        c.on_window_done(0, &[JobId::new(0)], 50.0, 52.0);
        c.on_job_finished(JobId::new(0), 0, 52.0, 52.0);
        c.on_job_preempted(JobId::new(1), 0, 52.0);
        assert_eq!((c.admitted, c.batches, c.windows, c.finished, c.preempted),
                   (2, 1, 1, 1, 1));
    }

    #[test]
    fn shared_counter_reads_through_clone() {
        let shared = SharedCounter::new();
        let mut handle = shared.clone();
        handle.on_job_admitted(JobId::new(3), 1, 0.0);
        handle.on_job_finished(JobId::new(3), 1, 9.0, 9.0);
        let snap = shared.snapshot();
        assert_eq!(snap.admitted, 1);
        assert_eq!(snap.finished, 1);
    }
}
