//! Observer hooks for the stepped [`Coordinator`](super::Coordinator) API.
//!
//! An [`EventSink`] receives a callback at every state transition of the
//! serving loop: job admitted, batch formed, window done, job finished,
//! job preempted.  Sinks are registered on the
//! [`CoordinatorBuilder`](super::CoordinatorBuilder) and called
//! synchronously from inside the loop, so they see events in exact causal
//! order with the coordinator's own timestamps (virtual or wall ms).
//!
//! Job-scoped events carry a [`JobMeta`] — the job's identity, tenant tag,
//! and size facts — so sinks can do per-tenant accounting without access
//! to the job table; completions additionally carry [`FinishStats`] with
//! the latency measurements.  This is the extension point the ROADMAP's
//! follow-on scenarios hang off: the live telemetry subsystem
//! ([`telemetry`](crate::telemetry)) builds its streaming sketches,
//! Prometheus export, and SLO policy feedback entirely from these hooks.

use super::job::JobId;

/// Immutable facts about a job, passed alongside lifecycle events.
#[derive(Debug, Clone, Copy)]
pub struct JobMeta<'a> {
    pub id: JobId,
    /// accounting tag threaded from `TraceRequest::tenant`
    pub tenant: Option<&'a str>,
    pub arrival_ms: f64,
    pub prompt_len: usize,
    pub total_len: usize,
}

/// Latency measurements delivered with [`EventSink::on_job_finished`].
#[derive(Debug, Clone, Copy)]
pub struct FinishStats {
    /// completion time: finish − arrival
    pub jct_ms: f64,
    /// None if the job finished without emitting tokens (engine anomaly)
    pub ttft_ms: Option<f64>,
    pub queue_delay_ms: f64,
    /// cumulative time inside executing batches
    pub service_ms: f64,
    /// response tokens generated
    pub tokens: usize,
    /// the scheduler's last predicted *total* response length for this
    /// job, captured before the prediction cache forgets it — `None`
    /// under policies that never consult the predictor (FCFS, MLFQ).
    /// Compared against `tokens`, this is the live predictor-accuracy
    /// signal the recalibration path consumes.
    pub predicted_total: Option<f64>,
}

/// A worker pod's own measurement of one executed window, stitched back
/// into the coordinator timeline over the wire ([`WindowDone`]'s optional
/// trace reply).  Proves which process actually ran the window.
///
/// [`WindowDone`]: crate::cluster::pool::WindowDone
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PodExec {
    /// the coordinator's window sequence number, echoed from the command
    pub window: u64,
    /// wall time the pod spent executing the window, ms
    pub exec_ms: f64,
    /// the executing process id (the pod's, not the coordinator's)
    pub pid: u32,
}

/// One job-scoped event inside a finished scheduling window, in causal
/// order.  Delivered in bulk via [`EventSink::on_window_applied`] so sinks
/// that guard shared state (e.g. the telemetry sink's `Arc<Mutex>`) can
/// take their lock once per window instead of once per job per window.
#[derive(Debug, Clone, Copy)]
pub enum WindowJobEvent<'a> {
    /// the job produced `tokens` inside the window (the actual token ids,
    /// borrowed from the job's response tail — this is what end-to-end
    /// streaming forwards to clients)
    Progress { job: JobMeta<'a>, tokens: &'a [i32] },
    /// the job produced its full response
    Finished { job: JobMeta<'a>, stats: FinishStats },
    /// the engine evicted the job's KV during the window
    Preempted { job: JobId },
}

/// Everything one finished scheduling window did, delivered as a single
/// [`EventSink::on_window_applied`] call per sink.
#[derive(Debug, Clone, Copy)]
pub struct WindowEvents<'a> {
    pub node: usize,
    /// the window's batch (jobs in priority order)
    pub batch: &'a [JobId],
    /// per-job events in the exact order the per-event hooks would fire
    pub events: &'a [WindowJobEvent<'a>],
    /// tokens produced across the batch
    pub tokens: usize,
    pub service_ms: f64,
    pub now_ms: f64,
    /// the executing pod's own span measurement, when the window ran on a
    /// remote worker that echoed trace fields (`None` on the in-process
    /// and virtual-clock paths)
    pub pod: Option<PodExec>,
}

/// One per-window scheduler decision, fired at dispatch time (before the
/// window executes) via [`EventSink::on_window_decision`].  This is the
/// flight-recorder record that answers "why did job X wait": what the
/// queue looked like, who was picked, who was marked for eviction, and
/// what the decision itself cost.
#[derive(Debug, Clone, Copy)]
pub struct DecisionRecord<'a> {
    pub node: usize,
    /// the coordinator's window sequence number
    pub window: u64,
    pub now_ms: f64,
    /// jobs queued on the node before this batch was selected
    pub queue_depth: usize,
    /// the selected batch, in priority order
    pub batch: &'a [JobId],
    /// the batch-size cap the selection ran under (engine cap, possibly
    /// tightened by `ServeConfig::max_batch` on the rebuild path) — with
    /// `batch.len()` this is the window's occupancy context: a full batch
    /// (`batch.len() >= batch_cap`) with jobs still queued is the
    /// head-of-line blocking signature JCT attribution accounts for
    pub batch_cap: usize,
    /// preemption victim candidates (raw job ids, the engine's eviction
    /// order), best victim first
    pub victims: &'a [u64],
    /// which dispatch shard planned this window (0 when planning ran
    /// inline — single shard or the rebuild path)
    pub shard: usize,
    /// smallest folded priority key in the batch (NaN if unkeyed)
    pub key_min: f64,
    /// largest folded priority key in the batch (NaN if unkeyed)
    pub key_max: f64,
    /// wall time this scheduling decision took
    pub sched_overhead_ms: f64,
}

/// Receiver for coordinator lifecycle events.  All methods default to
/// no-ops; implement only what you need.  Times are coordinator time
/// (virtual ms in [`ClockMode::Virtual`](super::ClockMode), wall ms since
/// serving start otherwise).
pub trait EventSink {
    /// A job arrived and was assigned to `node` by the load balancer.
    fn on_job_admitted(&mut self, _job: &JobMeta<'_>, _node: usize,
                       _now_ms: f64) {
    }

    /// A batch was formed for `node` (jobs in priority order) and is about
    /// to execute one scheduling window.
    fn on_batch_formed(&mut self, _node: usize, _jobs: &[JobId],
                       _now_ms: f64) {
    }

    /// A scheduling window completed on `node` after `service_ms`,
    /// producing `tokens` new tokens across the batch.
    fn on_window_done(&mut self, _node: usize, _batch: &[JobId],
                      _tokens: usize, _service_ms: f64, _now_ms: f64) {
    }

    /// One job produced `new_tokens` tokens inside a window.  Fires once
    /// per producing job per window, *before* that job's
    /// [`on_job_finished`](Self::on_job_finished) on its final window —
    /// this is the live-throughput signal (per-tenant token accounting
    /// would otherwise only move at job completion, which starves
    /// fairness policies of in-flight service for long jobs).
    fn on_job_progress(&mut self, _job: &JobMeta<'_>, _node: usize,
                       _new_tokens: usize, _now_ms: f64) {
    }

    /// Same per-job per-window event as
    /// [`on_job_progress`](Self::on_job_progress), but carrying the actual
    /// token ids produced in the window (a view into the job's response
    /// tail).  Fires immediately before the count-based hook — sinks that
    /// forward content (token streaming) implement this one; sinks that
    /// only account throughput keep the cheaper count.
    fn on_job_tokens(&mut self, _job: &JobMeta<'_>, _node: usize,
                     _tokens: &[i32], _now_ms: f64) {
    }

    /// A job produced its full response.
    fn on_job_finished(&mut self, _job: &JobMeta<'_>, _node: usize,
                       _stats: &FinishStats, _now_ms: f64) {
    }

    /// The engine evicted a job's KV during the last window.
    fn on_job_preempted(&mut self, _job: JobId, _node: usize, _now_ms: f64) {}

    /// A pooled/remote worker became unreachable: its window (if any) was
    /// rolled back and `rehomed` of its jobs were re-balanced onto
    /// surviving workers.  May fire again for the same `node` if late
    /// spills surface after the first failover pass.
    fn on_worker_lost(&mut self, _node: usize, _rehomed: usize,
                      _now_ms: f64) {
    }

    /// A scheduling decision was made for `node`: the batch is formed and
    /// about to dispatch.  Fires once per dispatched window, *at dispatch
    /// time* (the matching [`on_window_applied`](Self::on_window_applied)
    /// lands when the window's results come back).  Carries the queue
    /// depth, selected batch, victim ranking, folded-key range, and the
    /// decision's own measured cost — the scheduler flight-recorder feed.
    fn on_window_decision(&mut self, _d: &DecisionRecord<'_>) {}

    /// A scheduling window finished and all of its per-job events are
    /// known.  The default implementation dispatches each event to the
    /// matching per-event hook (in causal order) and then fires
    /// [`on_window_done`](Self::on_window_done), so existing sinks see an
    /// unchanged stream.  Sinks that pay a per-call synchronization cost
    /// (lock, channel, syscall) should override this and handle the whole
    /// window in one critical section — the coordinator calls only this
    /// method for window-scoped events.
    fn on_window_applied(&mut self, w: &WindowEvents<'_>) {
        for ev in w.events {
            match ev {
                WindowJobEvent::Progress { job, tokens } => {
                    self.on_job_tokens(job, w.node, tokens, w.now_ms);
                    self.on_job_progress(job, w.node, tokens.len(), w.now_ms)
                }
                WindowJobEvent::Finished { job, stats } => {
                    self.on_job_finished(job, w.node, stats, w.now_ms)
                }
                WindowJobEvent::Preempted { job } => {
                    self.on_job_preempted(*job, w.node, w.now_ms)
                }
            }
        }
        self.on_window_done(w.node, w.batch, w.tokens, w.service_ms,
                            w.now_ms);
    }
}

/// Counts every event kind — handy for tests, sanity checks, and quick
/// telemetry without a metrics stack.
#[derive(Debug, Default, Clone)]
pub struct EventCounter {
    pub admitted: u64,
    pub batches: u64,
    pub windows: u64,
    pub finished: u64,
    pub preempted: u64,
}

impl EventSink for EventCounter {
    fn on_job_admitted(&mut self, _job: &JobMeta<'_>, _node: usize,
                       _now_ms: f64) {
        self.admitted += 1;
    }

    fn on_batch_formed(&mut self, _node: usize, _jobs: &[JobId],
                       _now_ms: f64) {
        self.batches += 1;
    }

    fn on_window_done(&mut self, _node: usize, _batch: &[JobId],
                      _tokens: usize, _service_ms: f64, _now_ms: f64) {
        self.windows += 1;
    }

    fn on_job_finished(&mut self, _job: &JobMeta<'_>, _node: usize,
                       _stats: &FinishStats, _now_ms: f64) {
        self.finished += 1;
    }

    fn on_job_preempted(&mut self, _job: JobId, _node: usize, _now_ms: f64) {
        self.preempted += 1;
    }
}

/// Shared-cell wrapper so a caller can keep reading a sink it handed to the
/// builder (sinks are boxed into the coordinator).
#[derive(Debug, Default, Clone)]
pub struct SharedCounter(std::rc::Rc<std::cell::RefCell<EventCounter>>);

impl SharedCounter {
    pub fn new() -> SharedCounter {
        SharedCounter::default()
    }

    pub fn snapshot(&self) -> EventCounter {
        self.0.borrow().clone()
    }
}

impl EventSink for SharedCounter {
    fn on_job_admitted(&mut self, job: &JobMeta<'_>, node: usize,
                       now_ms: f64) {
        self.0.borrow_mut().on_job_admitted(job, node, now_ms);
    }

    fn on_batch_formed(&mut self, node: usize, jobs: &[JobId], now_ms: f64) {
        self.0.borrow_mut().on_batch_formed(node, jobs, now_ms);
    }

    fn on_window_done(&mut self, node: usize, batch: &[JobId],
                      tokens: usize, service_ms: f64, now_ms: f64) {
        self.0.borrow_mut().on_window_done(node, batch, tokens, service_ms,
                                           now_ms);
    }

    fn on_job_finished(&mut self, job: &JobMeta<'_>, node: usize,
                       stats: &FinishStats, now_ms: f64) {
        self.0.borrow_mut().on_job_finished(job, node, stats, now_ms);
    }

    fn on_job_preempted(&mut self, job: JobId, node: usize, now_ms: f64) {
        self.0.borrow_mut().on_job_preempted(job, node, now_ms);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(id: usize) -> JobMeta<'static> {
        JobMeta {
            id: JobId::new(id),
            tenant: None,
            arrival_ms: 0.0,
            prompt_len: 4,
            total_len: 20,
        }
    }

    fn stats() -> FinishStats {
        FinishStats {
            jct_ms: 52.0,
            ttft_ms: Some(50.0),
            queue_delay_ms: 2.0,
            service_ms: 50.0,
            tokens: 20,
            predicted_total: Some(22.0),
        }
    }

    #[test]
    fn counter_counts() {
        let mut c = EventCounter::default();
        c.on_job_admitted(&meta(0), 0, 0.0);
        c.on_job_admitted(&meta(1), 0, 1.0);
        c.on_batch_formed(0, &[JobId::new(0)], 2.0);
        c.on_window_done(0, &[JobId::new(0)], 20, 50.0, 52.0);
        c.on_job_finished(&meta(0), 0, &stats(), 52.0);
        c.on_job_preempted(JobId::new(1), 0, 52.0);
        assert_eq!((c.admitted, c.batches, c.windows, c.finished, c.preempted),
                   (2, 1, 1, 1, 1));
    }

    #[test]
    fn window_applied_default_dispatches_to_per_event_hooks() {
        // a sink that only implements the per-event hooks must see the
        // same stream whether the coordinator fires them one by one or
        // hands it the whole window at once
        let mut c = EventCounter::default();
        c.on_job_admitted(&meta(0), 0, 0.0);
        let toks = [7i32; 20];
        let events = [
            WindowJobEvent::Preempted { job: JobId::new(1) },
            WindowJobEvent::Progress { job: meta(0), tokens: &toks },
            WindowJobEvent::Finished { job: meta(0), stats: stats() },
        ];
        c.on_window_applied(&WindowEvents {
            node: 0,
            batch: &[JobId::new(0)],
            events: &events,
            tokens: 20,
            service_ms: 50.0,
            now_ms: 52.0,
            pod: None,
        });
        assert_eq!((c.windows, c.finished, c.preempted), (1, 1, 1));
    }

    #[test]
    fn window_applied_forwards_token_payloads() {
        // the token-carrying hook fires before the count-based one and
        // sees the exact ids the window produced
        struct Grab {
            toks: Vec<i32>,
            count: usize,
        }
        impl EventSink for Grab {
            fn on_job_tokens(&mut self, _job: &JobMeta<'_>, _node: usize,
                             tokens: &[i32], _now_ms: f64) {
                assert_eq!(self.count, 0, "tokens must precede the count");
                self.toks.extend_from_slice(tokens);
            }
            fn on_job_progress(&mut self, _job: &JobMeta<'_>, _node: usize,
                               new_tokens: usize, _now_ms: f64) {
                self.count += new_tokens;
            }
        }
        let toks = [3i32, 5, 7];
        let events = [WindowJobEvent::Progress { job: meta(0), tokens: &toks }];
        let mut g = Grab { toks: Vec::new(), count: 0 };
        g.on_window_applied(&WindowEvents {
            node: 0,
            batch: &[JobId::new(0)],
            events: &events,
            tokens: 3,
            service_ms: 1.0,
            now_ms: 2.0,
            pod: None,
        });
        assert_eq!(g.toks, vec![3, 5, 7]);
        assert_eq!(g.count, 3);
    }

    #[test]
    fn shared_counter_reads_through_clone() {
        let shared = SharedCounter::new();
        let mut handle = shared.clone();
        handle.on_job_admitted(&meta(3), 1, 0.0);
        handle.on_job_finished(&meta(3), 1, &stats(), 9.0);
        let snap = shared.snapshot();
        assert_eq!(snap.admitted, 1);
        assert_eq!(snap.finished, 1);
    }
}
