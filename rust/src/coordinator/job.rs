//! Job records and the frontend's job storage.
//!
//! * [`Job`] — the scheduler's internal record of a request (paper
//!   Algorithm 1, line 2: "store the text of prompt in a new job").
//! * [`JobId`] — typed handle into the [`JobTable`]; also the sequence id
//!   handed to the engine layer (via [`JobId::raw`]).
//! * [`JobTable`] — dense slab keyed by [`JobId`].  Jobs are created once
//!   per trace request and live for the whole run, so index i of the slab
//!   is trace request i; lookups are O(1) array indexing instead of the
//!   `BTreeMap<u64, Job>` walks (and `Vec::contains` scans) the original
//!   `run_serving` monolith paid per scheduling iteration.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Typed handle to a job in a [`JobTable`].
///
/// The raw value doubles as the engine-layer sequence id (`SeqSpec::id`),
/// so crossing the coordinator/engine boundary is a lossless cast.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct JobId(u32);

impl JobId {
    pub fn new(index: usize) -> JobId {
        debug_assert!(index <= u32::MAX as usize, "job index overflow");
        JobId(index as u32)
    }

    /// Recover a JobId from an engine-layer sequence id.  Panics on ids
    /// outside the u32 range rather than silently aliasing onto the wrong
    /// slab slot (engines echo back the ids the coordinator issued, so a
    /// violation means a broken engine, not a hot-path cost worth dodging).
    pub fn from_raw(raw: u64) -> JobId {
        assert!(raw <= u32::MAX as u64, "sequence id {raw} is not a JobId");
        JobId(raw as u32)
    }

    /// Slab index.
    pub fn index(self) -> usize {
        self.0 as usize
    }

    /// Engine-layer sequence id.
    pub fn raw(self) -> u64 {
        self.0 as u64
    }
}

impl fmt::Display for JobId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "j{}", self.0)
    }
}

/// Lossless cast to the engine-layer sequence id (see [`JobId::raw`]).
impl From<JobId> for u64 {
    fn from(id: JobId) -> u64 {
        id.raw()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// waiting in its node's JobPool
    Queued,
    /// inside the batch a worker is currently executing
    Running,
    Finished,
}

#[derive(Debug, Clone)]
pub struct Job {
    pub id: JobId,
    pub prompt: Vec<i32>,
    /// ground-truth response length (engine stop condition; only oracle
    /// predictors may read it)
    pub total_len: usize,
    pub topic: usize,
    /// accounting tag threaded from `TraceRequest::tenant` (multi-tenant
    /// telemetry + SLO budgets); None = untagged
    pub tenant: Option<String>,
    pub arrival_ms: f64,
    /// backend worker chosen by the load balancer
    pub node: Option<usize>,
    /// scheduling priority — lower runs first (predicted remaining tokens
    /// for ISRTF; arrival time for FCFS; etc.).  None = never assigned
    /// (Algorithm 1 line 11).
    pub priority: Option<f64>,
    pub state: JobState,
    /// prompt already registered with this job's engine (slab flag
    /// replacing the per-worker `admitted: Vec<u64>` linear scans)
    pub engine_admitted: bool,
    /// response tokens produced so far
    pub generated: usize,
    pub response: Vec<i32>,
    /// scheduling iterations this job has executed in
    pub windows: usize,
    /// times this job's KV was preempted
    pub preemptions: usize,
    pub first_token_ms: Option<f64>,
    pub finish_ms: Option<f64>,
    /// cumulative time inside executing batches (ms)
    pub service_ms: f64,
}

impl Job {
    pub fn new(id: JobId, prompt: Vec<i32>, total_len: usize, topic: usize,
               arrival_ms: f64) -> Job {
        Job {
            id,
            prompt,
            total_len: total_len.max(1),
            topic,
            tenant: None,
            arrival_ms,
            node: None,
            priority: None,
            state: JobState::Queued,
            engine_admitted: false,
            generated: 0,
            response: Vec::new(),
            windows: 0,
            preemptions: 0,
            first_token_ms: None,
            finish_ms: None,
            service_ms: 0.0,
        }
    }

    pub fn remaining(&self) -> usize {
        self.total_len.saturating_sub(self.generated)
    }

    pub fn is_finished(&self) -> bool {
        self.state == JobState::Finished
    }

    /// Job completion time (ms), defined as in the paper §6.2: arrival at
    /// the frontend until the response is completely formed.
    pub fn jct_ms(&self) -> Option<f64> {
        self.finish_ms.map(|f| f - self.arrival_ms)
    }

    /// Queueing delay: completion time minus time actually being served.
    pub fn queue_delay_ms(&self) -> Option<f64> {
        self.jct_ms().map(|j| (j - self.service_ms).max(0.0))
    }

    /// Time to first token.
    pub fn ttft_ms(&self) -> Option<f64> {
        self.first_token_ms.map(|t| t - self.arrival_ms)
    }
}

/// Dense job storage: slab index == trace request index == [`JobId`].
#[derive(Debug, Default)]
pub struct JobTable {
    slab: Vec<Job>,
}

impl JobTable {
    pub fn new() -> JobTable {
        JobTable { slab: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> JobTable {
        JobTable { slab: Vec::with_capacity(n) }
    }

    /// Insert the next job; the closure receives the id it will get.
    pub fn insert_with(&mut self, make: impl FnOnce(JobId) -> Job) -> JobId {
        let id = JobId::new(self.slab.len());
        let job = make(id);
        debug_assert_eq!(job.id, id, "job id must match its slot");
        self.slab.push(job);
        id
    }

    pub fn len(&self) -> usize {
        self.slab.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slab.is_empty()
    }

    pub fn get(&self, id: JobId) -> Option<&Job> {
        self.slab.get(id.index())
    }

    pub fn get_mut(&mut self, id: JobId) -> Option<&mut Job> {
        self.slab.get_mut(id.index())
    }

    /// Jobs in id (= trace) order.
    pub fn iter(&self) -> std::slice::Iter<'_, Job> {
        self.slab.iter()
    }

    /// Run `f` over disjoint mutable references to the listed jobs, in the
    /// order given.  Ids must be distinct (they are: a job sits in at most
    /// one queue).  O(k log k) — sorts the ids and walks the slab once with
    /// `split_at_mut`, replacing the monolith's per-iteration "split_mut
    /// dance" that rebuilt a `BTreeMap<u64, &mut Job>` with an
    /// `ids.contains` scan per entry (O(n·k)).
    pub fn with_mut_refs<R>(&mut self, ids: &[JobId],
                            f: impl FnOnce(&mut [&mut Job]) -> R) -> R {
        let mut order: Vec<(usize, usize)> = ids
            .iter()
            .enumerate()
            .map(|(pos, id)| (id.index(), pos))
            .collect();
        order.sort_unstable();
        // hard assert: a duplicate would otherwise underflow the split
        // arithmetic below and surface as a baffling out-of-bounds panic
        assert!(order.windows(2).all(|w| w[0].0 != w[1].0),
                "duplicate JobId in with_mut_refs");

        let mut slots: Vec<Option<&mut Job>> =
            std::iter::repeat_with(|| None).take(ids.len()).collect();
        let mut rest: &mut [Job] = &mut self.slab;
        let mut consumed = 0usize;
        for &(idx, pos) in &order {
            let tmp = std::mem::take(&mut rest);
            let (left, right) = tmp.split_at_mut(idx - consumed + 1);
            slots[pos] = Some(&mut left[idx - consumed]);
            consumed = idx + 1;
            rest = right;
        }
        let mut refs: Vec<&mut Job> =
            slots.into_iter().map(|s| s.expect("JobId out of range")).collect();
        f(&mut refs)
    }
}

impl Index<JobId> for JobTable {
    type Output = Job;
    fn index(&self, id: JobId) -> &Job {
        &self.slab[id.index()]
    }
}

impl IndexMut<JobId> for JobTable {
    fn index_mut(&mut self, id: JobId) -> &mut Job {
        &mut self.slab[id.index()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(table: &mut JobTable, total: usize, arrival: f64) -> JobId {
        table.insert_with(|id| Job::new(id, vec![1, 2, 3], total, 0, arrival))
    }

    #[test]
    fn lifecycle_metrics() {
        let mut j = Job::new(JobId::new(1), vec![1, 2, 3], 120, 0, 1000.0);
        assert_eq!(j.remaining(), 120);
        assert!(j.jct_ms().is_none());
        j.generated = 50;
        assert_eq!(j.remaining(), 70);
        j.first_token_ms = Some(1500.0);
        j.finish_ms = Some(9000.0);
        j.service_ms = 6000.0;
        assert_eq!(j.jct_ms(), Some(8000.0));
        assert_eq!(j.queue_delay_ms(), Some(2000.0));
        assert_eq!(j.ttft_ms(), Some(500.0));
    }

    #[test]
    fn total_len_floor() {
        let j = Job::new(JobId::new(1), vec![1], 0, 0, 0.0);
        assert_eq!(j.total_len, 1);
    }

    #[test]
    fn queue_delay_never_negative() {
        let mut j = Job::new(JobId::new(1), vec![1], 10, 0, 0.0);
        j.finish_ms = Some(100.0);
        j.service_ms = 500.0; // service longer than JCT (overlapping batches)
        assert_eq!(j.queue_delay_ms(), Some(0.0));
    }

    #[test]
    fn table_assigns_dense_ids() {
        let mut t = JobTable::new();
        let a = job(&mut t, 10, 0.0);
        let b = job(&mut t, 20, 1.0);
        assert_eq!(a.index(), 0);
        assert_eq!(b.index(), 1);
        assert_eq!(t.len(), 2);
        assert_eq!(t[b].total_len, 20);
        assert_eq!(JobId::from_raw(b.raw()), b);
        t[a].generated = 5;
        assert_eq!(t[a].remaining(), 5);
        assert!(t.get(JobId::new(7)).is_none());
    }

    #[test]
    fn with_mut_refs_visits_in_given_order() {
        let mut t = JobTable::new();
        for i in 0..6 {
            job(&mut t, 100 + i, i as f64);
        }
        // arbitrary (unsorted) id order must be preserved
        let ids = [JobId::new(4), JobId::new(0), JobId::new(5), JobId::new(2)];
        let seen: Vec<usize> = t.with_mut_refs(&ids, |refs| {
            for r in refs.iter_mut() {
                r.generated += 1;
            }
            refs.iter().map(|r| r.id.index()).collect()
        });
        assert_eq!(seen, vec![4, 0, 5, 2]);
        for i in 0..6 {
            let expect = usize::from(ids.contains(&JobId::new(i)));
            assert_eq!(t[JobId::new(i)].generated, expect, "job {i}");
        }
    }

    #[test]
    fn with_mut_refs_empty_and_full() {
        let mut t = JobTable::new();
        for i in 0..3 {
            job(&mut t, 10, i as f64);
        }
        assert_eq!(t.with_mut_refs(&[], |refs| refs.len()), 0);
        let all = [JobId::new(0), JobId::new(1), JobId::new(2)];
        assert_eq!(t.with_mut_refs(&all, |refs| refs.len()), 3);
    }
}
