//! Job: the frontend scheduler's internal record of a request
//! (paper Algorithm 1, line 2: "store the text of prompt in a new job").

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// waiting in its node's JobPool
    Queued,
    /// inside the batch a worker is currently executing
    Running,
    Finished,
}

#[derive(Debug, Clone)]
pub struct Job {
    pub id: u64,
    pub prompt: Vec<i32>,
    /// ground-truth response length (engine stop condition; only oracle
    /// predictors may read it)
    pub total_len: usize,
    pub topic: usize,
    pub arrival_ms: f64,
    /// backend worker chosen by the load balancer
    pub node: Option<usize>,
    /// scheduling priority — lower runs first (predicted remaining tokens
    /// for ISRTF; arrival time for FCFS; etc.).  None = never assigned
    /// (Algorithm 1 line 11).
    pub priority: Option<f64>,
    pub state: JobState,
    /// response tokens produced so far
    pub generated: usize,
    pub response: Vec<i32>,
    /// scheduling iterations this job has executed in
    pub windows: usize,
    /// times this job's KV was preempted
    pub preemptions: usize,
    pub first_token_ms: Option<f64>,
    pub finish_ms: Option<f64>,
    /// cumulative time inside executing batches (ms)
    pub service_ms: f64,
}

impl Job {
    pub fn new(id: u64, prompt: Vec<i32>, total_len: usize, topic: usize,
               arrival_ms: f64) -> Job {
        Job {
            id,
            prompt,
            total_len: total_len.max(1),
            topic,
            arrival_ms,
            node: None,
            priority: None,
            state: JobState::Queued,
            generated: 0,
            response: Vec::new(),
            windows: 0,
            preemptions: 0,
            first_token_ms: None,
            finish_ms: None,
            service_ms: 0.0,
        }
    }

    pub fn remaining(&self) -> usize {
        self.total_len.saturating_sub(self.generated)
    }

    pub fn is_finished(&self) -> bool {
        self.state == JobState::Finished
    }

    /// Job completion time (ms), defined as in the paper §6.2: arrival at
    /// the frontend until the response is completely formed.
    pub fn jct_ms(&self) -> Option<f64> {
        self.finish_ms.map(|f| f - self.arrival_ms)
    }

    /// Queueing delay: completion time minus time actually being served.
    pub fn queue_delay_ms(&self) -> Option<f64> {
        self.jct_ms().map(|j| (j - self.service_ms).max(0.0))
    }

    /// Time to first token.
    pub fn ttft_ms(&self) -> Option<f64> {
        self.first_token_ms.map(|t| t - self.arrival_ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_metrics() {
        let mut j = Job::new(1, vec![1, 2, 3], 120, 0, 1000.0);
        assert_eq!(j.remaining(), 120);
        assert!(j.jct_ms().is_none());
        j.generated = 50;
        assert_eq!(j.remaining(), 70);
        j.first_token_ms = Some(1500.0);
        j.finish_ms = Some(9000.0);
        j.service_ms = 6000.0;
        assert_eq!(j.jct_ms(), Some(8000.0));
        assert_eq!(j.queue_delay_ms(), Some(2000.0));
        assert_eq!(j.ttft_ms(), Some(500.0));
    }

    #[test]
    fn total_len_floor() {
        let j = Job::new(1, vec![1], 0, 0, 0.0);
        assert_eq!(j.total_len, 1);
    }

    #[test]
    fn queue_delay_never_negative() {
        let mut j = Job::new(1, vec![1], 10, 0, 0.0);
        j.finish_ms = Some(100.0);
        j.service_ms = 500.0; // service longer than JCT (overlapping batches)
        assert_eq!(j.queue_delay_ms(), Some(0.0));
    }
}
