//! Batcher (paper Algorithm 1 line 19: `BatchedPrompt <- Batcher.batch(...)`).
//!
//! Forms the next window's batch for an available backend from its priority
//! queue, honouring the engine's max batch size.  Also models the paper's
//! network optimization — "the input prompt of each job is sent to the
//! backend only once" — by tracking which jobs' prompts each node has
//! already received and counting transfer bytes saved.

use std::collections::HashSet;

use super::job::JobId;
use super::priority_buffer::{Entry, PriorityBuffer};

#[derive(Debug, Clone)]
pub struct Batch {
    pub node: usize,
    /// job ids in priority order (highest priority first)
    pub jobs: Vec<JobId>,
}

#[derive(Debug, Default, Clone)]
pub struct TransferStats {
    pub prompts_sent: u64,
    pub prompt_tokens_sent: u64,
    pub resend_avoided: u64,
}

pub struct Batcher {
    pub max_batch: usize,
    /// per-node set of job ids whose prompt was already transferred
    sent: Vec<HashSet<JobId>>,
    pub stats: TransferStats,
}

impl Batcher {
    pub fn new(nodes: usize, max_batch: usize) -> Batcher {
        assert!(max_batch >= 1);
        Batcher {
            max_batch,
            sent: (0..nodes).map(|_| HashSet::new()).collect(),
            stats: TransferStats::default(),
        }
    }

    /// Pop the top-priority jobs for `node` into a batch.  Returns None if
    /// the node's queue is empty.
    pub fn form_batch(&mut self, buffer: &mut PriorityBuffer, node: usize)
                      -> Option<Batch> {
        let entries: Vec<Entry> = buffer.pop_batch(node, self.max_batch);
        if entries.is_empty() {
            return None;
        }
        Some(Batch { node, jobs: entries.into_iter().map(|e| e.id).collect() })
    }

    /// Top-k partial selection for the incremental dispatch path: pop the
    /// next window's batch for `node` into `out` (cleared first), capped by
    /// both the batcher's limit and the engine's `engine_cap`.  O(k log n)
    /// against a persistent index — the selected prefix leaves the queue,
    /// everything else stays put and keeps its key.
    pub fn select_into(&mut self, buffer: &mut PriorityBuffer, node: usize,
                       engine_cap: usize, out: &mut Vec<Entry>) {
        buffer.pop_batch_into(node, self.max_batch.min(engine_cap), out);
    }

    /// Record the prompt transfer for a job; returns true if the prompt
    /// actually needs to be sent (first time on this node).
    pub fn mark_prompt_sent(&mut self, node: usize, job_id: JobId,
                            prompt_tokens: usize) -> bool {
        if self.sent[node].insert(job_id) {
            self.stats.prompts_sent += 1;
            self.stats.prompt_tokens_sent += prompt_tokens as u64;
            true
        } else {
            self.stats.resend_avoided += 1;
            false
        }
    }

    /// Forget a finished job's transfer record.
    pub fn forget(&mut self, node: usize, job_id: JobId) {
        self.sent[node].remove(&job_id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn push(b: &mut PriorityBuffer, node: usize, id: u64, prio: f64) {
        b.push(node, Entry {
            priority: prio,
            arrival_ms: 0.0,
            id: JobId::from_raw(id),
        });
    }

    #[test]
    fn batch_takes_top_k_in_order() {
        let mut buf = PriorityBuffer::new(1);
        for (id, p) in [(1, 30.0), (2, 10.0), (3, 20.0), (4, 40.0), (5, 5.0)] {
            push(&mut buf, 0, id, p);
        }
        let mut b = Batcher::new(1, 3);
        let batch = b.form_batch(&mut buf, 0).unwrap();
        let ids: Vec<u64> = batch.jobs.iter().map(|j| j.raw()).collect();
        assert_eq!(ids, vec![5, 2, 3]);
        assert_eq!(buf.len(0), 2, "unchosen jobs stay queued");
    }

    #[test]
    fn select_into_respects_both_caps_and_leaves_remainder() {
        let mut buf = PriorityBuffer::new(1);
        for (id, p) in [(1, 30.0), (2, 10.0), (3, 20.0), (4, 40.0), (5, 5.0)] {
            push(&mut buf, 0, id, p);
        }
        let mut b = Batcher::new(1, 3);
        let mut out = Vec::new();
        b.select_into(&mut buf, 0, 2, &mut out); // engine tighter than cfg
        let ids: Vec<u64> = out.iter().map(|e| e.id.raw()).collect();
        assert_eq!(ids, vec![5, 2]);
        assert_eq!(buf.len(0), 3, "unchosen jobs stay indexed");
        b.select_into(&mut buf, 0, 8, &mut out); // cfg tighter than engine
        let ids: Vec<u64> = out.iter().map(|e| e.id.raw()).collect();
        assert_eq!(ids, vec![3, 1, 4]);
    }

    #[test]
    fn empty_queue_yields_none() {
        let mut buf = PriorityBuffer::new(1);
        let mut b = Batcher::new(1, 4);
        assert!(b.form_batch(&mut buf, 0).is_none());
    }

    #[test]
    fn prompt_sent_once_per_node() {
        let mut b = Batcher::new(2, 4);
        let id = JobId::from_raw(7);
        assert!(b.mark_prompt_sent(0, id, 32));
        assert!(!b.mark_prompt_sent(0, id, 32), "resend avoided");
        assert!(b.mark_prompt_sent(1, id, 32), "other node needs it");
        assert_eq!(b.stats.prompts_sent, 2);
        assert_eq!(b.stats.resend_avoided, 1);
        assert_eq!(b.stats.prompt_tokens_sent, 64);
        b.forget(0, id);
        assert!(b.mark_prompt_sent(0, id, 32), "forgotten after finish");
    }
}
