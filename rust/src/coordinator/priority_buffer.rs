//! PriorityBuffer: per-node priority queues (paper §4.1: "multiple priority
//! queues, where each queue stores jobs assigned to a specific node").
//!
//! Two usage modes, chosen by the coordinator:
//!
//! * **persistent order index** (default, no shaper): entries stay in the
//!   heap across scheduling iterations.  A job's key is re-computed only
//!   when its priority input actually changed — it ran a window, was newly
//!   admitted, or was spilled back by an error path — which is exactly the
//!   set of jobs passing through the node's pending list, so a window
//!   costs O(k log n) heap traffic for a batch of k instead of an
//!   O(n log n) full rebuild.  Requires keys that do not drift with time;
//!   see `Scheduler::refresh_folded` for how anti-starvation aging is
//!   folded into a time-invariant key.
//! * **per-window rebuild** (shaper registered, or forced for reference
//!   runs): Algorithm 1 as written — every job is re-keyed and pushed each
//!   iteration, then the queue is drained sorted.
//!
//! Ordering is **fully deterministic**: priority, then arrival time, then
//! job id — all via `f64::total_cmp`, so even NaN priorities (a misbehaving
//! predictor) produce a stable, insertion-order-independent drain order.
//! Because the order is total (ids are unique), the heap's pop sequence for
//! a given *set* of entries is unique — the persistent index and a full
//! re-sort agree exactly.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::job::JobId;

/// Min-heap item: lower priority value runs first; arrival then id break
/// ties deterministically.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Entry {
    pub priority: f64,
    pub arrival_ms: f64,
    pub id: JobId,
}

impl Eq for Entry {}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed for min-heap on BinaryHeap (a max-heap); total_cmp makes
        // the order total even for NaN/-0.0 priorities
        other
            .priority
            .total_cmp(&self.priority)
            .then_with(|| other.arrival_ms.total_cmp(&self.arrival_ms))
            .then_with(|| other.id.cmp(&self.id))
    }
}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

#[derive(Debug, Default)]
pub struct PriorityBuffer {
    queues: Vec<BinaryHeap<Entry>>,
}

impl PriorityBuffer {
    pub fn new(nodes: usize) -> PriorityBuffer {
        PriorityBuffer {
            queues: (0..nodes).map(|_| BinaryHeap::new()).collect(),
        }
    }

    pub fn nodes(&self) -> usize {
        self.queues.len()
    }

    pub fn clear(&mut self) {
        for q in &mut self.queues {
            q.clear();
        }
    }

    pub fn push(&mut self, node: usize, e: Entry) {
        self.queues[node].push(e);
    }

    pub fn pop(&mut self, node: usize) -> Option<Entry> {
        self.queues[node].pop()
    }

    pub fn peek(&self, node: usize) -> Option<&Entry> {
        self.queues[node].peek()
    }

    pub fn len(&self, node: usize) -> usize {
        self.queues[node].len()
    }

    pub fn is_empty(&self, node: usize) -> bool {
        self.queues[node].is_empty()
    }

    pub fn total_len(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Pop up to `k` highest-priority entries from a node's queue.
    pub fn pop_batch(&mut self, node: usize, k: usize) -> Vec<Entry> {
        let mut out = Vec::with_capacity(k);
        self.pop_batch_into(node, k, &mut out);
        out
    }

    /// Like [`pop_batch`](Self::pop_batch), but into a caller-owned scratch
    /// vector (cleared first) so the dispatch hot loop reuses one
    /// allocation across windows.  This is the incremental top-k selection:
    /// k pops against the persistent heap, O(k log n).
    pub fn pop_batch_into(&mut self, node: usize, k: usize,
                          out: &mut Vec<Entry>) {
        out.clear();
        while out.len() < k {
            match self.queues[node].pop() {
                Some(e) => out.push(e),
                None => break,
            }
        }
    }

    /// Drain a node's queue in priority order (used to hand the engine its
    /// preemption-victim ordering).
    pub fn drain_sorted(&mut self, node: usize) -> Vec<Entry> {
        let mut out = Vec::with_capacity(self.queues[node].len());
        self.drain_sorted_into(node, &mut out);
        out
    }

    /// Like [`drain_sorted`](Self::drain_sorted), but into a caller-owned
    /// scratch vector (cleared first) — the rebuild dispatch path's
    /// per-window full ordering without a fresh allocation per window.
    pub fn drain_sorted_into(&mut self, node: usize, out: &mut Vec<Entry>) {
        out.clear();
        out.reserve(self.queues[node].len());
        while let Some(e) = self.queues[node].pop() {
            out.push(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    fn e(priority: f64, arrival: f64, id: u64) -> Entry {
        Entry { priority, arrival_ms: arrival, id: JobId::from_raw(id) }
    }

    #[test]
    fn pops_lowest_priority_first() {
        let mut b = PriorityBuffer::new(1);
        b.push(0, e(300.0, 0.0, 1));
        b.push(0, e(50.0, 0.0, 2));
        b.push(0, e(120.0, 0.0, 3));
        assert_eq!(b.pop(0).unwrap().id.raw(), 2);
        assert_eq!(b.pop(0).unwrap().id.raw(), 3);
        assert_eq!(b.pop(0).unwrap().id.raw(), 1);
        assert!(b.pop(0).is_none());
    }

    #[test]
    fn ties_break_by_arrival_then_id() {
        let mut b = PriorityBuffer::new(1);
        b.push(0, e(10.0, 5.0, 9));
        b.push(0, e(10.0, 1.0, 7));
        b.push(0, e(10.0, 1.0, 3));
        assert_eq!(b.pop(0).unwrap().id.raw(), 3);
        assert_eq!(b.pop(0).unwrap().id.raw(), 7);
        assert_eq!(b.pop(0).unwrap().id.raw(), 9);
    }

    #[test]
    fn equal_priority_drain_is_insertion_order_independent() {
        // regression: with equal priorities the drain order must be the
        // same whatever order the entries were pushed in
        let entries = [e(7.0, 3.0, 4), e(7.0, 1.0, 2), e(7.0, 1.0, 1),
                       e(7.0, 2.0, 8), e(7.0, 3.0, 0)];
        let expect: Vec<u64> = vec![1, 2, 8, 0, 4]; // (arrival, id) order

        // forward insertion
        let mut fwd = PriorityBuffer::new(1);
        for en in entries {
            fwd.push(0, en);
        }
        let got_fwd: Vec<u64> =
            fwd.drain_sorted(0).iter().map(|x| x.id.raw()).collect();
        assert_eq!(got_fwd, expect);

        // reverse insertion must give the identical order
        let mut rev = PriorityBuffer::new(1);
        for en in entries.iter().rev() {
            rev.push(0, *en);
        }
        let got_rev: Vec<u64> =
            rev.drain_sorted(0).iter().map(|x| x.id.raw()).collect();
        assert_eq!(got_rev, expect);
    }

    #[test]
    fn nan_priority_still_drains_deterministically() {
        let mut b = PriorityBuffer::new(1);
        b.push(0, e(f64::NAN, 0.0, 1));
        b.push(0, e(1.0, 0.0, 2));
        b.push(0, e(f64::NAN, 0.0, 3));
        let order: Vec<u64> = b.drain_sorted(0).iter().map(|x| x.id.raw()).collect();
        // total_cmp sorts NaN after every finite value; ids break the tie
        assert_eq!(order, vec![2, 1, 3]);
    }

    #[test]
    fn nodes_are_independent() {
        let mut b = PriorityBuffer::new(2);
        b.push(0, e(1.0, 0.0, 1));
        b.push(1, e(2.0, 0.0, 2));
        assert_eq!(b.len(0), 1);
        assert_eq!(b.len(1), 1);
        assert_eq!(b.pop(1).unwrap().id.raw(), 2);
        assert!(b.is_empty(1));
        assert!(!b.is_empty(0));
        assert_eq!(b.total_len(), 1);
    }

    #[test]
    fn pop_batch_respects_k() {
        let mut b = PriorityBuffer::new(1);
        for i in 0..10 {
            b.push(0, e(i as f64, 0.0, i));
        }
        let batch: Vec<u64> =
            b.pop_batch(0, 4).iter().map(|x| x.id.raw()).collect();
        assert_eq!(batch, vec![0, 1, 2, 3]);
        assert_eq!(b.len(0), 6);
    }

    #[test]
    fn into_variants_reuse_scratch_and_match() {
        let entries = [e(30.0, 0.0, 1), e(10.0, 0.0, 2), e(20.0, 0.0, 3)];
        let mut a = PriorityBuffer::new(1);
        let mut b = PriorityBuffer::new(1);
        for en in entries {
            a.push(0, en);
            b.push(0, en);
        }
        let mut scratch = vec![e(99.0, 99.0, 99)]; // stale contents
        a.pop_batch_into(0, 2, &mut scratch);
        assert_eq!(scratch, b.pop_batch(0, 2));
        a.drain_sorted_into(0, &mut scratch);
        assert_eq!(scratch, b.drain_sorted(0));
        assert!(a.is_empty(0));
    }

    #[test]
    fn persistent_pops_match_full_resort() {
        // the incremental index invariant: popping k, re-inserting with new
        // keys, and popping again must equal sorting the live set
        let mut heap = PriorityBuffer::new(1);
        let mut live: Vec<Entry> = Vec::new();
        let mut rng = crate::stats::rng::Pcg64::new(7);
        for i in 0..40u64 {
            let en = e(rng.f64() * 100.0, rng.f64() * 10.0, i);
            heap.push(0, en);
            live.push(en);
        }
        for round in 0..10 {
            let k = 4;
            let popped = heap.pop_batch(0, k);
            let mut sorted = live.clone();
            sorted.sort_by(|a, b| a.cmp(b).reverse()); // Entry: reversed Ord
            assert_eq!(popped, sorted[..k], "round {round}");
            live.retain(|en| !popped.contains(en));
            // "re-key" the popped jobs and return them to the pool
            for en in popped {
                let rekeyed = e(rng.f64() * 100.0, en.arrival_ms,
                                en.id.raw());
                heap.push(0, rekeyed);
                live.push(rekeyed);
            }
        }
    }

    #[test]
    fn prop_drain_is_sorted() {
        prop::check("priority-buffer-sorted", 100, |g| {
            let mut b = PriorityBuffer::new(1);
            let n = g.usize_in(1, 50);
            for i in 0..n {
                b.push(0, e(g.f64_in(-100.0, 100.0), g.f64_in(0.0, 10.0),
                            i as u64));
            }
            let drained = b.drain_sorted(0);
            assert_eq!(drained.len(), n);
            for w in drained.windows(2) {
                assert!(
                    w[0].priority < w[1].priority
                        || (w[0].priority == w[1].priority
                            && (w[0].arrival_ms, w[0].id)
                                <= (w[1].arrival_ms, w[1].id)),
                    "out of order: {w:?}"
                );
            }
        });
    }
}
