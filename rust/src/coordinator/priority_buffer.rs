//! PriorityBuffer: per-node priority queues (paper §4.1: "multiple priority
//! queues, where each queue stores jobs assigned to a specific node").
//!
//! Two usage modes, chosen by the coordinator:
//!
//! * **persistent order index** (default, no shaper): entries stay in the
//!   heap across scheduling iterations.  A job's key is re-computed only
//!   when its priority input actually changed — it ran a window, was newly
//!   admitted, or was spilled back by an error path — which is exactly the
//!   set of jobs passing through the node's pending list, so a window
//!   costs O(k log n) heap traffic for a batch of k instead of an
//!   O(n log n) full rebuild.  Requires keys that do not drift with time;
//!   see `Scheduler::refresh_folded` for how anti-starvation aging is
//!   folded into a time-invariant key.
//! * **per-window rebuild** (non-folding shaper registered, or forced for
//!   reference runs): Algorithm 1 as written — every job is re-keyed and
//!   pushed each iteration, then the queue is drained sorted.
//!
//! Shaped runs with a *folding* shaper (`PriorityShaper::as_folded`) keep a
//! persistent index too, via [`TenantQueues`]: one heap lane per tenant,
//! each stamped with the tenant epoch its keys were computed under.  When a
//! tenant's shaping term changes (pressure/virtual-time moved), only that
//! lane is drained and re-keyed; the global pop order is recovered by
//! scanning the lane heads — O(T + log n) per pop for T tenants, and
//! bit-identical to a single global heap because the entry order is total.
//!
//! Ordering is **fully deterministic**: priority, then arrival time, then
//! job id — all via `f64::total_cmp`, so even NaN priorities (a misbehaving
//! predictor) produce a stable, insertion-order-independent drain order.
//! Because the order is total (ids are unique), the heap's pop sequence for
//! a given *set* of entries is unique — the persistent index and a full
//! re-sort agree exactly.

use std::cmp::Ordering;
use std::collections::{BTreeMap, BinaryHeap};

use super::job::{JobId, JobTable};
use super::scheduler::FoldedShaper;

/// Min-heap item: lower priority value runs first; arrival then id break
/// ties deterministically.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Entry {
    pub priority: f64,
    pub arrival_ms: f64,
    pub id: JobId,
}

impl Eq for Entry {}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed for min-heap on BinaryHeap (a max-heap); total_cmp makes
        // the order total even for NaN/-0.0 priorities
        other
            .priority
            .total_cmp(&self.priority)
            .then_with(|| other.arrival_ms.total_cmp(&self.arrival_ms))
            .then_with(|| other.id.cmp(&self.id))
    }
}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

#[derive(Debug, Default)]
pub struct PriorityBuffer {
    queues: Vec<BinaryHeap<Entry>>,
}

impl PriorityBuffer {
    pub fn new(nodes: usize) -> PriorityBuffer {
        PriorityBuffer {
            queues: (0..nodes).map(|_| BinaryHeap::new()).collect(),
        }
    }

    pub fn nodes(&self) -> usize {
        self.queues.len()
    }

    pub fn clear(&mut self) {
        for q in &mut self.queues {
            q.clear();
        }
    }

    pub fn push(&mut self, node: usize, e: Entry) {
        self.queues[node].push(e);
    }

    pub fn pop(&mut self, node: usize) -> Option<Entry> {
        self.queues[node].pop()
    }

    pub fn peek(&self, node: usize) -> Option<&Entry> {
        self.queues[node].peek()
    }

    pub fn len(&self, node: usize) -> usize {
        self.queues[node].len()
    }

    pub fn is_empty(&self, node: usize) -> bool {
        self.queues[node].is_empty()
    }

    pub fn total_len(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Pop up to `k` highest-priority entries from a node's queue.
    pub fn pop_batch(&mut self, node: usize, k: usize) -> Vec<Entry> {
        let mut out = Vec::with_capacity(k);
        self.pop_batch_into(node, k, &mut out);
        out
    }

    /// Like [`pop_batch`](Self::pop_batch), but into a caller-owned scratch
    /// vector (cleared first) so the dispatch hot loop reuses one
    /// allocation across windows.  This is the incremental top-k selection:
    /// k pops against the persistent heap, O(k log n).
    pub fn pop_batch_into(&mut self, node: usize, k: usize,
                          out: &mut Vec<Entry>) {
        out.clear();
        while out.len() < k {
            match self.queues[node].pop() {
                Some(e) => out.push(e),
                None => break,
            }
        }
    }

    /// Drain a node's queue in priority order (used to hand the engine its
    /// preemption-victim ordering).
    pub fn drain_sorted(&mut self, node: usize) -> Vec<Entry> {
        let mut out = Vec::with_capacity(self.queues[node].len());
        self.drain_sorted_into(node, &mut out);
        out
    }

    /// Like [`drain_sorted`](Self::drain_sorted), but into a caller-owned
    /// scratch vector (cleared first) — the rebuild dispatch path's
    /// per-window full ordering without a fresh allocation per window.
    pub fn drain_sorted_into(&mut self, node: usize, out: &mut Vec<Entry>) {
        out.clear();
        out.reserve(self.queues[node].len());
        while let Some(e) = self.queues[node].pop() {
            out.push(e);
        }
    }
}

/// Shaped-index heap item: `entry.priority` holds the *shaped* folded key;
/// `base_folded` keeps the unshaped folded base so a lane can be re-keyed
/// from stored state when its tenant's epoch moves (no predictor call).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShapedEntry {
    pub entry: Entry,
    pub base_folded: f64,
}

impl Eq for ShapedEntry {}

impl Ord for ShapedEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // order is entirely the inner Entry's (already reversed for
        // min-heap use); base_folded is payload, not key
        self.entry.cmp(&other.entry)
    }
}

impl PartialOrd for ShapedEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

#[derive(Debug)]
struct Lane {
    tenant: Option<String>,
    /// the shaper epoch this lane's keys were computed under
    epoch_applied: u64,
    heap: BinaryHeap<ShapedEntry>,
}

/// Per-tenant heap lanes for one node's *shaped* persistent order index.
///
/// Invariant: every entry in a lane carries the shaped key
/// `shaper.shape_folded(job, base_folded)` as of epoch `epoch_applied` for
/// that tenant.  [`rekey_stale`](Self::rekey_stale) restores the invariant
/// at the top of a round; pushes within a round must pass the tenant's
/// current epoch.  Pop order equals a single global heap's because `Entry`'s
/// ordering is total (priority, arrival, id).
#[derive(Debug, Default)]
pub struct TenantQueues {
    lanes: Vec<Lane>,
    /// tenant name -> lane index (first-seen lane order is deterministic,
    /// but pops never depend on it)
    by_name: BTreeMap<String, usize>,
    /// lane index for untagged (tenant = None) jobs
    untagged: Option<usize>,
    len: usize,
}

impl TenantQueues {
    pub fn new() -> TenantQueues {
        TenantQueues::default()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn clear(&mut self) {
        self.lanes.clear();
        self.by_name.clear();
        self.untagged = None;
        self.len = 0;
    }

    fn lane_of(&mut self, tenant: Option<&str>, epoch: u64) -> usize {
        let slot = match tenant {
            Some(t) => self.by_name.get(t).copied(),
            None => self.untagged,
        };
        if let Some(i) = slot {
            return i;
        }
        let i = self.lanes.len();
        self.lanes.push(Lane {
            tenant: tenant.map(str::to_owned),
            epoch_applied: epoch,
            heap: BinaryHeap::new(),
        });
        match tenant {
            Some(t) => {
                self.by_name.insert(t.to_owned(), i);
            }
            None => self.untagged = Some(i),
        }
        i
    }

    /// Insert an entry keyed under the tenant's current `epoch`.  Callers
    /// must have synced stale lanes first ([`rekey_stale`](Self::rekey_stale));
    /// an existing lane at a different epoch would mix key generations.
    pub fn push(&mut self, tenant: Option<&str>, epoch: u64, e: ShapedEntry) {
        let i = self.lane_of(tenant, epoch);
        debug_assert_eq!(
            self.lanes[i].epoch_applied, epoch,
            "push into stale lane (tenant {:?}): rekey_stale must run first",
            tenant
        );
        self.lanes[i].heap.push(e);
        self.len += 1;
    }

    /// Re-key every lane whose tenant epoch moved since its keys were
    /// computed: drain, recompute `shape_folded` over the stored folded
    /// bases, heapify.  Returns the number of entries re-keyed (telemetry /
    /// tests).  This is the only O(lane) step of a shaped window, and it
    /// runs only for tenants whose pressure/lead term actually changed.
    pub fn rekey_stale(&mut self, shaper: &dyn FoldedShaper,
                       table: &JobTable) -> usize {
        let mut rekeyed = 0;
        for lane in &mut self.lanes {
            let cur = shaper.tenant_epoch(lane.tenant.as_deref());
            if lane.epoch_applied == cur {
                continue;
            }
            lane.epoch_applied = cur;
            if lane.heap.is_empty() {
                continue;
            }
            let mut v = std::mem::take(&mut lane.heap).into_vec();
            for se in &mut v {
                se.entry.priority =
                    shaper.shape_folded(&table[se.entry.id], se.base_folded);
            }
            rekeyed += v.len();
            lane.heap = BinaryHeap::from(v);
        }
        rekeyed
    }

    /// Pop the globally best entry: scan lane heads, take the minimum under
    /// the total (priority, arrival, id) order.  Ties across lanes are
    /// impossible (ids are unique), so the winner — and therefore the whole
    /// pop sequence — is unique.
    pub fn pop_best(&mut self) -> Option<ShapedEntry> {
        let mut best: Option<usize> = None;
        for (i, lane) in self.lanes.iter().enumerate() {
            let Some(head) = lane.heap.peek() else { continue };
            match best {
                // BinaryHeap::peek is the max under ShapedEntry's reversed
                // Ord, i.e. the lane's (priority, arrival, id) minimum;
                // `>` picks the smaller tuple across lanes
                Some(b) if !(head > self.lanes[b].heap.peek().unwrap()) => {}
                _ => best = Some(i),
            }
        }
        let popped = best.and_then(|i| self.lanes[i].heap.pop());
        if popped.is_some() {
            self.len -= 1;
        }
        popped
    }

    /// Pop up to `k` best entries into a caller-owned scratch vector
    /// (cleared first) — the shaped top-k selection, O(k (T + log n)).
    pub fn pop_batch_into(&mut self, k: usize, out: &mut Vec<ShapedEntry>) {
        out.clear();
        while out.len() < k {
            match self.pop_best() {
                Some(e) => out.push(e),
                None => break,
            }
        }
    }

    /// Drain every lane in global priority order (fail-over re-homing).
    pub fn drain_sorted_into(&mut self, out: &mut Vec<Entry>) {
        out.clear();
        out.reserve(self.len);
        while let Some(se) = self.pop_best() {
            out.push(se.entry);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    fn e(priority: f64, arrival: f64, id: u64) -> Entry {
        Entry { priority, arrival_ms: arrival, id: JobId::from_raw(id) }
    }

    #[test]
    fn pops_lowest_priority_first() {
        let mut b = PriorityBuffer::new(1);
        b.push(0, e(300.0, 0.0, 1));
        b.push(0, e(50.0, 0.0, 2));
        b.push(0, e(120.0, 0.0, 3));
        assert_eq!(b.pop(0).unwrap().id.raw(), 2);
        assert_eq!(b.pop(0).unwrap().id.raw(), 3);
        assert_eq!(b.pop(0).unwrap().id.raw(), 1);
        assert!(b.pop(0).is_none());
    }

    #[test]
    fn ties_break_by_arrival_then_id() {
        let mut b = PriorityBuffer::new(1);
        b.push(0, e(10.0, 5.0, 9));
        b.push(0, e(10.0, 1.0, 7));
        b.push(0, e(10.0, 1.0, 3));
        assert_eq!(b.pop(0).unwrap().id.raw(), 3);
        assert_eq!(b.pop(0).unwrap().id.raw(), 7);
        assert_eq!(b.pop(0).unwrap().id.raw(), 9);
    }

    #[test]
    fn equal_priority_drain_is_insertion_order_independent() {
        // regression: with equal priorities the drain order must be the
        // same whatever order the entries were pushed in
        let entries = [e(7.0, 3.0, 4), e(7.0, 1.0, 2), e(7.0, 1.0, 1),
                       e(7.0, 2.0, 8), e(7.0, 3.0, 0)];
        let expect: Vec<u64> = vec![1, 2, 8, 0, 4]; // (arrival, id) order

        // forward insertion
        let mut fwd = PriorityBuffer::new(1);
        for en in entries {
            fwd.push(0, en);
        }
        let got_fwd: Vec<u64> =
            fwd.drain_sorted(0).iter().map(|x| x.id.raw()).collect();
        assert_eq!(got_fwd, expect);

        // reverse insertion must give the identical order
        let mut rev = PriorityBuffer::new(1);
        for en in entries.iter().rev() {
            rev.push(0, *en);
        }
        let got_rev: Vec<u64> =
            rev.drain_sorted(0).iter().map(|x| x.id.raw()).collect();
        assert_eq!(got_rev, expect);
    }

    #[test]
    fn nan_priority_still_drains_deterministically() {
        let mut b = PriorityBuffer::new(1);
        b.push(0, e(f64::NAN, 0.0, 1));
        b.push(0, e(1.0, 0.0, 2));
        b.push(0, e(f64::NAN, 0.0, 3));
        let order: Vec<u64> = b.drain_sorted(0).iter().map(|x| x.id.raw()).collect();
        // total_cmp sorts NaN after every finite value; ids break the tie
        assert_eq!(order, vec![2, 1, 3]);
    }

    #[test]
    fn nodes_are_independent() {
        let mut b = PriorityBuffer::new(2);
        b.push(0, e(1.0, 0.0, 1));
        b.push(1, e(2.0, 0.0, 2));
        assert_eq!(b.len(0), 1);
        assert_eq!(b.len(1), 1);
        assert_eq!(b.pop(1).unwrap().id.raw(), 2);
        assert!(b.is_empty(1));
        assert!(!b.is_empty(0));
        assert_eq!(b.total_len(), 1);
    }

    #[test]
    fn pop_batch_respects_k() {
        let mut b = PriorityBuffer::new(1);
        for i in 0..10 {
            b.push(0, e(i as f64, 0.0, i));
        }
        let batch: Vec<u64> =
            b.pop_batch(0, 4).iter().map(|x| x.id.raw()).collect();
        assert_eq!(batch, vec![0, 1, 2, 3]);
        assert_eq!(b.len(0), 6);
    }

    #[test]
    fn into_variants_reuse_scratch_and_match() {
        let entries = [e(30.0, 0.0, 1), e(10.0, 0.0, 2), e(20.0, 0.0, 3)];
        let mut a = PriorityBuffer::new(1);
        let mut b = PriorityBuffer::new(1);
        for en in entries {
            a.push(0, en);
            b.push(0, en);
        }
        let mut scratch = vec![e(99.0, 99.0, 99)]; // stale contents
        a.pop_batch_into(0, 2, &mut scratch);
        assert_eq!(scratch, b.pop_batch(0, 2));
        a.drain_sorted_into(0, &mut scratch);
        assert_eq!(scratch, b.drain_sorted(0));
        assert!(a.is_empty(0));
    }

    #[test]
    fn persistent_pops_match_full_resort() {
        // the incremental index invariant: popping k, re-inserting with new
        // keys, and popping again must equal sorting the live set
        let mut heap = PriorityBuffer::new(1);
        let mut live: Vec<Entry> = Vec::new();
        let mut rng = crate::stats::rng::Pcg64::new(7);
        for i in 0..40u64 {
            let en = e(rng.f64() * 100.0, rng.f64() * 10.0, i);
            heap.push(0, en);
            live.push(en);
        }
        for round in 0..10 {
            let k = 4;
            let popped = heap.pop_batch(0, k);
            let mut sorted = live.clone();
            sorted.sort_by(|a, b| a.cmp(b).reverse()); // Entry: reversed Ord
            assert_eq!(popped, sorted[..k], "round {round}");
            live.retain(|en| !popped.contains(en));
            // "re-key" the popped jobs and return them to the pool
            for en in popped {
                let rekeyed = e(rng.f64() * 100.0, en.arrival_ms,
                                en.id.raw());
                heap.push(0, rekeyed);
                live.push(rekeyed);
            }
        }
    }

    #[test]
    fn prop_drain_is_sorted() {
        prop::check("priority-buffer-sorted", 100, |g| {
            let mut b = PriorityBuffer::new(1);
            let n = g.usize_in(1, 50);
            for i in 0..n {
                b.push(0, e(g.f64_in(-100.0, 100.0), g.f64_in(0.0, 10.0),
                            i as u64));
            }
            let drained = b.drain_sorted(0);
            assert_eq!(drained.len(), n);
            for w in drained.windows(2) {
                assert!(
                    w[0].priority < w[1].priority
                        || (w[0].priority == w[1].priority
                            && (w[0].arrival_ms, w[0].id)
                                <= (w[1].arrival_ms, w[1].id)),
                    "out of order: {w:?}"
                );
            }
        });
    }

    // ---- TenantQueues (shaped persistent index) ----

    use crate::coordinator::job::Job;
    use std::collections::BTreeMap as Map;

    /// Test shaper: shaped key = base + per-tenant offset, with explicit
    /// epochs the test bumps by hand.
    #[derive(Default)]
    struct OffsetShaper {
        offsets: Map<String, f64>,
        epochs: Map<String, u64>,
    }

    impl OffsetShaper {
        fn set(&mut self, tenant: &str, offset: f64) {
            self.offsets.insert(tenant.to_owned(), offset);
            *self.epochs.entry(tenant.to_owned()).or_insert(0) += 1;
        }
    }

    impl FoldedShaper for OffsetShaper {
        fn shape_folded(&self, job: &Job, base_folded: f64) -> f64 {
            let off = job
                .tenant
                .as_deref()
                .and_then(|t| self.offsets.get(t))
                .copied()
                .unwrap_or(0.0);
            base_folded + off
        }

        fn tenant_epoch(&self, tenant: Option<&str>) -> u64 {
            tenant
                .and_then(|t| self.epochs.get(t))
                .copied()
                .unwrap_or(0)
        }
    }

    fn tenant_table(jobs: &[(Option<&str>, f64)]) -> (JobTable, Vec<JobId>) {
        let mut table = JobTable::new();
        let mut ids = Vec::new();
        for (tenant, arrival) in jobs {
            let t = tenant.map(str::to_owned);
            let a = *arrival;
            ids.push(table.insert_with(|id| {
                let mut j = Job::new(id, vec![1], 10, 0, a);
                j.tenant = t;
                j
            }));
        }
        (table, ids)
    }

    fn shaped(shaper: &OffsetShaper, table: &JobTable, id: JobId,
              base: f64) -> ShapedEntry {
        ShapedEntry {
            entry: Entry {
                priority: shaper.shape_folded(&table[id], base),
                arrival_ms: table[id].arrival_ms,
                id,
            },
            base_folded: base,
        }
    }

    #[test]
    fn tenant_queues_pop_order_matches_global_heap() {
        let (table, ids) = tenant_table(&[
            (Some("a"), 0.0),
            (Some("b"), 1.0),
            (None, 2.0),
            (Some("a"), 3.0),
            (Some("b"), 4.0),
        ]);
        let mut sh = OffsetShaper::default();
        sh.set("a", 10.0);
        sh.set("b", 0.0);

        let bases = [5.0, 7.0, 1.0, 2.0, 30.0];
        let mut tq = TenantQueues::new();
        let mut global = BinaryHeap::new();
        for (&id, &b) in ids.iter().zip(&bases) {
            let se = shaped(&sh, &table, id, b);
            tq.push(table[id].tenant.as_deref(),
                    sh.tenant_epoch(table[id].tenant.as_deref()), se);
            global.push(se);
        }
        assert_eq!(tq.len(), 5);
        while let Some(expect) = global.pop() {
            assert_eq!(tq.pop_best(), Some(expect));
        }
        assert!(tq.pop_best().is_none());
        assert!(tq.is_empty());
    }

    #[test]
    fn rekey_touches_only_changed_tenant_and_restores_order() {
        let (table, ids) = tenant_table(&[
            (Some("a"), 0.0),
            (Some("a"), 1.0),
            (Some("b"), 2.0),
            (Some("b"), 3.0),
        ]);
        let mut sh = OffsetShaper::default();
        sh.set("a", 0.0);
        sh.set("b", 0.0);

        let bases = [4.0, 8.0, 5.0, 6.0];
        let mut tq = TenantQueues::new();
        for (&id, &b) in ids.iter().zip(&bases) {
            let se = shaped(&sh, &table, id, b);
            tq.push(table[id].tenant.as_deref(),
                    sh.tenant_epoch(table[id].tenant.as_deref()), se);
        }
        // no epoch movement -> nothing re-keyed
        assert_eq!(tq.rekey_stale(&sh, &table), 0);

        // tenant "a" gets a big offset: only its 2 entries re-key, and the
        // global order now puts both "b" jobs first
        sh.set("a", 100.0);
        assert_eq!(tq.rekey_stale(&sh, &table), 2);
        let mut order = Vec::new();
        tq.drain_sorted_into(&mut order);
        let got: Vec<u64> = order.iter().map(|e| e.id.raw()).collect();
        assert_eq!(got, vec![ids[2].raw(), ids[3].raw(), ids[0].raw(),
                             ids[1].raw()]);
        assert_eq!(order[0].priority, 5.0);
        assert_eq!(order[2].priority, 104.0);
    }

    #[test]
    fn prop_tenant_queues_match_single_heap_under_churn() {
        prop::check("tenant-queues-vs-heap", 50, |g| {
            let tenants = ["a", "b", "c"];
            let n = g.usize_in(1, 40);
            let spec: Vec<(Option<&str>, f64)> = (0..n)
                .map(|_| {
                    let t = if g.bool() {
                        Some(tenants[g.usize_in(0, tenants.len() - 1)])
                    } else {
                        None
                    };
                    (t, g.f64_in(0.0, 10.0))
                })
                .collect();
            let (table, ids) = tenant_table(&spec);
            let mut sh = OffsetShaper::default();
            for t in tenants {
                sh.set(t, g.f64_in(-50.0, 50.0));
            }

            let mut tq = TenantQueues::new();
            let mut live: Vec<(JobId, f64)> = Vec::new();
            for &id in &ids {
                let b = g.f64_in(-100.0, 100.0);
                tq.push(table[id].tenant.as_deref(),
                        sh.tenant_epoch(table[id].tenant.as_deref()),
                        shaped(&sh, &table, id, b));
                live.push((id, b));
            }
            for _ in 0..4 {
                // churn one tenant's offset, re-key, then pop a few and
                // compare against a fresh full sort of the live set
                sh.set(tenants[g.usize_in(0, tenants.len() - 1)],
                       g.f64_in(-50.0, 50.0));
                tq.rekey_stale(&sh, &table);
                let mut expect: Vec<Entry> = live
                    .iter()
                    .map(|&(id, b)| shaped(&sh, &table, id, b).entry)
                    .collect();
                expect.sort_unstable_by(|a, b| b.cmp(a)); // ascending keys
                let k = g.usize_in(1, 4).min(live.len());
                for want in expect.iter().take(k) {
                    let got = tq.pop_best().unwrap();
                    assert_eq!(&got.entry, want);
                    live.retain(|&(id, _)| id != want.id);
                }
                if live.is_empty() {
                    break;
                }
            }
        });
    }
}
