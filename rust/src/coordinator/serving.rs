//! The stepped, event-driven coordinator — paper §4.1 Algorithm 1 as a
//! first-class API instead of a closed loop.
//!
//! [`Coordinator`] owns the serving state (job table, per-node queues,
//! load balancer, priority buffer, batcher, preemption policy) and drives
//! a backend of engines for the duration of a run.  The serving loop
//! is decomposed into composable steps:
//!
//! * [`Coordinator::ingest`] — admit arrivals due at `now` (Algorithm 1
//!   lines 1–5: load-balance each new job onto a node).
//! * [`Coordinator::poll_completions`] — apply window outcomes whose
//!   (virtual) completion time has passed, and drain finished windows off
//!   the worker-pool completion channel in threaded wall-clock mode.
//! * [`Coordinator::dispatch`] — for every idle worker with queued jobs:
//!   fold newly-changed jobs into the node's **persistent order index**,
//!   select the top-k batch, and execute one scheduling window
//!   (Algorithm 1 lines 6–20).  Only jobs whose priority input actually
//!   changed since the last window — ran and got re-predicted, newly
//!   admitted, or spilled back by an error path — are re-keyed;
//!   anti-starvation aging is folded into a time-invariant key (see
//!   [`Scheduler::refresh_folded`]), so the steady-state cost per window
//!   is O(k log n) for a batch of k against a backlog of n, not the
//!   O(n log n) full rebuild.  A [`PriorityShaper`] that exposes a
//!   [`FoldedShaper`] view (the SLO policy without a shedding band, WFQ
//!   over a foldable inner — see `as_folded`) keeps the incremental
//!   path: its per-tenant shaping offsets fold into the key, and when a
//!   tenant's offset moves the coordinator re-keys **only that tenant's
//!   lane** (per-tenant epochs, see
//!   [`TenantQueues`](super::priority_buffer::TenantQueues)).
//!   A non-foldable shaper — or forcing
//!   [`CoordinatorBuilder::full_rebuild`] — selects the classic
//!   re-key-everything path instead; both paths produce bit-identical
//!   virtual-clock reports (regression-tested per policy and shaper).
//!
//!   Dispatch itself is split into three phases: a serial *pre-phase*
//!   (iteration accounting + predictor refresh — the scheduler is
//!   `&mut`), a *plan* phase that runs each ready node's index
//!   maintenance, top-k pops, and victim ranking — in parallel on a
//!   small persistent [`DispatchShards`] pool when
//!   [`ServeConfig::dispatch_shards`] > 1 — and a serial *apply* phase
//!   that admits, records, and executes windows in ascending node
//!   order.  Per-node plans read only shared snapshots and write only
//!   their own node's state, and the apply order is fixed, so reports
//!   are bit-identical regardless of shard count.
//! * [`Coordinator::step`] — one full iteration of the above plus clock
//!   advance when nothing could run; returns a [`StepOutcome`].
//! * [`Coordinator::run_to_completion`] — step until every job finished,
//!   then return the [`ServeReport`].
//!
//! Construction goes through [`CoordinatorBuilder`], which extends
//! [`ServeConfig`] with [`EventSink`] observers (job admitted / batch
//! formed / window done / job finished / preempted) for metrics, logging,
//! and policy experiments.  The original `run_serving` free function
//! survives in [`frontend`](super::frontend) as a thin wrapper over this
//! type and produces identical reports.
//!
//! Both evaluation modes of the paper are supported via [`ClockMode`]:
//! virtual (discrete-event; engine `service_ms` advances a simulated
//! timeline) and wall (real time; arrivals are waited for).  The
//! scheduling-iteration structure is identical in both.  Engines attach
//! through one of two backends:
//!
//! * **inline** ([`CoordinatorBuilder::build`]) — the coordinator borrows
//!   the engines and executes every window on the calling thread.  This
//!   is the only backend virtual mode accepts, and its code path is
//!   untouched by the threaded runtime, so simulated reports stay
//!   bit-identical.
//! * **pooled** ([`CoordinatorBuilder::build_pooled`]) — wall-clock only:
//!   engines live on [`WorkerPool`] threads, dispatch sends each formed
//!   batch over an mpsc channel, and completions drain asynchronously, so
//!   scheduling windows genuinely overlap across multi-worker configs
//!   (the paper's one-vLLM-per-pod deployment, in-process).
//! * **remote** ([`CoordinatorBuilder::build_remote`]) — the same pooled
//!   code path over a [`WorkerTransport`] whose workers are TCP pod
//!   connections ([`RemoteWorkerPool`], `elis worker --connect`): the
//!   paper's §5 cross-machine StatefulSet topology.  Worker-loss
//!   [`failover`](CoordinatorBuilder::failover) defaults on — a pod that
//!   vanishes mid-window has the window rolled back (partial admits
//!   wiped) and its jobs re-balanced onto survivors, resuming from the
//!   tokens the coordinator already holds.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::cluster::pool::{WindowDone, WorkerCmd, WorkerPool,
                           WorkerTransport};
use crate::cluster::remote::RemoteWorkerPool;
use crate::engine::{Engine, SeqSpec, WindowOutcome};
use crate::metrics::{JobRecord, ServeReport};
use crate::predictor::ObservedCompletion;
use crate::workload::TraceRequest;

use super::batcher::Batcher;
use super::events::{DecisionRecord, EventSink, FinishStats, JobMeta,
                    PodExec, WindowEvents, WindowJobEvent};
use super::job::{Job, JobId, JobState, JobTable};
use super::load_balancer::{GlobalState, LbStrategy, LoadBalancer};
use super::preemption::PreemptionPolicy;
use super::priority_buffer::{Entry, ShapedEntry, TenantQueues};
use super::scheduler::{FoldedShaper, PriorityShaper, Scheduler};
use super::shards::DispatchShards;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClockMode {
    /// discrete-event simulation (engine service_ms drives time)
    Virtual,
    /// real time (arrivals waited for)
    Wall,
}

#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub workers: usize,
    pub max_batch: usize,
    pub lb: LbStrategy,
    pub preemption: PreemptionPolicy,
    /// fixed extra scheduling cost added to the virtual timeline per
    /// iteration (models the paper's measured ~11 ms overhead; 0 = off)
    pub overhead_ms_per_iter: f64,
    pub clock: ClockMode,
    pub seed: u64,
    /// hard safety cap on scheduling iterations (0 = none)
    pub max_iterations: u64,
    /// wall mode: longest idle sleep (ms) before re-checking for work, so
    /// requests streamed in via [`Coordinator::push_request`] (e.g. the
    /// HTTP frontend) and pool completions are picked up promptly instead
    /// of waiting out the full gap to the next known arrival.  Ignored in
    /// virtual mode (the simulated clock jumps exactly).
    pub idle_tick_ms: f64,
    /// Dispatch-plan parallelism: per-node index maintenance / top-k /
    /// victim ranking run on this many shard threads.  `1` (the default)
    /// plans inline on the coordinator thread; `0` = auto (about half the
    /// machine's cores).  Always capped at `workers` — a shard never has
    /// less than one node — and ignored on the rebuild path (which stays
    /// serial as the reference implementation).  Shard count never
    /// changes the schedule: plans are applied serially in node order.
    pub dispatch_shards: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 1,
            max_batch: 4,
            lb: LbStrategy::MinLoad,
            preemption: PreemptionPolicy::default(),
            overhead_ms_per_iter: 0.0,
            clock: ClockMode::Virtual,
            seed: 1,
            max_iterations: 0,
            idle_tick_ms: 10.0,
            dispatch_shards: 1,
        }
    }
}

/// What one [`Coordinator::step`] did.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepOutcome {
    /// coordinator time after the step (virtual or wall ms)
    pub now_ms: f64,
    /// arrivals admitted this step
    pub admitted: usize,
    /// pending window outcomes applied this step
    pub completed: usize,
    /// scheduling windows dispatched this step
    pub dispatched: usize,
    /// no worker could run, so the clock advanced (virtual) or slept (wall)
    pub idled: bool,
    /// every job has finished; further steps are no-ops
    pub done: bool,
}

/// A window in flight on an inline worker (virtual mode: outcome applies
/// at `done_at` on the simulated timeline).
struct PendingWindow {
    done_at: f64,
    outcome: WindowOutcome,
    batch: Vec<JobId>,
}

struct WorkerSlot {
    /// virtual mode: executed outcome waiting for its completion time
    pending: Option<PendingWindow>,
    /// pooled wall mode: a window is running on the worker's thread
    in_flight: bool,
}

/// What a failed window hand-off must return to the node's pool: the
/// rebuild path drains the whole queue per window (so everything spills),
/// the incremental path only ever removes the batch from its index.
#[derive(Debug, Clone, Copy)]
enum SpillOnError {
    FullOrder,
    BatchOnly,
}

/// A queued-but-engine-resident job's cached ranking key: incremental
/// mode never re-reads the table for victim ranking, it keeps the folded
/// base and the shaped key here and lazily re-shapes when the job's
/// tenant epoch moved (same [`FoldedShaper`] contract as the index).
#[derive(Debug, Clone, Copy)]
struct WarmEntry {
    key: f64,
    base_folded: f64,
    arrival_ms: f64,
    epoch: u64,
}

/// One dispatch round's outputs for a node, produced by the (possibly
/// sharded) plan phase and consumed by the serial apply phase.  All the
/// vectors are reused across rounds.
struct NodePlan {
    /// node passed this round's dispatch guard (idle, alive, has work)
    ready: bool,
    /// global iteration number assigned to this window (serial pre-phase)
    window: u64,
    /// the engine's own batch cap this window
    engine_cap: usize,
    /// batch-cap context reported in the [`DecisionRecord`]
    cap: usize,
    /// queue depth observed at dispatch entry
    depth: usize,
    /// a victim ranking was built (preemption budget > 0)
    rank: bool,
    /// which shard chunk planned this node (0 when planning ran inline)
    shard: usize,
    /// plan-phase wall time, folded into the window's overhead metric
    sched_ns: u128,
    /// the selected batch, highest priority first
    batch: Vec<Entry>,
    /// rebuild path only: the sorted remainder behind the batch prefix
    rest: Vec<Entry>,
    /// preemption-victim order shipped to the engine (raw ids)
    victims: Vec<u64>,
    /// what an error hand-off must return to `pending`
    spill: SpillOnError,
    // scratch for the victim ranking
    ventries: Vec<Entry>,
    ranked: Vec<(JobId, usize)>,
}

impl NodePlan {
    fn new() -> NodePlan {
        NodePlan {
            ready: false,
            window: 0,
            engine_cap: 0,
            cap: 0,
            depth: 0,
            rank: false,
            shard: 0,
            sched_ns: 0,
            batch: Vec::new(),
            rest: Vec::new(),
            victims: Vec::new(),
            spill: SpillOnError::BatchOnly,
            ventries: Vec::new(),
            ranked: Vec::new(),
        }
    }
}

/// Everything dispatch needs that belongs to exactly one node, grouped so
/// the plan phase can hand each shard a disjoint `&mut` chunk.
struct NodeSched {
    /// Waiting jobs whose order key is missing or stale.  In incremental
    /// mode this is the *pending/dirty* list — everything that changed
    /// since the node's last window (new admits, returned batch members,
    /// error spills) — and the rest of the backlog lives keyed in the
    /// index.  In rebuild mode the index is drained every window, so this
    /// list is simply the whole pool.
    pending: Vec<JobId>,
    /// unshaped order index (min-heap on the folded key); also the
    /// rebuild path's per-window sort scratch
    flat: std::collections::BinaryHeap<Entry>,
    /// shaped order index: per-tenant lanes with epoch-stamped keys;
    /// `Some` exactly when a foldable shaper runs incrementally
    shaped: Option<TenantQueues>,
    /// ids in the index that may still hold engine KV state (admitted by
    /// an earlier batch, not since evicted) — the only preemption-victim
    /// candidates besides the batch itself.  Pruned on eviction;
    /// re-entered through the pending fold.
    warm: HashMap<JobId, WarmEntry>,
    plan: NodePlan,
}

impl NodeSched {
    fn new(shaped: bool) -> NodeSched {
        NodeSched {
            pending: Vec::new(),
            flat: std::collections::BinaryHeap::new(),
            shaped: shaped.then(TenantQueues::new),
            warm: HashMap::new(),
            plan: NodePlan::new(),
        }
    }

    fn index_len(&self) -> usize {
        self.flat.len() + self.shaped.as_ref().map_or(0, TenantQueues::len)
    }

    fn has_work(&self) -> bool {
        !self.pending.is_empty() || self.index_len() > 0
    }
}

/// A window's job-scoped event recorded during state mutation and
/// delivered afterwards (ids only — `JobMeta` borrows are resolved against
/// the then-immutable table at delivery time).
#[derive(Debug, Clone, Copy)]
enum PendingOutcomeEvent {
    Progress(JobId, usize),
    Finished(JobId, FinishStats),
    Preempted(JobId),
}

fn job_meta(table: &JobTable, id: JobId) -> JobMeta<'_> {
    let j = &table[id];
    JobMeta {
        id,
        tenant: j.tenant.as_deref(),
        arrival_ms: j.arrival_ms,
        prompt_len: j.prompt.len(),
        total_len: j.total_len,
    }
}

/// Where the engines live: borrowed and driven inline on the calling
/// thread, or behind a [`WorkerTransport`] — the in-process
/// [`WorkerPool`] (one OS thread per engine) or the
/// [`RemoteWorkerPool`] (one registered TCP pod connection per worker).
enum Backend<'a> {
    Inline(&'a mut [Box<dyn Engine>]),
    Pool(Box<dyn WorkerTransport>),
}

impl<'a> Backend<'a> {
    fn max_batch(&self, worker: usize) -> usize {
        match self {
            Backend::Inline(engines) => engines[worker].max_batch(),
            Backend::Pool(pool) => pool.max_batch(worker),
        }
    }

    /// Drop a finished sequence's engine state (best-effort for a pooled
    /// worker whose thread died — the run is already failing then).
    fn remove(&mut self, worker: usize, seq_id: u64) {
        match self {
            Backend::Inline(engines) => engines[worker].remove(seq_id),
            Backend::Pool(pool) => {
                let _ = pool.send(worker, WorkerCmd::Remove(seq_id));
            }
        }
    }
}

/// Builder for [`Coordinator`]: a [`ServeConfig`] plus observers and an
/// optional priority shaper.
#[derive(Default)]
pub struct CoordinatorBuilder {
    cfg: ServeConfig,
    sinks: Vec<Box<dyn EventSink>>,
    shaper: Option<Box<dyn PriorityShaper>>,
    force_rebuild: bool,
    /// worker-loss policy for pooled backends; `None` = the backend's
    /// default (remote pools fail over, the in-process pool fails fast)
    failover: Option<bool>,
}

impl CoordinatorBuilder {
    pub fn new() -> CoordinatorBuilder {
        CoordinatorBuilder::default()
    }

    pub fn from_config(cfg: ServeConfig) -> CoordinatorBuilder {
        CoordinatorBuilder { cfg, ..CoordinatorBuilder::default() }
    }

    pub fn workers(mut self, workers: usize) -> Self {
        self.cfg.workers = workers;
        self
    }

    pub fn max_batch(mut self, max_batch: usize) -> Self {
        self.cfg.max_batch = max_batch;
        self
    }

    pub fn lb(mut self, lb: LbStrategy) -> Self {
        self.cfg.lb = lb;
        self
    }

    pub fn preemption(mut self, preemption: PreemptionPolicy) -> Self {
        self.cfg.preemption = preemption;
        self
    }

    pub fn overhead_ms_per_iter(mut self, ms: f64) -> Self {
        self.cfg.overhead_ms_per_iter = ms;
        self
    }

    pub fn clock(mut self, clock: ClockMode) -> Self {
        self.cfg.clock = clock;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn max_iterations(mut self, cap: u64) -> Self {
        self.cfg.max_iterations = cap;
        self
    }

    /// Register an observer; sinks fire synchronously, in registration
    /// order, from inside the serving loop.
    pub fn sink(mut self, sink: Box<dyn EventSink>) -> Self {
        self.sinks.push(sink);
        self
    }

    /// Register a priority shaper: dispatch passes every queued job's base
    /// priority through it before ordering (the SLO-policy seam).  Without
    /// one, scheduling is bit-identical to the pre-shaper coordinator.
    ///
    /// A shaper that exposes a [`FoldedShaper`] view (see
    /// [`PriorityShaper::as_folded`]) keeps the incremental dispatch
    /// path: its shaping offset folds into the time-invariant key, and a
    /// round only re-keys the lanes of tenants whose offset actually
    /// moved — O(k log n + changed-tenant re-keys) per window.  A shaper
    /// without one (its output drifts per-job per-round) selects the
    /// re-shape-everything path: O(n log n) per window.
    pub fn priority_shaper(mut self, shaper: Box<dyn PriorityShaper>) -> Self {
        self.shaper = Some(shaper);
        self
    }

    /// Dispatch-plan parallelism (see [`ServeConfig::dispatch_shards`]):
    /// `1` = plan inline (default), `0` = auto-size to the machine, `n` =
    /// exactly n shard threads (capped at the worker count).
    pub fn dispatch_shards(mut self, shards: usize) -> Self {
        self.cfg.dispatch_shards = shards;
        self
    }

    /// Force the per-window full-rebuild dispatch path even without a
    /// shaper.  The schedule is bit-identical to the default incremental
    /// index — this knob exists for differential tests and for the
    /// dispatch-cost-at-depth benches that measure the gap.
    pub fn full_rebuild(mut self, on: bool) -> Self {
        self.force_rebuild = on;
        self
    }

    /// Worker-loss policy for pooled backends.  With failover **on**, a
    /// worker whose transport reports it gone is marked dead, its window
    /// rolls back (partial admits wiped via the reply's `fresh` list),
    /// and every job homed on it is re-balanced onto surviving workers —
    /// partially-generated jobs resume where they left off (see
    /// [`SeqSpec::resume`](crate::engine::SeqSpec)).  The run only fails
    /// once *every* worker is lost.  With failover **off**, a lost worker
    /// fails the run fast (the in-process pool's historical behaviour: a
    /// worker thread only dies with the process's own engine panicking).
    ///
    /// Defaults per backend: [`build_remote`](Self::build_remote) → on,
    /// [`build_pooled`](Self::build_pooled) → off.
    pub fn failover(mut self, on: bool) -> Self {
        self.failover = Some(on);
        self
    }

    /// Load `trace` into a job table and wire up the serving state.
    /// `engines[i]` is worker i's backend, driven inline on the calling
    /// thread; `scheduler` owns the policy and the length predictor.  An
    /// empty trace is allowed: the coordinator starts [`done`] and waits
    /// for [`Coordinator::push_request`].
    ///
    /// [`done`]: Coordinator::is_done
    pub fn build<'a>(self, trace: &[TraceRequest],
                     engines: &'a mut [Box<dyn Engine>],
                     scheduler: &'a mut Scheduler)
                     -> Result<Coordinator<'a>> {
        if engines.len() != self.cfg.workers {
            bail!("expected {} engines, got {}", self.cfg.workers,
                  engines.len());
        }
        // preemption frequency control (§3.4) is enforced inside the
        // engines: each may evict at most this many sequences per window
        for e in engines.iter_mut() {
            e.set_preemption_cap(self.cfg.preemption.max_per_iteration);
        }
        self.finish(trace, Backend::Inline(engines), scheduler)
    }

    /// Like [`build`](Self::build), but the engines are owned by a
    /// threaded [`WorkerPool`] (one OS thread per engine): dispatch sends
    /// each formed batch over the worker's channel and
    /// [`Coordinator::poll_completions`] drains the shared completion
    /// channel, so windows overlap across workers.  Wall-clock only —
    /// virtual mode needs synchronous windows for its deterministic
    /// timeline (and gains nothing from threads).
    pub fn build_pooled<'a>(self, trace: &[TraceRequest], pool: WorkerPool,
                            scheduler: &'a mut Scheduler)
                            -> Result<Coordinator<'a>> {
        self.build_transport(trace, Box::new(pool), scheduler, false)
    }

    /// Like [`build_pooled`](Self::build_pooled), but the workers are
    /// **remote pods** registered over TCP (`elis worker --connect`): the
    /// same dispatch/completion code drives them through the
    /// [`WorkerTransport`] boundary, and worker-loss
    /// [`failover`](Self::failover) defaults **on** — a pod that
    /// disconnects mid-window has its window rolled back and its jobs
    /// re-homed onto the surviving pods.  Wall-clock only, like every
    /// pooled backend.
    pub fn build_remote<'a>(self, trace: &[TraceRequest],
                            pool: RemoteWorkerPool,
                            scheduler: &'a mut Scheduler)
                            -> Result<Coordinator<'a>> {
        self.build_transport(trace, Box::new(pool), scheduler, true)
    }

    /// The generic pooled constructor behind
    /// [`build_pooled`](Self::build_pooled) /
    /// [`build_remote`](Self::build_remote): any [`WorkerTransport`]
    /// carrying the `WorkerCmd`/`WindowDone` protocol works, which is
    /// also the seam fault-injection tests plug custom transports into.
    /// `failover_default` applies when [`failover`](Self::failover) was
    /// not set explicitly.
    pub fn build_transport<'a>(mut self, trace: &[TraceRequest],
                               pool: Box<dyn WorkerTransport>,
                               scheduler: &'a mut Scheduler,
                               failover_default: bool)
                               -> Result<Coordinator<'a>> {
        if self.cfg.clock != ClockMode::Wall {
            bail!("a pooled backend requires ClockMode::Wall \
                   (virtual mode executes windows inline)");
        }
        if pool.workers() != self.cfg.workers {
            bail!("expected {} pool workers, got {}", self.cfg.workers,
                  pool.workers());
        }
        for w in 0..pool.workers() {
            pool.send(w, WorkerCmd::SetPreemptionCap(
                self.cfg.preemption.max_per_iteration))?;
        }
        if self.failover.is_none() {
            self.failover = Some(failover_default);
        }
        self.finish(trace, Backend::Pool(pool), scheduler)
    }

    fn finish<'a>(self, trace: &[TraceRequest], backend: Backend<'a>,
                  scheduler: &'a mut Scheduler) -> Result<Coordinator<'a>> {
        let CoordinatorBuilder { cfg, sinks, shaper, force_rebuild,
                                 failover } = self;
        let mut table = JobTable::with_capacity(trace.len());
        let mut arrivals: Vec<(f64, JobId)> = Vec::with_capacity(trace.len());
        for r in trace {
            let id = table.insert_with(|id| {
                let mut job = Job::new(id, r.prompt.clone(), r.total_len,
                                       r.topic, r.arrival_ms);
                job.tenant = r.tenant.clone();
                job
            });
            arrivals.push((r.arrival_ms, id));
        }
        // stable: equal arrival times keep trace order
        arrivals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

        let workers_n = cfg.workers;
        // a shaper keeps the incremental path iff its shaping folds into
        // the time-invariant key (per-tenant offsets with epochs);
        // otherwise keys drift per-job per-round and the node needs the
        // re-key-everything path
        let incremental = !force_rebuild
            && shaper.as_ref().map_or(true, |s| s.as_folded().is_some());
        // the shaped index stores full shaped keys (base + tenant offset),
        // so it only exists when a foldable shaper runs incrementally
        let shaped_index = incremental && shaper.is_some();
        // dispatch-shard resolution: 0 = auto (about half the cores), and
        // never more shards than nodes.  A pool is only worth spawning
        // when the incremental plan phase can actually run >1 node
        // concurrently; the rebuild reference path stays serial.
        let requested = if cfg.dispatch_shards == 0 {
            std::thread::available_parallelism()
                .map_or(1, |p| (p.get() / 2).max(1))
        } else {
            cfg.dispatch_shards
        };
        let n_shards = requested.min(workers_n).max(1);
        let shards = (incremental && n_shards > 1)
            .then(|| DispatchShards::new(n_shards));
        Ok(Coordinator {
            backend,
            scheduler,
            table,
            arrivals,
            next_arrival: 0,
            nodes: (0..workers_n).map(|_| NodeSched::new(shaped_index))
                .collect(),
            workers: (0..workers_n)
                .map(|_| WorkerSlot { pending: None, in_flight: false })
                .collect(),
            state: GlobalState::new(workers_n),
            lb: LoadBalancer::new(cfg.lb, cfg.seed),
            batcher: Batcher::new(workers_n, cfg.max_batch),
            incremental,
            n_shards,
            shards,
            dispatch_rounds: 0,
            dead: vec![false; workers_n],
            failover: failover.unwrap_or(false),
            pending_scratch: Vec::new(),
            order_scratch: Vec::new(),
            events_scratch: Vec::new(),
            sinks,
            shaper,
            now: 0.0,
            wall_start: Instant::now(),
            finished: 0,
            total_preemptions: 0,
            sched_overhead_ns: 0,
            iterations: 0,
            cfg,
        })
    }
}

/// The serving frontend: owns jobs, queues, balancer, buffer, and batcher;
/// drives an inline (borrowed) or pooled (owned, threaded) engine backend
/// for the lifetime of the run.
pub struct Coordinator<'a> {
    cfg: ServeConfig,
    backend: Backend<'a>,
    scheduler: &'a mut Scheduler,
    table: JobTable,
    /// (arrival_ms, id), sorted by arrival time
    arrivals: Vec<(f64, JobId)>,
    next_arrival: usize,
    /// Per-node scheduling state — pending/dirty list, persistent order
    /// index (flat or shaped), warm set, and the current round's plan —
    /// grouped per node so the plan phase can hand each dispatch shard a
    /// disjoint `&mut` chunk.
    nodes: Vec<NodeSched>,
    workers: Vec<WorkerSlot>,
    state: GlobalState,
    lb: LoadBalancer,
    batcher: Batcher,
    /// false when the registered shaper can't fold (or a reference run
    /// forced the rebuild path)
    incremental: bool,
    /// resolved dispatch-shard count (≥ 1; see
    /// [`ServeConfig::dispatch_shards`])
    n_shards: usize,
    /// the persistent planner pool; `None` when planning runs inline
    /// (single shard, or rebuild path)
    shards: Option<DispatchShards>,
    /// dispatch rounds begun — the monotone round id handed to
    /// [`PriorityShaper::begin_round`] so shapers snapshot telemetry once
    /// per round instead of once per (node, window)
    dispatch_rounds: u64,
    /// Workers whose transport connection/thread is gone.  Dead workers
    /// are skipped by dispatch and excluded from load balancing; set only
    /// through [`fail_over`](Self::fail_over) (failover-enabled pooled
    /// backends).
    dead: Vec<bool>,
    /// see [`CoordinatorBuilder::failover`]
    failover: bool,
    // -- cross-round scratch buffers (allocations reused) --
    pending_scratch: Vec<JobId>,
    order_scratch: Vec<Entry>,
    events_scratch: Vec<PendingOutcomeEvent>,
    sinks: Vec<Box<dyn EventSink>>,
    shaper: Option<Box<dyn PriorityShaper>>,
    now: f64,
    wall_start: Instant,
    finished: usize,
    total_preemptions: u64,
    sched_overhead_ns: u128,
    iterations: u64,
}

impl<'a> Coordinator<'a> {
    // ---- observers / accessors ------------------------------------------

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Current coordinator time (virtual or wall ms).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// The time to stamp externally-arriving work with
    /// ([`push_request`](Self::push_request)).  Wall mode reads the live
    /// wall clock — [`now`](Self::now) only advances inside `step()`, so
    /// it goes stale while a serving loop idles between steps — and a
    /// stale stamp would inflate the job's JCT/TTFT and mislead
    /// deadline policies.  Virtual mode returns the simulated now.
    pub fn admission_now_ms(&self) -> f64 {
        match self.cfg.clock {
            ClockMode::Wall => self.wall_ms(),
            ClockMode::Virtual => self.now,
        }
    }

    pub fn total_jobs(&self) -> usize {
        self.table.len()
    }

    pub fn finished_jobs(&self) -> usize {
        self.finished
    }

    pub fn is_done(&self) -> bool {
        self.finished == self.table.len()
    }

    /// Scheduling iterations executed so far.
    pub fn iterations(&self) -> u64 {
        self.iterations
    }

    pub fn table(&self) -> &JobTable {
        &self.table
    }

    /// Jobs waiting in `node`'s pool (excludes the running batch): the
    /// keyed entries in the node's order index plus the pending re-keys.
    pub fn queue_len(&self, node: usize) -> usize {
        self.nodes[node].pending.len() + self.nodes[node].index_len()
    }

    /// Resolved dispatch-plan parallelism: how many shard threads the
    /// plan phase fans out over (1 = inline).  Exposed for the metrics
    /// exporter's `elis_dispatch_shards` gauge and the shard-scaling
    /// benches.
    pub fn dispatch_shards(&self) -> usize {
        if self.shards.is_some() { self.n_shards } else { 1 }
    }

    /// Cumulative scheduling-overhead wall time (ms) across all iterations
    /// so far — the numerator of `sched_overhead_ms_avg`, exposed for the
    /// dispatch-cost-at-depth benches that difference it between steps.
    pub fn sched_overhead_ms_total(&self) -> f64 {
        self.sched_overhead_ns as f64 / 1e6
    }

    /// Per-worker active-job counts maintained by the load balancer.
    pub fn global_state(&self) -> &GlobalState {
        &self.state
    }

    /// Workers marked dead by failover — surfaced by the HTTP frontend's
    /// `/healthz` body so probes see a degraded fleet before it empties.
    pub fn dead_workers(&self) -> usize {
        self.dead.iter().filter(|&&d| d).count()
    }

    pub fn transfer_stats(&self) -> &super::batcher::TransferStats {
        &self.batcher.stats
    }

    fn wall_ms(&self) -> f64 {
        self.wall_start.elapsed().as_secs_f64() * 1e3
    }

    // ---- composable steps -----------------------------------------------

    /// Admit every arrival due at `now` (Algorithm 1 lines 1–5): the load
    /// balancer picks its node and the job joins that node's pool.
    /// Returns the number of jobs admitted.
    pub fn ingest(&mut self, now: f64) -> usize {
        let mut admitted = 0;
        while self.next_arrival < self.arrivals.len()
            && self.arrivals[self.next_arrival].0 <= now
        {
            let (_, id) = self.arrivals[self.next_arrival];
            self.next_arrival += 1;
            let node = self.lb.assign_excluding(&mut self.state, &self.dead);
            self.table[id].node = Some(node);
            self.nodes[node].pending.push(id);
            let meta = job_meta(&self.table, id);
            for s in self.sinks.iter_mut() {
                s.on_job_admitted(&meta, node, now);
            }
            admitted += 1;
        }
        admitted
    }

    /// Streaming admission: append a new request to a (possibly running)
    /// coordinator.  The job is admitted by the next
    /// [`ingest`](Self::ingest) whose `now` has reached its `arrival_ms`
    /// (an arrival already in the past is picked up on the very next
    /// step), so mid-run and out-of-order pushes are each admitted,
    /// scheduled, and counted exactly once.  Returns the new job's id.
    pub fn push_request(&mut self, r: &TraceRequest) -> JobId {
        let id = self.table.insert_with(|id| {
            let mut job = Job::new(id, r.prompt.clone(), r.total_len,
                                   r.topic, r.arrival_ms);
            job.tenant = r.tenant.clone();
            job
        });
        // keep the un-ingested tail of `arrivals` sorted by arrival time;
        // everything before `next_arrival` has already been admitted
        let tail = &self.arrivals[self.next_arrival..];
        let pos = self.next_arrival
            + tail.partition_point(|&(t, _)| t <= r.arrival_ms);
        self.arrivals.insert(pos, (r.arrival_ms, id));
        id
    }

    /// Apply every finished window due at `now`: virtual-mode outcomes
    /// whose simulated completion time has passed, plus (for a pooled
    /// backend) everything waiting on the worker threads' completion
    /// channel.  Inline wall mode applies outcomes directly in
    /// [`dispatch`](Self::dispatch).  Returns the number of windows
    /// applied; errs if a pooled worker reported an admit/window failure
    /// (its batch is returned to the queue first — no job is lost).
    pub fn poll_completions(&mut self, now: f64) -> Result<usize> {
        let mut applied = 0;

        // pooled backend: drain the shared completion channel
        let mut threaded: Vec<WindowDone> = Vec::new();
        if let Backend::Pool(pool) = &mut self.backend {
            while let Some(done) = pool.try_recv_done() {
                threaded.push(done);
            }
        }
        // apply every drained reply before surfacing any error — an early
        // return would discard another worker's already-consumed Ok reply
        // and strand that worker in_flight forever
        let mut first_err: Option<anyhow::Error> = None;
        for done in threaded {
            self.workers[done.worker].in_flight = false;
            match done.outcome {
                Ok(outcome) => {
                    self.apply_outcome(now, outcome, &done.batch, done.worker,
                                       done.trace);
                    applied += 1;
                }
                Err(err) => {
                    // as in the inline error paths: restore the batch so
                    // the coordinator stays consistent for callers that
                    // outlive the error.  The window's *fresh* admits may
                    // have partially landed on the engine — wipe exactly
                    // those (Remove is idempotent) and drop their
                    // engine_admitted flag so a retry re-admits cleanly.
                    for &id in &done.batch {
                        self.table[id].state = JobState::Queued;
                        self.nodes[done.worker].pending.push(id);
                    }
                    for &raw in &done.fresh {
                        let id = JobId::from_raw(raw);
                        if let Some(j) = self.table.get_mut(id) {
                            j.engine_admitted = false;
                        }
                        self.backend.remove(done.worker, raw);
                    }
                    // an error from a *lost* worker (disconnect reply)
                    // under failover re-homes the rolled-back jobs onto
                    // survivors instead of failing the run; an engine
                    // error from a live worker still surfaces
                    let lost = match &self.backend {
                        Backend::Pool(p) => !p.worker_alive(done.worker),
                        Backend::Inline(_) => false,
                    };
                    if self.failover && lost {
                        self.fail_over(done.worker, now)?;
                    } else {
                        first_err.get_or_insert(err);
                    }
                }
            }
        }
        if let Some(err) = first_err {
            return Err(err);
        }

        // liveness sweep.  Without failover: a worker thread that died
        // (engine panic) can never answer its in-flight window — the
        // drain above has already consumed every reply it managed to
        // send, so fail fast instead of idling forever.  With failover: a
        // synthesizing transport (TCP pool) is *guaranteed* to deliver an
        // error reply for the in-flight window, so wait for it (the error
        // branch above then rolls back and fails over); a worker lost
        // while idle is failed over right here.
        let mut lost_idle: Vec<usize> = Vec::new();
        if let Backend::Pool(pool) = &self.backend {
            for w in 0..self.workers.len() {
                if self.dead[w] || pool.worker_alive(w) {
                    continue;
                }
                if self.workers[w].in_flight {
                    if !(self.failover && pool.synthesizes_disconnects()) {
                        bail!("worker thread {w} died with a window in \
                               flight (engine panic?)");
                    }
                } else if self.failover {
                    lost_idle.push(w);
                }
            }
        }
        for w in lost_idle {
            self.fail_over(w, now)?;
        }

        // virtual mode: outcomes whose simulated completion time passed
        let mut due: Vec<(usize, PendingWindow)> = Vec::new();
        for w in 0..self.workers.len() {
            if matches!(&self.workers[w].pending, Some(p) if p.done_at <= now)
            {
                due.push((w, self.workers[w].pending.take().unwrap()));
            }
        }
        // apply in completion-time order (ties: worker index) so sinks and
        // the online predictor see windows chronologically even when the
        // caller jumps `now` past several completions at once
        due.sort_by(|a, b| {
            a.1.done_at.total_cmp(&b.1.done_at).then(a.0.cmp(&b.0))
        });
        applied += due.len();
        for (w, p) in due {
            self.apply_outcome(p.done_at, p.outcome, &p.batch, w, None);
        }
        Ok(applied)
    }

    /// Run one scheduling iteration on every idle worker with queued jobs
    /// (Algorithm 1 lines 6–20): bring the node's order index up to date,
    /// set the preemption-victim order, form the batch, and execute one
    /// window — inline on this thread, or by handing the batch to the
    /// worker's pool thread.  Returns the number of windows dispatched.
    ///
    /// Two key paths (chosen at build time, see
    /// [`CoordinatorBuilder::full_rebuild`]):
    /// * **incremental** (default; kept by foldable shapers): only the
    ///   node's pending jobs — new admits, batch members returned by the
    ///   last window, error spills — are re-keyed (time-invariant folded
    ///   keys, plus the shaper's per-tenant offset when one is set) and
    ///   pushed; the batch is a top-k pop off the persistent index,
    ///   O(k log n) per window plus re-keys for tenants whose shaping
    ///   offset moved since the node's last window.
    /// * **rebuild** (non-foldable shaper / forced): every queued job is
    ///   re-keyed and the whole queue re-sorted, O(n log n) per window.
    ///
    /// Structured as three phases: a serial pre-phase (iteration
    /// accounting, engine caps, predictor refresh), a plan phase — per
    /// node, fanned out over [`DispatchShards`] when configured — and a
    /// serial apply phase in ascending node order.  Shard count never
    /// changes the schedule.
    pub fn dispatch(&mut self, now: f64) -> Result<usize> {
        // phase 0: this round's dispatch guard, per node
        let mut any = false;
        for w in 0..self.cfg.workers {
            let ready = !self.dead[w]
                && self.workers[w].pending.is_none()
                && !self.workers[w].in_flight
                && self.nodes[w].has_work();
            self.nodes[w].plan.ready = ready;
            any |= ready;
        }
        if !any {
            return Ok(0);
        }

        // one shaper round per dispatch call: snapshot live telemetry and
        // advance per-tenant epochs exactly once, off the planning path
        self.dispatch_rounds += 1;
        if let Some(s) = self.shaper.as_mut() {
            s.begin_round(self.dispatch_rounds, now);
        }

        // phase 1 (serial): iteration accounting + predictor refresh over
        // each ready node's pending list — the scheduler (predictor,
        // prediction cache) is `&mut` and stays on this thread
        let fold = self.shaper.as_ref()
            .map_or(true, |s| s.as_folded().is_some());
        for w in 0..self.cfg.workers {
            if !self.nodes[w].plan.ready {
                continue;
            }
            self.iterations += 1;
            if self.cfg.max_iterations > 0
                && self.iterations > self.cfg.max_iterations
            {
                // nothing has been consumed yet this round: every pending
                // list and index is exactly as the guard saw it
                bail!("iteration cap {} exceeded (livelock?)",
                      self.cfg.max_iterations);
            }
            let t = Instant::now();
            let engine_cap = self.backend.max_batch(w);
            let node = &mut self.nodes[w];
            node.plan.window = self.iterations;
            node.plan.engine_cap = engine_cap;
            node.plan.depth = node.pending.len() + node.index_len();
            node.plan.shard = 0;
            if !node.pending.is_empty() {
                let (table, scheduler) =
                    (&mut self.table, &mut *self.scheduler);
                table.with_mut_refs(&node.pending, |refs| if fold {
                    scheduler.refresh_folded(refs)
                } else {
                    scheduler.refresh(refs, now)
                });
            }
            node.plan.sched_ns = t.elapsed().as_nanos();
        }

        // phase 2: per-node planning (index maintenance, top-k, victim
        // ranking) — reads only shared snapshots, writes only its node
        if self.incremental {
            let table = &self.table;
            let folded = self.shaper.as_deref().and_then(|s| s.as_folded());
            let preemption = &self.cfg.preemption;
            let rank = preemption.can_fire();
            let max_batch = self.cfg.max_batch;
            match &self.shards {
                Some(pool) => {
                    let per = self.nodes.len().div_ceil(pool.shards());
                    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = self
                        .nodes
                        .chunks_mut(per)
                        .enumerate()
                        .map(|(ci, chunk)| {
                            let f: Box<dyn FnOnce() + Send + '_> =
                                Box::new(move || {
                                    for ns in chunk.iter_mut() {
                                        if !ns.plan.ready {
                                            continue;
                                        }
                                        ns.plan.shard = ci;
                                        plan_incremental(ns, table, folded,
                                                         preemption, rank,
                                                         max_batch);
                                    }
                                });
                            f
                        })
                        .collect();
                    pool.run(tasks);
                }
                None => {
                    for ns in self.nodes.iter_mut() {
                        if ns.plan.ready {
                            plan_incremental(ns, table, folded, preemption,
                                             rank, max_batch);
                        }
                    }
                }
            }
        } else {
            // rebuild reference path: serial (the shaper may mutate its
            // memo per shape() call)
            for w in 0..self.cfg.workers {
                if self.nodes[w].plan.ready {
                    self.plan_rebuild(w, now);
                }
            }
        }

        // phase 3 (serial, ascending node order): admit, record, execute
        let mut dispatched = 0;
        let mut failed: Option<anyhow::Error> = None;
        for w in 0..self.cfg.workers {
            if !self.nodes[w].plan.ready {
                continue;
            }
            self.nodes[w].plan.ready = false;
            if failed.is_some() {
                // an earlier window already failed the round: return this
                // node's planned (already popped) work to its pending
                // list so no job is lost
                self.spill_planned(w);
                continue;
            }
            match self.execute_planned(w, now) {
                Ok(()) => dispatched += 1,
                Err(err) => {
                    // the hand-off already spilled the window back into
                    // the node's pending list; if the worker died under
                    // our feet and failover is on, re-home its jobs and
                    // keep serving
                    let lost = match &self.backend {
                        Backend::Pool(p) => !p.worker_alive(w),
                        Backend::Inline(_) => false,
                    };
                    if self.failover && lost {
                        if let Err(e) = self.fail_over(w, now) {
                            failed = Some(e);
                        }
                    } else {
                        failed = Some(err);
                    }
                }
            }
        }
        if let Some(err) = failed {
            return Err(err);
        }
        Ok(dispatched)
    }

    /// Mark worker `w` dead and re-home every job still assigned to it —
    /// its pending/dirty list, its keyed order index, and any batch the
    /// error path just spilled back — onto surviving workers via the load
    /// balancer.  Re-homed jobs are re-admitted fresh on their new engine
    /// and resume from the tokens the coordinator already holds
    /// ([`SeqSpec::resume`](crate::engine::SeqSpec)).  Idempotent: late
    /// spills for an already-dead worker re-home on the next call.  Errs
    /// only when no worker is left alive for unfinished work.
    fn fail_over(&mut self, w: usize, now: f64) -> Result<()> {
        let first = !self.dead[w];
        self.dead[w] = true;
        self.workers[w].in_flight = false;
        self.workers[w].pending = None;
        if self.dead.iter().all(|&d| d) && self.finished < self.table.len() {
            bail!("all {} workers are lost with {} jobs unfinished",
                  self.cfg.workers, self.table.len() - self.finished);
        }

        let mut moved = std::mem::take(&mut self.pending_scratch);
        moved.clear();
        {
            let node = &mut self.nodes[w];
            moved.append(&mut node.pending);
            let mut order = std::mem::take(&mut self.order_scratch);
            order.clear();
            match &mut node.shaped {
                Some(tq) => tq.drain_sorted_into(&mut order),
                None => {
                    while let Some(e) = node.flat.pop() {
                        order.push(e);
                    }
                }
            }
            moved.extend(order.iter().map(|e| e.id));
            self.order_scratch = order;
            node.warm.clear();
        }
        for &id in &moved {
            self.table[id].engine_admitted = false;
            // the prompt must travel again to wherever the job lands
            self.batcher.forget(w, id);
            self.state.on_finish(w);
            let node = self.lb.assign_excluding(&mut self.state, &self.dead);
            self.table[id].node = Some(node);
            self.nodes[node].pending.push(id);
        }
        let rehomed = moved.len();
        self.pending_scratch = moved;
        if first || rehomed > 0 {
            for s in self.sinks.iter_mut() {
                s.on_worker_lost(w, rehomed, now);
            }
        }
        Ok(())
    }

    /// Plan one window on node `w`, rebuild path (non-foldable shaper or
    /// forced): re-key and re-sort the entire pool, rank victims over the
    /// full queue — Algorithm 1 as written, through reusable buffers.
    ///
    /// Key choice: a *foldable* shaper keys through the same
    /// `shape_folded` values as the incremental path, so forced-rebuild
    /// reference runs compare bit-for-bit even under shaping.  A
    /// non-foldable shaper gets the *aged* priority as its base (its
    /// whole point is now-relative shaping).  A forced rebuild without a
    /// shaper uses the folded keys — aged and folded keys order
    /// identically in exact arithmetic, but could split an f64-rounding
    /// near-tie, so the reference never mixes key kinds with the path it
    /// is compared against.
    fn plan_rebuild(&mut self, w: usize, now: f64) {
        let t = Instant::now();
        let fold = self.shaper.as_ref()
            .map_or(true, |s| s.as_folded().is_some());

        // re-key every job in the pool (phase 1 already refreshed the
        // bases); an optional shaper adjusts each base priority
        let mut pending = std::mem::take(&mut self.nodes[w].pending);
        for &id in &pending {
            let entry = {
                let j = &self.table[id];
                let base = j.priority.unwrap_or(f64::MAX);
                let priority = match self.shaper.as_mut() {
                    Some(s) if fold => {
                        s.as_folded().unwrap().shape_folded(j, base)
                    }
                    Some(s) => s.shape(j, base, now),
                    None => base,
                };
                Entry { priority, arrival_ms: j.arrival_ms, id }
            };
            self.nodes[w].flat.push(entry);
        }
        pending.clear();
        self.nodes[w].pending = pending;

        // drain fully sorted (highest priority first); the remainder past
        // the batch prefix becomes the node's new pool
        let mut order = std::mem::take(&mut self.nodes[w].plan.batch);
        order.clear();
        while let Some(e) = self.nodes[w].flat.pop() {
            order.push(e);
        }

        let rank = self.cfg.preemption.can_fire();
        let node = &mut self.nodes[w];
        // preemption victim ordering for the engine (skipped when the
        // per-window eviction budget is zero: the engine checks the
        // budget before ever consulting the ranking)
        node.plan.victims.clear();
        if rank {
            node.plan.ranked.clear();
            for e in &order {
                node.plan.ranked.push((e.id, self.table[e.id].preemptions));
            }
            self.cfg.preemption.victim_order_into(&node.plan.ranked,
                                                  &mut node.plan.victims);
        }

        // form the batch from the highest-priority prefix
        let take = self.cfg.max_batch.min(node.plan.engine_cap);
        node.plan.cap = take;
        let cut = take.min(order.len());
        node.plan.rest.clear();
        node.plan.rest.extend_from_slice(&order[cut..]);
        order.truncate(cut);
        node.plan.batch = order;
        node.plan.rank = rank;
        node.plan.spill = SpillOnError::FullOrder;
        node.plan.sched_ns += t.elapsed().as_nanos();
    }

    /// Apply one planned window on node `w` (serial phase 3): admit fresh
    /// batch members, account scheduling overhead, notify sinks, and
    /// execute the window inline or ship it to the worker's pool thread.
    /// On error the plan is spilled back into the node's pending list
    /// first, so no job is ever lost.
    fn execute_planned(&mut self, w: usize, now: f64) -> Result<()> {
        let t_apply = Instant::now();
        let rank = self.nodes[w].plan.rank;
        if rank {
            if let Backend::Inline(engines) = &mut self.backend {
                engines[w].set_priority_order(&self.nodes[w].plan.victims);
            } // pooled: the order ships inside the RunWindow command
        }
        let batch: Vec<JobId> =
            self.nodes[w].plan.batch.iter().map(|e| e.id).collect();

        // admit + (modelled) prompt transfer
        let mut admits: Vec<SeqSpec> = Vec::new();
        for &id in &batch {
            let prompt_tokens = self.table[id].prompt.len();
            if !self.table[id].engine_admitted {
                let spec = {
                    let j = &self.table[id];
                    SeqSpec {
                        id: id.raw(),
                        prompt: j.prompt.clone(),
                        target_total: j.total_len,
                        topic: j.topic,
                        // empty on first admission; after a failover the
                        // new engine resumes from the coordinator's copy
                        // of the response so far
                        resume: j.response.clone(),
                    }
                };
                match &mut self.backend {
                    Backend::Inline(engines) => {
                        if let Err(err) = engines[w].admit(spec) {
                            // restore the pool so the coordinator stays
                            // consistent for callers that outlive the error
                            self.spill_planned(w);
                            return Err(err);
                        }
                    }
                    // pooled: admits run on the worker thread as part of
                    // the RunWindow command; an error comes back through
                    // poll_completions
                    Backend::Pool(_) => admits.push(spec),
                }
                self.table[id].engine_admitted = true;
            }
            self.batcher.mark_prompt_sent(w, id, prompt_tokens);
        }
        let sched_ns =
            self.nodes[w].plan.sched_ns + t_apply.elapsed().as_nanos();
        self.sched_overhead_ns += sched_ns;

        // flight-recorder decision record: what the queue looked like, who
        // was picked (with the folded-key range actually compared), who
        // would be evicted first, which shard planned it, and what the
        // decision cost.  Fired before the victims move into a pooled
        // RunWindow command below.
        {
            let plan = &self.nodes[w].plan;
            let mut key_min = f64::NAN;
            let mut key_max = f64::NAN;
            for e in &plan.batch {
                if !(e.priority >= key_min) {
                    key_min = e.priority;
                }
                if !(e.priority <= key_max) {
                    key_max = e.priority;
                }
            }
            let d = DecisionRecord {
                node: w,
                window: plan.window,
                now_ms: now,
                queue_depth: plan.depth,
                batch: &batch,
                batch_cap: plan.cap,
                victims: &plan.victims,
                shard: plan.shard,
                key_min,
                key_max,
                sched_overhead_ms: sched_ns as f64 / 1e6,
            };
            for s in self.sinks.iter_mut() {
                s.on_window_decision(&d);
            }
        }
        for s in self.sinks.iter_mut() {
            s.on_batch_formed(w, &batch, now);
        }

        // execute one scheduling window
        let window = self.nodes[w].plan.window;
        let raw_batch: Vec<u64> = batch.iter().map(|id| id.raw()).collect();
        if matches!(self.backend, Backend::Pool(_)) {
            // hand the window to the worker's thread; the outcome comes
            // back through poll_completions
            let sent = match &mut self.backend {
                Backend::Pool(pool) => pool.send(w, WorkerCmd::RunWindow {
                    admits: std::mem::take(&mut admits),
                    // move the ranking into the command (no per-window
                    // copy); the plan rebuilds it next window anyway
                    priority_order: if rank {
                        std::mem::take(&mut self.nodes[w].plan.victims)
                    } else {
                        Vec::new()
                    },
                    batch: raw_batch,
                    echo: batch.clone(),
                    // window span id: the pod echoes it back with its own
                    // execute measurement so the timelines stitch; omitted
                    // for workers that didn't negotiate tracing
                    trace: if pool.trace_capable(w) {
                        Some(window)
                    } else {
                        None
                    },
                }),
                Backend::Inline(_) => unreachable!(),
            };
            if let Err(err) = sent {
                self.spill_planned(w);
                return Err(err);
            }
            self.requeue_planned_rest(w);
            for &id in &batch {
                self.table[id].state = JobState::Running;
            }
            self.workers[w].in_flight = true;
        } else {
            let run = match &mut self.backend {
                Backend::Inline(engines) => engines[w].run_window(&raw_batch),
                Backend::Pool(_) => unreachable!(),
            };
            let outcome = match run {
                Ok(o) => o,
                Err(err) => {
                    // as above: no job may be lost on an engine error
                    self.spill_planned(w);
                    return Err(err);
                }
            };

            self.requeue_planned_rest(w);
            for &id in &batch {
                self.table[id].state = JobState::Running;
            }

            match self.cfg.clock {
                ClockMode::Virtual => {
                    let done_at = now + outcome.service_ms
                        + self.cfg.overhead_ms_per_iter;
                    self.workers[w].pending =
                        Some(PendingWindow { done_at, outcome, batch });
                }
                ClockMode::Wall => {
                    let t_done = self.wall_ms();
                    self.apply_outcome(t_done, outcome, &batch, w, None);
                }
            }
        }
        Ok(())
    }

    /// Error recovery: return this window's planned jobs to the node's
    /// pending list.  Rebuild mode drained the whole pool into the plan
    /// (batch + rest), so everything goes back; incremental mode only
    /// popped the batch — the remainder never left the index.
    fn spill_planned(&mut self, w: usize) {
        let node = &mut self.nodes[w];
        for e in &node.plan.batch {
            node.pending.push(e.id);
        }
        node.plan.batch.clear();
        if let SpillOnError::FullOrder = node.plan.spill {
            for e in &node.plan.rest {
                node.pending.push(e.id);
            }
            node.plan.rest.clear();
        }
    }

    /// After a successful hand-off: in rebuild mode the sorted remainder
    /// (everything past the batch prefix) becomes the node's new pool; in
    /// incremental mode the remainder is still keyed in the index and
    /// nothing needs re-queueing.
    fn requeue_planned_rest(&mut self, w: usize) {
        let node = &mut self.nodes[w];
        if let SpillOnError::FullOrder = node.plan.spill {
            for e in &node.plan.rest {
                node.pending.push(e.id);
            }
            node.plan.rest.clear();
        }
    }

    /// One full scheduling iteration: ingest → poll completions → dispatch,
    /// advancing the clock (virtual) or sleeping (wall) when no worker
    /// could run.  A no-op once [`is_done`](Self::is_done).
    pub fn step(&mut self) -> Result<StepOutcome> {
        if self.is_done() {
            return Ok(StepOutcome {
                now_ms: self.now,
                admitted: 0,
                completed: 0,
                dispatched: 0,
                idled: false,
                done: true,
            });
        }
        // A fully-failed-over backend can reach here with every worker
        // dead but nothing unfinished *at the time of the last loss*
        // (fail_over only errs for unfinished work) — a later
        // push_request must then fail cleanly before ingest would ask
        // the load balancer for a surviving node it cannot have.
        if !self.dead.is_empty() && self.dead.iter().all(|&d| d) {
            bail!("all {} workers are lost with {} jobs unfinished",
                  self.cfg.workers, self.table.len() - self.finished);
        }
        if self.cfg.clock == ClockMode::Wall {
            self.now = self.wall_ms();
        }
        let now = self.now;
        let admitted = self.ingest(now);
        let completed = self.poll_completions(now)?;
        let dispatched = self.dispatch(now)?;
        let mut idled = false;
        if !self.is_done() && dispatched == 0 {
            self.advance_clock()?;
            idled = true;
        }
        Ok(StepOutcome {
            now_ms: self.now,
            admitted,
            completed,
            dispatched,
            idled,
            done: self.is_done(),
        })
    }

    /// Step until every job finishes; returns the final report.
    pub fn run_to_completion(&mut self) -> Result<ServeReport> {
        while !self.is_done() {
            self.step()?;
        }
        Ok(self.report())
    }

    /// Snapshot the run metrics (records cover finished jobs only, so this
    /// is also meaningful mid-run).
    pub fn report(&self) -> ServeReport {
        let makespan_ms = self
            .table
            .iter()
            .filter_map(|j| j.finish_ms)
            .fold(0.0, f64::max);
        let records: Vec<JobRecord> =
            self.table.iter().filter_map(JobRecord::from_job).collect();
        ServeReport {
            scheduler: self.scheduler.policy.name().to_string(),
            predictor_name: self.scheduler.predictor_name().to_string(),
            records,
            makespan_ms,
            total_preemptions: self.total_preemptions,
            sched_overhead_ms_avg: if self.iterations == 0 {
                0.0
            } else {
                self.sched_overhead_ns as f64 / self.iterations as f64 / 1e6
            },
            sched_iterations: self.iterations,
        }
    }

    // ---- internals ------------------------------------------------------

    /// Fold a finished window back into coordinator state: count
    /// preemptions, append tokens, retire finished jobs, return the rest
    /// to their node's pool.  All state mutates first; the window's events
    /// are recorded along the way and delivered afterwards as **one**
    /// [`EventSink::on_window_applied`] call per sink (same causal order),
    /// so lock-guarded sinks pay one critical section per window instead
    /// of one per job per window.
    fn apply_outcome(&mut self, t_done: f64, outcome: WindowOutcome,
                     batch: &[JobId], node: usize, pod: Option<PodExec>) {
        let window_tokens: usize =
            outcome.outputs.iter().map(|o| o.new_tokens.len()).sum();
        let mut events = std::mem::take(&mut self.events_scratch);
        events.clear();
        for &pid_raw in &outcome.preempted {
            let pid = JobId::from_raw(pid_raw);
            if let Some(j) = self.table.get_mut(pid) {
                j.preemptions += 1;
            }
            // an evicted job is no longer resident, so it can't be a
            // victim again until a batch re-stages it (which re-folds it
            // into `warm` via the pending list) — pruning here keeps the
            // victim ranking proportional to the *resident* set even in
            // preemption-heavy regimes
            self.nodes[node].warm.remove(&pid);
            self.total_preemptions += 1;
            events.push(PendingOutcomeEvent::Preempted(pid));
        }
        for out in &outcome.outputs {
            let id = JobId::from_raw(out.id);
            {
                let j = &mut self.table[id];
                j.windows += 1;
                j.service_ms += outcome.service_ms;
                if !out.new_tokens.is_empty() && j.first_token_ms.is_none() {
                    j.first_token_ms = Some(t_done);
                }
                j.generated += out.new_tokens.len();
                j.response.extend_from_slice(&out.new_tokens);
            }
            if !out.new_tokens.is_empty() {
                // live progress: per-job, per-window token production,
                // recorded before a final window's finish event
                events.push(PendingOutcomeEvent::Progress(
                    id, out.new_tokens.len()));
            }
            if out.done {
                let j = &mut self.table[id];
                j.state = JobState::Finished;
                j.finish_ms = Some(t_done);
                let total_len = j.total_len;
                self.finished += 1;
                self.state.on_finish(node);
                // the accuracy signal must be read before `forget` drops
                // the prediction-cache entry
                let predicted_total = self.scheduler.predicted_total(id);
                self.scheduler.observe_completion(&ObservedCompletion {
                    prompt: &self.table[id].prompt,
                    response: &self.table[id].response,
                    total_len,
                });
                self.scheduler.forget(id);
                self.batcher.forget(node, id);
                self.nodes[node].warm.remove(&id);
                self.backend.remove(node, out.id);
                let j = &self.table[id];
                let stats = FinishStats {
                    jct_ms: t_done - j.arrival_ms,
                    ttft_ms: j.ttft_ms(),
                    queue_delay_ms: j.queue_delay_ms().unwrap_or(0.0),
                    service_ms: j.service_ms,
                    tokens: j.generated,
                    predicted_total,
                };
                events.push(PendingOutcomeEvent::Finished(id, stats));
            } else {
                self.table[id].state = JobState::Queued;
                self.nodes[node].pending.push(id);
            }
        }
        // batch jobs that produced no output (couldn't be staged) go back
        for &id in batch {
            let j = &mut self.table[id];
            if j.state == JobState::Running {
                j.state = JobState::Queued;
                self.nodes[node].pending.push(id);
            }
        }
        // deliver: resolve metas against the now-quiescent table and hand
        // each sink the whole window at once (the default trait impl
        // re-expands into the per-event hooks, in causal order, with
        // window-done last)
        {
            let resolved: Vec<WindowJobEvent<'_>> = events
                .iter()
                .map(|ev| match *ev {
                    PendingOutcomeEvent::Progress(id, n) => {
                        // each job appears at most once per window, so the
                        // response tail is exactly this window's tokens
                        let resp = &self.table[id].response;
                        WindowJobEvent::Progress {
                            job: job_meta(&self.table, id),
                            tokens: &resp[resp.len() - n..],
                        }
                    }
                    PendingOutcomeEvent::Finished(id, stats) => {
                        WindowJobEvent::Finished {
                            job: job_meta(&self.table, id),
                            stats,
                        }
                    }
                    PendingOutcomeEvent::Preempted(id) => {
                        WindowJobEvent::Preempted { job: id }
                    }
                })
                .collect();
            let window = WindowEvents {
                node,
                batch,
                events: &resolved,
                tokens: window_tokens,
                service_ms: outcome.service_ms,
                now_ms: t_done,
                pod,
            };
            for s in self.sinks.iter_mut() {
                s.on_window_applied(&window);
            }
        }
        self.events_scratch = events;
    }

    /// Nothing could run: jump the virtual clock to the next event, or
    /// sleep (at most one idle tick) in wall mode.  Errors on deadlock
    /// (unfinished jobs but no future event and nothing in flight).
    fn advance_clock(&mut self) -> Result<()> {
        let next_completion = self
            .workers
            .iter()
            .filter_map(|s| s.pending.as_ref().map(|p| p.done_at))
            .fold(f64::INFINITY, f64::min);
        let next_arrival_t = if self.next_arrival < self.arrivals.len() {
            self.arrivals[self.next_arrival].0
        } else {
            f64::INFINITY
        };
        let next_t = next_completion.min(next_arrival_t);
        match self.cfg.clock {
            ClockMode::Virtual => {
                if !next_t.is_finite() {
                    bail!("deadlock: no pending work but {} jobs unfinished",
                          self.table.len() - self.finished);
                }
                self.now = next_t.max(self.now);
            }
            ClockMode::Wall => {
                let in_flight = self.workers.iter().any(|s| s.in_flight);
                if !next_t.is_finite() && !in_flight {
                    bail!("deadlock: no pending work but {} jobs unfinished",
                          self.table.len() - self.finished);
                }
                // cap the idle sleep at one tick so streamed admissions
                // (push_request / HTTP frontend) and pool completions are
                // picked up promptly instead of waiting out the full gap
                // to the next known arrival
                let tick = self.cfg.idle_tick_ms.max(0.1);
                let wait_ms = if next_t.is_finite() {
                    (next_t - self.wall_ms()).min(tick)
                } else {
                    tick
                };
                if wait_ms > 0.0 {
                    std::thread::sleep(std::time::Duration::from_secs_f64(
                        wait_ms / 1e3,
                    ));
                }
            }
        }
        Ok(())
    }
}

/// Plan one window on a node, incremental path — the (possibly sharded)
/// phase-2 body.  Re-keys only the pending jobs (plus the lanes of
/// tenants whose shaping epoch moved, when a foldable shaper is set),
/// top-k selects against the persistent index, and ranks preemption
/// victims over the engine-relevant (warm ∪ batch) set only.
///
/// A free function on purpose: it takes the node's own state `&mut` and
/// everything shared strictly `&` (job table, folded-shaper snapshot,
/// preemption config), which is exactly the contract that lets
/// [`DispatchShards`] run disjoint node chunks concurrently without
/// changing any result.
fn plan_incremental(ns: &mut NodeSched, table: &JobTable,
                    folded: Option<&dyn FoldedShaper>,
                    preemption: &PreemptionPolicy, rank: bool,
                    max_batch: usize) {
    let t = Instant::now();

    // fold pending (changed) jobs into the index: their folded keys were
    // recomputed in phase 1, the shaper offset (if any) is applied here,
    // and everything already in the index keeps its key untouched —
    // except lanes whose tenant epoch moved, which re-key wholesale from
    // their stored folded bases
    match (&mut ns.shaped, folded) {
        (Some(tq), Some(sh)) => {
            tq.rekey_stale(sh, table);
            for i in 0..ns.pending.len() {
                let id = ns.pending[i];
                let j = &table[id];
                let base = j.priority.unwrap_or(f64::MAX);
                let key = sh.shape_folded(j, base);
                let epoch = sh.tenant_epoch(j.tenant.as_deref());
                tq.push(j.tenant.as_deref(), epoch, ShapedEntry {
                    entry: Entry {
                        priority: key,
                        arrival_ms: j.arrival_ms,
                        id,
                    },
                    base_folded: base,
                });
                if j.engine_admitted {
                    ns.warm.insert(id, WarmEntry {
                        key,
                        base_folded: base,
                        arrival_ms: j.arrival_ms,
                        epoch,
                    });
                }
            }
        }
        _ => {
            for i in 0..ns.pending.len() {
                let id = ns.pending[i];
                let j = &table[id];
                let key = j.priority.unwrap_or(f64::MAX);
                ns.flat.push(Entry {
                    priority: key,
                    arrival_ms: j.arrival_ms,
                    id,
                });
                if j.engine_admitted {
                    ns.warm.insert(id, WarmEntry {
                        key,
                        base_folded: key,
                        arrival_ms: j.arrival_ms,
                        epoch: 0,
                    });
                }
            }
        }
    }
    ns.pending.clear();

    // top-k partial selection: k pops, the rest never moves
    let k = max_batch.min(ns.plan.engine_cap);
    ns.plan.batch.clear();
    match &mut ns.shaped {
        Some(tq) => {
            while ns.plan.batch.len() < k {
                match tq.pop_best() {
                    Some(se) => ns.plan.batch.push(se.entry),
                    None => break,
                }
            }
        }
        None => {
            while ns.plan.batch.len() < k {
                match ns.flat.pop() {
                    Some(e) => ns.plan.batch.push(e),
                    None => break,
                }
            }
        }
    }
    for i in 0..ns.plan.batch.len() {
        let id = ns.plan.batch[i].id;
        ns.warm.remove(&id);
    }

    // preemption victim ordering over the engine-relevant set only: the
    // batch plus queued jobs that still hold engine KV state.  Jobs the
    // engine has never admitted can't be evicted, and the engine skips
    // unknown ids, so the filtered ranking drives the exact same eviction
    // choices as a full-queue ranking.  Warm keys are cached; a warm
    // job whose tenant epoch moved re-shapes from its stored folded base
    // (same inputs as the index re-key, so ranking and index order stay
    // in lockstep).
    ns.plan.victims.clear();
    if rank {
        ns.plan.ventries.clear();
        for i in 0..ns.plan.batch.len() {
            let e = ns.plan.batch[i];
            ns.plan.ventries.push(e);
        }
        for (&id, we) in ns.warm.iter_mut() {
            if let Some(sh) = folded {
                let cur = sh.tenant_epoch(table[id].tenant.as_deref());
                if we.epoch != cur {
                    we.key = sh.shape_folded(&table[id], we.base_folded);
                    we.epoch = cur;
                }
            }
            ns.plan.ventries.push(Entry {
                priority: we.key,
                arrival_ms: we.arrival_ms,
                id,
            });
        }
        // ascending (priority, arrival, id) — Entry's total order is
        // reversed for the min-heap, so highest-priority-first is the
        // reverse of Ord; one comparator shared with the index keeps this
        // ranking and the pop order in lockstep (and makes the unstable
        // sort deterministic: ids are unique, so the order is total)
        ns.plan.ventries.sort_unstable_by(|a, b| b.cmp(a));
        ns.plan.ranked.clear();
        for i in 0..ns.plan.ventries.len() {
            let e = ns.plan.ventries[i];
            ns.plan.ranked.push((e.id, table[e.id].preemptions));
        }
        preemption.victim_order_into(&ns.plan.ranked, &mut ns.plan.victims);
    }
    ns.plan.rank = rank;
    ns.plan.cap = ns.plan.engine_cap;
    ns.plan.rest.clear();
    ns.plan.spill = SpillOnError::BatchOnly;
    ns.plan.sched_ns += t.elapsed().as_nanos();
}
