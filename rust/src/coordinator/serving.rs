//! The stepped, event-driven coordinator — paper §4.1 Algorithm 1 as a
//! first-class API instead of a closed loop.
//!
//! [`Coordinator`] owns the serving state (job table, per-node queues,
//! load balancer, priority buffer, batcher, preemption policy) and drives
//! a backend of engines for the duration of a run.  The serving loop
//! is decomposed into composable steps:
//!
//! * [`Coordinator::ingest`] — admit arrivals due at `now` (Algorithm 1
//!   lines 1–5: load-balance each new job onto a node).
//! * [`Coordinator::poll_completions`] — apply window outcomes whose
//!   (virtual) completion time has passed, and drain finished windows off
//!   the worker-pool completion channel in threaded wall-clock mode.
//! * [`Coordinator::dispatch`] — for every idle worker with queued jobs:
//!   fold newly-changed jobs into the node's **persistent order index**,
//!   select the top-k batch, and execute one scheduling window
//!   (Algorithm 1 lines 6–20).  Only jobs whose priority input actually
//!   changed since the last window — ran and got re-predicted, newly
//!   admitted, or spilled back by an error path — are re-keyed;
//!   anti-starvation aging is folded into a time-invariant key (see
//!   [`Scheduler::refresh_folded`]), so the steady-state cost per window
//!   is O(k log n) for a batch of k against a backlog of n, not the
//!   O(n log n) full rebuild.  Registering a [`PriorityShaper`] (whose
//!   output legitimately drifts every round) — or forcing
//!   [`CoordinatorBuilder::full_rebuild`] — selects the classic
//!   re-key-everything path instead; both paths produce bit-identical
//!   virtual-clock reports (regression-tested per policy).
//! * [`Coordinator::step`] — one full iteration of the above plus clock
//!   advance when nothing could run; returns a [`StepOutcome`].
//! * [`Coordinator::run_to_completion`] — step until every job finished,
//!   then return the [`ServeReport`].
//!
//! Construction goes through [`CoordinatorBuilder`], which extends
//! [`ServeConfig`] with [`EventSink`] observers (job admitted / batch
//! formed / window done / job finished / preempted) for metrics, logging,
//! and policy experiments.  The original `run_serving` free function
//! survives in [`frontend`](super::frontend) as a thin wrapper over this
//! type and produces identical reports.
//!
//! Both evaluation modes of the paper are supported via [`ClockMode`]:
//! virtual (discrete-event; engine `service_ms` advances a simulated
//! timeline) and wall (real time; arrivals are waited for).  The
//! scheduling-iteration structure is identical in both.  Engines attach
//! through one of two backends:
//!
//! * **inline** ([`CoordinatorBuilder::build`]) — the coordinator borrows
//!   the engines and executes every window on the calling thread.  This
//!   is the only backend virtual mode accepts, and its code path is
//!   untouched by the threaded runtime, so simulated reports stay
//!   bit-identical.
//! * **pooled** ([`CoordinatorBuilder::build_pooled`]) — wall-clock only:
//!   engines live on [`WorkerPool`] threads, dispatch sends each formed
//!   batch over an mpsc channel, and completions drain asynchronously, so
//!   scheduling windows genuinely overlap across multi-worker configs
//!   (the paper's one-vLLM-per-pod deployment, in-process).
//! * **remote** ([`CoordinatorBuilder::build_remote`]) — the same pooled
//!   code path over a [`WorkerTransport`] whose workers are TCP pod
//!   connections ([`RemoteWorkerPool`], `elis worker --connect`): the
//!   paper's §5 cross-machine StatefulSet topology.  Worker-loss
//!   [`failover`](CoordinatorBuilder::failover) defaults on — a pod that
//!   vanishes mid-window has the window rolled back (partial admits
//!   wiped) and its jobs re-balanced onto survivors, resuming from the
//!   tokens the coordinator already holds.

use std::collections::HashSet;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::cluster::pool::{WindowDone, WorkerCmd, WorkerPool,
                           WorkerTransport};
use crate::cluster::remote::RemoteWorkerPool;
use crate::engine::{Engine, SeqSpec, WindowOutcome};
use crate::metrics::{JobRecord, ServeReport};
use crate::workload::TraceRequest;

use super::batcher::Batcher;
use super::events::{DecisionRecord, EventSink, FinishStats, JobMeta,
                    PodExec, WindowEvents, WindowJobEvent};
use super::job::{Job, JobId, JobState, JobTable};
use super::load_balancer::{GlobalState, LbStrategy, LoadBalancer};
use super::preemption::PreemptionPolicy;
use super::priority_buffer::{Entry, PriorityBuffer};
use super::scheduler::{PriorityShaper, Scheduler};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClockMode {
    /// discrete-event simulation (engine service_ms drives time)
    Virtual,
    /// real time (arrivals waited for)
    Wall,
}

#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub workers: usize,
    pub max_batch: usize,
    pub lb: LbStrategy,
    pub preemption: PreemptionPolicy,
    /// fixed extra scheduling cost added to the virtual timeline per
    /// iteration (models the paper's measured ~11 ms overhead; 0 = off)
    pub overhead_ms_per_iter: f64,
    pub clock: ClockMode,
    pub seed: u64,
    /// hard safety cap on scheduling iterations (0 = none)
    pub max_iterations: u64,
    /// wall mode: longest idle sleep (ms) before re-checking for work, so
    /// requests streamed in via [`Coordinator::push_request`] (e.g. the
    /// HTTP frontend) and pool completions are picked up promptly instead
    /// of waiting out the full gap to the next known arrival.  Ignored in
    /// virtual mode (the simulated clock jumps exactly).
    pub idle_tick_ms: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 1,
            max_batch: 4,
            lb: LbStrategy::MinLoad,
            preemption: PreemptionPolicy::default(),
            overhead_ms_per_iter: 0.0,
            clock: ClockMode::Virtual,
            seed: 1,
            max_iterations: 0,
            idle_tick_ms: 10.0,
        }
    }
}

/// What one [`Coordinator::step`] did.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepOutcome {
    /// coordinator time after the step (virtual or wall ms)
    pub now_ms: f64,
    /// arrivals admitted this step
    pub admitted: usize,
    /// pending window outcomes applied this step
    pub completed: usize,
    /// scheduling windows dispatched this step
    pub dispatched: usize,
    /// no worker could run, so the clock advanced (virtual) or slept (wall)
    pub idled: bool,
    /// every job has finished; further steps are no-ops
    pub done: bool,
}

/// A window in flight on an inline worker (virtual mode: outcome applies
/// at `done_at` on the simulated timeline).
struct PendingWindow {
    done_at: f64,
    outcome: WindowOutcome,
    batch: Vec<JobId>,
}

struct WorkerSlot {
    /// virtual mode: executed outcome waiting for its completion time
    pending: Option<PendingWindow>,
    /// pooled wall mode: a window is running on the worker's thread
    in_flight: bool,
}

/// What a failed window hand-off must return to the node's pool: the
/// rebuild path drains the whole queue per window (so everything spills),
/// the incremental path only ever removes the batch from its index.
#[derive(Debug, Clone, Copy)]
enum SpillOnError {
    FullOrder,
    BatchOnly,
}

/// A window's job-scoped event recorded during state mutation and
/// delivered afterwards (ids only — `JobMeta` borrows are resolved against
/// the then-immutable table at delivery time).
#[derive(Debug, Clone, Copy)]
enum PendingOutcomeEvent {
    Progress(JobId, usize),
    Finished(JobId, FinishStats),
    Preempted(JobId),
}

fn job_meta(table: &JobTable, id: JobId) -> JobMeta<'_> {
    let j = &table[id];
    JobMeta {
        id,
        tenant: j.tenant.as_deref(),
        arrival_ms: j.arrival_ms,
        prompt_len: j.prompt.len(),
        total_len: j.total_len,
    }
}

/// Where the engines live: borrowed and driven inline on the calling
/// thread, or behind a [`WorkerTransport`] — the in-process
/// [`WorkerPool`] (one OS thread per engine) or the
/// [`RemoteWorkerPool`] (one registered TCP pod connection per worker).
enum Backend<'a> {
    Inline(&'a mut [Box<dyn Engine>]),
    Pool(Box<dyn WorkerTransport>),
}

impl<'a> Backend<'a> {
    fn max_batch(&self, worker: usize) -> usize {
        match self {
            Backend::Inline(engines) => engines[worker].max_batch(),
            Backend::Pool(pool) => pool.max_batch(worker),
        }
    }

    /// Drop a finished sequence's engine state (best-effort for a pooled
    /// worker whose thread died — the run is already failing then).
    fn remove(&mut self, worker: usize, seq_id: u64) {
        match self {
            Backend::Inline(engines) => engines[worker].remove(seq_id),
            Backend::Pool(pool) => {
                let _ = pool.send(worker, WorkerCmd::Remove(seq_id));
            }
        }
    }
}

/// Builder for [`Coordinator`]: a [`ServeConfig`] plus observers and an
/// optional priority shaper.
#[derive(Default)]
pub struct CoordinatorBuilder {
    cfg: ServeConfig,
    sinks: Vec<Box<dyn EventSink>>,
    shaper: Option<Box<dyn PriorityShaper>>,
    force_rebuild: bool,
    /// worker-loss policy for pooled backends; `None` = the backend's
    /// default (remote pools fail over, the in-process pool fails fast)
    failover: Option<bool>,
}

impl CoordinatorBuilder {
    pub fn new() -> CoordinatorBuilder {
        CoordinatorBuilder::default()
    }

    pub fn from_config(cfg: ServeConfig) -> CoordinatorBuilder {
        CoordinatorBuilder { cfg, ..CoordinatorBuilder::default() }
    }

    pub fn workers(mut self, workers: usize) -> Self {
        self.cfg.workers = workers;
        self
    }

    pub fn max_batch(mut self, max_batch: usize) -> Self {
        self.cfg.max_batch = max_batch;
        self
    }

    pub fn lb(mut self, lb: LbStrategy) -> Self {
        self.cfg.lb = lb;
        self
    }

    pub fn preemption(mut self, preemption: PreemptionPolicy) -> Self {
        self.cfg.preemption = preemption;
        self
    }

    pub fn overhead_ms_per_iter(mut self, ms: f64) -> Self {
        self.cfg.overhead_ms_per_iter = ms;
        self
    }

    pub fn clock(mut self, clock: ClockMode) -> Self {
        self.cfg.clock = clock;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn max_iterations(mut self, cap: u64) -> Self {
        self.cfg.max_iterations = cap;
        self
    }

    /// Register an observer; sinks fire synchronously, in registration
    /// order, from inside the serving loop.
    pub fn sink(mut self, sink: Box<dyn EventSink>) -> Self {
        self.sinks.push(sink);
        self
    }

    /// Register a priority shaper: dispatch passes every queued job's base
    /// priority through it before ordering (the SLO-policy seam).  Without
    /// one, scheduling is bit-identical to the pre-shaper coordinator.
    ///
    /// A shaper's output legitimately changes every round (deadlines,
    /// live-telemetry pressure), so registering one selects the
    /// re-shape-everything dispatch path: O(n log n) per window instead of
    /// the incremental index's O(k log n).
    pub fn priority_shaper(mut self, shaper: Box<dyn PriorityShaper>) -> Self {
        self.shaper = Some(shaper);
        self
    }

    /// Force the per-window full-rebuild dispatch path even without a
    /// shaper.  The schedule is bit-identical to the default incremental
    /// index — this knob exists for differential tests and for the
    /// dispatch-cost-at-depth benches that measure the gap.
    pub fn full_rebuild(mut self, on: bool) -> Self {
        self.force_rebuild = on;
        self
    }

    /// Worker-loss policy for pooled backends.  With failover **on**, a
    /// worker whose transport reports it gone is marked dead, its window
    /// rolls back (partial admits wiped via the reply's `fresh` list),
    /// and every job homed on it is re-balanced onto surviving workers —
    /// partially-generated jobs resume where they left off (see
    /// [`SeqSpec::resume`](crate::engine::SeqSpec)).  The run only fails
    /// once *every* worker is lost.  With failover **off**, a lost worker
    /// fails the run fast (the in-process pool's historical behaviour: a
    /// worker thread only dies with the process's own engine panicking).
    ///
    /// Defaults per backend: [`build_remote`](Self::build_remote) → on,
    /// [`build_pooled`](Self::build_pooled) → off.
    pub fn failover(mut self, on: bool) -> Self {
        self.failover = Some(on);
        self
    }

    /// Load `trace` into a job table and wire up the serving state.
    /// `engines[i]` is worker i's backend, driven inline on the calling
    /// thread; `scheduler` owns the policy and the length predictor.  An
    /// empty trace is allowed: the coordinator starts [`done`] and waits
    /// for [`Coordinator::push_request`].
    ///
    /// [`done`]: Coordinator::is_done
    pub fn build<'a>(self, trace: &[TraceRequest],
                     engines: &'a mut [Box<dyn Engine>],
                     scheduler: &'a mut Scheduler)
                     -> Result<Coordinator<'a>> {
        if engines.len() != self.cfg.workers {
            bail!("expected {} engines, got {}", self.cfg.workers,
                  engines.len());
        }
        // preemption frequency control (§3.4) is enforced inside the
        // engines: each may evict at most this many sequences per window
        for e in engines.iter_mut() {
            e.set_preemption_cap(self.cfg.preemption.max_per_iteration);
        }
        self.finish(trace, Backend::Inline(engines), scheduler)
    }

    /// Like [`build`](Self::build), but the engines are owned by a
    /// threaded [`WorkerPool`] (one OS thread per engine): dispatch sends
    /// each formed batch over the worker's channel and
    /// [`Coordinator::poll_completions`] drains the shared completion
    /// channel, so windows overlap across workers.  Wall-clock only —
    /// virtual mode needs synchronous windows for its deterministic
    /// timeline (and gains nothing from threads).
    pub fn build_pooled<'a>(self, trace: &[TraceRequest], pool: WorkerPool,
                            scheduler: &'a mut Scheduler)
                            -> Result<Coordinator<'a>> {
        self.build_transport(trace, Box::new(pool), scheduler, false)
    }

    /// Like [`build_pooled`](Self::build_pooled), but the workers are
    /// **remote pods** registered over TCP (`elis worker --connect`): the
    /// same dispatch/completion code drives them through the
    /// [`WorkerTransport`] boundary, and worker-loss
    /// [`failover`](Self::failover) defaults **on** — a pod that
    /// disconnects mid-window has its window rolled back and its jobs
    /// re-homed onto the surviving pods.  Wall-clock only, like every
    /// pooled backend.
    pub fn build_remote<'a>(self, trace: &[TraceRequest],
                            pool: RemoteWorkerPool,
                            scheduler: &'a mut Scheduler)
                            -> Result<Coordinator<'a>> {
        self.build_transport(trace, Box::new(pool), scheduler, true)
    }

    /// The generic pooled constructor behind
    /// [`build_pooled`](Self::build_pooled) /
    /// [`build_remote`](Self::build_remote): any [`WorkerTransport`]
    /// carrying the `WorkerCmd`/`WindowDone` protocol works, which is
    /// also the seam fault-injection tests plug custom transports into.
    /// `failover_default` applies when [`failover`](Self::failover) was
    /// not set explicitly.
    pub fn build_transport<'a>(mut self, trace: &[TraceRequest],
                               pool: Box<dyn WorkerTransport>,
                               scheduler: &'a mut Scheduler,
                               failover_default: bool)
                               -> Result<Coordinator<'a>> {
        if self.cfg.clock != ClockMode::Wall {
            bail!("a pooled backend requires ClockMode::Wall \
                   (virtual mode executes windows inline)");
        }
        if pool.workers() != self.cfg.workers {
            bail!("expected {} pool workers, got {}", self.cfg.workers,
                  pool.workers());
        }
        for w in 0..pool.workers() {
            pool.send(w, WorkerCmd::SetPreemptionCap(
                self.cfg.preemption.max_per_iteration))?;
        }
        if self.failover.is_none() {
            self.failover = Some(failover_default);
        }
        self.finish(trace, Backend::Pool(pool), scheduler)
    }

    fn finish<'a>(self, trace: &[TraceRequest], backend: Backend<'a>,
                  scheduler: &'a mut Scheduler) -> Result<Coordinator<'a>> {
        let CoordinatorBuilder { cfg, sinks, shaper, force_rebuild,
                                 failover } = self;
        let mut table = JobTable::with_capacity(trace.len());
        let mut arrivals: Vec<(f64, JobId)> = Vec::with_capacity(trace.len());
        for r in trace {
            let id = table.insert_with(|id| {
                let mut job = Job::new(id, r.prompt.clone(), r.total_len,
                                       r.topic, r.arrival_ms);
                job.tenant = r.tenant.clone();
                job
            });
            arrivals.push((r.arrival_ms, id));
        }
        // stable: equal arrival times keep trace order
        arrivals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

        let workers_n = cfg.workers;
        // shaped priorities drift every round, so a shaper needs the
        // re-key-everything path; otherwise keys are change-driven and the
        // index persists across windows
        let incremental = shaper.is_none() && !force_rebuild;
        Ok(Coordinator {
            backend,
            scheduler,
            table,
            arrivals,
            next_arrival: 0,
            queued: vec![Vec::new(); workers_n],
            workers: (0..workers_n)
                .map(|_| WorkerSlot { pending: None, in_flight: false })
                .collect(),
            state: GlobalState::new(workers_n),
            lb: LoadBalancer::new(cfg.lb, cfg.seed),
            buffer: PriorityBuffer::new(workers_n),
            batcher: Batcher::new(workers_n, cfg.max_batch),
            incremental,
            warm: vec![HashSet::new(); workers_n],
            dead: vec![false; workers_n],
            failover: failover.unwrap_or(false),
            pending_scratch: Vec::new(),
            order_scratch: Vec::new(),
            victim_entries_scratch: Vec::new(),
            ranked_scratch: Vec::new(),
            victims_scratch: Vec::new(),
            events_scratch: Vec::new(),
            decision_depth: 0,
            decision_cap: 0,
            sinks,
            shaper,
            now: 0.0,
            wall_start: Instant::now(),
            finished: 0,
            total_preemptions: 0,
            sched_overhead_ns: 0,
            iterations: 0,
            cfg,
        })
    }
}

/// The serving frontend: owns jobs, queues, balancer, buffer, and batcher;
/// drives an inline (borrowed) or pooled (owned, threaded) engine backend
/// for the lifetime of the run.
pub struct Coordinator<'a> {
    cfg: ServeConfig,
    backend: Backend<'a>,
    scheduler: &'a mut Scheduler,
    table: JobTable,
    /// (arrival_ms, id), sorted by arrival time
    arrivals: Vec<(f64, JobId)>,
    next_arrival: usize,
    /// Per-node list of waiting jobs whose order key is missing or stale.
    /// In incremental mode this is the *pending/dirty* list — everything
    /// that changed since the node's last window (new admits, returned
    /// batch members, error spills) — and the rest of the backlog lives
    /// keyed inside `buffer`.  In rebuild mode the buffer is drained every
    /// window, so this list is simply the whole pool.
    queued: Vec<Vec<JobId>>,
    workers: Vec<WorkerSlot>,
    state: GlobalState,
    lb: LoadBalancer,
    /// per-node order index: persistent across windows in incremental
    /// mode, rebuilt per window in rebuild mode
    buffer: PriorityBuffer,
    batcher: Batcher,
    /// false when a shaper is registered (or a reference run forced the
    /// rebuild path)
    incremental: bool,
    /// Per-node ids currently *in the index* that may still be resident
    /// on the engine (admitted by an earlier batch and not since evicted)
    /// — a superset of the engine's resident queued jobs and the only
    /// candidates it could pick as preemption victims besides the batch
    /// itself, so victim ranking sorts these instead of the whole
    /// backlog.  Pruned on eviction; re-entered through the pending fold
    /// when the job is next re-keyed.
    warm: Vec<HashSet<JobId>>,
    /// Workers whose transport connection/thread is gone.  Dead workers
    /// are skipped by dispatch and excluded from load balancing; set only
    /// through [`fail_over`](Self::fail_over) (failover-enabled pooled
    /// backends).
    dead: Vec<bool>,
    /// see [`CoordinatorBuilder::failover`]
    failover: bool,
    // -- per-window scratch buffers (allocations reused across windows) --
    pending_scratch: Vec<JobId>,
    order_scratch: Vec<Entry>,
    victim_entries_scratch: Vec<Entry>,
    ranked_scratch: Vec<(JobId, usize)>,
    victims_scratch: Vec<u64>,
    events_scratch: Vec<PendingOutcomeEvent>,
    /// queue depth observed at the current window's dispatch entry, for
    /// the [`DecisionRecord`] fired by [`execute_window`](Self) — written
    /// by both dispatch paths before they start draining the pool
    decision_depth: usize,
    /// batch-size cap the current window's selection ran under (engine
    /// cap, possibly tightened by `max_batch` on the rebuild path) —
    /// batch-occupancy context for the [`DecisionRecord`]
    decision_cap: usize,
    sinks: Vec<Box<dyn EventSink>>,
    shaper: Option<Box<dyn PriorityShaper>>,
    now: f64,
    wall_start: Instant,
    finished: usize,
    total_preemptions: u64,
    sched_overhead_ns: u128,
    iterations: u64,
}

impl<'a> Coordinator<'a> {
    // ---- observers / accessors ------------------------------------------

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Current coordinator time (virtual or wall ms).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// The time to stamp externally-arriving work with
    /// ([`push_request`](Self::push_request)).  Wall mode reads the live
    /// wall clock — [`now`](Self::now) only advances inside `step()`, so
    /// it goes stale while a serving loop idles between steps — and a
    /// stale stamp would inflate the job's JCT/TTFT and mislead
    /// deadline policies.  Virtual mode returns the simulated now.
    pub fn admission_now_ms(&self) -> f64 {
        match self.cfg.clock {
            ClockMode::Wall => self.wall_ms(),
            ClockMode::Virtual => self.now,
        }
    }

    pub fn total_jobs(&self) -> usize {
        self.table.len()
    }

    pub fn finished_jobs(&self) -> usize {
        self.finished
    }

    pub fn is_done(&self) -> bool {
        self.finished == self.table.len()
    }

    /// Scheduling iterations executed so far.
    pub fn iterations(&self) -> u64 {
        self.iterations
    }

    pub fn table(&self) -> &JobTable {
        &self.table
    }

    /// Jobs waiting in `node`'s pool (excludes the running batch): the
    /// keyed entries in the node's order index plus the pending re-keys.
    pub fn queue_len(&self, node: usize) -> usize {
        self.queued[node].len() + self.buffer.len(node)
    }

    /// Cumulative scheduling-overhead wall time (ms) across all iterations
    /// so far — the numerator of `sched_overhead_ms_avg`, exposed for the
    /// dispatch-cost-at-depth benches that difference it between steps.
    pub fn sched_overhead_ms_total(&self) -> f64 {
        self.sched_overhead_ns as f64 / 1e6
    }

    /// Per-worker active-job counts maintained by the load balancer.
    pub fn global_state(&self) -> &GlobalState {
        &self.state
    }

    /// Workers marked dead by failover — surfaced by the HTTP frontend's
    /// `/healthz` body so probes see a degraded fleet before it empties.
    pub fn dead_workers(&self) -> usize {
        self.dead.iter().filter(|&&d| d).count()
    }

    pub fn transfer_stats(&self) -> &super::batcher::TransferStats {
        &self.batcher.stats
    }

    fn wall_ms(&self) -> f64 {
        self.wall_start.elapsed().as_secs_f64() * 1e3
    }

    // ---- composable steps -----------------------------------------------

    /// Admit every arrival due at `now` (Algorithm 1 lines 1–5): the load
    /// balancer picks its node and the job joins that node's pool.
    /// Returns the number of jobs admitted.
    pub fn ingest(&mut self, now: f64) -> usize {
        let mut admitted = 0;
        while self.next_arrival < self.arrivals.len()
            && self.arrivals[self.next_arrival].0 <= now
        {
            let (_, id) = self.arrivals[self.next_arrival];
            self.next_arrival += 1;
            let node = self.lb.assign_excluding(&mut self.state, &self.dead);
            self.table[id].node = Some(node);
            self.queued[node].push(id);
            let meta = job_meta(&self.table, id);
            for s in self.sinks.iter_mut() {
                s.on_job_admitted(&meta, node, now);
            }
            admitted += 1;
        }
        admitted
    }

    /// Streaming admission: append a new request to a (possibly running)
    /// coordinator.  The job is admitted by the next
    /// [`ingest`](Self::ingest) whose `now` has reached its `arrival_ms`
    /// (an arrival already in the past is picked up on the very next
    /// step), so mid-run and out-of-order pushes are each admitted,
    /// scheduled, and counted exactly once.  Returns the new job's id.
    pub fn push_request(&mut self, r: &TraceRequest) -> JobId {
        let id = self.table.insert_with(|id| {
            let mut job = Job::new(id, r.prompt.clone(), r.total_len,
                                   r.topic, r.arrival_ms);
            job.tenant = r.tenant.clone();
            job
        });
        // keep the un-ingested tail of `arrivals` sorted by arrival time;
        // everything before `next_arrival` has already been admitted
        let tail = &self.arrivals[self.next_arrival..];
        let pos = self.next_arrival
            + tail.partition_point(|&(t, _)| t <= r.arrival_ms);
        self.arrivals.insert(pos, (r.arrival_ms, id));
        id
    }

    /// Apply every finished window due at `now`: virtual-mode outcomes
    /// whose simulated completion time has passed, plus (for a pooled
    /// backend) everything waiting on the worker threads' completion
    /// channel.  Inline wall mode applies outcomes directly in
    /// [`dispatch`](Self::dispatch).  Returns the number of windows
    /// applied; errs if a pooled worker reported an admit/window failure
    /// (its batch is returned to the queue first — no job is lost).
    pub fn poll_completions(&mut self, now: f64) -> Result<usize> {
        let mut applied = 0;

        // pooled backend: drain the shared completion channel
        let mut threaded: Vec<WindowDone> = Vec::new();
        if let Backend::Pool(pool) = &mut self.backend {
            while let Some(done) = pool.try_recv_done() {
                threaded.push(done);
            }
        }
        // apply every drained reply before surfacing any error — an early
        // return would discard another worker's already-consumed Ok reply
        // and strand that worker in_flight forever
        let mut first_err: Option<anyhow::Error> = None;
        for done in threaded {
            self.workers[done.worker].in_flight = false;
            match done.outcome {
                Ok(outcome) => {
                    self.apply_outcome(now, outcome, &done.batch, done.worker,
                                       done.trace);
                    applied += 1;
                }
                Err(err) => {
                    // as in the inline error paths: restore the batch so
                    // the coordinator stays consistent for callers that
                    // outlive the error.  The window's *fresh* admits may
                    // have partially landed on the engine — wipe exactly
                    // those (Remove is idempotent) and drop their
                    // engine_admitted flag so a retry re-admits cleanly.
                    for &id in &done.batch {
                        self.table[id].state = JobState::Queued;
                        self.queued[done.worker].push(id);
                    }
                    for &raw in &done.fresh {
                        let id = JobId::from_raw(raw);
                        if let Some(j) = self.table.get_mut(id) {
                            j.engine_admitted = false;
                        }
                        self.backend.remove(done.worker, raw);
                    }
                    // an error from a *lost* worker (disconnect reply)
                    // under failover re-homes the rolled-back jobs onto
                    // survivors instead of failing the run; an engine
                    // error from a live worker still surfaces
                    let lost = match &self.backend {
                        Backend::Pool(p) => !p.worker_alive(done.worker),
                        Backend::Inline(_) => false,
                    };
                    if self.failover && lost {
                        self.fail_over(done.worker, now)?;
                    } else {
                        first_err.get_or_insert(err);
                    }
                }
            }
        }
        if let Some(err) = first_err {
            return Err(err);
        }

        // liveness sweep.  Without failover: a worker thread that died
        // (engine panic) can never answer its in-flight window — the
        // drain above has already consumed every reply it managed to
        // send, so fail fast instead of idling forever.  With failover: a
        // synthesizing transport (TCP pool) is *guaranteed* to deliver an
        // error reply for the in-flight window, so wait for it (the error
        // branch above then rolls back and fails over); a worker lost
        // while idle is failed over right here.
        let mut lost_idle: Vec<usize> = Vec::new();
        if let Backend::Pool(pool) = &self.backend {
            for w in 0..self.workers.len() {
                if self.dead[w] || pool.worker_alive(w) {
                    continue;
                }
                if self.workers[w].in_flight {
                    if !(self.failover && pool.synthesizes_disconnects()) {
                        bail!("worker thread {w} died with a window in \
                               flight (engine panic?)");
                    }
                } else if self.failover {
                    lost_idle.push(w);
                }
            }
        }
        for w in lost_idle {
            self.fail_over(w, now)?;
        }

        // virtual mode: outcomes whose simulated completion time passed
        let mut due: Vec<(usize, PendingWindow)> = Vec::new();
        for w in 0..self.workers.len() {
            if matches!(&self.workers[w].pending, Some(p) if p.done_at <= now)
            {
                due.push((w, self.workers[w].pending.take().unwrap()));
            }
        }
        // apply in completion-time order (ties: worker index) so sinks and
        // the online predictor see windows chronologically even when the
        // caller jumps `now` past several completions at once
        due.sort_by(|a, b| {
            a.1.done_at.total_cmp(&b.1.done_at).then(a.0.cmp(&b.0))
        });
        applied += due.len();
        for (w, p) in due {
            self.apply_outcome(p.done_at, p.outcome, &p.batch, w, None);
        }
        Ok(applied)
    }

    /// Run one scheduling iteration on every idle worker with queued jobs
    /// (Algorithm 1 lines 6–20): bring the node's order index up to date,
    /// set the preemption-victim order, form the batch, and execute one
    /// window — inline on this thread, or by handing the batch to the
    /// worker's pool thread.  Returns the number of windows dispatched.
    ///
    /// Two key paths (chosen at build time, see
    /// [`CoordinatorBuilder::full_rebuild`]):
    /// * **incremental** (default): only the node's pending jobs — new
    ///   admits, batch members returned by the last window, error spills —
    ///   are re-keyed (time-invariant folded keys) and pushed; the batch
    ///   is a top-k pop off the persistent heap, O(k log n) per window.
    /// * **rebuild** (shaper registered / forced): every queued job is
    ///   re-keyed (aged, optionally shaped) and the whole queue re-sorted,
    ///   O(n log n) per window.
    pub fn dispatch(&mut self, now: f64) -> Result<usize> {
        let mut dispatched = 0;
        for w in 0..self.cfg.workers {
            if self.dead[w]
                || self.workers[w].pending.is_some()
                || self.workers[w].in_flight
                || (self.queued[w].is_empty() && self.buffer.is_empty(w))
            {
                continue;
            }
            self.iterations += 1;
            if self.cfg.max_iterations > 0
                && self.iterations > self.cfg.max_iterations
            {
                bail!("iteration cap {} exceeded (livelock?)",
                      self.cfg.max_iterations);
            }
            let run = if self.incremental {
                self.dispatch_window_incremental(w, now)
            } else {
                self.dispatch_window_rebuild(w, now)
            };
            match run {
                Ok(()) => dispatched += 1,
                Err(err) => {
                    // the hand-off already spilled the window back into
                    // `queued[w]`; if the worker died under our feet and
                    // failover is on, re-home its jobs and keep serving
                    let lost = match &self.backend {
                        Backend::Pool(p) => !p.worker_alive(w),
                        Backend::Inline(_) => false,
                    };
                    if self.failover && lost {
                        self.fail_over(w, now)?;
                    } else {
                        return Err(err);
                    }
                }
            }
        }
        Ok(dispatched)
    }

    /// Mark worker `w` dead and re-home every job still assigned to it —
    /// its pending/dirty list, its keyed order index, and any batch the
    /// error path just spilled back — onto surviving workers via the load
    /// balancer.  Re-homed jobs are re-admitted fresh on their new engine
    /// and resume from the tokens the coordinator already holds
    /// ([`SeqSpec::resume`](crate::engine::SeqSpec)).  Idempotent: late
    /// spills for an already-dead worker re-home on the next call.  Errs
    /// only when no worker is left alive for unfinished work.
    fn fail_over(&mut self, w: usize, now: f64) -> Result<()> {
        let first = !self.dead[w];
        self.dead[w] = true;
        self.workers[w].in_flight = false;
        self.workers[w].pending = None;
        if self.dead.iter().all(|&d| d) && self.finished < self.table.len() {
            bail!("all {} workers are lost with {} jobs unfinished",
                  self.cfg.workers, self.table.len() - self.finished);
        }

        let mut moved = std::mem::take(&mut self.pending_scratch);
        moved.clear();
        moved.append(&mut self.queued[w]);
        {
            let mut order = std::mem::take(&mut self.order_scratch);
            self.buffer.drain_sorted_into(w, &mut order);
            moved.extend(order.iter().map(|e| e.id));
            self.order_scratch = order;
        }
        self.warm[w].clear();
        for &id in &moved {
            self.table[id].engine_admitted = false;
            // the prompt must travel again to wherever the job lands
            self.batcher.forget(w, id);
            self.state.on_finish(w);
            let node = self.lb.assign_excluding(&mut self.state, &self.dead);
            self.table[id].node = Some(node);
            self.queued[node].push(id);
        }
        let rehomed = moved.len();
        self.pending_scratch = moved;
        if first || rehomed > 0 {
            for s in self.sinks.iter_mut() {
                s.on_worker_lost(w, rehomed, now);
            }
        }
        Ok(())
    }

    /// One window on node `w`, incremental path: re-key only the pending
    /// jobs, top-k select against the persistent index, rank victims over
    /// the engine-relevant (warm ∪ batch) set only.
    fn dispatch_window_incremental(&mut self, w: usize, now: f64)
                                   -> Result<()> {
        let t_sched = Instant::now();
        self.decision_depth = self.queued[w].len() + self.buffer.len(w);

        // fold pending (changed) jobs into the index: their folded keys
        // are recomputed — cache-hitting unless the job actually produced
        // tokens since its last prediction — and everything already in the
        // heap keeps its key untouched
        let mut pending = std::mem::take(&mut self.pending_scratch);
        pending.clear();
        pending.append(&mut self.queued[w]);
        if !pending.is_empty() {
            let (table, scheduler) = (&mut self.table, &mut *self.scheduler);
            table.with_mut_refs(&pending,
                                |refs| scheduler.refresh_folded(refs));
        }
        for &id in &pending {
            let j = &self.table[id];
            self.buffer.push(w, Entry {
                priority: j.priority.unwrap_or(f64::MAX),
                arrival_ms: j.arrival_ms,
                id,
            });
            if j.engine_admitted {
                self.warm[w].insert(id);
            }
        }
        self.pending_scratch = pending;

        // top-k partial selection: k pops, the rest never moves
        let engine_cap = self.backend.max_batch(w);
        self.decision_cap = engine_cap;
        let mut batch_entries = std::mem::take(&mut self.order_scratch);
        self.batcher.select_into(&mut self.buffer, w, engine_cap,
                                 &mut batch_entries);
        for e in &batch_entries {
            self.warm[w].remove(&e.id);
        }
        let batch: Vec<JobId> = batch_entries.iter().map(|e| e.id).collect();

        // preemption victim ordering over the engine-relevant set only:
        // the batch plus queued jobs that still hold engine KV state.
        // Jobs the engine has never admitted can't be evicted, and the
        // engine skips unknown ids, so the filtered ranking drives the
        // exact same eviction choices as the old full-queue ranking.
        let rank = self.cfg.preemption.can_fire();
        let mut victims = std::mem::take(&mut self.victims_scratch);
        victims.clear();
        if rank {
            let mut ve = std::mem::take(&mut self.victim_entries_scratch);
            ve.clear();
            ve.extend_from_slice(&batch_entries);
            for &id in &self.warm[w] {
                let j = &self.table[id];
                ve.push(Entry {
                    priority: j.priority.unwrap_or(f64::MAX),
                    arrival_ms: j.arrival_ms,
                    id,
                });
            }
            // ascending (priority, arrival, id) — Entry's total order is
            // reversed for the min-heap, so highest-priority-first is the
            // reverse of Ord; one comparator shared with the heap keeps
            // this ranking and the index order in lockstep
            ve.sort_unstable_by(|a, b| b.cmp(a));
            let mut ranked = std::mem::take(&mut self.ranked_scratch);
            ranked.clear();
            ranked.extend(ve.iter()
                .map(|e| (e.id, self.table[e.id].preemptions)));
            self.cfg.preemption.victim_order_into(&ranked, &mut victims);
            self.ranked_scratch = ranked;
            self.victim_entries_scratch = ve;
        }
        self.victims_scratch = victims;
        self.order_scratch = batch_entries;

        self.execute_window(w, now, batch, rank, t_sched,
                            SpillOnError::BatchOnly)
    }

    /// One window on node `w`, rebuild path (shaper registered or forced):
    /// re-key and re-sort the entire pool, rank victims over the full
    /// queue — Algorithm 1 as written, through reusable scratch buffers.
    ///
    /// Key choice: a shaper gets the *aged* priority as its base (its
    /// whole point is now-relative shaping); a forced rebuild without a
    /// shaper uses the same *folded* keys as the incremental path, so the
    /// two paths compare bit-for-bit — not merely algebraically — even
    /// with aging enabled (aged and folded keys order identically in
    /// exact arithmetic, but could split an f64-rounding near-tie).
    fn dispatch_window_rebuild(&mut self, w: usize, now: f64) -> Result<()> {
        let t_sched = Instant::now();
        self.decision_depth = self.queued[w].len() + self.buffer.len(w);

        // refresh priorities of every queued job on this node: disjoint
        // slab references, no per-iteration map rebuild or cloning
        let mut pending = std::mem::take(&mut self.pending_scratch);
        pending.clear();
        pending.append(&mut self.queued[w]);
        {
            let (table, scheduler) = (&mut self.table, &mut *self.scheduler);
            let shaped = self.shaper.is_some();
            table.with_mut_refs(&pending, |refs| if shaped {
                scheduler.refresh(refs, now)
            } else {
                scheduler.refresh_folded(refs)
            });
        }

        // rebuild this node's priority queue and drain it sorted; an
        // optional shaper (SLO policy) adjusts each base priority
        for &id in &pending {
            let (priority, arrival_ms) = {
                let j = &self.table[id];
                let base = j.priority.unwrap_or(f64::MAX);
                let shaped = match self.shaper.as_mut() {
                    Some(s) => s.shape(j, base, now),
                    None => base,
                };
                (shaped, j.arrival_ms)
            };
            self.buffer.push(w, Entry { priority, arrival_ms, id });
        }
        self.pending_scratch = pending;
        let mut full_order = std::mem::take(&mut self.order_scratch);
        self.buffer.drain_sorted_into(w, &mut full_order);

        // preemption victim ordering for the engine (skipped when the
        // per-window eviction budget is zero: the engine checks the budget
        // before ever consulting the ranking)
        let rank = self.cfg.preemption.can_fire();
        let mut victims = std::mem::take(&mut self.victims_scratch);
        victims.clear();
        if rank {
            let mut ranked = std::mem::take(&mut self.ranked_scratch);
            ranked.clear();
            ranked.extend(full_order.iter()
                .map(|e| (e.id, self.table[e.id].preemptions)));
            self.cfg.preemption.victim_order_into(&ranked, &mut victims);
            self.ranked_scratch = ranked;
        }
        self.victims_scratch = victims;

        // form the batch from the highest-priority prefix; the sorted
        // remainder becomes the node's new pool
        let take = self.cfg.max_batch.min(self.backend.max_batch(w));
        self.decision_cap = take;
        let batch: Vec<JobId> =
            full_order.iter().take(take).map(|e| e.id).collect();
        self.order_scratch = full_order;

        self.execute_window(w, now, batch, rank, t_sched,
                            SpillOnError::FullOrder)
    }

    /// Shared tail of both dispatch paths: admit fresh batch members,
    /// account scheduling overhead, notify sinks, and execute the window
    /// inline or ship it to the worker's pool thread.  `rank` says whether
    /// a victim ranking was built this window (it lives in
    /// `victims_scratch`); `spill` says what to return to the node's pool
    /// if the engine errors so no job is ever lost.
    fn execute_window(&mut self, w: usize, now: f64, batch: Vec<JobId>,
                      rank: bool, t_sched: Instant, spill: SpillOnError)
                      -> Result<()> {
        if rank {
            if let Backend::Inline(engines) = &mut self.backend {
                engines[w].set_priority_order(&self.victims_scratch);
            } // pooled: the order ships inside the RunWindow command
        }

        // admit + (modelled) prompt transfer
        let mut admits: Vec<SeqSpec> = Vec::new();
        for &id in &batch {
            let prompt_tokens = self.table[id].prompt.len();
            if !self.table[id].engine_admitted {
                let spec = {
                    let j = &self.table[id];
                    SeqSpec {
                        id: id.raw(),
                        prompt: j.prompt.clone(),
                        target_total: j.total_len,
                        topic: j.topic,
                        // empty on first admission; after a failover the
                        // new engine resumes from the coordinator's copy
                        // of the response so far
                        resume: j.response.clone(),
                    }
                };
                match &mut self.backend {
                    Backend::Inline(engines) => {
                        if let Err(err) = engines[w].admit(spec) {
                            // restore the pool so the coordinator stays
                            // consistent for callers that outlive the error
                            self.spill_window(w, &batch, spill);
                            return Err(err);
                        }
                    }
                    // pooled: admits run on the worker thread as part of
                    // the RunWindow command; an error comes back through
                    // poll_completions
                    Backend::Pool(_) => admits.push(spec),
                }
                self.table[id].engine_admitted = true;
            }
            self.batcher.mark_prompt_sent(w, id, prompt_tokens);
        }
        let sched_ns = t_sched.elapsed().as_nanos();
        self.sched_overhead_ns += sched_ns;

        // flight-recorder decision record: what the queue looked like, who
        // was picked (with the folded-key range actually compared), who
        // would be evicted first, and what the decision cost.  Fired
        // before the victims move into a pooled RunWindow command below.
        {
            let mut key_min = f64::NAN;
            let mut key_max = f64::NAN;
            for e in self.order_scratch.iter().take(batch.len()) {
                if !(e.priority >= key_min) {
                    key_min = e.priority;
                }
                if !(e.priority <= key_max) {
                    key_max = e.priority;
                }
            }
            let d = DecisionRecord {
                node: w,
                window: self.iterations,
                now_ms: now,
                queue_depth: self.decision_depth,
                batch: &batch,
                batch_cap: self.decision_cap,
                victims: &self.victims_scratch,
                key_min,
                key_max,
                sched_overhead_ms: sched_ns as f64 / 1e6,
            };
            for s in self.sinks.iter_mut() {
                s.on_window_decision(&d);
            }
        }
        for s in self.sinks.iter_mut() {
            s.on_batch_formed(w, &batch, now);
        }

        // execute one scheduling window
        let raw_batch: Vec<u64> = batch.iter().map(|id| id.raw()).collect();
        if matches!(self.backend, Backend::Pool(_)) {
            // hand the window to the worker's thread; the outcome comes
            // back through poll_completions
            let sent = match &mut self.backend {
                Backend::Pool(pool) => pool.send(w, WorkerCmd::RunWindow {
                    admits: std::mem::take(&mut admits),
                    // move the ranking into the command (no per-window
                    // copy); the scratch is rebuilt from scratch next
                    // window anyway
                    priority_order: if rank {
                        std::mem::take(&mut self.victims_scratch)
                    } else {
                        Vec::new()
                    },
                    batch: raw_batch,
                    echo: batch.clone(),
                    // window span id: the pod echoes it back with its own
                    // execute measurement so the timelines stitch; omitted
                    // for workers that didn't negotiate tracing
                    trace: if pool.trace_capable(w) {
                        Some(self.iterations)
                    } else {
                        None
                    },
                }),
                Backend::Inline(_) => unreachable!(),
            };
            if let Err(err) = sent {
                self.spill_window(w, &batch, spill);
                return Err(err);
            }
            self.requeue_rest(w, batch.len(), spill);
            for &id in &batch {
                self.table[id].state = JobState::Running;
            }
            self.workers[w].in_flight = true;
        } else {
            let run = match &mut self.backend {
                Backend::Inline(engines) => engines[w].run_window(&raw_batch),
                Backend::Pool(_) => unreachable!(),
            };
            let outcome = match run {
                Ok(o) => o,
                Err(err) => {
                    // as above: no job may be lost on an engine error
                    self.spill_window(w, &batch, spill);
                    return Err(err);
                }
            };

            self.requeue_rest(w, batch.len(), spill);
            for &id in &batch {
                self.table[id].state = JobState::Running;
            }

            match self.cfg.clock {
                ClockMode::Virtual => {
                    let done_at = now + outcome.service_ms
                        + self.cfg.overhead_ms_per_iter;
                    self.workers[w].pending =
                        Some(PendingWindow { done_at, outcome, batch });
                }
                ClockMode::Wall => {
                    let t_done = self.wall_ms();
                    self.apply_outcome(t_done, outcome, &batch, w, None);
                }
            }
        }
        Ok(())
    }

    /// Error recovery: return this window's jobs to the node's pending
    /// list.  Rebuild mode drained the whole pool into `order_scratch`, so
    /// everything goes back; incremental mode only popped the batch — the
    /// remainder never left the index.
    fn spill_window(&mut self, w: usize, batch: &[JobId], spill: SpillOnError) {
        match spill {
            SpillOnError::FullOrder => {
                let order = std::mem::take(&mut self.order_scratch);
                self.queued[w].extend(order.iter().map(|e| e.id));
                self.order_scratch = order;
            }
            SpillOnError::BatchOnly => {
                self.queued[w].extend(batch.iter().copied());
            }
        }
    }

    /// After a successful hand-off: in rebuild mode the sorted remainder
    /// (everything past the batch prefix) becomes the node's new pool (the
    /// monolith instead re-scanned the old queue with `batch_ids.contains`
    /// per element); in incremental mode the remainder is still keyed in
    /// the index and nothing needs re-queueing.
    fn requeue_rest(&mut self, w: usize, batch_len: usize,
                    spill: SpillOnError) {
        if let SpillOnError::FullOrder = spill {
            let order = std::mem::take(&mut self.order_scratch);
            self.queued[w].extend(order.iter().skip(batch_len).map(|e| e.id));
            self.order_scratch = order;
        }
    }

    /// One full scheduling iteration: ingest → poll completions → dispatch,
    /// advancing the clock (virtual) or sleeping (wall) when no worker
    /// could run.  A no-op once [`is_done`](Self::is_done).
    pub fn step(&mut self) -> Result<StepOutcome> {
        if self.is_done() {
            return Ok(StepOutcome {
                now_ms: self.now,
                admitted: 0,
                completed: 0,
                dispatched: 0,
                idled: false,
                done: true,
            });
        }
        // A fully-failed-over backend can reach here with every worker
        // dead but nothing unfinished *at the time of the last loss*
        // (fail_over only errs for unfinished work) — a later
        // push_request must then fail cleanly before ingest would ask
        // the load balancer for a surviving node it cannot have.
        if !self.dead.is_empty() && self.dead.iter().all(|&d| d) {
            bail!("all {} workers are lost with {} jobs unfinished",
                  self.cfg.workers, self.table.len() - self.finished);
        }
        if self.cfg.clock == ClockMode::Wall {
            self.now = self.wall_ms();
        }
        let now = self.now;
        let admitted = self.ingest(now);
        let completed = self.poll_completions(now)?;
        let dispatched = self.dispatch(now)?;
        let mut idled = false;
        if !self.is_done() && dispatched == 0 {
            self.advance_clock()?;
            idled = true;
        }
        Ok(StepOutcome {
            now_ms: self.now,
            admitted,
            completed,
            dispatched,
            idled,
            done: self.is_done(),
        })
    }

    /// Step until every job finishes; returns the final report.
    pub fn run_to_completion(&mut self) -> Result<ServeReport> {
        while !self.is_done() {
            self.step()?;
        }
        Ok(self.report())
    }

    /// Snapshot the run metrics (records cover finished jobs only, so this
    /// is also meaningful mid-run).
    pub fn report(&self) -> ServeReport {
        let makespan_ms = self
            .table
            .iter()
            .filter_map(|j| j.finish_ms)
            .fold(0.0, f64::max);
        let records: Vec<JobRecord> =
            self.table.iter().filter_map(JobRecord::from_job).collect();
        ServeReport {
            scheduler: self.scheduler.policy.name().to_string(),
            predictor_name: self.scheduler.predictor_name().to_string(),
            records,
            makespan_ms,
            total_preemptions: self.total_preemptions,
            sched_overhead_ms_avg: if self.iterations == 0 {
                0.0
            } else {
                self.sched_overhead_ns as f64 / self.iterations as f64 / 1e6
            },
            sched_iterations: self.iterations,
        }
    }

    // ---- internals ------------------------------------------------------

    /// Fold a finished window back into coordinator state: count
    /// preemptions, append tokens, retire finished jobs, return the rest
    /// to their node's pool.  All state mutates first; the window's events
    /// are recorded along the way and delivered afterwards as **one**
    /// [`EventSink::on_window_applied`] call per sink (same causal order),
    /// so lock-guarded sinks pay one critical section per window instead
    /// of one per job per window.
    fn apply_outcome(&mut self, t_done: f64, outcome: WindowOutcome,
                     batch: &[JobId], node: usize, pod: Option<PodExec>) {
        let window_tokens: usize =
            outcome.outputs.iter().map(|o| o.new_tokens.len()).sum();
        let mut events = std::mem::take(&mut self.events_scratch);
        events.clear();
        for &pid_raw in &outcome.preempted {
            let pid = JobId::from_raw(pid_raw);
            if let Some(j) = self.table.get_mut(pid) {
                j.preemptions += 1;
            }
            // an evicted job is no longer resident, so it can't be a
            // victim again until a batch re-stages it (which re-folds it
            // into `warm` via the pending list) — pruning here keeps the
            // victim ranking proportional to the *resident* set even in
            // preemption-heavy regimes
            self.warm[node].remove(&pid);
            self.total_preemptions += 1;
            events.push(PendingOutcomeEvent::Preempted(pid));
        }
        for out in &outcome.outputs {
            let id = JobId::from_raw(out.id);
            {
                let j = &mut self.table[id];
                j.windows += 1;
                j.service_ms += outcome.service_ms;
                if !out.new_tokens.is_empty() && j.first_token_ms.is_none() {
                    j.first_token_ms = Some(t_done);
                }
                j.generated += out.new_tokens.len();
                j.response.extend_from_slice(&out.new_tokens);
            }
            if !out.new_tokens.is_empty() {
                // live progress: per-job, per-window token production,
                // recorded before a final window's finish event
                events.push(PendingOutcomeEvent::Progress(
                    id, out.new_tokens.len()));
            }
            if out.done {
                let j = &mut self.table[id];
                j.state = JobState::Finished;
                j.finish_ms = Some(t_done);
                let (prompt_len, total_len) = (j.prompt.len(), j.total_len);
                self.finished += 1;
                self.state.on_finish(node);
                // the accuracy signal must be read before `forget` drops
                // the prediction-cache entry
                let predicted_total = self.scheduler.predicted_total(id);
                self.scheduler.observe_completion(prompt_len, total_len);
                self.scheduler.forget(id);
                self.batcher.forget(node, id);
                self.warm[node].remove(&id);
                self.backend.remove(node, out.id);
                let j = &self.table[id];
                let stats = FinishStats {
                    jct_ms: t_done - j.arrival_ms,
                    ttft_ms: j.ttft_ms(),
                    queue_delay_ms: j.queue_delay_ms().unwrap_or(0.0),
                    service_ms: j.service_ms,
                    tokens: j.generated,
                    predicted_total,
                };
                events.push(PendingOutcomeEvent::Finished(id, stats));
            } else {
                self.table[id].state = JobState::Queued;
                self.queued[node].push(id);
            }
        }
        // batch jobs that produced no output (couldn't be staged) go back
        for &id in batch {
            let j = &mut self.table[id];
            if j.state == JobState::Running {
                j.state = JobState::Queued;
                self.queued[node].push(id);
            }
        }
        // deliver: resolve metas against the now-quiescent table and hand
        // each sink the whole window at once (the default trait impl
        // re-expands into the per-event hooks, in causal order, with
        // window-done last)
        {
            let resolved: Vec<WindowJobEvent<'_>> = events
                .iter()
                .map(|ev| match *ev {
                    PendingOutcomeEvent::Progress(id, n) => {
                        // each job appears at most once per window, so the
                        // response tail is exactly this window's tokens
                        let resp = &self.table[id].response;
                        WindowJobEvent::Progress {
                            job: job_meta(&self.table, id),
                            tokens: &resp[resp.len() - n..],
                        }
                    }
                    PendingOutcomeEvent::Finished(id, stats) => {
                        WindowJobEvent::Finished {
                            job: job_meta(&self.table, id),
                            stats,
                        }
                    }
                    PendingOutcomeEvent::Preempted(id) => {
                        WindowJobEvent::Preempted { job: id }
                    }
                })
                .collect();
            let window = WindowEvents {
                node,
                batch,
                events: &resolved,
                tokens: window_tokens,
                service_ms: outcome.service_ms,
                now_ms: t_done,
                pod,
            };
            for s in self.sinks.iter_mut() {
                s.on_window_applied(&window);
            }
        }
        self.events_scratch = events;
    }

    /// Nothing could run: jump the virtual clock to the next event, or
    /// sleep (at most one idle tick) in wall mode.  Errors on deadlock
    /// (unfinished jobs but no future event and nothing in flight).
    fn advance_clock(&mut self) -> Result<()> {
        let next_completion = self
            .workers
            .iter()
            .filter_map(|s| s.pending.as_ref().map(|p| p.done_at))
            .fold(f64::INFINITY, f64::min);
        let next_arrival_t = if self.next_arrival < self.arrivals.len() {
            self.arrivals[self.next_arrival].0
        } else {
            f64::INFINITY
        };
        let next_t = next_completion.min(next_arrival_t);
        match self.cfg.clock {
            ClockMode::Virtual => {
                if !next_t.is_finite() {
                    bail!("deadlock: no pending work but {} jobs unfinished",
                          self.table.len() - self.finished);
                }
                self.now = next_t.max(self.now);
            }
            ClockMode::Wall => {
                let in_flight = self.workers.iter().any(|s| s.in_flight);
                if !next_t.is_finite() && !in_flight {
                    bail!("deadlock: no pending work but {} jobs unfinished",
                          self.table.len() - self.finished);
                }
                // cap the idle sleep at one tick so streamed admissions
                // (push_request / HTTP frontend) and pool completions are
                // picked up promptly instead of waiting out the full gap
                // to the next known arrival
                let tick = self.cfg.idle_tick_ms.max(0.1);
                let wait_ms = if next_t.is_finite() {
                    (next_t - self.wall_ms()).min(tick)
                } else {
                    tick
                };
                if wait_ms > 0.0 {
                    std::thread::sleep(std::time::Duration::from_secs_f64(
                        wait_ms / 1e3,
                    ));
                }
            }
        }
        Ok(())
    }
}
