//! Scheduling policies (paper §4.1 Algorithm 1, lines 10–18).
//!
//! Priorities are `f64`, **lower runs first**:
//! * `Fcfs`   — arrival time (vLLM default; the paper's baseline).
//! * `Sjf`    — total profiled length, fixed at arrival (the paper's oracle
//!              baseline: "SJF serving as an oracle scheduler").
//! * `Isrtf`  — the paper's contribution: predicted *remaining* tokens,
//!              re-predicted at every scheduling iteration via the length
//!              predictor (`Predictor.init` / `Predictor.iter`).
//! * `Srpt`   — oracle remaining tokens (upper bound for ISRTF).
//! * `Mlfq`   — FastServe-style multi-level feedback queue (related-work
//!              baseline): demote one level per executed window.
//!
//! Anti-starvation aging (paper §3.4: "policies that ... prevent
//! starvation") subtracts `aging_per_s × wait` from the priority of
//! length-based policies so long-waiting jobs eventually win.

use std::collections::BTreeMap;

use crate::predictor::{LengthPredictor, PredictQuery};

use super::job::{Job, JobId};

/// Post-scheduler priority hook, called by the coordinator's dispatch for
/// every queued job each scheduling iteration — after the base policy
/// assigned `base_priority` ([`Scheduler::refresh`]) and before the job
/// enters its node's priority queue.  Returns the priority actually used
/// for ordering (lower still runs first).
///
/// This is the seam SLO-aware policies plug into (e.g.
/// `telemetry::SloPolicy`, which re-orders work earliest-deadline-first
/// against per-tenant budgets using live latency sketches).  When no
/// shaper is registered the base priority is used untouched, so the
/// schedule — and every report — is bit-identical to a shaper-less run.
pub trait PriorityShaper {
    fn shape(&mut self, job: &Job, base_priority: f64, now_ms: f64) -> f64;
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Fcfs,
    Sjf,
    Isrtf,
    Srpt,
    Mlfq,
}

impl Policy {
    /// Parse a policy name; the error lists the valid names.
    pub fn parse(s: &str) -> Result<Policy, String> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fcfs" => Policy::Fcfs,
            "sjf" => Policy::Sjf,
            "isrtf" => Policy::Isrtf,
            "srpt" => Policy::Srpt,
            "mlfq" => Policy::Mlfq,
            _ => {
                return Err(format!(
                    "unknown scheduler policy '{s}' \
                     (valid: fcfs, sjf, isrtf, srpt, mlfq)"
                ))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Fcfs => "FCFS",
            Policy::Sjf => "SJF",
            Policy::Isrtf => "ISRTF",
            Policy::Srpt => "SRPT",
            Policy::Mlfq => "MLFQ",
        }
    }

    /// Does this policy re-assign priorities at every iteration?
    pub fn iterative(&self) -> bool {
        matches!(self, Policy::Isrtf | Policy::Srpt | Policy::Mlfq)
    }

    /// Does this policy consult the length predictor?
    pub fn uses_predictor(&self) -> bool {
        matches!(self, Policy::Sjf | Policy::Isrtf | Policy::Srpt)
    }
}

pub struct Scheduler {
    pub policy: Policy,
    predictor: Box<dyn LengthPredictor>,
    /// priority bonus per second of waiting (0 disables aging)
    pub aging_per_s: f64,
    /// MLFQ quantum thresholds (windows executed -> level)
    mlfq_levels: usize,
    /// prediction cache: job id -> (generated count at prediction, base
    /// priority).  The predictor is deterministic in (prompt, generated),
    /// so a job that has not produced tokens since the last refresh keeps
    /// its base priority — this is what keeps the per-iteration scheduling
    /// overhead at the paper's ~11 ms instead of re-running the encoder for
    /// the whole queue every window.
    cache: BTreeMap<JobId, (usize, f64)>,
    /// predictor invocations actually made (profiling)
    pub predictor_queries: u64,
}

impl Scheduler {
    pub fn new(policy: Policy, predictor: Box<dyn LengthPredictor>) -> Scheduler {
        Scheduler {
            policy,
            predictor,
            aging_per_s: 0.0,
            mlfq_levels: 4,
            cache: BTreeMap::new(),
            predictor_queries: 0,
        }
    }

    pub fn with_aging(mut self, aging_per_s: f64) -> Scheduler {
        self.aging_per_s = aging_per_s;
        self
    }

    pub fn predictor_name(&self) -> &'static str {
        self.predictor.name()
    }

    /// Algorithm 1 lines 10–18: assign/refresh the priority of every job.
    /// `now_ms` is the current (virtual or wall) time for aging.
    pub fn refresh(&mut self, jobs: &mut [&mut Job], now_ms: f64) {
        // which jobs need a predictor call this iteration?  A cached base
        // priority is reused unless the job produced tokens since the last
        // prediction (ISRTF re-predicts per *iteration of the job*, and a
        // job's input to the predictor only changes when it runs).
        let needs: Vec<usize> = jobs
            .iter()
            .enumerate()
            .filter(|(_, j)| {
                if !self.policy.uses_predictor() {
                    return false;
                }
                match self.cache.get(&j.id) {
                    None => true,
                    Some((gen, _)) => self.policy.iterative() && *gen != j.generated,
                }
            })
            .map(|(i, _)| i)
            .collect();

        if !needs.is_empty() {
            let queries: Vec<PredictQuery<'_>> = needs
                .iter()
                .map(|&i| {
                    let j = &jobs[i];
                    PredictQuery {
                        job_id: j.id.raw(),
                        prompt: &j.prompt,
                        // paper §3.3: partial output feeds back each iteration
                        gen_suffix: &j.response,
                        generated: if self.policy == Policy::Sjf {
                            0
                        } else {
                            j.generated
                        },
                        true_total: j.total_len,
                    }
                })
                .collect();
            self.predictor_queries += queries.len() as u64;
            let preds = self.predictor.predict(&queries);
            for (&i, p) in needs.iter().zip(preds) {
                self.cache.insert(jobs[i].id, (jobs[i].generated, p));
            }
        }

        for j in jobs.iter_mut() {
            let base = match self.policy {
                Policy::Fcfs => j.arrival_ms,
                Policy::Mlfq => {
                    // level-major ordering; FCFS within a level
                    let level = j.windows.min(self.mlfq_levels - 1) as f64;
                    level * 1e9 + j.arrival_ms
                }
                _ => self.cache.get(&j.id).map(|(_, p)| *p).unwrap_or(f64::MAX),
            };
            let aged = if self.aging_per_s > 0.0 && self.policy != Policy::Fcfs {
                let wait_s = ((now_ms - j.arrival_ms) / 1000.0).max(0.0);
                base - self.aging_per_s * wait_s
            } else {
                base
            };
            j.priority = Some(aged);
        }
    }

    /// Drop a finished job's cache entry.
    pub fn forget(&mut self, job_id: JobId) {
        self.cache.remove(&job_id);
    }

    /// Completion feedback for online predictors.
    pub fn observe_completion(&mut self, prompt_len: usize, total_len: usize) {
        self.predictor.observe(prompt_len, total_len);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::oracle::{FrozenOracle, OraclePredictor};

    fn job(id: u64, arrival: f64, total: usize, generated: usize) -> Job {
        let mut j = Job::new(JobId::from_raw(id), vec![5; 10], total, 0,
                             arrival);
        j.generated = generated;
        j
    }

    fn refresh(s: &mut Scheduler, jobs: &mut [Job], now: f64) {
        let mut refs: Vec<&mut Job> = jobs.iter_mut().collect();
        s.refresh(&mut refs, now);
    }

    #[test]
    fn fcfs_orders_by_arrival() {
        let mut s = Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
        let mut jobs = vec![job(1, 200.0, 10, 0), job(2, 100.0, 500, 0)];
        refresh(&mut s, &mut jobs, 300.0);
        assert!(jobs[1].priority.unwrap() < jobs[0].priority.unwrap());
    }

    #[test]
    fn srpt_orders_by_remaining() {
        let mut s = Scheduler::new(Policy::Srpt, Box::new(OraclePredictor));
        let mut jobs = vec![job(1, 0.0, 400, 350), job(2, 0.0, 100, 0)];
        refresh(&mut s, &mut jobs, 0.0);
        // job 1 has 50 remaining < job 2's 100
        assert!(jobs[0].priority.unwrap() < jobs[1].priority.unwrap());
    }

    #[test]
    fn sjf_freezes_initial_estimate() {
        let mut s = Scheduler::new(Policy::Sjf, Box::new(FrozenOracle));
        let mut jobs = vec![job(1, 0.0, 200, 0)];
        refresh(&mut s, &mut jobs, 0.0);
        let p0 = jobs[0].priority.unwrap();
        jobs[0].generated = 150;
        refresh(&mut s, &mut jobs, 0.0);
        assert_eq!(jobs[0].priority.unwrap(), p0, "SJF never re-predicts");
    }

    #[test]
    fn isrtf_repredicts_each_iteration() {
        let mut s = Scheduler::new(Policy::Isrtf, Box::new(OraclePredictor));
        let mut jobs = vec![job(1, 0.0, 200, 0)];
        refresh(&mut s, &mut jobs, 0.0);
        let p0 = jobs[0].priority.unwrap();
        jobs[0].generated = 150;
        refresh(&mut s, &mut jobs, 0.0);
        assert!(jobs[0].priority.unwrap() < p0, "remaining must shrink");
    }

    #[test]
    fn mlfq_demotes_by_windows() {
        let mut s = Scheduler::new(Policy::Mlfq, Box::new(OraclePredictor));
        let mut jobs = vec![job(1, 50.0, 500, 0), job(2, 500.0, 500, 0)];
        jobs[0].windows = 2; // demoted twice
        refresh(&mut s, &mut jobs, 600.0);
        assert!(jobs[1].priority.unwrap() < jobs[0].priority.unwrap(),
                "fresh job outranks demoted job despite later arrival");
    }

    #[test]
    fn aging_eventually_promotes_long_waiters() {
        let mut s = Scheduler::new(Policy::Srpt, Box::new(OraclePredictor))
            .with_aging(10.0);
        // long job waiting an hour vs short job arriving now
        let mut jobs = vec![job(1, 0.0, 400, 0), job(2, 3_600_000.0, 10, 0)];
        refresh(&mut s, &mut jobs, 3_600_000.0);
        assert!(jobs[0].priority.unwrap() < jobs[1].priority.unwrap(),
                "hour-old 400-token job must outrank fresh 10-token job");
    }

    #[test]
    fn fcfs_ignores_aging() {
        let mut s = Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor))
            .with_aging(10.0);
        let mut jobs = vec![job(1, 100.0, 10, 0)];
        refresh(&mut s, &mut jobs, 50_000.0);
        assert_eq!(jobs[0].priority.unwrap(), 100.0);
    }

    #[test]
    fn policy_parse() {
        assert_eq!(Policy::parse("ISRTF"), Ok(Policy::Isrtf));
        assert_eq!(Policy::parse("fcfs"), Ok(Policy::Fcfs));
        let err = Policy::parse("nope").unwrap_err();
        assert!(err.contains("nope") && err.contains("isrtf"),
                "error must name the input and the valid policies: {err}");
    }
}
