//! Scheduling policies (paper §4.1 Algorithm 1, lines 10–18).
//!
//! Priorities are `f64`, **lower runs first**:
//! * `Fcfs`   — arrival time (vLLM default; the paper's baseline).
//! * `Sjf`    — total profiled length, fixed at arrival (the paper's oracle
//!              baseline: "SJF serving as an oracle scheduler").
//! * `Isrtf`  — the paper's contribution: predicted *remaining* tokens,
//!              re-predicted at every scheduling iteration via the length
//!              predictor (`Predictor.init` / `Predictor.iter`).
//! * `Srpt`   — oracle remaining tokens (upper bound for ISRTF).
//! * `Mlfq`   — FastServe-style multi-level feedback queue (related-work
//!              baseline): demote one level per executed window.
//!
//! Anti-starvation aging (paper §3.4: "policies that ... prevent
//! starvation") subtracts `aging_per_s × wait` from the priority of
//! length-based policies so long-waiting jobs eventually win.
//!
//! Two key flavours feed the coordinator's two dispatch paths:
//!
//! * [`Scheduler::refresh`] — the per-window **aged** priority
//!   `base − a·(now − arrival)/1000`, recomputed for the whole queue each
//!   iteration (shaper / full-rebuild path).
//! * [`Scheduler::refresh_folded`] — the **time-invariant folded** key
//!   `base + a·arrival/1000`.  Algebraically the aged priority equals the
//!   folded key minus `a·now/1000`, and that second term is the *same
//!   uniform shift for every queued job at a given instant*, so ordering
//!   by folded keys is ordering by aged priorities — without ever touching
//!   the entries that didn't change.  This is what lets the incremental
//!   index keep stale-but-correct keys across windows.

use crate::predictor::{LengthPredictor, ObservedCompletion, PredictQuery};

use super::job::{Job, JobId};

/// Post-scheduler priority hook, called by the coordinator's dispatch for
/// every queued job each scheduling iteration — after the base policy
/// assigned `base_priority` ([`Scheduler::refresh`]) and before the job
/// enters its node's priority queue.  Returns the priority actually used
/// for ordering (lower still runs first).
///
/// This is the seam SLO-aware policies plug into (e.g.
/// `telemetry::SloPolicy`, which re-orders work earliest-deadline-first
/// against per-tenant budgets using live latency sketches).  When no
/// shaper is registered the base priority is used untouched, so the
/// schedule — and every report — is bit-identical to a shaper-less run.
///
/// A shaper that can express its per-tenant offset as a time-invariant
/// term over folded keys additionally returns itself from
/// [`as_folded`](Self::as_folded); the coordinator then keeps the
/// persistent incremental order index even under shaping (see
/// [`FoldedShaper`]).  The default (`None`) preserves the classic
/// re-shape-everything rebuild path for arbitrary shapers.
///
/// `Send + Sync` bounds let folded shapers be consulted from the
/// coordinator's dispatch shards; all mutation is confined to
/// [`begin_round`](Self::begin_round), which runs serially.
pub trait PriorityShaper: Send + Sync {
    fn shape(&mut self, job: &Job, base_priority: f64, now_ms: f64) -> f64;

    /// Called once at the top of every dispatch round, before any
    /// `shape`/[`FoldedShaper::shape_folded`] call of that round.  This is
    /// where per-round state (telemetry snapshots, tenant pressure memos,
    /// epoch bumps) is rebuilt — keyed on the round counter, not on
    /// `now_ms`, so wall-clock pooled runs that dispatch several nodes in
    /// one round snapshot the telemetry exactly once.
    fn begin_round(&mut self, _round: u64, _now_ms: f64) {}

    /// `Some(self)` when this shaper folds (its shaped key over a *folded*
    /// base is constant between [`begin_round`](Self::begin_round)s and
    /// per-tenant epochs flag every change).  `None` (default) selects the
    /// per-window rebuild dispatch path.
    fn as_folded(&self) -> Option<&dyn FoldedShaper> {
        None
    }
}

/// The folded-shaping surface behind [`PriorityShaper::as_folded`]: a
/// shaped analogue of [`Scheduler::refresh_folded`]'s time-invariant keys.
///
/// Contract: for a fixed job and fixed `base_folded`,
/// [`shape_folded`](Self::shape_folded) returns bit-identical keys across
/// rounds as long as [`tenant_epoch`](Self::tenant_epoch) for the job's
/// tenant is unchanged — so the coordinator re-keys only the lanes of
/// tenants whose pressure/lead term actually moved, and a shaped
/// steady-state window costs O(k log n + changed-tenant re-keys) instead
/// of the O(n log n) rebuild.  Both dispatch paths key with
/// `shape_folded` when a shaper folds, so the incremental index and the
/// rebuild reference compare the exact same f64s.
///
/// `shape_folded` takes `&self` (it is called concurrently from dispatch
/// shards); every mutation belongs in `begin_round`.
pub trait FoldedShaper: Send + Sync {
    /// Shaped time-invariant key for `job` given its folded base priority.
    fn shape_folded(&self, job: &Job, base_folded: f64) -> f64;

    /// Monotone per-tenant change counter: bumped (during `begin_round`)
    /// whenever the tenant's shaping term changed since the last round.
    /// `None` is the untagged-tenant lane.
    fn tenant_epoch(&self, tenant: Option<&str>) -> u64;
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Fcfs,
    Sjf,
    Isrtf,
    Srpt,
    Mlfq,
}

impl Policy {
    /// Parse a policy name; the error lists the valid names.
    pub fn parse(s: &str) -> Result<Policy, String> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fcfs" => Policy::Fcfs,
            "sjf" => Policy::Sjf,
            "isrtf" => Policy::Isrtf,
            "srpt" => Policy::Srpt,
            "mlfq" => Policy::Mlfq,
            _ => {
                return Err(format!(
                    "unknown scheduler policy '{s}' \
                     (valid: fcfs, sjf, isrtf, srpt, mlfq)"
                ))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Fcfs => "FCFS",
            Policy::Sjf => "SJF",
            Policy::Isrtf => "ISRTF",
            Policy::Srpt => "SRPT",
            Policy::Mlfq => "MLFQ",
        }
    }

    /// Does this policy re-assign priorities at every iteration?
    pub fn iterative(&self) -> bool {
        matches!(self, Policy::Isrtf | Policy::Srpt | Policy::Mlfq)
    }

    /// Does this policy consult the length predictor?
    pub fn uses_predictor(&self) -> bool {
        matches!(self, Policy::Sjf | Policy::Isrtf | Policy::Srpt)
    }
}

pub struct Scheduler {
    pub policy: Policy,
    predictor: Box<dyn LengthPredictor>,
    /// priority bonus per second of waiting (0 disables aging)
    pub aging_per_s: f64,
    /// MLFQ quantum thresholds (windows executed -> level)
    mlfq_levels: usize,
    /// prediction cache, dense over [`JobId::index`]: (generated count at
    /// prediction, base priority).  The predictor is deterministic in
    /// (prompt, generated), so a job that has not produced tokens since the
    /// last refresh keeps its base priority — this is what keeps the
    /// per-iteration scheduling overhead at the paper's ~11 ms instead of
    /// re-running the encoder for the whole queue every window.  Job ids
    /// are slab indices, so a flat Vec replaces the former
    /// `BTreeMap<JobId, _>` walks (one pointer-chasing lookup per queued
    /// job per window).
    cache: Vec<Option<(usize, f64)>>,
    /// scratch (reused across refreshes): positions in the refresh slice
    /// that need a predictor call this iteration
    needs: Vec<usize>,
    /// scratch (reused across refreshes): the batched predictor queries.
    /// Stored with an erased lifetime; it is only ever non-empty inside
    /// one `refresh_impl` call.
    queries_buf: Vec<PredictQuery<'static>>,
    /// predictor invocations actually made (profiling)
    pub predictor_queries: u64,
}

impl Scheduler {
    pub fn new(policy: Policy, predictor: Box<dyn LengthPredictor>) -> Scheduler {
        Scheduler {
            policy,
            predictor,
            aging_per_s: 0.0,
            mlfq_levels: 4,
            cache: Vec::new(),
            needs: Vec::new(),
            queries_buf: Vec::new(),
            predictor_queries: 0,
        }
    }

    pub fn with_aging(mut self, aging_per_s: f64) -> Scheduler {
        self.aging_per_s = aging_per_s;
        self
    }

    pub fn predictor_name(&self) -> &'static str {
        self.predictor.name()
    }

    fn cache_get(&self, id: JobId) -> Option<(usize, f64)> {
        self.cache.get(id.index()).copied().flatten()
    }

    fn cache_set(&mut self, id: JobId, entry: (usize, f64)) {
        let i = id.index();
        if self.cache.len() <= i {
            self.cache.resize(i + 1, None);
        }
        self.cache[i] = Some(entry);
    }

    /// Algorithm 1 lines 10–18: assign/refresh the priority of every job.
    /// `now_ms` is the current (virtual or wall) time for aging.  This is
    /// the shaper path's key: the *aged* priority, which drifts with
    /// `now_ms` and therefore must be recomputed each window (shapers want
    /// a now-relative base).  Shaper-less dispatch — incremental *and*
    /// forced-rebuild — keys with [`refresh_folded`](Self::refresh_folded)
    /// instead, so the two shaper-less paths compare the exact same f64s.
    pub fn refresh(&mut self, jobs: &mut [&mut Job], now_ms: f64) {
        self.refresh_impl(jobs, now_ms, false);
    }

    /// Like [`refresh`](Self::refresh), but writes the **time-invariant
    /// folded key** `base + aging_per_s·arrival/1000` instead of the aged
    /// priority.  The aged priority is this key minus the uniform shift
    /// `aging_per_s·now/1000`, so comparing folded keys compares aged
    /// priorities — which is what lets the coordinator's persistent index
    /// keep untouched entries across windows without re-keying them.
    /// (The aged form's `max(0)` wait clamp never fires in either path:
    /// a job is only refreshed after its arrival time has passed.)
    pub fn refresh_folded(&mut self, jobs: &mut [&mut Job]) {
        self.refresh_impl(jobs, 0.0, true);
    }

    fn refresh_impl(&mut self, jobs: &mut [&mut Job], now_ms: f64,
                    folded: bool) {
        // which jobs need a predictor call this iteration?  A cached base
        // priority is reused unless the job produced tokens since the last
        // prediction (ISRTF re-predicts per *iteration of the job*, and a
        // job's input to the predictor only changes when it runs).
        let mut needs = std::mem::take(&mut self.needs);
        needs.clear();
        if self.policy.uses_predictor() {
            for (i, j) in jobs.iter().enumerate() {
                let need = match self.cache_get(j.id) {
                    None => true,
                    Some((gen, _)) => {
                        self.policy.iterative() && gen != j.generated
                    }
                };
                if need {
                    needs.push(i);
                }
            }
        }

        if !needs.is_empty() {
            // recycle the query buffer's allocation (covariance shortens
            // the stored 'static lifetime to this call's borrow)
            let mut queries: Vec<PredictQuery<'_>> =
                std::mem::take(&mut self.queries_buf);
            queries.extend(needs.iter().map(|&i| {
                let j = &jobs[i];
                PredictQuery {
                    job_id: j.id.raw(),
                    prompt: &j.prompt,
                    // paper §3.3: partial output feeds back each iteration
                    gen_suffix: &j.response,
                    generated: if self.policy == Policy::Sjf {
                        0
                    } else {
                        j.generated
                    },
                    true_total: j.total_len,
                }
            }));
            self.predictor_queries += queries.len() as u64;
            let preds = self.predictor.predict(&queries);
            for (&i, p) in needs.iter().zip(preds) {
                self.cache_set(jobs[i].id, (jobs[i].generated, p));
            }
            queries.clear();
            // SAFETY: `queries` is empty, so no data with the shorter
            // borrow survives; the two Vec types differ only in a lifetime
            // parameter, which has no runtime representation.  This hands
            // the allocation back to the scratch field for the next call.
            // (clippy calls a lifetime-only transmute "useless"; it is the
            // point here — there is no safe way to widen the lifetime.)
            #[allow(clippy::useless_transmute)]
            {
                self.queries_buf = unsafe {
                    std::mem::transmute::<Vec<PredictQuery<'_>>,
                                          Vec<PredictQuery<'static>>>(queries)
                };
            }
        }
        self.needs = needs;

        let aging = if self.policy != Policy::Fcfs {
            self.aging_per_s.max(0.0)
        } else {
            0.0
        };
        for j in jobs.iter_mut() {
            let base = match self.policy {
                Policy::Fcfs => j.arrival_ms,
                Policy::Mlfq => {
                    // level-major ordering; FCFS within a level
                    let level = j.windows.min(self.mlfq_levels - 1) as f64;
                    level * 1e9 + j.arrival_ms
                }
                _ => self.cache_get(j.id).map(|(_, p)| p).unwrap_or(f64::MAX),
            };
            let keyed = if aging > 0.0 {
                if folded {
                    base + aging * (j.arrival_ms / 1000.0)
                } else {
                    base - aging * ((now_ms - j.arrival_ms) / 1000.0).max(0.0)
                }
            } else {
                base
            };
            j.priority = Some(keyed);
        }
    }

    /// The scheduler's current predicted **total** length for a job, in
    /// tokens, from the prediction cache — the number the accuracy
    /// telemetry compares against the realized total at finish.  `None`
    /// when the policy never consults the predictor (FCFS / MLFQ) or the
    /// job was never refreshed.  Must be read *before* [`forget`]
    /// (Self::forget) drops the entry.
    ///
    /// SJF queries with `generated: 0`, so its cached value already *is*
    /// the predicted total; the remaining-token policies cache predicted
    /// remaining, so total = generated-at-prediction + remaining.
    pub fn predicted_total(&self, id: JobId) -> Option<f64> {
        if !self.policy.uses_predictor() {
            return None;
        }
        self.cache_get(id).map(|(gen, p)| match self.policy {
            Policy::Sjf => p,
            _ => gen as f64 + p.max(0.0),
        })
    }

    /// Drop a finished job's cache entry.
    pub fn forget(&mut self, job_id: JobId) {
        if let Some(slot) = self.cache.get_mut(job_id.index()) {
            *slot = None;
        }
    }

    /// Completion feedback for online predictors.  Carries the full token
    /// streams so content-reading learners (e.g. the rank predictor) can
    /// train; scalar learners fall through to `observe` via the trait's
    /// default `observe_rich`.
    pub fn observe_completion(&mut self, c: &ObservedCompletion<'_>) {
        self.predictor.observe_rich(c);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::oracle::{FrozenOracle, OraclePredictor};

    fn job(id: u64, arrival: f64, total: usize, generated: usize) -> Job {
        let mut j = Job::new(JobId::from_raw(id), vec![5; 10], total, 0,
                             arrival);
        j.generated = generated;
        j
    }

    fn refresh(s: &mut Scheduler, jobs: &mut [Job], now: f64) {
        let mut refs: Vec<&mut Job> = jobs.iter_mut().collect();
        s.refresh(&mut refs, now);
    }

    #[test]
    fn fcfs_orders_by_arrival() {
        let mut s = Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
        let mut jobs = vec![job(1, 200.0, 10, 0), job(2, 100.0, 500, 0)];
        refresh(&mut s, &mut jobs, 300.0);
        assert!(jobs[1].priority.unwrap() < jobs[0].priority.unwrap());
    }

    #[test]
    fn srpt_orders_by_remaining() {
        let mut s = Scheduler::new(Policy::Srpt, Box::new(OraclePredictor));
        let mut jobs = vec![job(1, 0.0, 400, 350), job(2, 0.0, 100, 0)];
        refresh(&mut s, &mut jobs, 0.0);
        // job 1 has 50 remaining < job 2's 100
        assert!(jobs[0].priority.unwrap() < jobs[1].priority.unwrap());
    }

    #[test]
    fn sjf_freezes_initial_estimate() {
        let mut s = Scheduler::new(Policy::Sjf, Box::new(FrozenOracle));
        let mut jobs = vec![job(1, 0.0, 200, 0)];
        refresh(&mut s, &mut jobs, 0.0);
        let p0 = jobs[0].priority.unwrap();
        jobs[0].generated = 150;
        refresh(&mut s, &mut jobs, 0.0);
        assert_eq!(jobs[0].priority.unwrap(), p0, "SJF never re-predicts");
    }

    #[test]
    fn isrtf_repredicts_each_iteration() {
        let mut s = Scheduler::new(Policy::Isrtf, Box::new(OraclePredictor));
        let mut jobs = vec![job(1, 0.0, 200, 0)];
        refresh(&mut s, &mut jobs, 0.0);
        let p0 = jobs[0].priority.unwrap();
        jobs[0].generated = 150;
        refresh(&mut s, &mut jobs, 0.0);
        assert!(jobs[0].priority.unwrap() < p0, "remaining must shrink");
    }

    #[test]
    fn mlfq_demotes_by_windows() {
        let mut s = Scheduler::new(Policy::Mlfq, Box::new(OraclePredictor));
        let mut jobs = vec![job(1, 50.0, 500, 0), job(2, 500.0, 500, 0)];
        jobs[0].windows = 2; // demoted twice
        refresh(&mut s, &mut jobs, 600.0);
        assert!(jobs[1].priority.unwrap() < jobs[0].priority.unwrap(),
                "fresh job outranks demoted job despite later arrival");
    }

    #[test]
    fn aging_eventually_promotes_long_waiters() {
        let mut s = Scheduler::new(Policy::Srpt, Box::new(OraclePredictor))
            .with_aging(10.0);
        // long job waiting an hour vs short job arriving now
        let mut jobs = vec![job(1, 0.0, 400, 0), job(2, 3_600_000.0, 10, 0)];
        refresh(&mut s, &mut jobs, 3_600_000.0);
        assert!(jobs[0].priority.unwrap() < jobs[1].priority.unwrap(),
                "hour-old 400-token job must outrank fresh 10-token job");
    }

    #[test]
    fn fcfs_ignores_aging() {
        let mut s = Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor))
            .with_aging(10.0);
        let mut jobs = vec![job(1, 100.0, 10, 0)];
        refresh(&mut s, &mut jobs, 50_000.0);
        assert_eq!(jobs[0].priority.unwrap(), 100.0);
    }

    #[test]
    fn folded_equals_aged_when_aging_disabled() {
        // without aging the folded key IS the base priority, bit for bit
        for policy in [Policy::Fcfs, Policy::Sjf, Policy::Isrtf, Policy::Srpt,
                       Policy::Mlfq] {
            let mk = || match policy {
                Policy::Sjf => Scheduler::new(policy, Box::new(FrozenOracle)),
                _ => Scheduler::new(policy, Box::new(OraclePredictor)),
            };
            let mut jobs = vec![job(1, 120.0, 300, 40), job(2, 40.0, 90, 0)];
            let mut aged = mk();
            refresh(&mut aged, &mut jobs, 5_000.0);
            let a: Vec<f64> = jobs.iter().map(|j| j.priority.unwrap()).collect();
            let mut folded = mk();
            let mut refs: Vec<&mut Job> = jobs.iter_mut().collect();
            folded.refresh_folded(&mut refs);
            let f: Vec<f64> = jobs.iter().map(|j| j.priority.unwrap()).collect();
            assert_eq!(a, f, "{policy:?}");
        }
    }

    #[test]
    fn folded_keys_order_like_aged_priorities() {
        // the tentpole's aging algebra: aged = folded − a·now/1000, a
        // uniform shift, so sorting (key, arrival, id) must agree at any
        // refresh instant
        use crate::testing::prop;
        prop::check("aging-fold-order", 60, |g| {
            let aging = g.f64_in(0.5, 25.0);
            let n = g.usize_in(2, 30);
            let mut jobs: Vec<Job> = (0..n as u64)
                .map(|i| {
                    let arrival = g.f64_in(0.0, 50_000.0);
                    let total = g.usize_in(2, 2_000);
                    let mut j = job(i, arrival, total, 0);
                    j.generated = g.usize_in(0, total - 1);
                    j
                })
                .collect();
            let now = 50_000.0 + g.f64_in(0.0, 100_000.0);
            let order = |prios: &[f64], jobs: &[Job]| -> Vec<u64> {
                let mut idx: Vec<usize> = (0..jobs.len()).collect();
                idx.sort_by(|&a, &b| {
                    prios[a].total_cmp(&prios[b])
                        .then(jobs[a].arrival_ms.total_cmp(&jobs[b].arrival_ms))
                        .then(jobs[a].id.cmp(&jobs[b].id))
                });
                idx.iter().map(|&i| jobs[i].id.raw()).collect()
            };
            let mut aged_s = Scheduler::new(Policy::Srpt,
                                            Box::new(OraclePredictor))
                .with_aging(aging);
            refresh(&mut aged_s, &mut jobs, now);
            let aged: Vec<f64> =
                jobs.iter().map(|j| j.priority.unwrap()).collect();
            let mut folded_s = Scheduler::new(Policy::Srpt,
                                              Box::new(OraclePredictor))
                .with_aging(aging);
            let mut refs: Vec<&mut Job> = jobs.iter_mut().collect();
            folded_s.refresh_folded(&mut refs);
            let folded: Vec<f64> =
                jobs.iter().map(|j| j.priority.unwrap()).collect();
            assert_eq!(order(&aged, &jobs), order(&folded, &jobs),
                       "aged {aged:?} vs folded {folded:?}");
        });
    }

    #[test]
    fn dense_cache_forget_is_safe_out_of_range() {
        let mut s = Scheduler::new(Policy::Srpt, Box::new(OraclePredictor));
        s.forget(JobId::from_raw(999)); // never predicted: no-op, no panic
        let mut jobs = vec![job(3, 0.0, 100, 0)];
        refresh(&mut s, &mut jobs, 0.0);
        assert_eq!(s.predictor_queries, 1);
        refresh(&mut s, &mut jobs, 0.0);
        assert_eq!(s.predictor_queries, 1, "cache hit, no re-query");
        s.forget(JobId::from_raw(3));
        refresh(&mut s, &mut jobs, 0.0);
        assert_eq!(s.predictor_queries, 2, "forgotten entry re-queries");
    }

    #[test]
    fn predicted_total_reconstructs_total_from_cache() {
        // SRPT caches remaining at prediction time; total folds the
        // generated count back in
        let mut s = Scheduler::new(Policy::Srpt, Box::new(OraclePredictor));
        let mut jobs = vec![job(1, 0.0, 400, 350)];
        refresh(&mut s, &mut jobs, 0.0);
        assert_eq!(s.predicted_total(JobId::from_raw(1)), Some(400.0));
        // SJF queries with generated: 0, so the cache already holds totals
        let mut s = Scheduler::new(Policy::Sjf, Box::new(OraclePredictor));
        let mut jobs = vec![job(2, 0.0, 200, 50)];
        refresh(&mut s, &mut jobs, 0.0);
        assert_eq!(s.predicted_total(JobId::from_raw(2)), Some(200.0));
        // FCFS never consults the predictor
        let mut s = Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
        let mut jobs = vec![job(3, 0.0, 100, 0)];
        refresh(&mut s, &mut jobs, 0.0);
        assert_eq!(s.predicted_total(JobId::from_raw(3)), None);
        // never-refreshed id: no cache entry
        let s = Scheduler::new(Policy::Srpt, Box::new(OraclePredictor));
        assert_eq!(s.predicted_total(JobId::from_raw(9)), None);
    }

    #[test]
    fn policy_parse() {
        assert_eq!(Policy::parse("ISRTF"), Ok(Policy::Isrtf));
        assert_eq!(Policy::parse("fcfs"), Ok(Policy::Fcfs));
        let err = Policy::parse("nope").unwrap_err();
        assert!(err.contains("nope") && err.contains("isrtf"),
                "error must name the input and the valid policies: {err}");
    }
}
