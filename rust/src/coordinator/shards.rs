//! DispatchShards: a small persistent thread pool the coordinator uses to
//! plan per-node scheduling work (index maintenance, top-k pops, victim
//! ranking) in parallel — per-node shards over `std::sync::mpsc` channels,
//! mirroring the `WorkerPool` idiom from `cluster/pool.rs`.
//!
//! Determinism: the pool only ever runs *per-node* planning closures whose
//! inputs are that node's own state plus read-only snapshots (job table,
//! folded shaper memo), and whose outputs land in that node's own plan
//! slot.  The coordinator then applies plans serially in ascending node
//! order, so reports are bit-identical regardless of shard count (asserted
//! by the `--dispatch-shards 1|2|8` sweep in the integration suites).
//!
//! Threads are spawned once at coordinator build time and live for the
//! coordinator's lifetime — per-window cost is one channel send/recv pair
//! per shard, not a thread spawn.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

/// A unit of planning work; always consumed before [`DispatchShards::run`]
/// returns (see the safety argument there).
type Task = Box<dyn FnOnce() + Send + 'static>;

pub struct DispatchShards {
    /// one command channel per shard thread
    senders: Vec<Sender<Task>>,
    /// completion barrier: every finished task reports here, carrying its
    /// panic payload if it unwound
    done_rx: Receiver<std::thread::Result<()>>,
    threads: Vec<JoinHandle<()>>,
}

impl DispatchShards {
    /// Spawn `shards` planner threads (callers pass ≥ 2; a single shard is
    /// run inline by the coordinator without a pool).
    pub fn new(shards: usize) -> DispatchShards {
        assert!(shards >= 1, "a dispatch shard pool needs at least 1 shard");
        let (done_tx, done_rx) = channel();
        let mut senders = Vec::with_capacity(shards);
        let mut threads = Vec::with_capacity(shards);
        for i in 0..shards {
            let (tx, rx) = channel::<Task>();
            let done = done_tx.clone();
            let join = std::thread::Builder::new()
                .name(format!("elis-dispatch-shard-{i}"))
                .spawn(move || {
                    for task in rx {
                        let r = catch_unwind(AssertUnwindSafe(task));
                        if done.send(r).is_err() {
                            break; // coordinator gone: shut down
                        }
                    }
                })
                .expect("spawn dispatch shard thread");
            senders.push(tx);
            threads.push(join);
        }
        DispatchShards { senders, done_rx, threads }
    }

    pub fn shards(&self) -> usize {
        self.senders.len()
    }

    /// Run one task per shard slot (task `i` on thread `i % shards`) and
    /// block until **all** of them completed.  If any task panicked, the
    /// first payload is re-raised here — after the barrier, so no task is
    /// still running when this frame unwinds.
    ///
    /// Tasks may borrow from the caller's stack: the barrier guarantees
    /// every borrow ends before `run` returns, which is what makes the
    /// lifetime erasure below sound.
    pub fn run<'scope>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        let n = tasks.len();
        for (i, task) in tasks.into_iter().enumerate() {
            // SAFETY: `run` does not return (or unwind) until the
            // completion barrier below has observed every submitted task,
            // so the 'scope borrows inside `task` strictly outlive its
            // execution.  Box<dyn FnOnce> has the same layout for both
            // lifetimes; only the bound is erased.
            let task: Task = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce() + Send + 'scope>,
                    Box<dyn FnOnce() + Send + 'static>,
                >(task)
            };
            self.senders[i % self.senders.len()]
                .send(task)
                .expect("dispatch shard thread alive");
        }
        let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
        for _ in 0..n {
            match self.done_rx.recv().expect("dispatch shard thread alive") {
                Ok(()) => {}
                Err(payload) => {
                    panic.get_or_insert(payload);
                }
            }
        }
        if let Some(payload) = panic {
            resume_unwind(payload);
        }
    }
}

impl Drop for DispatchShards {
    fn drop(&mut self) {
        // closing the command channels ends each thread's recv loop
        self.senders.clear();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_task_and_blocks_until_done() {
        let pool = DispatchShards::new(3);
        assert_eq!(pool.shards(), 3);
        let mut out = vec![0usize; 8];
        {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
                .chunks_mut(3)
                .enumerate()
                .map(|(ci, chunk)| {
                    let f: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                        for (j, slot) in chunk.iter_mut().enumerate() {
                            *slot = ci * 10 + j;
                        }
                    });
                    f
                })
                .collect();
            pool.run(tasks);
        }
        assert_eq!(out, vec![0, 1, 2, 10, 11, 12, 20, 21]);
    }

    #[test]
    fn reusable_across_rounds() {
        let pool = DispatchShards::new(2);
        let hits = AtomicUsize::new(0);
        for _ in 0..5 {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
                .map(|_| {
                    let f: Box<dyn FnOnce() + Send + '_> = Box::new(|| {
                        hits.fetch_add(1, Ordering::SeqCst);
                    });
                    f
                })
                .collect();
            pool.run(tasks);
        }
        assert_eq!(hits.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn task_panic_resumes_on_caller_after_barrier() {
        let pool = DispatchShards::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(|| panic!("shard boom")),
                Box::new(|| {}),
            ];
            pool.run(tasks);
        }));
        assert!(r.is_err(), "panic must surface on the caller");
        // the pool survives a panicked task
        let ok = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![Box::new(|| {
            ok.fetch_add(1, Ordering::SeqCst);
        })];
        pool.run(tasks);
        assert_eq!(ok.load(Ordering::SeqCst), 1);
    }
}
