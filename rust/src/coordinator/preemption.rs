//! Preemption policy knobs (paper §3.4).
//!
//! The paper found preemption rare at production request rates but ships
//! "policies that can adjust the frequency of preemption and prevent
//! starvation".  This module is that code: a per-job preemption budget, a
//! global rate limiter, and the victim-ordering filter applied before the
//! engine receives its priority order.

use super::job::JobId;

#[derive(Debug, Clone)]
pub struct PreemptionPolicy {
    pub enabled: bool,
    /// a job preempted this many times becomes protected (starvation guard)
    pub max_preemptions_per_job: usize,
    /// at most this many preemptions per scheduling iteration (frequency
    /// control; usize::MAX = unlimited)
    pub max_per_iteration: usize,
}

impl Default for PreemptionPolicy {
    fn default() -> Self {
        PreemptionPolicy {
            enabled: true,
            max_preemptions_per_job: 3,
            max_per_iteration: usize::MAX,
        }
    }
}

impl PreemptionPolicy {
    pub fn disabled() -> Self {
        PreemptionPolicy { enabled: false, ..Default::default() }
    }

    /// Can engine-side eviction happen at all under this policy?  The
    /// per-window budget is checked by the engine *before* it consults its
    /// victim ranking, so with `max_per_iteration == 0` the ranking is
    /// dead weight — dispatch skips building it entirely.
    pub fn can_fire(&self) -> bool {
        self.max_per_iteration > 0
    }

    /// Order the engine's preemption victims: jobs are given lowest-first
    /// eviction preference, and protected jobs (over their preemption
    /// budget) are moved to the front (= evicted last).
    ///
    /// `ranked` is (job id, preemption count) in priority order, highest
    /// priority first.  Returns the order to hand the engine.
    pub fn victim_order(&self, ranked: &[(JobId, usize)]) -> Vec<JobId> {
        let mut out = Vec::with_capacity(ranked.len());
        self.victim_order_into(ranked, &mut out);
        out
    }

    /// Allocation-free variant of [`victim_order`](Self::victim_order) for
    /// the dispatch hot loop: writes engine-layer sequence ids into `out`
    /// (cleared first), reusing its capacity across windows.
    pub fn victim_order_into<T: From<JobId>>(&self,
                                             ranked: &[(JobId, usize)],
                                             out: &mut Vec<T>) {
        out.clear();
        if !self.enabled {
            // disabled: hand the ranking through unchanged (the engine
            // only reads it when memory pressure forces an eviction)
            out.extend(ranked.iter().map(|&(id, _)| id.into()));
            return;
        }
        // protected jobs (over budget) first = evicted last; two stable
        // passes replace the old pair of temporary Vecs
        out.extend(ranked.iter()
            .filter(|&&(_, c)| c >= self.max_preemptions_per_job)
            .map(|&(id, _)| id.into()));
        out.extend(ranked.iter()
            .filter(|&&(_, c)| c < self.max_preemptions_per_job)
            .map(|&(id, _)| id.into()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ranked(pairs: &[(u64, usize)]) -> Vec<(JobId, usize)> {
        pairs.iter().map(|&(id, c)| (JobId::from_raw(id), c)).collect()
    }

    fn raw(order: Vec<JobId>) -> Vec<u64> {
        order.iter().map(|id| id.raw()).collect()
    }

    #[test]
    fn protected_jobs_move_to_front() {
        let p = PreemptionPolicy {
            enabled: true,
            max_preemptions_per_job: 2,
            max_per_iteration: usize::MAX,
        };
        // (id, preemptions), priority order 1 > 2 > 3
        let order = p.victim_order(&ranked(&[(1, 0), (2, 2), (3, 0)]));
        // job 2 hit its budget: protected, so listed first (evicted last)
        assert_eq!(raw(order), vec![2, 1, 3]);
    }

    #[test]
    fn default_budget() {
        let p = PreemptionPolicy::default();
        assert!(p.enabled);
        assert_eq!(p.max_preemptions_per_job, 3);
    }

    #[test]
    fn no_protection_under_budget() {
        let p = PreemptionPolicy::default();
        let order = p.victim_order(&ranked(&[(5, 1), (6, 0)]));
        assert_eq!(raw(order), vec![5, 6]);
    }

    #[test]
    fn victim_order_into_matches_victim_order() {
        for policy in [
            PreemptionPolicy {
                enabled: true,
                max_preemptions_per_job: 1,
                max_per_iteration: usize::MAX,
            },
            PreemptionPolicy::disabled(),
        ] {
            let r = ranked(&[(1, 0), (2, 3), (3, 1), (4, 0)]);
            let mut scratch: Vec<u64> = vec![99; 8]; // stale contents
            policy.victim_order_into(&r, &mut scratch);
            assert_eq!(scratch, raw(policy.victim_order(&r)));
        }
    }

    #[test]
    fn can_fire_tracks_per_iteration_budget() {
        assert!(PreemptionPolicy::default().can_fire());
        let frozen = PreemptionPolicy {
            max_per_iteration: 0,
            ..Default::default()
        };
        assert!(!frozen.can_fire());
    }
}
