//! Preemption policy knobs (paper §3.4).
//!
//! The paper found preemption rare at production request rates but ships
//! "policies that can adjust the frequency of preemption and prevent
//! starvation".  This module is that code: a per-job preemption budget, a
//! global rate limiter, and the victim-ordering filter applied before the
//! engine receives its priority order.

use super::job::JobId;

#[derive(Debug, Clone)]
pub struct PreemptionPolicy {
    pub enabled: bool,
    /// a job preempted this many times becomes protected (starvation guard)
    pub max_preemptions_per_job: usize,
    /// at most this many preemptions per scheduling iteration (frequency
    /// control; usize::MAX = unlimited)
    pub max_per_iteration: usize,
}

impl Default for PreemptionPolicy {
    fn default() -> Self {
        PreemptionPolicy {
            enabled: true,
            max_preemptions_per_job: 3,
            max_per_iteration: usize::MAX,
        }
    }
}

impl PreemptionPolicy {
    pub fn disabled() -> Self {
        PreemptionPolicy { enabled: false, ..Default::default() }
    }

    /// Order the engine's preemption victims: jobs are given lowest-first
    /// eviction preference, and protected jobs (over their preemption
    /// budget) are moved to the front (= evicted last).
    ///
    /// `ranked` is (job id, preemption count) in priority order, highest
    /// priority first.  Returns the order to hand the engine.
    pub fn victim_order(&self, ranked: &[(JobId, usize)]) -> Vec<JobId> {
        if !self.enabled {
            // engine treats an empty order as "no preemption candidates";
            // protect everything by listing all as highest priority
            return ranked.iter().map(|(id, _)| *id).collect();
        }
        let mut protected: Vec<JobId> = Vec::new();
        let mut normal: Vec<JobId> = Vec::new();
        for &(id, count) in ranked {
            if count >= self.max_preemptions_per_job {
                protected.push(id);
            } else {
                normal.push(id);
            }
        }
        protected.extend(normal);
        protected
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ranked(pairs: &[(u64, usize)]) -> Vec<(JobId, usize)> {
        pairs.iter().map(|&(id, c)| (JobId::from_raw(id), c)).collect()
    }

    fn raw(order: Vec<JobId>) -> Vec<u64> {
        order.iter().map(|id| id.raw()).collect()
    }

    #[test]
    fn protected_jobs_move_to_front() {
        let p = PreemptionPolicy {
            enabled: true,
            max_preemptions_per_job: 2,
            max_per_iteration: usize::MAX,
        };
        // (id, preemptions), priority order 1 > 2 > 3
        let order = p.victim_order(&ranked(&[(1, 0), (2, 2), (3, 0)]));
        // job 2 hit its budget: protected, so listed first (evicted last)
        assert_eq!(raw(order), vec![2, 1, 3]);
    }

    #[test]
    fn default_budget() {
        let p = PreemptionPolicy::default();
        assert!(p.enabled);
        assert_eq!(p.max_preemptions_per_job, 3);
    }

    #[test]
    fn no_protection_under_budget() {
        let p = PreemptionPolicy::default();
        let order = p.victim_order(&ranked(&[(5, 1), (6, 0)]));
        assert_eq!(raw(order), vec![5, 6]);
    }
}
