//! Load balancer + global state (paper Algorithm 1, line 3:
//! `job.node <- LoadBalancer.get_min_load(G)`).
//!
//! The paper's LB greedily picks the worker executing the fewest jobs,
//! consulting the frontend's global state `G`.  Round-robin and random are
//! provided as ablation baselines (the scalability result of Fig 7 depends
//! on min-load doing better than naive placement under bursty arrivals).

use crate::stats::rng::Pcg64;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LbStrategy {
    /// paper default: fewest active jobs
    MinLoad,
    RoundRobin,
    Random,
}

impl LbStrategy {
    /// Parse a strategy name; the error lists every accepted spelling.
    pub fn parse(s: &str) -> Result<LbStrategy, String> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "minload" | "min-load" => LbStrategy::MinLoad,
            "rr" | "roundrobin" | "round-robin" => LbStrategy::RoundRobin,
            "random" => LbStrategy::Random,
            _ => {
                return Err(format!(
                    "unknown load-balancer strategy '{s}' \
                     (valid: minload, rr, random)"
                ))
            }
        })
    }
}

/// Global state `G`: per-worker active job counts maintained by the
/// frontend as jobs are assigned and finish.
#[derive(Debug, Clone)]
pub struct GlobalState {
    pub active_jobs: Vec<usize>,
    /// lifetime assignment counter (stats)
    pub total_assigned: Vec<u64>,
}

impl GlobalState {
    pub fn new(nodes: usize) -> GlobalState {
        GlobalState {
            active_jobs: vec![0; nodes],
            total_assigned: vec![0; nodes],
        }
    }

    pub fn nodes(&self) -> usize {
        self.active_jobs.len()
    }

    pub fn on_assign(&mut self, node: usize) {
        self.active_jobs[node] += 1;
        self.total_assigned[node] += 1;
    }

    pub fn on_finish(&mut self, node: usize) {
        debug_assert!(self.active_jobs[node] > 0, "finish without assign");
        self.active_jobs[node] = self.active_jobs[node].saturating_sub(1);
    }

    /// Max/min active-job imbalance (Fig 7 diagnostics).
    pub fn imbalance(&self) -> usize {
        let max = self.active_jobs.iter().copied().max().unwrap_or(0);
        let min = self.active_jobs.iter().copied().min().unwrap_or(0);
        max - min
    }
}

pub struct LoadBalancer {
    pub strategy: LbStrategy,
    rr_next: usize,
    rng: Pcg64,
}

impl LoadBalancer {
    pub fn new(strategy: LbStrategy, seed: u64) -> LoadBalancer {
        LoadBalancer { strategy, rr_next: 0, rng: Pcg64::new(seed) }
    }

    /// Pick a node for a new job (Algorithm 1 `get_min_load`).
    pub fn assign(&mut self, state: &mut GlobalState) -> usize {
        self.assign_excluding(state, &[])
    }

    /// Like [`assign`](Self::assign), but never picks a node marked
    /// `true` in `dead` (missing entries count as alive) — the cluster
    /// runtime's worker-loss failover re-homes jobs through this so a
    /// lost pod stops receiving work.  With no dead nodes the decision —
    /// including RNG consumption and round-robin state — is exactly
    /// [`assign`](Self::assign)'s, so single-pool schedules are
    /// unchanged.  Panics if every node is dead (callers bail before
    /// that).
    pub fn assign_excluding(&mut self, state: &mut GlobalState,
                            dead: &[bool]) -> usize {
        let n = state.nodes();
        assert!(n > 0);
        let alive = |i: usize| !dead.get(i).copied().unwrap_or(false);
        assert!((0..n).any(alive), "no surviving node to assign to");
        let node = match self.strategy {
            LbStrategy::MinLoad => state
                .active_jobs
                .iter()
                .enumerate()
                .filter(|&(i, _)| alive(i))
                .min_by_key(|(_, &c)| c)
                .map(|(i, _)| i)
                .unwrap(),
            LbStrategy::RoundRobin => {
                let mut i = self.rr_next % n;
                while !alive(i) {
                    i = (i + 1) % n;
                }
                self.rr_next = (i + 1) % n;
                i
            }
            LbStrategy::Random => {
                let alive_nodes: Vec<usize> =
                    (0..n).filter(|&i| alive(i)).collect();
                alive_nodes
                    [self.rng.below(alive_nodes.len() as u64) as usize]
            }
        };
        state.on_assign(node);
        node
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    #[test]
    fn min_load_picks_least_loaded() {
        let mut st = GlobalState::new(3);
        st.active_jobs = vec![4, 1, 2];
        let mut lb = LoadBalancer::new(LbStrategy::MinLoad, 1);
        assert_eq!(lb.assign(&mut st), 1);
        assert_eq!(st.active_jobs, vec![4, 2, 2]);
    }

    #[test]
    fn round_robin_cycles() {
        let mut st = GlobalState::new(3);
        let mut lb = LoadBalancer::new(LbStrategy::RoundRobin, 1);
        let picks: Vec<usize> = (0..6).map(|_| lb.assign(&mut st)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn finish_decrements() {
        let mut st = GlobalState::new(2);
        st.on_assign(0);
        st.on_assign(0);
        st.on_finish(0);
        assert_eq!(st.active_jobs[0], 1);
    }

    #[test]
    fn prop_min_load_keeps_balance_tight() {
        // with equal service, min-load never lets imbalance exceed 1
        prop::check("minload-balance", 50, |g| {
            let nodes = g.usize_in(2, 8);
            let mut st = GlobalState::new(nodes);
            let mut lb = LoadBalancer::new(LbStrategy::MinLoad, 1);
            for _ in 0..g.usize_in(1, 200) {
                lb.assign(&mut st);
                assert!(st.imbalance() <= 1, "imbalance {}", st.imbalance());
            }
        });
    }

    #[test]
    fn prop_random_in_range() {
        prop::check("random-lb-range", 20, |g| {
            let nodes = g.usize_in(1, 5);
            let mut st = GlobalState::new(nodes);
            let mut lb = LoadBalancer::new(LbStrategy::Random, g.rng.next_u64());
            for _ in 0..50 {
                let n = lb.assign(&mut st);
                assert!(n < nodes);
            }
        });
    }

    #[test]
    fn excluding_skips_dead_nodes_for_every_strategy() {
        // min-load: node 1 is the least loaded but dead -> next-least wins
        let mut st = GlobalState::new(3);
        st.active_jobs = vec![4, 1, 2];
        let mut lb = LoadBalancer::new(LbStrategy::MinLoad, 1);
        assert_eq!(lb.assign_excluding(&mut st, &[false, true, false]), 2);

        // round-robin: dead nodes are stepped over, cycle continues after
        let mut st = GlobalState::new(3);
        let mut lb = LoadBalancer::new(LbStrategy::RoundRobin, 1);
        let dead = [false, true, false];
        let picks: Vec<usize> =
            (0..4).map(|_| lb.assign_excluding(&mut st, &dead)).collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);

        // random: never lands on a dead node
        let mut st = GlobalState::new(4);
        let mut lb = LoadBalancer::new(LbStrategy::Random, 9);
        for _ in 0..100 {
            let n = lb.assign_excluding(&mut st, &[true, false, true, false]);
            assert!(n == 1 || n == 3, "picked dead node {n}");
        }
    }

    #[test]
    fn excluding_nothing_matches_assign_exactly() {
        // the failover path must not perturb single-pool schedules: with
        // no dead nodes the two entry points make identical decisions
        // (including RNG draws and round-robin state)
        for strategy in [LbStrategy::MinLoad, LbStrategy::RoundRobin,
                         LbStrategy::Random] {
            let mut st_a = GlobalState::new(5);
            let mut st_b = GlobalState::new(5);
            let mut lb_a = LoadBalancer::new(strategy, 33);
            let mut lb_b = LoadBalancer::new(strategy, 33);
            for _ in 0..50 {
                assert_eq!(lb_a.assign(&mut st_a),
                           lb_b.assign_excluding(&mut st_b, &[]));
            }
        }
    }

    #[test]
    fn strategy_parse() {
        assert_eq!(LbStrategy::parse("minload"), Ok(LbStrategy::MinLoad));
        assert_eq!(LbStrategy::parse("rr"), Ok(LbStrategy::RoundRobin));
        let err = LbStrategy::parse("bogus").unwrap_err();
        assert!(err.contains("bogus") && err.contains("minload"),
                "error must name the input and the valid strategies: {err}");
    }
}
