//! The frontend serving loop — paper §4.1 Algorithm 1, end to end.
//!
//! One function, [`run_serving`], drives both evaluation modes:
//! * **Virtual clock** — discrete-event: engine `service_ms` advances a
//!   simulated timeline.  Used with [`SimEngine`](crate::engine::sim_engine)
//!   for the A100-scale experiments (Fig 5/6/7, Table 5/6).
//! * **Wall clock** — real time: arrivals are waited for, windows block on
//!   PJRT execution.  Used with [`PjrtEngine`](crate::engine::pjrt_engine)
//!   for the end-to-end examples.
//!
//! The scheduling-iteration structure is identical in both modes: ingest
//! arrivals → refresh priorities (predictor init/iter) → form per-node
//! batches from the PriorityBuffer → execute one 50-token window → return
//! unfinished jobs to the pool.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::engine::Engine;
use crate::metrics::{JobRecord, ServeReport};
use crate::workload::TraceRequest;

use super::batcher::Batcher;
use super::job::{Job, JobState};
use super::load_balancer::{GlobalState, LbStrategy, LoadBalancer};
use super::preemption::PreemptionPolicy;
use super::priority_buffer::{Entry, PriorityBuffer};
use super::scheduler::Scheduler;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClockMode {
    /// discrete-event simulation (engine service_ms drives time)
    Virtual,
    /// real time (arrivals waited for, windows block)
    Wall,
}

#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub workers: usize,
    pub max_batch: usize,
    pub lb: LbStrategy,
    pub preemption: PreemptionPolicy,
    /// fixed extra scheduling cost added to the virtual timeline per
    /// iteration (models the paper's measured ~11 ms overhead; 0 = off)
    pub overhead_ms_per_iter: f64,
    pub clock: ClockMode,
    pub seed: u64,
    /// hard safety cap on scheduling iterations (0 = none)
    pub max_iterations: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 1,
            max_batch: 4,
            lb: LbStrategy::MinLoad,
            preemption: PreemptionPolicy::default(),
            overhead_ms_per_iter: 0.0,
            clock: ClockMode::Virtual,
            seed: 1,
            max_iterations: 0,
        }
    }
}

struct WorkerSlot {
    /// virtual completion time + the outcome to apply, if busy
    pending: Option<(f64, crate::engine::WindowOutcome, Vec<u64>)>,
}

/// Serve a trace through the full coordinator stack.
///
/// `engines[i]` is worker i's backend; `scheduler` owns the policy and the
/// length predictor.
pub fn run_serving(
    cfg: &ServeConfig,
    trace: &[TraceRequest],
    engines: &mut [Box<dyn Engine>],
    scheduler: &mut Scheduler,
) -> Result<ServeReport> {
    if engines.len() != cfg.workers {
        bail!("expected {} engines, got {}", cfg.workers, engines.len());
    }
    if trace.is_empty() {
        bail!("empty trace");
    }

    // ---- state ----
    let mut jobs: BTreeMap<u64, Job> = BTreeMap::new();
    let mut arrivals: Vec<(f64, u64)> = Vec::with_capacity(trace.len());
    for (i, r) in trace.iter().enumerate() {
        let id = i as u64;
        jobs.insert(id, Job::new(id, r.prompt.clone(), r.total_len, r.topic,
                                 r.arrival_ms));
        arrivals.push((r.arrival_ms, id));
    }
    arrivals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut next_arrival = 0usize;

    let mut queued: Vec<Vec<u64>> = vec![Vec::new(); cfg.workers];
    let mut admitted: Vec<Vec<u64>> = vec![Vec::new(); cfg.workers];
    let mut workers: Vec<WorkerSlot> =
        (0..cfg.workers).map(|_| WorkerSlot { pending: None }).collect();

    let mut state = GlobalState::new(cfg.workers);
    let mut lb = LoadBalancer::new(cfg.lb, cfg.seed);
    let mut buffer = PriorityBuffer::new(cfg.workers);
    let mut batcher = Batcher::new(cfg.workers, cfg.max_batch);

    let mut now: f64 = 0.0;
    let wall_start = Instant::now();
    let mut finished = 0usize;
    let total_jobs = jobs.len();
    let mut total_preemptions: u64 = 0;
    let mut sched_overhead_ns: u128 = 0;
    let mut iterations: u64 = 0;

    // ---- helpers as closures are awkward with borrows; use a loop ----
    loop {
        if cfg.clock == ClockMode::Wall {
            now = wall_start.elapsed().as_secs_f64() * 1e3;
        }

        // 1. ingest arrivals (Algorithm 1 lines 1–5)
        while next_arrival < arrivals.len() && arrivals[next_arrival].0 <= now {
            let (_, id) = arrivals[next_arrival];
            next_arrival += 1;
            let node = lb.assign(&mut state);
            let job = jobs.get_mut(&id).unwrap();
            job.node = Some(node);
            queued[node].push(id);
        }

        // 2. apply completions due at `now` (virtual mode)
        for (w, slot) in workers.iter_mut().enumerate() {
            let due = matches!(&slot.pending, Some((t, _, _)) if *t <= now);
            if due {
                let (t_done, outcome, batch) = slot.pending.take().unwrap();
                apply_outcome(
                    t_done, outcome, &batch, w, &mut jobs, &mut queued,
                    engines, &mut state, scheduler, &mut batcher,
                    &mut finished, &mut total_preemptions,
                );
            }
        }

        // 3. dispatch idle workers with work (Algorithm 1 lines 6–20)
        let mut dispatched = false;
        for w in 0..cfg.workers {
            if workers[w].pending.is_some() || queued[w].is_empty() {
                continue;
            }
            iterations += 1;
            if cfg.max_iterations > 0 && iterations > cfg.max_iterations {
                bail!("iteration cap {} exceeded (livelock?)", cfg.max_iterations);
            }
            let t_sched = Instant::now();

            // refresh priorities of every queued job on this node
            let ids: Vec<u64> = queued[w].clone();
            {
                let mut refs: Vec<&mut Job> = Vec::with_capacity(ids.len());
                // split_mut dance: collect mutable refs one by one
                let mut remaining: &mut BTreeMap<u64, Job> = &mut jobs;
                // BTreeMap doesn't give disjoint &mut easily; use values_mut
                let _ = &mut remaining;
                let mut map_refs: BTreeMap<u64, &mut Job> = BTreeMap::new();
                for (k, v) in jobs.iter_mut() {
                    if ids.contains(k) {
                        map_refs.insert(*k, v);
                    }
                }
                for id in &ids {
                    if let Some(j) = map_refs.remove(id) {
                        refs.push(j);
                    }
                }
                scheduler.refresh(&mut refs, now);
            }

            // rebuild this node's priority queue
            let mut full_order: Vec<Entry> = Vec::with_capacity(ids.len());
            for id in &ids {
                let j = &jobs[id];
                buffer.push(w, Entry {
                    priority: j.priority.unwrap_or(f64::MAX),
                    arrival_ms: j.arrival_ms,
                    id: *id,
                });
            }
            let sorted = buffer.drain_sorted(w);
            full_order.extend(sorted);

            // preemption victim ordering for the engine
            let ranked: Vec<(u64, usize)> = full_order
                .iter()
                .map(|e| (e.id, jobs[&e.id].preemptions))
                .collect();
            engines[w].set_priority_order(&cfg.preemption.victim_order(&ranked));

            // form the batch
            let batch_ids: Vec<u64> = full_order
                .iter()
                .take(cfg.max_batch.min(engines[w].max_batch()))
                .map(|e| e.id)
                .collect();

            // admit + (modelled) prompt transfer
            for &id in &batch_ids {
                if !admitted[w].contains(&id) {
                    engines[w].admit(crate::engine::SeqSpec {
                        id,
                        prompt: jobs[&id].prompt.clone(),
                        target_total: jobs[&id].total_len,
                        topic: jobs[&id].topic,
                    })?;
                    admitted[w].push(id);
                }
                batcher.mark_prompt_sent(w, id, jobs[&id].prompt.len());
            }
            sched_overhead_ns += t_sched.elapsed().as_nanos();

            // execute one scheduling window
            let outcome = engines[w].run_window(&batch_ids)?;

            // pull batch jobs out of the waiting queue
            queued[w].retain(|id| !batch_ids.contains(id));
            for id in &batch_ids {
                jobs.get_mut(id).unwrap().state = JobState::Running;
            }

            match cfg.clock {
                ClockMode::Virtual => {
                    let done_at = now + outcome.service_ms + cfg.overhead_ms_per_iter;
                    workers[w].pending = Some((done_at, outcome, batch_ids));
                }
                ClockMode::Wall => {
                    let t_done = wall_start.elapsed().as_secs_f64() * 1e3;
                    apply_outcome(
                        t_done, outcome, &batch_ids, w, &mut jobs, &mut queued,
                        engines, &mut state, scheduler, &mut batcher,
                        &mut finished, &mut total_preemptions,
                    );
                }
            }
            dispatched = true;
        }

        // 4. termination / time advance
        if finished == total_jobs {
            break;
        }
        if dispatched {
            continue;
        }
        let next_completion = workers
            .iter()
            .filter_map(|s| s.pending.as_ref().map(|(t, _, _)| *t))
            .fold(f64::INFINITY, f64::min);
        let next_arrival_t = if next_arrival < arrivals.len() {
            arrivals[next_arrival].0
        } else {
            f64::INFINITY
        };
        let next_t = next_completion.min(next_arrival_t);
        match cfg.clock {
            ClockMode::Virtual => {
                if !next_t.is_finite() {
                    bail!("deadlock: no pending work but {} jobs unfinished",
                          total_jobs - finished);
                }
                now = next_t.max(now);
            }
            ClockMode::Wall => {
                if next_t.is_finite() {
                    let wait_ms = next_t - wall_start.elapsed().as_secs_f64() * 1e3;
                    if wait_ms > 0.0 {
                        std::thread::sleep(std::time::Duration::from_secs_f64(
                            wait_ms / 1e3,
                        ));
                    }
                } else {
                    bail!("deadlock: no pending work but {} jobs unfinished",
                          total_jobs - finished);
                }
            }
        }
    }

    let makespan_ms = jobs
        .values()
        .filter_map(|j| j.finish_ms)
        .fold(0.0, f64::max);
    let records: Vec<JobRecord> =
        jobs.values().filter_map(JobRecord::from_job).collect();
    Ok(ServeReport {
        scheduler: scheduler.policy.name().to_string(),
        predictor_name: scheduler.predictor_name().to_string(),
        records,
        makespan_ms,
        total_preemptions,
        sched_overhead_ms_avg: if iterations == 0 {
            0.0
        } else {
            sched_overhead_ns as f64 / iterations as f64 / 1e6
        },
        sched_iterations: iterations,
    })
}

#[allow(clippy::too_many_arguments)]
fn apply_outcome(
    t_done: f64,
    outcome: crate::engine::WindowOutcome,
    batch: &[u64],
    node: usize,
    jobs: &mut BTreeMap<u64, Job>,
    queued: &mut [Vec<u64>],
    engines: &mut [Box<dyn Engine>],
    state: &mut GlobalState,
    scheduler: &mut Scheduler,
    batcher: &mut Batcher,
    finished: &mut usize,
    total_preemptions: &mut u64,
) {
    for &pid in &outcome.preempted {
        if let Some(j) = jobs.get_mut(&pid) {
            j.preemptions += 1;
        }
        *total_preemptions += 1;
    }
    for out in &outcome.outputs {
        let j = jobs.get_mut(&out.id).unwrap();
        j.windows += 1;
        j.service_ms += outcome.service_ms;
        if !out.new_tokens.is_empty() && j.first_token_ms.is_none() {
            j.first_token_ms = Some(t_done);
        }
        j.generated += out.new_tokens.len();
        j.response.extend_from_slice(&out.new_tokens);
        if out.done {
            j.state = JobState::Finished;
            j.finish_ms = Some(t_done);
            *finished += 1;
            state.on_finish(node);
            scheduler.observe_completion(j.prompt.len(), j.total_len);
            scheduler.forget(out.id);
            batcher.forget(node, out.id);
            engines[node].remove(out.id);
        } else {
            j.state = JobState::Queued;
            queued[node].push(out.id);
        }
    }
    // batch jobs that produced no output (couldn't be staged) go back too
    for &id in batch {
        let j = jobs.get_mut(&id).unwrap();
        if j.state == JobState::Running {
            j.state = JobState::Queued;
            queued[node].push(id);
        }
    }
}

/// Binary-search the peak request rate where `delay_fn(rps)` (avg queueing
/// delay, seconds) stays within `limit_s` (Fig 7's 0.5 s criterion).
pub fn peak_rps_search<F: FnMut(f64) -> f64>(
    mut delay_fn: F, mut lo: f64, mut hi: f64, iters: usize, limit_s: f64,
) -> f64 {
    // expand hi until it violates (or give up)
    let mut expand = 0;
    while delay_fn(hi) <= limit_s && expand < 8 {
        lo = hi;
        hi *= 2.0;
        expand += 1;
    }
    for _ in 0..iters {
        let mid = 0.5 * (lo + hi);
        if delay_fn(mid) <= limit_s {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::profiles::ModelProfile;
    use crate::engine::sim_engine::SimEngine;
    use crate::predictor::oracle::OraclePredictor;
    use crate::runtime::manifest::ServedModelMeta;
    use crate::coordinator::scheduler::Policy;
    use crate::workload::corpus::Corpus;
    use crate::workload::generator::RequestGenerator;

    fn profile() -> ModelProfile {
        ModelProfile::from_meta(&ServedModelMeta {
            name: "test".into(),
            abbrev: "test".into(),
            params_b: 7.0,
            avg_latency_ms: 2000.0,
            kv_bytes_per_token: 1 << 20,
            preempt_batch: 0,
            mem_limit_frac: 0.9,
        })
    }

    fn engines(n: usize) -> Vec<Box<dyn Engine>> {
        (0..n)
            .map(|_| {
                Box::new(SimEngine::new(profile(), 50, 4, 8 << 30)) as Box<dyn Engine>
            })
            .collect()
    }

    fn run(policy: Policy, workers: usize, rps: f64, n: usize) -> ServeReport {
        let corpus = Corpus::synthetic(300, 5);
        let mut gen = RequestGenerator::fabrix(rps, 42);
        let trace = gen.trace(&corpus, n);
        let mut sched = Scheduler::new(policy, Box::new(OraclePredictor));
        let cfg = ServeConfig {
            workers,
            max_iterations: 2_000_000,
            ..Default::default()
        };
        let mut e = engines(workers);
        run_serving(&cfg, &trace, &mut e, &mut sched).unwrap()
    }

    #[test]
    fn completes_all_jobs() {
        let r = run(Policy::Fcfs, 1, 1.0, 40);
        assert_eq!(r.n(), 40);
        assert!(r.avg_jct_s() > 0.0);
        assert!(r.makespan_ms > 0.0);
        for rec in &r.records {
            assert!(rec.jct_ms >= 0.0);
            assert!(rec.tokens >= 1);
        }
    }

    #[test]
    fn srpt_beats_fcfs_under_load() {
        let fcfs = run(Policy::Fcfs, 1, 3.0, 80);
        let srpt = run(Policy::Srpt, 1, 3.0, 80);
        assert!(
            srpt.avg_jct_s() < fcfs.avg_jct_s(),
            "SRPT {} must beat FCFS {}",
            srpt.avg_jct_s(),
            fcfs.avg_jct_s()
        );
    }

    #[test]
    fn more_workers_reduce_jct() {
        let one = run(Policy::Fcfs, 1, 4.0, 60);
        let four = run(Policy::Fcfs, 4, 4.0, 60);
        assert!(four.avg_jct_s() < one.avg_jct_s());
    }

    #[test]
    fn tokens_match_targets() {
        let corpus = Corpus::synthetic(50, 9);
        let mut gen = RequestGenerator::fabrix(2.0, 3);
        let trace = gen.trace(&corpus, 30);
        let mut sched = Scheduler::new(Policy::Srpt, Box::new(OraclePredictor));
        let cfg = ServeConfig { max_iterations: 500_000, ..Default::default() };
        let mut e = engines(1);
        let r = run_serving(&cfg, &trace, &mut e, &mut sched).unwrap();
        for (rec, req) in r.records.iter().zip(trace.iter()) {
            // records are in id order == trace order
            assert_eq!(rec.tokens, req.total_len, "job {}", rec.id);
        }
    }

    #[test]
    fn peak_search_monotone_function() {
        // delay(rps) = rps^2 / 10; limit 0.5 -> rps* = sqrt(5) ≈ 2.236
        let peak = peak_rps_search(|r| r * r / 10.0, 0.1, 1.0, 30, 0.5);
        assert!((peak - 5f64.sqrt()).abs() < 0.01, "peak {peak}");
    }
}
