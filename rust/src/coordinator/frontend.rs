//! Compatibility frontend — the original one-call serving entry point.
//!
//! The serving loop itself now lives in [`serving`](super::serving) as the
//! stepped [`Coordinator`] API (`ingest` / `poll_completions` / `dispatch`
//! / `step` / `run_to_completion`, built via [`CoordinatorBuilder`] with
//! optional [`EventSink`](super::events::EventSink) observers).  This
//! module keeps the historical surface:
//!
//! * [`run_serving`] — builds a [`Coordinator`] from a [`ServeConfig`] and
//!   runs it to completion.  It produces a [`ServeReport`] identical to
//!   driving the coordinator by hand (same records, makespan, preemption
//!   counts for a fixed seed) in both [`ClockMode::Virtual`] and
//!   [`ClockMode::Wall`].
//! * [`peak_rps_search`] — the Fig 7 peak-request-rate bisection helper.
//!
//! Prefer the [`Coordinator`] API for anything that wants to observe or
//! extend the loop; prefer `run_serving` for one-shot experiment drivers.

use anyhow::Result;

use crate::engine::Engine;
use crate::metrics::ServeReport;
use crate::workload::TraceRequest;

use super::scheduler::Scheduler;
use super::serving::CoordinatorBuilder;
pub use super::serving::{ClockMode, ServeConfig};

/// Serve a trace through the full coordinator stack.
///
/// `engines[i]` is worker i's backend; `scheduler` owns the policy and the
/// length predictor.  Thin wrapper over
/// [`CoordinatorBuilder`] + [`run_to_completion`](super::Coordinator::run_to_completion).
pub fn run_serving(
    cfg: &ServeConfig,
    trace: &[TraceRequest],
    engines: &mut [Box<dyn Engine>],
    scheduler: &mut Scheduler,
) -> Result<ServeReport> {
    CoordinatorBuilder::from_config(cfg.clone())
        .build(trace, engines, scheduler)?
        .run_to_completion()
}

/// Binary-search the peak request rate where `delay_fn(rps)` (avg queueing
/// delay, seconds) stays within `limit_s` (Fig 7's 0.5 s criterion).
pub fn peak_rps_search<F: FnMut(f64) -> f64>(
    mut delay_fn: F, mut lo: f64, mut hi: f64, iters: usize, limit_s: f64,
) -> f64 {
    // expand hi until it violates (or give up)
    let mut expand = 0;
    while delay_fn(hi) <= limit_s && expand < 8 {
        lo = hi;
        hi *= 2.0;
        expand += 1;
    }
    for _ in 0..iters {
        let mid = 0.5 * (lo + hi);
        if delay_fn(mid) <= limit_s {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::events::SharedCounter;
    use crate::coordinator::scheduler::Policy;
    use crate::engine::profiles::ModelProfile;
    use crate::engine::sim_engine::SimEngine;
    use crate::predictor::oracle::OraclePredictor;
    use crate::runtime::manifest::ServedModelMeta;
    use crate::workload::corpus::Corpus;
    use crate::workload::generator::RequestGenerator;

    fn profile() -> ModelProfile {
        ModelProfile::from_meta(&ServedModelMeta {
            name: "test".into(),
            abbrev: "test".into(),
            params_b: 7.0,
            avg_latency_ms: 2000.0,
            kv_bytes_per_token: 1 << 20,
            preempt_batch: 0,
            mem_limit_frac: 0.9,
        })
    }

    fn engines(n: usize) -> Vec<Box<dyn Engine>> {
        (0..n)
            .map(|_| {
                Box::new(SimEngine::new(profile(), 50, 4, 8 << 30)) as Box<dyn Engine>
            })
            .collect()
    }

    fn run(policy: Policy, workers: usize, rps: f64, n: usize) -> ServeReport {
        let corpus = Corpus::synthetic(300, 5);
        let mut gen = RequestGenerator::fabrix(rps, 42);
        let trace = gen.trace(&corpus, n);
        let mut sched = Scheduler::new(policy, Box::new(OraclePredictor));
        let cfg = ServeConfig {
            workers,
            max_iterations: 2_000_000,
            ..Default::default()
        };
        let mut e = engines(workers);
        run_serving(&cfg, &trace, &mut e, &mut sched).unwrap()
    }

    #[test]
    fn completes_all_jobs() {
        let r = run(Policy::Fcfs, 1, 1.0, 40);
        assert_eq!(r.n(), 40);
        assert!(r.avg_jct_s() > 0.0);
        assert!(r.makespan_ms > 0.0);
        for rec in &r.records {
            assert!(rec.jct_ms >= 0.0);
            assert!(rec.tokens >= 1);
        }
    }

    #[test]
    fn srpt_beats_fcfs_under_load() {
        let fcfs = run(Policy::Fcfs, 1, 3.0, 80);
        let srpt = run(Policy::Srpt, 1, 3.0, 80);
        assert!(
            srpt.avg_jct_s() < fcfs.avg_jct_s(),
            "SRPT {} must beat FCFS {}",
            srpt.avg_jct_s(),
            fcfs.avg_jct_s()
        );
    }

    #[test]
    fn more_workers_reduce_jct() {
        let one = run(Policy::Fcfs, 1, 4.0, 60);
        let four = run(Policy::Fcfs, 4, 4.0, 60);
        assert!(four.avg_jct_s() < one.avg_jct_s());
    }

    #[test]
    fn tokens_match_targets() {
        let corpus = Corpus::synthetic(50, 9);
        let mut gen = RequestGenerator::fabrix(2.0, 3);
        let trace = gen.trace(&corpus, 30);
        let mut sched = Scheduler::new(Policy::Srpt, Box::new(OraclePredictor));
        let cfg = ServeConfig { max_iterations: 500_000, ..Default::default() };
        let mut e = engines(1);
        let r = run_serving(&cfg, &trace, &mut e, &mut sched).unwrap();
        for (rec, req) in r.records.iter().zip(trace.iter()) {
            // records are in id order == trace order
            assert_eq!(rec.tokens, req.total_len, "job {}", rec.id);
        }
    }

    #[test]
    fn wrapper_matches_manual_stepping() {
        // acceptance: run_serving == CoordinatorBuilder + step loop, same
        // records / makespan / preemption counts for a fixed seed
        let corpus = Corpus::synthetic(200, 7);
        let mut gen = RequestGenerator::fabrix(3.0, 7);
        let trace = gen.trace(&corpus, 50);
        let cfg = ServeConfig {
            workers: 2,
            max_iterations: 2_000_000,
            ..Default::default()
        };

        let mut sched_a = Scheduler::new(Policy::Srpt, Box::new(OraclePredictor));
        let mut e_a = engines(2);
        let a = run_serving(&cfg, &trace, &mut e_a, &mut sched_a).unwrap();

        let mut sched_b = Scheduler::new(Policy::Srpt, Box::new(OraclePredictor));
        let mut e_b = engines(2);
        let mut coord = CoordinatorBuilder::from_config(cfg.clone())
            .build(&trace, &mut e_b, &mut sched_b)
            .unwrap();
        while !coord.step().unwrap().done {}
        let b = coord.report();

        assert_eq!(a.records, b.records);
        assert_eq!(a.makespan_ms, b.makespan_ms);
        assert_eq!(a.total_preemptions, b.total_preemptions);
        assert_eq!(a.sched_iterations, b.sched_iterations);
    }

    #[test]
    fn wall_clock_smoke_via_step() {
        // drive ClockMode::Wall through the stepped API (arrivals in the
        // past -> no sleeping) and watch events fire
        let corpus = Corpus::synthetic(60, 21);
        let mut gen = RequestGenerator::fabrix(1000.0, 21);
        let trace = gen.trace(&corpus, 8);
        let mut sched = Scheduler::new(Policy::Fcfs, Box::new(OraclePredictor));
        let mut e = engines(1);
        let counter = SharedCounter::new();
        let mut coord = CoordinatorBuilder::new()
            .clock(ClockMode::Wall)
            .max_iterations(100_000)
            .sink(Box::new(counter.clone()))
            .build(&trace, &mut e, &mut sched)
            .unwrap();
        let mut steps = 0u64;
        while !coord.is_done() {
            coord.step().unwrap();
            steps += 1;
            assert!(steps < 100_000, "wall-clock run did not converge");
        }
        let r = coord.report();
        assert_eq!(r.n(), 8);
        let c = counter.snapshot();
        assert_eq!(c.admitted, 8);
        assert_eq!(c.finished, 8);
        assert!(c.batches >= 1);
        assert_eq!(c.batches, c.windows);
    }

    #[test]
    fn peak_search_monotone_function() {
        // delay(rps) = rps^2 / 10; limit 0.5 -> rps* = sqrt(5) ≈ 2.236
        let peak = peak_rps_search(|r| r * r / 10.0, 0.1, 1.0, 30, 0.5);
        assert!((peak - 5f64.sqrt()).abs() < 0.01, "peak {peak}");
    }
}
