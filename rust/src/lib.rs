//! # ELIS — Efficient LLM Iterative Scheduling (paper reproduction)
//!
//! A three-layer serving stack reproducing Choi et al., "ELIS: Efficient
//! LLM Iterative Scheduling System with Response Length Predictor":
//!
//! * **L3 (this crate)** — the paper's contribution: the ISRTF frontend
//!   scheduler ([`coordinator`]), response-length predictors
//!   ([`predictor`]), load balancing, batching, preemption policy, a
//!   multi-worker serving loop in virtual or wall clock, and the
//!   [`cluster`] runtime (threaded worker pool + HTTP frontend) that
//!   serves it as the networked system of paper §5.
//! * **L2 (python/compile, build-time)** — the served TinyGPT model and the
//!   BGE-substitute predictor, AOT-lowered to HLO text by `aot.py`.
//! * **L1 (Pallas)** — the attention kernels inside those HLOs
//!   (interpret=True on CPU).
//!
//! Python never runs on the request path: [`runtime`] loads the AOT
//! artifacts via PJRT and [`engine`]/[`predictor`] execute them from rust.
pub mod cluster;
pub mod coordinator;
pub mod engine;
pub mod k8s;
pub mod loadgen;
pub mod metrics;
pub mod predictor;
pub mod runtime;
pub mod stats;
pub mod telemetry;
pub mod testing;
pub mod util;
pub mod workload;
