//! `elis` — CLI for the ELIS serving system reproduction.
//!
//! Subcommands:
//!   info             inspect artifacts and loaded models
//!   serve            wall-clock serving (PJRT or sim engines); with
//!                    --listen, run as a network service: worker-pool
//!                    threads + HTTP frontend (/healthz /metrics /v1/generate);
//!                    with --worker-listen, accept remote `elis worker` pods
//!                    over TCP instead of local engines
//!   worker           backend pod: connect to a coordinator's --worker-listen
//!                    address and serve scheduling windows over TCP
//!   loadgen          client-side load harness: drive concurrent streaming
//!                    /v1/generate connections against a live `elis serve`
//!                    and report TTFT/TPOT/JCT percentiles
//!   simulate         run a scheduling experiment on the calibrated sim engine
//!   predictor-eval   rank-sufficiency smoke for the online rank predictor
//!   trace-fit        reproduce the Fig 4 inter-arrival analysis
//!   preempt-profile  reproduce the Table 6 preemption profiling
//!   k8s-manifests    emit the paper's Kubernetes deployment YAML
//!
//! Examples:
//!   elis simulate --model lam13 --scheduler isrtf --rps-mult 5 --n 200
//!   elis serve --n 12 --rps 0.5 --scheduler isrtf --workers 2
//!   elis serve --worker-listen 0.0.0.0:7000 --listen 0.0.0.0:8080 --workers 2
//!   elis worker --connect coordinator:7000 --engine sim
//!   elis trace-fit --n 200000

use anyhow::{anyhow, bail, Result};

use elis::cluster::{run_worker, Admission, AdmissionConfig, ApiBridge,
                    Gateway, HttpServer, RemoteWorkerPool, WorkerPool,
                    WorkerTransport};
use elis::coordinator::{
    ClockMode, CoordinatorBuilder, LbStrategy, Policy, PreemptionPolicy,
    PriorityShaper, Scheduler, ServeConfig,
};
use elis::telemetry::{AttributionSink, FlightRecorder, ShadowMode,
                      ShadowScheduler, SloPolicy, SloSpec, TelemetrySink,
                      WfqPolicy};
use elis::engine::profiles::{avg_request_rate, ModelProfile};
use elis::engine::sim_engine::SimEngine;
use elis::engine::pjrt_engine::PjrtEngine;
use elis::engine::Engine;
use elis::k8s;
use elis::predictor::eval::rank_metrics;
use elis::predictor::heuristic::HeuristicPredictor;
use elis::predictor::hlo::HloPredictor;
use elis::predictor::oracle::{FrozenOracle, OraclePredictor};
use elis::predictor::rank::RankPredictor;
use elis::predictor::surrogate::SurrogatePredictor;
use elis::predictor::{LengthPredictor, ObservedCompletion, PredictQuery};
use elis::runtime::{default_artifacts_dir, Manifest, Runtime, WeightStore};
use elis::stats::rng::Pcg64;
use elis::util::cli::Args;
use elis::workload::tracefit::analyse;
use elis::workload::{Corpus, RequestGenerator};

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand.as_deref() {
        Some("info") => cmd_info(&args),
        Some("serve") => cmd_serve(&args),
        Some("worker") => cmd_worker(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("predictor-eval") => cmd_predictor_eval(&args),
        Some("trace-fit") => cmd_trace_fit(&args),
        Some("preempt-profile") => cmd_preempt_profile(&args),
        Some("gen-trace") => cmd_gen_trace(&args),
        Some("k8s-manifests") => cmd_k8s(&args),
        _ => {
            eprintln!("{}", HELP);
            return;
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "\
elis — ELIS serving system (ISRTF scheduler + response length predictor)

USAGE: elis <subcommand> [--flags]

  info              artifact + model summary
  serve             wall-clock serving: --n --rps --scheduler --workers
                    --engine(pjrt|sim)
                    --predictor(hlo|heuristic|rank|surrogate|oracle)
                    --lb(minload|rr|random) --tenants --slo-ms --wfq
                    --listen addr:port   run as a network service: engines
                    move onto worker-pool threads (windows overlap across
                    workers) and an HTTP frontend serves GET /healthz
                    (structured probe JSON), GET /metrics (Prometheus),
                    GET /debug/trace[?job=ID] (Chrome trace-event JSON
                    from the flight recorder; load in Perfetto),
                    GET /debug/explain?job=ID (per-job JCT breakdown:
                    queueing / head-of-line blocking / preemption stall /
                    failover stall / execution, summing to the JCT),
                    POST /v1/generate (JSON reply carrying trace_id and a
                    breakdown object, or chunked SSE token streaming with
                    \"stream\": true; the done event carries breakdown).
                    With --listen: --http-conns
                    (max concurrent connections, default 4096)
                    --wait-timeout-s --idle-exit-ms (0 = serve forever)
                    --idle-tick-ms --trace-dump path (flush the flight
                    recorder as Chrome trace JSON on shutdown)
                    --admission-rps N (front-door token-bucket rate, 0 =
                    off) --admission-burst N --admission-queue N (bounded
                    pending-admission queue, 0 = unbounded); overload is
                    shed with 429 + Retry-After, per-tenant rates split
                    by the --tenants weights
                    --worker-listen addr:port   accept --workers remote
                    `elis worker` pod registrations over TCP instead of
                    building local engines, so workers span machines; a
                    pod lost mid-run fails over to the survivors.  With
                    --worker-listen: --accept-timeout-s (default 120)
                    --shadow fcfs|srpt|off (default off): replay finished
                    jobs through a deterministic counterfactual scheduler
                    off the hot path and export elis_shadow_jct_delta_ms /
                    elis_shadow_jct_saved_ratio on /metrics — live
                    measurement of what the scheduling policy saves
                    --log-jobs path|-   append one NDJSON line per
                    finished job (tenant, predicted vs realized tokens,
                    JCT breakdown, trace_id)
  worker            backend pod for a distributed coordinator:
                    --connect host:port (required, the coordinator's
                    --worker-listen address)  --engine sim|pjrt
                    --model --batch --connect-timeout-s (default 10).
                    Runs until the coordinator closes the connection.
                    Without artifacts, --engine sim falls back to a
                    built-in 7B profile
  loadgen           drive a live `elis serve --listen` frontend and
                    measure client-side latency: --target host:port
                    --duration-s (default 10) --streams N (closed-loop
                    concurrent streaming connections, default 8)
                    --rps R (open-loop Poisson arrivals instead;
                    --max-in-flight caps client-side) --total-len
                    --prompt-len --tenants a,b --no-stream (use
                    \"wait\": true instead of SSE) --seed
                    --json-out BENCH_serve.json (includes error/429
                    counts and a trace_sample of the slowest requests'
                    trace ids for /debug/trace?job=ID)
  simulate          calibrated simulation: --model --scheduler --rps-mult
                    --batch --workers --n --shuffles --predictor --lb
                    --tenants name[=weight],... (weighted round-robin tags)
                    --slo-ms N (default JCT budget; enables the SLO-aware
                    priority policy + live telemetry; prints a Prometheus
                    snapshot and per-tenant deadline misses)
                    --wfq (weighted-fair tenant shaper over the live
                    per-tenant token counters; composes with --slo-ms)
                    --dispatch-shards auto|N (serve + simulate: plan
                    per-node scheduling on N persistent shard threads;
                    auto sizes from the host, 1 = inline; reports are
                    bit-identical at any shard count)
                    With --predictor surrogate and --shuffles > 1, the
                    surrogate's noise profile recalibrates between
                    shuffles from the previous shuffle's live mispredict
                    telemetry (sigma0/decay fitted from the per-step
                    |log error| sketches)
  predictor-eval    rank-sufficiency smoke: train the online rank
                    predictor on a content-coded synthetic workload and
                    score the held-out ordering (Kendall tau, pairwise
                    accuracy, realized-JCT regret) vs the heuristic
                    baseline: --n --seed --slots
                    --json-out BENCH_predictor.json
  trace-fit         Fig 4 reproduction: --n --process(gamma|poisson)
  preempt-profile   Table 6 reproduction: --model(all|abbrev)
  gen-trace         standalone request generator: --n --rps --out file
                    (--process gamma|poisson|uniform) --tenants; replay
                    with serve/simulate --trace file
  k8s-manifests     --workers --policy --image
";

/// Parse `--dispatch-shards auto|N` (0 = auto-size from the host).
fn parse_dispatch_shards(args: &Args) -> Result<usize> {
    let v = args.str("dispatch-shards", "auto");
    if v == "auto" {
        return Ok(0);
    }
    v.parse::<usize>().map_err(|_| {
        anyhow!("--dispatch-shards expects 'auto' or a shard count, got '{v}'")
    })
}

/// Parse a `--tenants` spec: comma-separated `name` or `name=weight`.
fn parse_tenant_spec(items: &[String]) -> Result<Vec<(String, u32)>> {
    items
        .iter()
        .map(|item| match item.split_once('=') {
            Some((name, w)) => {
                let weight: u32 = w.trim().parse().map_err(|_| {
                    anyhow!("--tenants: bad weight in '{item}' \
                             (expected name=integer)")
                })?;
                Ok((name.trim().to_string(), weight))
            }
            None => Ok((item.trim().to_string(), 1)),
        })
        .collect()
}

/// Shared `--tenants`/`--slo-ms`/`--wfq` wiring: tag the trace with the
/// (already parsed) tenant spec, and when tenants, an SLO budget, or the
/// fairness shaper are configured return the telemetry sink plus the
/// budget (ms; 0 = observe only, no SLO policy).
fn telemetry_for(args: &Args, workers: usize,
                 trace: &mut [elis::workload::TraceRequest],
                 tenant_spec: &[(String, u32)])
                 -> Result<Option<(TelemetrySink, f64)>> {
    if !tenant_spec.is_empty() {
        elis::workload::assign_tenants(trace, tenant_spec);
    }
    let slo_ms = args.f64("slo-ms", 0.0);
    if slo_ms <= 0.0 && tenant_spec.is_empty() && !args.bool("wfq") {
        return Ok(None);
    }
    let sink = TelemetrySink::with_slo(workers, SloSpec::new(slo_ms));
    Ok(Some((sink, slo_ms)))
}

/// Register the telemetry sink and the configured priority shapers on a
/// builder — shared by `serve` and `simulate`.  `--slo-ms` enables the
/// deadline-driven [`SloPolicy`]; `wfq` adds the weighted-fair tenant
/// shaper on top (fairness penalty over the SLO/base order), with the
/// `--tenants name=weight` values doubling as the tenants' fair-share
/// weights.
fn register_telemetry(mut builder: CoordinatorBuilder,
                      telemetry: &Option<(TelemetrySink, f64)>, wfq: bool,
                      tenant_spec: &[(String, u32)])
                      -> CoordinatorBuilder {
    if let Some((sink, slo_ms)) = telemetry {
        builder = builder.sink(Box::new(sink.clone()));
        let slo: Option<Box<dyn PriorityShaper>> = (*slo_ms > 0.0).then(|| {
            Box::new(SloPolicy::new(sink, SloSpec::new(*slo_ms)))
                as Box<dyn PriorityShaper>
        });
        let shaper: Option<Box<dyn PriorityShaper>> = if wfq {
            let mut policy = WfqPolicy::new(sink);
            // --tenants weights drive both the round-robin tagging ratio
            // and, here, each tenant's fair-share entitlement
            for (name, weight) in tenant_spec {
                if *weight > 0 {
                    policy = policy.weight(name, *weight as f64);
                }
            }
            if let Some(inner) = slo {
                policy = policy.over(inner);
            }
            Some(Box::new(policy))
        } else {
            slo
        };
        if let Some(shaper) = shaper {
            builder = builder.priority_shaper(shaper);
        }
    }
    builder
}

fn print_telemetry(sink: &TelemetrySink) {
    println!("--- telemetry snapshot (Prometheus text exposition) ---");
    print!("{}", sink.render_prometheus());
    sink.with_state(|st| {
        for (tenant, t) in &st.tenants {
            println!(
                "tenant {tenant}: {}/{} finished, p50 jct {:.0} ms, \
                 p99 jct {:.0} ms, deadline misses {}",
                t.finished, t.admitted, t.jct_ms.p50(), t.jct_ms.p99(),
                t.deadline_misses
            );
        }
    });
}

/// Build a scheduler with the right predictor wiring for a policy.
pub fn scheduler_for(policy: Policy, predictor_kind: &str,
                     artifacts: Option<(&Manifest, &WeightStore)>)
                     -> Result<Scheduler> {
    let predictor: Box<dyn LengthPredictor> = match (policy, predictor_kind) {
        (Policy::Fcfs | Policy::Mlfq, _) => Box::new(OraclePredictor),
        (Policy::Sjf, _) => Box::new(FrozenOracle),
        (Policy::Srpt, _) => Box::new(OraclePredictor),
        (Policy::Isrtf, "hlo") => {
            let (m, store) = artifacts
                .ok_or_else(|| anyhow!("hlo predictor needs artifacts"))?;
            let rt = Runtime::cpu()?;
            Box::new(HloPredictor::load(rt, m, store, None)?)
        }
        (Policy::Isrtf, "heuristic") => Box::new(HeuristicPredictor::new()),
        (Policy::Isrtf, "rank") => Box::new(RankPredictor::new(7)),
        (Policy::Isrtf, "surrogate") => Box::new(SurrogatePredictor::calibrated(7)),
        (Policy::Isrtf, "oracle") => Box::new(OraclePredictor),
        (p, k) => bail!("unsupported predictor '{k}' for policy {:?}", p),
    };
    Ok(Scheduler::new(policy, predictor))
}

fn cmd_info(_args: &Args) -> Result<()> {
    let dir = default_artifacts_dir();
    println!("artifacts: {}", dir.display());
    let manifest = Manifest::load(&dir)?;
    println!("window_size: {}", manifest.window_size);
    println!("batch_sizes: {:?}", manifest.batch_sizes);
    println!(
        "served model: TinyGPT vocab={} d={} L={} H={} S={} ({} params)",
        manifest.model.vocab, manifest.model.d_model, manifest.model.n_layers,
        manifest.model.n_heads, manifest.model.max_seq, manifest.model.n_params
    );
    println!("executables:");
    for (name, e) in &manifest.executables {
        println!("  {name:<22} {} in -> {} out (weights: {})",
                 e.inputs.len(), e.outputs.len(), e.weights_group);
    }
    let corpus = Corpus::load(&dir)?;
    println!("corpus: {} prompts, mean output len {:.1} tokens",
             corpus.len(), corpus.mean_total_len());
    println!("profiles (paper Table 4):");
    for m in &manifest.served_models {
        let p = ModelProfile::from_meta(m);
        println!("  {:<8} {:>5.1}B  avg latency {:>8.1} ms  tpot {:>6.2} ms",
                 p.abbrev, p.params_b, p.avg_latency_ms, p.tpot_ms);
    }
    Ok(())
}

/// Where `elis serve`'s engines come from: constructed locally (inline
/// run or in-process worker-pool threads), or registered remotely over
/// `--worker-listen` (one `elis worker` pod per worker).
enum ServeBackend {
    Local(Vec<Box<dyn Engine>>),
    Remote(RemoteWorkerPool),
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dir = default_artifacts_dir();

    let n = args.usize("n", 12);
    let rps = args.f64("rps", 0.5);
    let workers = args.usize("workers", 1);
    let policy = args.parse_with("scheduler", "isrtf", Policy::parse)?;
    let lb = args.parse_with("lb", "minload", LbStrategy::parse)?;
    let worker_listen = args.opt_str("worker-listen").map(str::to_string);
    let engine_kind = args.str("engine", "pjrt");
    // remote pods bring their own engines, so the coordinator side only
    // needs artifacts when the predictor does
    let predictor_kind = args.str(
        "predictor",
        if engine_kind == "sim" || worker_listen.is_some() {
            "heuristic"
        } else {
            "hlo"
        },
    );
    let seed = args.u64("seed", 42);
    let listen = args.opt_str("listen").map(str::to_string);

    let mut trace = match args.opt_str("trace") {
        Some(path) => elis::workload::trace_io::load(std::path::Path::new(path))?,
        None => {
            let corpus = Corpus::load(&dir)?;
            RequestGenerator::fabrix(rps, seed).trace(&corpus, n)
        }
    };
    let n = trace.len();
    let tenant_spec = parse_tenant_spec(&args.list("tenants"))?;
    let mut telemetry = telemetry_for(args, workers, &mut trace,
                                      &tenant_spec)?;
    if listen.is_some() && telemetry.is_none() {
        // the HTTP frontend always exposes /metrics
        telemetry = Some((TelemetrySink::new(workers), 0.0));
    }
    println!("serving {n} requests at {rps} rps over {workers} worker(s), \
              policy {}", policy.name());

    // weights are needed for local PJRT engines and/or the hlo predictor
    let need_local_engines = worker_listen.is_none();
    let manifest = if (need_local_engines
                       && matches!(engine_kind.as_str(), "pjrt" | "sim"))
        || predictor_kind == "hlo"
    {
        Some(Manifest::load(&dir)?)
    } else {
        None
    };
    let store = if (need_local_engines && engine_kind == "pjrt")
        || predictor_kind == "hlo"
    {
        Some(WeightStore::load(manifest.as_ref().expect("loaded above"))?)
    } else {
        None
    };

    let backend = match &worker_listen {
        Some(addr) => {
            // distributed mode: wait for the pods to register over TCP
            let listener = std::net::TcpListener::bind(addr.as_str())
                .map_err(|e| anyhow!("binding --worker-listen {addr}: {e}"))?;
            println!("workers: listening on {} for {workers} pod \
                      registration(s)  (start them with `elis worker \
                      --connect <this address>`)", listener.local_addr()?);
            std::io::Write::flush(&mut std::io::stdout()).ok();
            let pool = RemoteWorkerPool::accept(
                &listener, workers,
                std::time::Duration::from_secs(
                    args.u64("accept-timeout-s", 120)))?;
            for w in 0..workers {
                println!("worker {w}: {} @ {}", pool.describe(w),
                         pool.peer(w));
            }
            ServeBackend::Remote(pool)
        }
        None => {
            let engines: Vec<Box<dyn Engine>> = match engine_kind.as_str() {
                "pjrt" => {
                    let manifest = manifest.as_ref().expect("loaded above");
                    let store = store.as_ref().expect("loaded above for pjrt");
                    let rt = Runtime::cpu()?;
                    println!("PJRT platform: {}", rt.platform());
                    (0..workers)
                        .map(|_| {
                            PjrtEngine::load(rt.clone(), manifest, store,
                                             1 << 20)
                                .map(|e| Box::new(e) as Box<dyn Engine>)
                        })
                        .collect::<Result<_>>()?
                }
                "sim" => {
                    let manifest = manifest.as_ref().expect("loaded above");
                    let profiles = ModelProfile::all(&manifest.served_models);
                    let model = args.str("model", "lam13");
                    let profile = ModelProfile::find(&profiles, &model)
                        .ok_or_else(|| anyhow!("unknown model {model}"))?
                        .clone();
                    let batch = args.usize("batch", 4);
                    (0..workers)
                        .map(|_| {
                            Box::new(SimEngine::with_profile_budget(
                                profile.clone(), manifest.window_size, batch))
                                as Box<dyn Engine>
                        })
                        .collect()
                }
                other => bail!("unknown --engine '{other}' (valid: pjrt, sim)"),
            };
            println!("engine: {}", engines[0].describe());
            ServeBackend::Local(engines)
        }
    };

    let mut sched = scheduler_for(
        policy, &predictor_kind,
        match (&manifest, &store) {
            (Some(m), Some(s)) => Some((m, s)),
            _ => None,
        })?;
    let cfg = ServeConfig {
        workers,
        max_batch: args.usize("batch", 4),
        lb,
        preemption: PreemptionPolicy::default(),
        overhead_ms_per_iter: 0.0,
        clock: ClockMode::Wall,
        seed,
        // a network service runs unbounded windows by design; the safety
        // cap stays on for one-shot trace serving
        max_iterations: args.u64(
            "max-iterations",
            if listen.is_some() { 0 } else { 1_000_000 },
        ),
        idle_tick_ms: args.f64("idle-tick-ms", 10.0),
        dispatch_shards: parse_dispatch_shards(args)?,
    };
    let mut builder = register_telemetry(CoordinatorBuilder::from_config(cfg),
                                         &telemetry, args.bool("wfq"),
                                         &tenant_spec);

    // JCT attribution: fold window events into per-job breakdowns for
    // /debug/explain, the generate replies, and --log-jobs NDJSON.  The
    // sink registers ahead of the completion bridge so the breakdown is
    // already folded when a waiting handler wakes.
    let explain = if listen.is_some() || args.opt_str("log-jobs").is_some() {
        let sink = AttributionSink::default();
        if let Some(path) = args.opt_str("log-jobs") {
            let out: Box<dyn std::io::Write + Send> = if path == "-" {
                Box::new(std::io::stdout())
            } else {
                Box::new(std::fs::File::create(path).map_err(|e| {
                    anyhow!("--log-jobs: cannot create {path}: {e}")
                })?)
            };
            sink.log_to(out);
        }
        builder = builder.sink(Box::new(sink.clone()));
        Some(sink)
    } else {
        None
    };

    // --shadow: deterministic counterfactual replay of the live arrival
    // stream (off the dispatch path; runs on job-finish events only)
    let shadow_mode = args.parse_with("shadow", "off", |s| {
        ShadowMode::parse(s)
            .ok_or_else(|| format!("unknown mode '{s}' (valid: off, \
                                    fcfs, srpt)"))
    })?;
    if shadow_mode != ShadowMode::Off {
        let shadow = ShadowScheduler::new(
            shadow_mode, elis::telemetry::shadow::DEFAULT_SHADOW_WINDOW);
        builder = builder.sink(Box::new(shadow.clone()));
        if let Some((sink, _)) = &telemetry {
            sink.attach_shadow(shadow);
        }
        println!("shadow scheduler: counterfactual {} replay on /metrics",
                 shadow_mode.label());
    }

    let report = match (listen, backend) {
        (None, ServeBackend::Local(mut engines)) => {
            let mut coord = builder.build(&trace, &mut engines, &mut sched)?;
            coord.run_to_completion()?
        }
        (None, ServeBackend::Remote(pool)) => {
            let mut coord = builder.build_remote(&trace, pool, &mut sched)?;
            coord.run_to_completion()?
        }
        (Some(addr), backend) => {
            serve_http(args, &addr, backend, builder, &trace, &mut sched,
                       &telemetry, explain)?
        }
    };
    report.print_summary();
    println!("avg TTFT {:.2}s  TPOT {:.1}ms  tokens/s {:.1}",
             report.avg_ttft_s(), report.avg_tpot_s() * 1e3,
             report.tokens_per_s());
    if let Some((sink, _)) = &telemetry {
        print_telemetry(sink);
    }
    if let Some(path) = args.opt_str("json-out") {
        std::fs::write(path, report.to_json().to_string())?;
        println!("report written to {path}");
    }
    Ok(())
}

/// `elis worker`: the backend-pod half of the distributed deployment.
/// Connects to a coordinator's `--worker-listen` address (retrying until
/// `--connect-timeout-s`, since pods usually start before the frontend),
/// announces the engine over the hello handshake, then serves scheduling
/// windows until the coordinator closes the connection.
fn cmd_worker(args: &Args) -> Result<()> {
    let addr = args.require_str("connect")?.to_string();
    let engine_kind = args.str("engine", "sim");
    let batch = args.usize("batch", 4);
    let dir = default_artifacts_dir();

    let engine: Box<dyn Engine> = match engine_kind.as_str() {
        "sim" => {
            // artifacts are optional for the sim engine: a pod on a bare
            // node falls back to a built-in 7B profile
            let (profile, window) = match Manifest::load(&dir) {
                Ok(manifest) => {
                    let profiles = ModelProfile::all(&manifest.served_models);
                    let model = args.str("model", "lam13");
                    let profile = ModelProfile::find(&profiles, &model)
                        .ok_or_else(|| anyhow!("unknown model {model}"))?
                        .clone();
                    (profile, manifest.window_size)
                }
                Err(_) => {
                    eprintln!("no artifacts found; using the built-in \
                               fallback sim profile");
                    let meta = elis::runtime::manifest::ServedModelMeta {
                        name: "Fallback-7B".into(),
                        abbrev: "sim7".into(),
                        params_b: 7.0,
                        avg_latency_ms: 2000.0,
                        kv_bytes_per_token: 1 << 20,
                        preempt_batch: 0,
                        mem_limit_frac: 0.9,
                    };
                    (ModelProfile::from_meta(&meta), 50)
                }
            };
            Box::new(SimEngine::with_profile_budget(profile, window, batch))
        }
        "pjrt" => {
            let manifest = Manifest::load(&dir)?;
            let store = WeightStore::load(&manifest)?;
            let rt = Runtime::cpu()?;
            println!("PJRT platform: {}", rt.platform());
            Box::new(PjrtEngine::load(rt, &manifest, &store, 1 << 20)?)
        }
        other => bail!("unknown --engine '{other}' (valid: sim, pjrt)"),
    };

    // retry the connect: in a rollout the pods race the coordinator
    let timeout = std::time::Duration::from_secs(
        args.u64("connect-timeout-s", 10));
    let deadline = std::time::Instant::now() + timeout;
    let stream = loop {
        match std::net::TcpStream::connect(&addr) {
            Ok(s) => break s,
            Err(e) => {
                if std::time::Instant::now() >= deadline {
                    bail!("could not connect to coordinator {addr}: {e}");
                }
                std::thread::sleep(std::time::Duration::from_millis(100));
            }
        }
    };
    println!("worker connected to {addr}: {}", engine.describe());
    std::io::Write::flush(&mut std::io::stdout()).ok();
    run_worker(stream, engine)?;
    println!("coordinator closed the connection; worker exiting");
    Ok(())
}

/// `elis serve --listen <addr>`: the cluster runtime.  Engines move onto
/// [`WorkerPool`] threads (or are already remote `elis worker` pods), the
/// HTTP frontend exposes `/healthz` + `/metrics` + `/v1/generate`, and
/// this loop drives the coordinator, pumping HTTP admissions between
/// steps.  Exits once the run is idle for `--idle-exit-ms` (0 = serve
/// until killed); held `wait: true` connections racing that exit get a
/// terminal 503 via the shutdown drain.
fn serve_http(args: &Args, addr: &str, backend: ServeBackend,
              builder: CoordinatorBuilder,
              trace: &[elis::workload::TraceRequest],
              sched: &mut Scheduler,
              telemetry: &Option<(TelemetrySink, f64)>,
              explain: Option<AttributionSink>)
              -> Result<elis::metrics::ServeReport> {
    let (api_tx, mut bridge) = ApiBridge::channel();
    // request-scoped tracing: one bounded flight recorder shared between
    // the serving loop (as an event sink) and /debug/trace handlers
    let recorder = FlightRecorder::default();
    let builder = builder
        .sink(Box::new(bridge.completion_sink()))
        .sink(Box::new(recorder.clone()));
    let mut coord = match backend {
        ServeBackend::Local(engines) => {
            builder.build_pooled(trace, WorkerPool::new(engines), sched)?
        }
        ServeBackend::Remote(pool) => builder.build_remote(trace, pool,
                                                           sched)?,
    };
    let adm_rps = args.f64("admission-rps", 0.0);
    let admission = Admission::new(AdmissionConfig {
        rps: adm_rps,
        burst: args.f64("admission-burst", adm_rps.max(1.0)),
        queue_cap: args.usize("admission-queue", 0),
        tenant_weights: parse_tenant_spec(&args.list("tenants"))?,
    });
    let stats = bridge.frontend_stats();
    if let Some((sink, _)) = telemetry {
        // surface the front-door gauges on /metrics
        sink.attach_frontend(stats.clone());
    }
    let gateway = Gateway {
        telemetry: telemetry.as_ref().map(|(sink, _)| sink.clone()),
        api_tx,
        wait_timeout: args.duration_s("wait-timeout-s", 30.0),
        admission,
        stats,
        trace: Some(recorder.clone()),
        explain,
        started: std::time::Instant::now(),
    };
    let mut server = HttpServer::serve(addr, gateway,
                                       args.usize("http-conns", 4096))?;
    println!("listening on http://{}  \
              (GET /healthz | GET /metrics | GET /debug/trace | \
              GET /debug/explain | POST /v1/generate)",
             server.local_addr());
    std::io::Write::flush(&mut std::io::stdout()).ok();

    let idle_exit_ms = args.f64("idle-exit-ms", 0.0);
    // the drained-idle poll honours the same latency bound as the
    // coordinator's own wall-clock tick (--idle-tick-ms)
    let tick = std::time::Duration::from_secs_f64(
        args.f64("idle-tick-ms", 10.0).max(0.1) / 1e3);
    let mut last_activity = std::time::Instant::now();
    loop {
        let pumped = bridge.pump(&mut coord);
        let finished_before = coord.finished_jobs();
        if coord.is_done() {
            std::thread::sleep(tick); // fully drained: wait for HTTP work
        } else {
            coord.step()?;
        }
        if pumped > 0 || coord.finished_jobs() != finished_before {
            last_activity = std::time::Instant::now();
        }
        if idle_exit_ms > 0.0
            && coord.is_done()
            && last_activity.elapsed().as_secs_f64() * 1e3 >= idle_exit_ms
        {
            break;
        }
    }
    // the loop is exiting: answer every queued or still-waiting generate
    // with a terminal 503, then close the channel so a request racing the
    // drain fails fast in its handler instead of hanging out its timeout
    bridge.drain_shutdown();
    drop(bridge);
    server.shutdown();
    if let Some(path) = args.opt_str("trace-dump") {
        std::fs::write(path, format!("{}\n", recorder.render_chrome(None)))?;
        println!("trace written to {path}");
    }
    Ok(coord.report())
}

/// `elis loadgen`: the client half of the streaming serving path.
/// Measures what users see — TTFT to the first SSE token chunk, TPOT,
/// and JCT, socket to socket — against a live `elis serve --listen`.
fn cmd_loadgen(args: &Args) -> Result<()> {
    let cfg = elis::loadgen::LoadgenConfig {
        target: args.str("target", "127.0.0.1:8080"),
        duration: args.duration_s("duration-s", 10.0),
        streams: args.usize("streams", 8),
        rps: args.f64("rps", 0.0),
        max_in_flight: args.usize("max-in-flight", 0),
        total_len: args.usize("total-len", 120),
        prompt_len: args.usize("prompt-len", 16),
        // accept the same name=weight spec as --tenants elsewhere; only
        // the names matter client-side
        tenants: parse_tenant_spec(&args.list("tenants"))?
            .into_iter()
            .map(|(name, _)| name)
            .collect(),
        stream: !args.bool("no-stream"),
        seed: args.u64("seed", 1),
    };
    if cfg.rps > 0.0 {
        println!("loadgen: open-loop {} rps against {} for {:.1}s \
                  (max in flight: {})",
                 cfg.rps, cfg.target, cfg.duration.as_secs_f64(),
                 cfg.max_in_flight);
    } else {
        println!("loadgen: closed-loop {} concurrent {} connections \
                  against {} for {:.1}s",
                 cfg.streams,
                 if cfg.stream { "streaming" } else { "waiting" },
                 cfg.target, cfg.duration.as_secs_f64());
    }
    let report = elis::loadgen::run(&cfg)?;
    println!(
        "sent {}  ok {}  errors {}  rejected(429) {}  shed {}  \
         tokens {}  peak in-flight {}",
        report.sent, report.ok, report.errors, report.rejected,
        report.shed, report.tokens_streamed, report.peak_in_flight
    );
    if report.ttft_ms.count() > 0 {
        println!("TTFT ms  p50 {:.1}  p90 {:.1}  p99 {:.1}",
                 report.ttft_ms.p50(), report.ttft_ms.p90(),
                 report.ttft_ms.p99());
    }
    if report.tpot_ms.count() > 0 {
        println!("TPOT ms  p50 {:.2}  p90 {:.2}  p99 {:.2}",
                 report.tpot_ms.p50(), report.tpot_ms.p90(),
                 report.tpot_ms.p99());
    }
    if report.jct_ms.count() > 0 {
        println!("JCT ms   p50 {:.0}  p90 {:.0}  p99 {:.0}",
                 report.jct_ms.p50(), report.jct_ms.p90(),
                 report.jct_ms.p99());
    }
    if let Some(path) = args.opt_str("json-out") {
        std::fs::write(path, format!("{}\n", report.to_json(&cfg)))?;
        println!("report written to {path}");
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let dir = default_artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    let corpus = Corpus::load(&dir)?;
    let profiles = ModelProfile::all(&manifest.served_models);
    let model = args.str("model", "lam13");
    let profile = ModelProfile::find(&profiles, &model)
        .ok_or_else(|| anyhow!("unknown model {model}"))?
        .clone();

    let policy = args.parse_with("scheduler", "isrtf", Policy::parse)?;
    let lb = args.parse_with("lb", "minload", LbStrategy::parse)?;
    let predictor_kind = args.str("predictor", "hlo");
    let batch = args.usize("batch", 4);
    let workers = args.usize("workers", 1);
    let n = args.usize("n", 200);
    let shuffles = args.usize("shuffles", 1);
    let rps_mult = args.f64("rps-mult", 1.0);
    let seed = args.u64("seed", 42);
    let rps = avg_request_rate(&profile, batch) * rps_mult * workers as f64;

    println!(
        "simulate: {} on {} worker(s), batch {}, {}x avg rate = {:.3} rps, \
         {} prompts × {} shuffles, policy {} ({})",
        profile.abbrev, workers, batch, rps_mult, rps, n, shuffles,
        policy.name(), predictor_kind
    );

    let store = WeightStore::load(&manifest)?;
    let tenant_spec = parse_tenant_spec(&args.list("tenants"))?;
    // with --predictor surrogate and multiple shuffles, each shuffle's
    // mispredict telemetry recalibrates the next shuffle's noise profile
    let recalibrating =
        policy == Policy::Isrtf && predictor_kind == "surrogate" && shuffles > 1;
    let mut live_profile: Option<(f64, f64)> = None;
    let mut jcts = Vec::new();
    for s in 0..shuffles {
        let mut gen = RequestGenerator::fabrix(rps, seed + s as u64);
        let mut trace = gen.trace(&corpus, n);
        let mut telemetry = telemetry_for(args, workers, &mut trace,
                                          &tenant_spec)?;
        let print_snapshot = telemetry.is_some();
        if recalibrating && telemetry.is_none() {
            // a bare observing sink: registering it leaves reports
            // bit-identical, and its PredictorStats feed the refit
            telemetry = Some((TelemetrySink::new(workers), 0.0));
        }
        let mut engines: Vec<Box<dyn Engine>> = (0..workers)
            .map(|_| {
                Box::new(SimEngine::with_profile_budget(
                    profile.clone(), manifest.window_size, batch))
                    as Box<dyn Engine>
            })
            .collect();
        let mut sched = match live_profile {
            Some((sigma0, decay)) if recalibrating => {
                println!("  surrogate recalibrated from live telemetry: \
                          sigma0 {sigma0:.3} decay {decay:.3}");
                let mut sp = SurrogatePredictor::calibrated(7);
                sp.recalibrate(sigma0, decay);
                Scheduler::new(policy, Box::new(sp))
            }
            _ => scheduler_for(policy, &predictor_kind,
                               Some((&manifest, &store)))?,
        };
        let cfg = ServeConfig {
            workers,
            max_batch: batch,
            lb,
            clock: ClockMode::Virtual,
            seed: seed + s as u64,
            max_iterations: 10_000_000,
            dispatch_shards: parse_dispatch_shards(args)?,
            ..Default::default()
        };
        let report = register_telemetry(CoordinatorBuilder::from_config(cfg),
                                        &telemetry, args.bool("wfq"),
                                        &tenant_spec)
            .build(&trace, &mut engines, &mut sched)?
            .run_to_completion()?;
        report.print_summary();
        if let Some((sink, _)) = &telemetry {
            if print_snapshot {
                print_telemetry(sink);
            }
            if recalibrating {
                if let Some(fitted) = sink.surrogate_calibration(8) {
                    live_profile = Some(fitted);
                }
            }
        }
        jcts.push(report.avg_jct_s());
    }
    let avg = jcts.iter().sum::<f64>() / jcts.len() as f64;
    println!("=> avg JCT over {shuffles} shuffles: {avg:.2}s");
    Ok(())
}

/// Content-coded synthetic rank workload: each prompt is a single repeated
/// token id `v` and the response length is a monotone function of `v`,
/// while the prompt *length* is deliberately uncorrelated — learnable by a
/// content-reading ranker, invisible to the length-only heuristic.
fn rank_eval_example(rng: &mut Pcg64) -> (Vec<i32>, usize) {
    let v = 16 + rng.below(1984) as i32;
    let plen = 8 + rng.below(32) as usize;
    (vec![v; plen], 5 + v as usize / 4)
}

fn cmd_predictor_eval(args: &Args) -> Result<()> {
    let n = args.usize("n", 600);
    let seed = args.u64("seed", 7);
    let slots = args.usize("slots", 4);
    if n < 20 {
        bail!("--n must be at least 20 for a train/eval split");
    }
    let n_train = n / 2;
    let mut rng = Pcg64::new(seed);
    let examples: Vec<(Vec<i32>, usize)> =
        (0..n).map(|_| rank_eval_example(&mut rng)).collect();

    // online training: completions arrive one at a time, exactly like the
    // coordinator's finish-feedback path
    let mut rank = RankPredictor::new(seed);
    let mut heuristic = HeuristicPredictor::new();
    for (prompt, total) in &examples[..n_train] {
        let response = vec![prompt[0]; *total];
        let c = ObservedCompletion {
            prompt,
            response: &response,
            total_len: *total,
        };
        rank.observe_rich(&c);
        heuristic.observe_rich(&c);
    }

    let held = &examples[n_train..];
    let truths: Vec<f64> = held.iter().map(|(_, t)| *t as f64).collect();
    let queries: Vec<PredictQuery<'_>> = held
        .iter()
        .enumerate()
        .map(|(i, (prompt, _))| PredictQuery {
            job_id: i as u64,
            prompt,
            gen_suffix: &[],
            generated: 0,
            true_total: 0,
        })
        .collect();
    let rm = rank_metrics(&rank.predict(&queries), &truths, slots);
    let hm = rank_metrics(&heuristic.predict(&queries), &truths, slots);

    println!("predictor-eval: {n_train} train completions, {} held out, \
              {slots} replay slots", held.len());
    for (name, m) in [("rank", &rm), ("heuristic", &hm)] {
        println!("  {name:<10} kendall_tau {:+.3}  pairwise_acc {:.3}  \
                  jct_regret {:+.3}", m.tau, m.pairwise_acc, m.jct_regret);
    }

    if let Some(path) = args.opt_str("json-out") {
        let num = |x: f64| {
            if x.is_finite() { format!("{x:.6}") } else { "null".into() }
        };
        let block = |m: &elis::predictor::eval::RankMetrics| {
            format!("{{\"kendall_tau\": {}, \"pairwise_acc\": {}, \
                     \"jct_regret\": {}}}",
                    num(m.tau), num(m.pairwise_acc), num(m.jct_regret))
        };
        let json = format!(
            "{{\n  \"n_train\": {n_train},\n  \"n_eval\": {},\n  \
             \"slots\": {slots},\n  \"rank\": {},\n  \"heuristic\": {}\n}}\n",
            held.len(), block(&rm), block(&hm));
        std::fs::write(path, json)?;
        println!("rank metrics written to {path}");
    }
    Ok(())
}

fn cmd_trace_fit(args: &Args) -> Result<()> {
    let n = args.usize("n", 200_000);
    let process = args.str("process", "gamma");
    let mut gen = match process.as_str() {
        "gamma" => RequestGenerator::fabrix(1.0, args.u64("seed", 7)),
        "poisson" => RequestGenerator::new(
            elis::workload::ArrivalProcess::Poisson, 0.73, 1.0,
            args.u64("seed", 7)),
        other => bail!("unknown process {other}"),
    };
    let intervals = gen.intervals(n);
    let a = analyse(&intervals, 40);
    println!("n={} mean={:.1}ms cv={:.3}", a.n, a.mean, a.cv);
    if let Some(g) = a.gamma {
        println!("gamma fit: shape={:.3} scale={:.2} loglik={:.1}",
                 g.shape, g.scale, g.loglik);
    }
    if let Some(e) = a.expo {
        println!("poisson(exp) fit: mean={:.2} loglik={:.1}", e.mean, e.loglik);
    }
    println!("winner: {}", a.winner());
    Ok(())
}

/// Sweep batch size by 10 up to 250 (paper Appendix A) until a saturated
/// pool preempts.
pub fn find_preempt_batch(profile: &ModelProfile, window: usize) -> Option<usize> {
    let budget = profile.kv_budget_bytes(profile.mem_limit_frac);
    for batch in (10..=250).step_by(10) {
        let mut engine = SimEngine::new(profile.clone(), window, batch, budget);
        // saturate: give every slot a long job (paper: 10K prompts sampled
        // from LMSYS at an effectively infinite request rate)
        for id in 0..batch as u64 {
            engine
                .admit(elis::engine::SeqSpec {
                    id,
                    prompt: vec![7; 64],
                    target_total: 400, topic: 0,
                    resume: Vec::new(),
                })
                .ok()?;
        }
        let ids: Vec<u64> = (0..batch as u64).collect();
        engine.set_priority_order(&ids);
        // run windows until everyone is resident and growing
        for _ in 0..8 {
            if engine.run_window(&ids).is_err() {
                return Some(batch);
            }
            if engine.total_preemptions > 0 {
                return Some(batch);
            }
        }
    }
    None
}

fn cmd_preempt_profile(args: &Args) -> Result<()> {
    let dir = default_artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    let profiles = ModelProfile::all(&manifest.served_models);
    let which = args.str("model", "all");
    println!("{:<12} {:>10} {:>12} {:>10}", "model", "batch", "mem-limit", "paper");
    for p in &profiles {
        if which != "all" && p.abbrev != which {
            continue;
        }
        let b = find_preempt_batch(p, manifest.window_size);
        println!("{:<12} {:>10} {:>11.0}% {:>10}",
                 p.abbrev, b.map(|x| x.to_string()).unwrap_or("-".into()),
                 p.mem_limit_frac * 100.0, p.preempt_batch_ref);
    }
    Ok(())
}

fn cmd_gen_trace(args: &Args) -> Result<()> {
    let dir = default_artifacts_dir();
    let corpus = Corpus::load(&dir)?;
    let n = args.usize("n", 200);
    let rps = args.f64("rps", 1.0);
    let seed = args.u64("seed", 42);
    let out = args.str("out", "trace.json");
    let process = match args.str("process", "gamma").as_str() {
        "gamma" => elis::workload::ArrivalProcess::Gamma,
        "poisson" => elis::workload::ArrivalProcess::Poisson,
        "uniform" => elis::workload::ArrivalProcess::Uniform,
        other => bail!("unknown process {other}"),
    };
    let mut gen = RequestGenerator::new(process, 0.73, rps, seed);
    let mut trace = gen.trace(&corpus, n);
    let spec = parse_tenant_spec(&args.list("tenants"))?;
    if !spec.is_empty() {
        elis::workload::assign_tenants(&mut trace, &spec);
    }
    elis::workload::trace_io::save(&trace, std::path::Path::new(&out))?;
    println!("wrote {n} requests ({:?}, {rps} rps) to {out}", process);
    Ok(())
}

fn cmd_k8s(args: &Args) -> Result<()> {
    let cfg = k8s::K8sConfig {
        workers: args.usize("workers", 4),
        scheduler_policy: args.str("policy", "isrtf"),
        image: args.str("image", "elis/serving:latest"),
        model: args.str("model", "lam13"),
        ..Default::default()
    };
    println!("{}", k8s::all_manifests(&cfg));
    Ok(())
}
