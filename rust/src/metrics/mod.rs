//! Serving metrics: per-job records and run-level aggregates
//! (JCT, queueing delay, TTFT, throughput — the quantities of
//! paper §6.2–6.4).

use crate::coordinator::job::Job;
use crate::stats::summary::{Percentiles, Summary};
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct JobRecord {
    pub id: u64,
    /// accounting tag threaded from `TraceRequest::tenant`
    pub tenant: Option<String>,
    pub node: usize,
    pub arrival_ms: f64,
    pub finish_ms: f64,
    pub jct_ms: f64,
    pub queue_delay_ms: f64,
    /// None if the job finished without ever emitting a token; averaged
    /// skip-missing (a 0.0 placeholder would deflate [`ServeReport::avg_ttft_s`])
    pub ttft_ms: Option<f64>,
    pub service_ms: f64,
    pub tokens: usize,
    pub windows: usize,
    pub preemptions: usize,
}

impl JobRecord {
    pub fn from_job(j: &Job) -> Option<JobRecord> {
        Some(JobRecord {
            id: j.id.raw(),
            tenant: j.tenant.clone(),
            node: j.node?,
            arrival_ms: j.arrival_ms,
            finish_ms: j.finish_ms?,
            jct_ms: j.jct_ms()?,
            queue_delay_ms: j.queue_delay_ms()?,
            ttft_ms: j.ttft_ms(),
            service_ms: j.service_ms,
            tokens: j.generated,
            windows: j.windows,
            preemptions: j.preemptions,
        })
    }
}

/// Aggregated result of one serving run (one bar of Fig 5, one cell of
/// Table 5, one point of Fig 7).
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub scheduler: String,
    pub records: Vec<JobRecord>,
    pub makespan_ms: f64,
    pub total_preemptions: u64,
    /// measured scheduling overhead per iteration (priority refresh +
    /// batching + predictor), wall time
    pub sched_overhead_ms_avg: f64,
    pub sched_iterations: u64,
    pub predictor_name: String,
}

impl ServeReport {
    pub fn n(&self) -> usize {
        self.records.len()
    }

    pub fn avg_jct_s(&self) -> f64 {
        self.mean(|r| r.jct_ms) / 1000.0
    }

    pub fn min_jct_s(&self) -> f64 {
        self.records.iter().map(|r| r.jct_ms).fold(f64::INFINITY, f64::min) / 1000.0
    }

    pub fn max_jct_s(&self) -> f64 {
        self.records.iter().map(|r| r.jct_ms).fold(0.0, f64::max) / 1000.0
    }

    pub fn avg_queue_delay_s(&self) -> f64 {
        self.mean(|r| r.queue_delay_ms) / 1000.0
    }

    /// Mean TTFT over the jobs that produced a first token (skip-missing,
    /// like [`avg_tpot_s`](Self::avg_tpot_s) — a 0.0 placeholder for the
    /// rare tokenless finish would deflate the average).
    pub fn avg_ttft_s(&self) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for r in &self.records {
            if let Some(ttft) = r.ttft_ms {
                sum += ttft;
                n += 1;
            }
        }
        if n == 0 { 0.0 } else { sum / n as f64 / 1000.0 }
    }

    /// Average time per output token across jobs (s/token).
    pub fn avg_tpot_s(&self) -> f64 {
        let mut s = 0.0;
        let mut n = 0usize;
        for r in &self.records {
            if r.tokens > 1 {
                if let Some(ttft) = r.ttft_ms {
                    s += (r.jct_ms - ttft) / 1000.0 / (r.tokens - 1) as f64;
                    n += 1;
                }
            }
        }
        if n == 0 { 0.0 } else { s / n as f64 }
    }

    pub fn throughput_rps(&self) -> f64 {
        if self.makespan_ms <= 0.0 {
            return 0.0;
        }
        self.n() as f64 / (self.makespan_ms / 1000.0)
    }

    pub fn tokens_per_s(&self) -> f64 {
        if self.makespan_ms <= 0.0 {
            return 0.0;
        }
        self.records.iter().map(|r| r.tokens as f64).sum::<f64>()
            / (self.makespan_ms / 1000.0)
    }

    pub fn p99_jct_s(&self) -> f64 {
        let mut p = Percentiles::new();
        for r in &self.records {
            p.add(r.jct_ms);
        }
        p.p99() / 1000.0
    }

    pub fn jct_summary(&self) -> Summary {
        let mut s = Summary::new();
        for r in &self.records {
            s.add(r.jct_ms / 1000.0);
        }
        s
    }

    /// Machine-readable dump for EXPERIMENTS.md / external plotting.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scheduler", Json::Str(self.scheduler.clone())),
            ("predictor", Json::Str(self.predictor_name.clone())),
            ("n", Json::Num(self.n() as f64)),
            ("avg_jct_s", Json::Num(self.avg_jct_s())),
            ("min_jct_s", Json::Num(self.min_jct_s())),
            ("max_jct_s", Json::Num(self.max_jct_s())),
            ("p99_jct_s", Json::Num(self.p99_jct_s())),
            ("avg_queue_delay_s", Json::Num(self.avg_queue_delay_s())),
            ("avg_ttft_s", Json::Num(self.avg_ttft_s())),
            ("throughput_rps", Json::Num(self.throughput_rps())),
            ("tokens_per_s", Json::Num(self.tokens_per_s())),
            ("total_preemptions", Json::Num(self.total_preemptions as f64)),
            ("sched_overhead_ms_avg", Json::Num(self.sched_overhead_ms_avg)),
            ("sched_iterations", Json::Num(self.sched_iterations as f64)),
            ("makespan_ms", Json::Num(self.makespan_ms)),
        ])
    }

    fn mean<F: Fn(&JobRecord) -> f64>(&self, f: F) -> f64 {
        if self.records.is_empty() {
            return f64::NAN;
        }
        self.records.iter().map(|r| f(r)).sum::<f64>() / self.records.len() as f64
    }

    pub fn print_summary(&self) {
        println!(
            "[{}/{}] n={} avg_jct={:.2}s (min {:.2} max {:.2} p99 {:.2}) \
             queue={:.2}s ttft={:.2}s thpt={:.2}rps preempt={} sched={:.2}ms/iter",
            self.scheduler,
            self.predictor_name,
            self.n(),
            self.avg_jct_s(),
            self.min_jct_s(),
            self.max_jct_s(),
            self.p99_jct_s(),
            self.avg_queue_delay_s(),
            self.avg_ttft_s(),
            self.throughput_rps(),
            self.total_preemptions,
            self.sched_overhead_ms_avg,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(id: u64, jct_ms: f64, qd_ms: f64, tokens: usize) -> JobRecord {
        JobRecord {
            id,
            tenant: None,
            node: 0,
            arrival_ms: 0.0,
            finish_ms: jct_ms,
            jct_ms,
            queue_delay_ms: qd_ms,
            ttft_ms: Some(100.0),
            service_ms: jct_ms - qd_ms,
            tokens,
            windows: 1,
            preemptions: 0,
        }
    }

    fn report(records: Vec<JobRecord>) -> ServeReport {
        ServeReport {
            scheduler: "TEST".into(),
            makespan_ms: 10_000.0,
            total_preemptions: 0,
            sched_overhead_ms_avg: 0.0,
            sched_iterations: 1,
            predictor_name: "none".into(),
            records,
        }
    }

    #[test]
    fn aggregates() {
        let r = report(vec![record(1, 2000.0, 500.0, 100),
                            record(2, 4000.0, 1500.0, 200)]);
        assert!((r.avg_jct_s() - 3.0).abs() < 1e-9);
        assert!((r.min_jct_s() - 2.0).abs() < 1e-9);
        assert!((r.max_jct_s() - 4.0).abs() < 1e-9);
        assert!((r.avg_queue_delay_s() - 1.0).abs() < 1e-9);
        assert!((r.throughput_rps() - 0.2).abs() < 1e-9);
        assert!((r.tokens_per_s() - 30.0).abs() < 1e-9);
    }

    #[test]
    fn missing_ttft_does_not_deflate_average() {
        // regression: a tokenless finish used to be recorded as ttft 0.0,
        // dragging the mean down; skip-missing keeps it honest
        let a = record(1, 2000.0, 0.0, 10); // ttft 100 ms
        let mut b = record(2, 4000.0, 0.0, 10);
        b.ttft_ms = None;
        let r = report(vec![a, b]);
        assert!((r.avg_ttft_s() - 0.1).abs() < 1e-9,
                "avg must ignore the missing sample: {}", r.avg_ttft_s());
        // and tpot likewise skips the record with no first token
        let with_all = report(vec![record(1, 2000.0, 0.0, 11)]);
        assert!(with_all.avg_tpot_s() > 0.0);
    }

    #[test]
    fn tenant_threads_through_records() {
        use crate::coordinator::job::JobId;
        let mut j = Job::new(JobId::new(3), vec![1], 10, 0, 0.0);
        j.node = Some(0);
        j.finish_ms = Some(50.0);
        j.tenant = Some("paid".into());
        let rec = JobRecord::from_job(&j).unwrap();
        assert_eq!(rec.tenant.as_deref(), Some("paid"));
        assert_eq!(rec.ttft_ms, None, "no first token -> no TTFT");
    }

    #[test]
    fn from_job_requires_finish() {
        use crate::coordinator::job::JobId;
        let j = Job::new(JobId::new(1), vec![1], 10, 0, 0.0);
        assert!(JobRecord::from_job(&j).is_none());
        let mut j2 = Job::new(JobId::new(2), vec![1], 10, 0, 0.0);
        j2.node = Some(0);
        j2.finish_ms = Some(50.0);
        assert!(JobRecord::from_job(&j2).is_some());
    }
}
