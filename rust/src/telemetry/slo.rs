//! SLO-aware admission/priority policy driven by live telemetry.
//!
//! [`SloPolicy`] implements
//! [`PriorityShaper`](crate::coordinator::scheduler::PriorityShaper): the
//! coordinator calls it for every queued job each scheduling iteration,
//! after the base scheduler assigned its priority and before the job
//! enters the node's priority queue.  The policy orders work
//! earliest-deadline-first against each tenant's SLO budget, with two
//! telemetry-driven refinements:
//!
//! * **boost** — a tenant whose *observed* p99 JCT (streaming P² sketch
//!   from the shared [`TelemetrySink`]) exceeds its budget has its jobs'
//!   slack scaled by the overload ratio, so persistently-late tenants win
//!   against on-track ones even at equal nominal slack;
//! * **shed** — a job older than `shed_after × slo` has already missed by
//!   so much that serving it first only converts other jobs into misses
//!   too; it is parked behind all in-budget work (still finite priority,
//!   so it drains once the queue clears — no job is ever dropped).
//!
//! Tenants whose budget is 0/∞ are exempt: their jobs keep the base
//! scheduler priority, offset behind all deadline-carrying work.
//!
//! Cost note: with the default configuration (`shed_after = ∞`) the policy
//! **folds** ([`FoldedShaper`]): slack EDF keys all drift with `now_ms` at
//! the same rate within a tenant, so dispatch orders by the time-invariant
//! key `(arrival + slo) / pressure` instead and keeps the incremental
//! O(k log n) index — re-keying only the lanes of tenants whose live
//! pressure actually moved (tracked by per-tenant epochs bumped in
//! [`begin_round`](PriorityShaper::begin_round)).  Enabling `shed_after`
//! introduces an age threshold that is not affine in `now`, which drops
//! the policy back to the per-window rebuild path.

use std::collections::BTreeMap;

use crate::coordinator::job::Job;
use crate::coordinator::scheduler::{FoldedShaper, PriorityShaper};

use super::sink::{SloSpec, TelemetrySink, DEFAULT_TENANT};

/// Priority band for shed (hopelessly late) jobs.  Far above any slack
/// value yet finite, so shed work still drains when the system is idle.
const SHED_BAND: f64 = 1e15;
/// Priority band for jobs of SLO-exempt tenants: behind every
/// deadline-carrying job, ahead of shed work.
const EXEMPT_BAND: f64 = 1e12;

pub struct SloPolicy {
    telemetry: TelemetrySink,
    slo: SloSpec,
    /// scale slack by live p99/slo overload (set false for pure EDF)
    pub live_boost: bool,
    /// shed jobs older than this multiple of their SLO (∞ disables)
    pub shed_after: f64,
    /// sketch samples required before live feedback engages
    pub min_samples: u64,
    /// legacy per-`now_ms` memo for direct `shape` calls made outside a
    /// coordinator dispatch round (unit tests, ad-hoc use)
    pressure_memo: (f64, BTreeMap<String, f64>),
    /// round-keyed pressure snapshot: rebuilt once per dispatch round in
    /// `begin_round` (one telemetry lock for *all* tenants), so wall-clock
    /// pooled runs — where `now` is shared but many nodes dispatch in one
    /// round — read tenant pressure exactly once per round
    round_memo: BTreeMap<String, f64>,
    /// round the snapshot belongs to; `None` until `begin_round` first runs
    round: Option<u64>,
    /// per-tenant change counters: bumped when a tenant's snapshot pressure
    /// bits moved (the folded index re-keys exactly those lanes)
    epochs: BTreeMap<String, u64>,
}

impl SloPolicy {
    /// `telemetry` must be (a clone of) the sink registered on the same
    /// coordinator, so the policy sees the run's own live sketches.
    pub fn new(telemetry: &TelemetrySink, slo: SloSpec) -> SloPolicy {
        SloPolicy {
            telemetry: telemetry.clone(),
            slo,
            live_boost: true,
            shed_after: f64::INFINITY,
            min_samples: 5,
            pressure_memo: (f64::NEG_INFINITY, BTreeMap::new()),
            round_memo: BTreeMap::new(),
            round: None,
            epochs: BTreeMap::new(),
        }
    }

    /// Builder-style: shed jobs older than `mult × slo`.
    pub fn shed_after(mut self, mult: f64) -> SloPolicy {
        self.shed_after = mult;
        self
    }

    /// Builder-style: disable the live-sketch boost (pure EDF).
    pub fn without_live_boost(mut self) -> SloPolicy {
        self.live_boost = false;
        self
    }

    /// Overload ratio for a tenant: observed p99 JCT over budget, floored
    /// at 1 (on-track tenants get no boost).  Inside a dispatch round this
    /// reads the `begin_round` snapshot; direct calls outside any round
    /// fall back to the legacy per-`now_ms` memo.
    fn pressure(&mut self, tenant: &str, slo_ms: f64, now_ms: f64) -> f64 {
        if !self.live_boost {
            return 1.0;
        }
        if self.round.is_some() {
            return self.round_memo.get(tenant).copied().unwrap_or(1.0);
        }
        if self.pressure_memo.0 != now_ms {
            self.pressure_memo.0 = now_ms;
            self.pressure_memo.1.clear();
        }
        if let Some(&p) = self.pressure_memo.1.get(tenant) {
            return p;
        }
        let p = match self.telemetry.tenant_p99_jct_ms(tenant,
                                                       self.min_samples) {
            Some(p99) => (p99 / slo_ms).max(1.0),
            None => 1.0,
        };
        self.pressure_memo.1.insert(tenant.to_string(), p);
        p
    }
}

impl PriorityShaper for SloPolicy {
    fn shape(&mut self, job: &Job, base_priority: f64, now_ms: f64) -> f64 {
        let tenant = job.tenant.as_deref().unwrap_or(DEFAULT_TENANT);
        let slo_ms = self.slo.slo_for(tenant);
        if !(slo_ms > 0.0) || !slo_ms.is_finite() {
            // no deadline for this tenant: keep the scheduler's order,
            // parked behind every deadline-carrying job
            return EXEMPT_BAND + base_priority.clamp(-1e11, 1e11);
        }
        let age = now_ms - job.arrival_ms;
        if age > self.shed_after * slo_ms {
            // hopeless: drain FIFO once in-budget work is clear
            return SHED_BAND + job.arrival_ms;
        }
        let slack = (job.arrival_ms + slo_ms) - now_ms;
        let pressure = self.pressure(tenant, slo_ms, now_ms);
        // smaller runs first; overloaded tenants shrink positive slack
        // (run sooner) and amplify lateness (run sooner still)
        if slack >= 0.0 {
            slack / pressure
        } else {
            slack * pressure
        }
    }

    fn begin_round(&mut self, round: u64, _now_ms: f64) {
        if self.round == Some(round) {
            return;
        }
        self.round = Some(round);
        if !self.live_boost {
            return;
        }
        // one lock for every tenant's sketch, then bit-compare against the
        // previous round's snapshot to bump only the epochs that moved
        let min = self.min_samples;
        let snap: Vec<(String, f64)> = self.telemetry.with_state(|st| {
            st.tenants
                .iter()
                .filter(|(_, t)| t.jct_ms.count() >= min)
                .map(|(name, t)| (name.clone(), t.jct_ms.p99()))
                .collect()
        });
        let mut fresh = BTreeMap::new();
        for (name, p99) in snap {
            let slo_ms = self.slo.slo_for(&name);
            if !(slo_ms > 0.0) || !slo_ms.is_finite() {
                continue; // exempt tenant: pressure is never consulted
            }
            fresh.insert(name, (p99 / slo_ms).max(1.0));
        }
        for (name, p) in &fresh {
            let prev = self.round_memo.get(name).copied().unwrap_or(1.0);
            if p.to_bits() != prev.to_bits() {
                *self.epochs.entry(name.clone()).or_insert(0) += 1;
            }
        }
        // a tenant dropping out of the snapshot falls back to pressure 1.0
        for (name, prev) in &self.round_memo {
            if !fresh.contains_key(name) && prev.to_bits() != 1.0f64.to_bits()
            {
                *self.epochs.entry(name.clone()).or_insert(0) += 1;
            }
        }
        self.round_memo = fresh;
    }

    fn as_folded(&self) -> Option<&dyn FoldedShaper> {
        // the shed threshold is an age cutoff — not affine in `now` — so a
        // shedding policy keeps the rebuild path
        if self.shed_after.is_infinite() {
            Some(self)
        } else {
            None
        }
    }
}

impl FoldedShaper for SloPolicy {
    /// Time-invariant shaped key: pressure-scaled static EDF.  Within a
    /// round, live slack EDF subtracts the same `now` from every deadline,
    /// so ordering by `(arrival + slo) / pressure` is the same
    /// earliest-deadline-first policy expressed without the drift (both
    /// dispatch paths key with this when the policy folds).
    fn shape_folded(&self, job: &Job, base_folded: f64) -> f64 {
        let tenant = job.tenant.as_deref().unwrap_or(DEFAULT_TENANT);
        let slo_ms = self.slo.slo_for(tenant);
        if !(slo_ms > 0.0) || !slo_ms.is_finite() {
            return EXEMPT_BAND + base_folded.clamp(-1e11, 1e11);
        }
        let pressure = if self.live_boost {
            self.round_memo.get(tenant).copied().unwrap_or(1.0)
        } else {
            1.0
        };
        (job.arrival_ms + slo_ms) / pressure
    }

    fn tenant_epoch(&self, tenant: Option<&str>) -> u64 {
        let tenant = tenant.unwrap_or(DEFAULT_TENANT);
        self.epochs.get(tenant).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::events::{EventSink, FinishStats, JobMeta};
    use crate::coordinator::job::{Job, JobId};

    fn job(id: usize, tenant: Option<&str>, arrival_ms: f64) -> Job {
        let mut j = Job::new(JobId::new(id), vec![1, 2, 3], 50, 0, arrival_ms);
        j.tenant = tenant.map(str::to_string);
        j
    }

    fn policy(spec: SloSpec) -> (TelemetrySink, SloPolicy) {
        let sink = TelemetrySink::with_slo(1, spec.clone());
        let p = SloPolicy::new(&sink, spec);
        (sink, p)
    }

    #[test]
    fn tight_budget_outranks_loose_budget() {
        let spec = SloSpec::new(60_000.0).tenant("paid", 5_000.0);
        let (_sink, mut p) = policy(spec);
        let paid = job(0, Some("paid"), 0.0);
        let free = job(1, Some("free"), 0.0);
        let now = 1_000.0;
        assert!(p.shape(&paid, 0.0, now) < p.shape(&free, 0.0, now),
                "tighter deadline must run first");
    }

    #[test]
    fn older_job_outranks_newer_same_tenant() {
        let spec = SloSpec::new(10_000.0);
        let (_sink, mut p) = policy(spec);
        let old = job(0, None, 0.0);
        let new = job(1, None, 4_000.0);
        assert!(p.shape(&old, 0.0, 5_000.0) < p.shape(&new, 0.0, 5_000.0));
    }

    #[test]
    fn live_pressure_boosts_late_tenant() {
        let spec = SloSpec::new(10_000.0).tenant("late", 1_000.0)
            .tenant("ontrack", 1_000.0);
        let (sink, mut p) = policy(spec);
        // feed the sketches: "late" finishes at 4x its budget, "ontrack"
        // well inside it
        let mut h = sink.clone();
        for i in 0..6 {
            for (tenant, jct) in [("late", 4_000.0), ("ontrack", 200.0)] {
                let m = JobMeta {
                    id: JobId::new(i),
                    tenant: Some(tenant),
                    arrival_ms: 0.0,
                    prompt_len: 3,
                    total_len: 50,
                };
                h.on_job_finished(&m, 0, &FinishStats {
                    jct_ms: jct,
                    ttft_ms: Some(50.0),
                    queue_delay_ms: 10.0,
                    service_ms: jct,
                    tokens: 50,
                    predicted_total: None,
                }, jct);
            }
        }
        // equal nominal slack: both arrived now, same 1s budget
        let late = job(0, Some("late"), 0.0);
        let ontrack = job(1, Some("ontrack"), 0.0);
        let (a, b) = (p.shape(&late, 0.0, 0.0), p.shape(&ontrack, 0.0, 0.0));
        assert!(a < b, "overloaded tenant must be boosted: {a} vs {b}");
        // pure EDF sees them as equal
        let mut pure = SloPolicy::new(&sink,
            SloSpec::new(10_000.0).tenant("late", 1_000.0)
                .tenant("ontrack", 1_000.0)).without_live_boost();
        let (a, b) = (pure.shape(&late, 0.0, 0.0),
                      pure.shape(&ontrack, 0.0, 0.0));
        assert_eq!(a, b);
    }

    #[test]
    fn shed_parks_hopeless_jobs_behind_everything() {
        let spec = SloSpec::new(1_000.0);
        let (_sink, mut p) = policy(spec);
        p = p.shed_after(3.0);
        let hopeless = job(0, None, 0.0);
        let fresh = job(1, None, 3_400.0);
        let now = 3_500.0; // hopeless is 3.5 budgets old
        let (h, f) = (p.shape(&hopeless, 0.0, now), p.shape(&fresh, 0.0, now));
        assert!(h > f, "shed job must not outrank in-budget work");
        assert!(h >= SHED_BAND);
        assert!(h.is_finite(), "shed priority must stay orderable");
        // just-late (but not hopeless) jobs are NOT shed: lateness boosts
        let late = job(2, None, now - 1_500.0); // 1.5 budgets old
        assert!(p.shape(&late, 0.0, now) < f,
                "late-but-recoverable work still outranks fresh work");
    }

    #[test]
    fn exempt_tenant_keeps_base_order_behind_deadlines() {
        let spec = SloSpec::new(0.0).tenant("slo", 5_000.0);
        let (_sink, mut p) = policy(spec);
        let exempt_a = job(0, None, 0.0);
        let exempt_b = job(1, None, 100.0);
        let deadline = job(2, Some("slo"), 0.0);
        let now = 200.0;
        let (a, b) = (p.shape(&exempt_a, 1.0, now),
                      p.shape(&exempt_b, 2.0, now));
        assert!(a < b, "base priority still orders exempt jobs");
        assert!(p.shape(&deadline, 9.0, now) < a,
                "deadline work outranks exempt work");
    }

    #[test]
    fn folds_only_without_shed_and_orders_like_live_edf() {
        let spec = SloSpec::new(60_000.0).tenant("paid", 5_000.0);
        let (_sink, mut p) = policy(spec.clone());
        assert!(p.as_folded().is_some(), "default policy must fold");
        let (_sink2, shed) = policy(spec);
        let shed = shed.shed_after(3.0);
        assert!(shed.as_folded().is_none(), "shed threshold is not affine in now");

        p.begin_round(1, 0.0);
        let paid = job(0, Some("paid"), 100.0);
        let free = job(1, Some("free"), 0.0);
        let folded = p.as_folded().unwrap();
        let (fp, ff) = (folded.shape_folded(&paid, 0.0),
                        folded.shape_folded(&free, 0.0));
        assert!(fp < ff, "tighter deadline wins under folded keys too");
        // same relative order as the live slack keys at any now
        let (lp, lf) = (p.shape(&paid, 0.0, 2_000.0),
                        p.shape(&free, 0.0, 2_000.0));
        assert!(lp < lf);
    }

    #[test]
    fn epochs_move_only_when_pressure_moves() {
        let spec = SloSpec::new(10_000.0).tenant("late", 1_000.0);
        let (sink, mut p) = policy(spec);
        p.begin_round(1, 0.0);
        assert_eq!(p.tenant_epoch(Some("late")), 0);

        // rounds without telemetry movement keep every epoch still
        p.begin_round(2, 10.0);
        assert_eq!(p.tenant_epoch(Some("late")), 0);

        // feed enough finishes to engage pressure for "late"
        let mut h = sink.clone();
        for i in 0..6 {
            let m = JobMeta {
                id: JobId::new(i),
                tenant: Some("late"),
                arrival_ms: 0.0,
                prompt_len: 3,
                total_len: 50,
            };
            h.on_job_finished(&m, 0, &FinishStats {
                jct_ms: 4_000.0,
                ttft_ms: Some(50.0),
                queue_delay_ms: 10.0,
                service_ms: 4_000.0,
                tokens: 50,
                predicted_total: None,
            }, 4_000.0);
        }
        p.begin_round(3, 20.0);
        assert_eq!(p.tenant_epoch(Some("late")), 1, "pressure moved");
        assert_eq!(p.tenant_epoch(Some("other")), 0, "unrelated tenant still");
        p.begin_round(4, 30.0);
        assert_eq!(p.tenant_epoch(Some("late")), 1, "no further movement");
    }
}
