//! SLO-aware admission/priority policy driven by live telemetry.
//!
//! [`SloPolicy`] implements
//! [`PriorityShaper`](crate::coordinator::scheduler::PriorityShaper): the
//! coordinator calls it for every queued job each scheduling iteration,
//! after the base scheduler assigned its priority and before the job
//! enters the node's priority queue.  The policy orders work
//! earliest-deadline-first against each tenant's SLO budget, with two
//! telemetry-driven refinements:
//!
//! * **boost** — a tenant whose *observed* p99 JCT (streaming P² sketch
//!   from the shared [`TelemetrySink`]) exceeds its budget has its jobs'
//!   slack scaled by the overload ratio, so persistently-late tenants win
//!   against on-track ones even at equal nominal slack;
//! * **shed** — a job older than `shed_after × slo` has already missed by
//!   so much that serving it first only converts other jobs into misses
//!   too; it is parked behind all in-budget work (still finite priority,
//!   so it drains once the queue clears — no job is ever dropped).
//!
//! Tenants whose budget is 0/∞ are exempt: their jobs keep the base
//! scheduler priority, offset behind all deadline-carrying work.
//!
//! Cost note: slack drifts with `now_ms`, so registering any shaper makes
//! the coordinator re-shape **every queued job each scheduling iteration**
//! (the per-window rebuild path) instead of the incremental O(k log n)
//! index it uses shaper-less.  Keep `shape` cheap — per-round state like
//! the pressure memo below is the pattern.

use std::collections::BTreeMap;

use crate::coordinator::job::Job;
use crate::coordinator::scheduler::PriorityShaper;

use super::sink::{SloSpec, TelemetrySink, DEFAULT_TENANT};

/// Priority band for shed (hopelessly late) jobs.  Far above any slack
/// value yet finite, so shed work still drains when the system is idle.
const SHED_BAND: f64 = 1e15;
/// Priority band for jobs of SLO-exempt tenants: behind every
/// deadline-carrying job, ahead of shed work.
const EXEMPT_BAND: f64 = 1e12;

pub struct SloPolicy {
    telemetry: TelemetrySink,
    slo: SloSpec,
    /// scale slack by live p99/slo overload (set false for pure EDF)
    pub live_boost: bool,
    /// shed jobs older than this multiple of their SLO (∞ disables)
    pub shed_after: f64,
    /// sketch samples required before live feedback engages
    pub min_samples: u64,
    /// per-dispatch-round memo: pressure is identical for every job of a
    /// tenant at one `now_ms`, so compute it once per tenant per round
    /// instead of once per queued job (dispatch is the hot loop)
    pressure_memo: (f64, BTreeMap<String, f64>),
}

impl SloPolicy {
    /// `telemetry` must be (a clone of) the sink registered on the same
    /// coordinator, so the policy sees the run's own live sketches.
    pub fn new(telemetry: &TelemetrySink, slo: SloSpec) -> SloPolicy {
        SloPolicy {
            telemetry: telemetry.clone(),
            slo,
            live_boost: true,
            shed_after: f64::INFINITY,
            min_samples: 5,
            pressure_memo: (f64::NEG_INFINITY, BTreeMap::new()),
        }
    }

    /// Builder-style: shed jobs older than `mult × slo`.
    pub fn shed_after(mut self, mult: f64) -> SloPolicy {
        self.shed_after = mult;
        self
    }

    /// Builder-style: disable the live-sketch boost (pure EDF).
    pub fn without_live_boost(mut self) -> SloPolicy {
        self.live_boost = false;
        self
    }

    /// Overload ratio for a tenant: observed p99 JCT over budget, floored
    /// at 1 (on-track tenants get no boost).  Memoised per (now_ms,
    /// tenant) — one sketch read per tenant per dispatch round.
    fn pressure(&mut self, tenant: &str, slo_ms: f64, now_ms: f64) -> f64 {
        if !self.live_boost {
            return 1.0;
        }
        if self.pressure_memo.0 != now_ms {
            self.pressure_memo.0 = now_ms;
            self.pressure_memo.1.clear();
        }
        if let Some(&p) = self.pressure_memo.1.get(tenant) {
            return p;
        }
        let p = match self.telemetry.tenant_p99_jct_ms(tenant,
                                                       self.min_samples) {
            Some(p99) => (p99 / slo_ms).max(1.0),
            None => 1.0,
        };
        self.pressure_memo.1.insert(tenant.to_string(), p);
        p
    }
}

impl PriorityShaper for SloPolicy {
    fn shape(&mut self, job: &Job, base_priority: f64, now_ms: f64) -> f64 {
        let tenant = job.tenant.as_deref().unwrap_or(DEFAULT_TENANT);
        let slo_ms = self.slo.slo_for(tenant);
        if !(slo_ms > 0.0) || !slo_ms.is_finite() {
            // no deadline for this tenant: keep the scheduler's order,
            // parked behind every deadline-carrying job
            return EXEMPT_BAND + base_priority.clamp(-1e11, 1e11);
        }
        let age = now_ms - job.arrival_ms;
        if age > self.shed_after * slo_ms {
            // hopeless: drain FIFO once in-budget work is clear
            return SHED_BAND + job.arrival_ms;
        }
        let slack = (job.arrival_ms + slo_ms) - now_ms;
        let pressure = self.pressure(tenant, slo_ms, now_ms);
        // smaller runs first; overloaded tenants shrink positive slack
        // (run sooner) and amplify lateness (run sooner still)
        if slack >= 0.0 {
            slack / pressure
        } else {
            slack * pressure
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::events::{EventSink, FinishStats, JobMeta};
    use crate::coordinator::job::{Job, JobId};

    fn job(id: usize, tenant: Option<&str>, arrival_ms: f64) -> Job {
        let mut j = Job::new(JobId::new(id), vec![1, 2, 3], 50, 0, arrival_ms);
        j.tenant = tenant.map(str::to_string);
        j
    }

    fn policy(spec: SloSpec) -> (TelemetrySink, SloPolicy) {
        let sink = TelemetrySink::with_slo(1, spec.clone());
        let p = SloPolicy::new(&sink, spec);
        (sink, p)
    }

    #[test]
    fn tight_budget_outranks_loose_budget() {
        let spec = SloSpec::new(60_000.0).tenant("paid", 5_000.0);
        let (_sink, mut p) = policy(spec);
        let paid = job(0, Some("paid"), 0.0);
        let free = job(1, Some("free"), 0.0);
        let now = 1_000.0;
        assert!(p.shape(&paid, 0.0, now) < p.shape(&free, 0.0, now),
                "tighter deadline must run first");
    }

    #[test]
    fn older_job_outranks_newer_same_tenant() {
        let spec = SloSpec::new(10_000.0);
        let (_sink, mut p) = policy(spec);
        let old = job(0, None, 0.0);
        let new = job(1, None, 4_000.0);
        assert!(p.shape(&old, 0.0, 5_000.0) < p.shape(&new, 0.0, 5_000.0));
    }

    #[test]
    fn live_pressure_boosts_late_tenant() {
        let spec = SloSpec::new(10_000.0).tenant("late", 1_000.0)
            .tenant("ontrack", 1_000.0);
        let (sink, mut p) = policy(spec);
        // feed the sketches: "late" finishes at 4x its budget, "ontrack"
        // well inside it
        let mut h = sink.clone();
        for i in 0..6 {
            for (tenant, jct) in [("late", 4_000.0), ("ontrack", 200.0)] {
                let m = JobMeta {
                    id: JobId::new(i),
                    tenant: Some(tenant),
                    arrival_ms: 0.0,
                    prompt_len: 3,
                    total_len: 50,
                };
                h.on_job_finished(&m, 0, &FinishStats {
                    jct_ms: jct,
                    ttft_ms: Some(50.0),
                    queue_delay_ms: 10.0,
                    service_ms: jct,
                    tokens: 50,
                    predicted_total: None,
                }, jct);
            }
        }
        // equal nominal slack: both arrived now, same 1s budget
        let late = job(0, Some("late"), 0.0);
        let ontrack = job(1, Some("ontrack"), 0.0);
        let (a, b) = (p.shape(&late, 0.0, 0.0), p.shape(&ontrack, 0.0, 0.0));
        assert!(a < b, "overloaded tenant must be boosted: {a} vs {b}");
        // pure EDF sees them as equal
        let mut pure = SloPolicy::new(&sink,
            SloSpec::new(10_000.0).tenant("late", 1_000.0)
                .tenant("ontrack", 1_000.0)).without_live_boost();
        let (a, b) = (pure.shape(&late, 0.0, 0.0),
                      pure.shape(&ontrack, 0.0, 0.0));
        assert_eq!(a, b);
    }

    #[test]
    fn shed_parks_hopeless_jobs_behind_everything() {
        let spec = SloSpec::new(1_000.0);
        let (_sink, mut p) = policy(spec);
        p = p.shed_after(3.0);
        let hopeless = job(0, None, 0.0);
        let fresh = job(1, None, 3_400.0);
        let now = 3_500.0; // hopeless is 3.5 budgets old
        let (h, f) = (p.shape(&hopeless, 0.0, now), p.shape(&fresh, 0.0, now));
        assert!(h > f, "shed job must not outrank in-budget work");
        assert!(h >= SHED_BAND);
        assert!(h.is_finite(), "shed priority must stay orderable");
        // just-late (but not hopeless) jobs are NOT shed: lateness boosts
        let late = job(2, None, now - 1_500.0); // 1.5 budgets old
        assert!(p.shape(&late, 0.0, now) < f,
                "late-but-recoverable work still outranks fresh work");
    }

    #[test]
    fn exempt_tenant_keeps_base_order_behind_deadlines() {
        let spec = SloSpec::new(0.0).tenant("slo", 5_000.0);
        let (_sink, mut p) = policy(spec);
        let exempt_a = job(0, None, 0.0);
        let exempt_b = job(1, None, 100.0);
        let deadline = job(2, Some("slo"), 0.0);
        let now = 200.0;
        let (a, b) = (p.shape(&exempt_a, 1.0, now),
                      p.shape(&exempt_b, 2.0, now));
        assert!(a < b, "base priority still orders exempt jobs");
        assert!(p.shape(&deadline, 9.0, now) < a,
                "deadline work outranks exempt work");
    }
}
